"""Resilience subsystem: fault injection, training health, recovery.

GUM's unbiasedness and convergence guarantees only hold for the steps that
are actually *applied* — at pretraining scale, loss spikes, subspace
collapse after a bad projector refresh, preemptions and corrupted
checkpoints are routine.  This package makes the training loop survive them
deterministically, in three parts wired through :class:`repro.train.Trainer`:

:mod:`repro.resilience.inject`
    a declarative, seeded :class:`FaultPlan` that gives every recovery path
    a reproducible trigger — gradient corruption (NaN / Inf / spike) through
    a traced :class:`FaultGate`, projector-refresh sabotage, checkpoint
    truncation / bit-flips, and a mid-save process kill.

:mod:`repro.resilience.health`
    cheap in-jit signals (loss, raw/clipped gradient norm, update norm,
    per-family captured energy from the ``probe_spectrum`` probes) feeding
    host-side windowed detectors — z-score loss spike, monotone blowup,
    dead-subspace collapse, non-finite skip — unified with the straggler
    :class:`~repro.train.StepTimeMonitor` into one :class:`HealthReport`.

:mod:`repro.resilience.recovery`
    a declarative escalation ladder — skip step → force an off-cycle
    projector refresh → roll back to an in-memory ring of last-K snapshots
    → restore the last *verified* durable checkpoint — driven by
    :class:`RecoveryController`, every event counted in ``TrainResult``.

The checkpoint layer (:mod:`repro.checkpoint`) backs the last rung: atomic
tmp+rename saves, per-leaf CRC32 checksums in the manifest, verify-on-
restore, and automatic fallback to the previous verified step.
"""
from .health import HealthEvent, HealthMonitor, HealthReport
from .inject import (
    FaultEvent,
    FaultGate,
    FaultPlan,
    bitflip_checkpoint,
    poison_projectors,
    truncate_checkpoint,
)
from .recovery import (
    RecoveryController,
    ResilienceConfig,
    SnapshotRing,
    force_refresh,
)

__all__ = [
    "FaultEvent",
    "FaultGate",
    "FaultPlan",
    "HealthEvent",
    "HealthMonitor",
    "HealthReport",
    "RecoveryController",
    "ResilienceConfig",
    "SnapshotRing",
    "bitflip_checkpoint",
    "force_refresh",
    "poison_projectors",
    "truncate_checkpoint",
]
