"""Training health monitor: cheap in-jit signals, host-side detectors.

The jitted train step already computes a loss and a gradient norm; with
``make_train_step(extra_metrics=True)`` it additionally reports the raw
(pre-clip) gradient norm and the applied update norm — four scalars per
step, fetched together with the loss the trainer already synchronizes on,
so the steady-state overhead is one extra global-norm reduction in-jit and
three extra scalar device→host copies (measured in
``benchmarks/resilience.py``; acceptance budget ≤ 2% step time).

Host-side, :class:`HealthMonitor` runs windowed detectors over those
signals and folds in the two pre-existing guards — the in-jit NaN/Inf skip
(``update_applied``) and the straggler :class:`~repro.train.StepTimeMonitor`
— emitting one :class:`HealthReport` per step:

=================  ========================================  =============
detector           fires when                                default action
=================  ========================================  =============
``nonfinite``      the in-jit guard skipped the update       skip (rung 0);
                                                             rollback after
                                                             ``max_skips``
``loss_spike``     loss > mean + z·std of the window         rollback
``grad_spike``     raw (pre-clip) grad norm > mean + z·std   rollback
                   of its window AND > 10× its mean
``blowup``         ``blowup_k`` consecutive loss increases   rollback
                   totalling > ``blowup_factor``×
``dead_subspace``  update norm < ``collapse_tol`` × its      force refresh
                   trailing median, grad norm healthy
``subspace_energy``probe captured-energy fraction < floor    (warn only)
``straggler``      step wall time > mean + z·std             (warn only)
=================  ========================================  =============

Detector state is deliberately *resettable* (:meth:`HealthMonitor.reset`):
after a rollback the windows are cleared so replayed steps are judged
fresh — this is what makes an injected run's detection trace a pure
function of the fault plan."""
from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Any, Optional

PyTree = Any

WARN = "warn"
CRITICAL = "critical"


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    step: int
    kind: str           # nonfinite | loss_spike | blowup | dead_subspace |
                        # subspace_energy | straggler
    severity: str       # warn | critical
    value: float = 0.0
    detail: str = ""

    def to_json(self) -> dict:
        return {"step": self.step, "kind": self.kind,
                "severity": self.severity, "value": self.value,
                "detail": self.detail}


@dataclasses.dataclass
class HealthReport:
    """One step's verdict: ``ok`` (no events), ``warn`` or ``critical``."""

    step: int
    status: str
    events: list
    loss: float
    grad_norm: float
    update_norm: Optional[float] = None

    @property
    def critical(self) -> list:
        return [e for e in self.events if e.severity == CRITICAL]


class HealthMonitor:
    """Windowed detectors over the per-step scalar signals.

    ``observe`` is called once per step with host-side floats; it returns a
    :class:`HealthReport` and appends any events to ``self.events``.
    Unhealthy samples are *not* folded into the detector windows (a spike
    must not inflate the very std that detects the next one)."""

    def __init__(self, cfg=None, step_monitor=None):
        from .recovery import ResilienceConfig

        self.cfg = cfg or ResilienceConfig()
        self.step_monitor = step_monitor
        self.events: list = []
        self.counts: collections.Counter = collections.Counter()
        self.reset()

    def reset(self) -> None:
        """Clear detector windows (called after a rollback/restore so
        replayed steps are judged against fresh statistics)."""
        c = self.cfg
        self._losses = collections.deque(maxlen=c.spike_window)
        self._gnorms = collections.deque(maxlen=c.spike_window)
        self._unorms = collections.deque(maxlen=c.collapse_window)
        self._trend: list = []

    # ------------------------------------------------------------- detectors

    def _detect_nonfinite(self, step, applied, out):
        if not applied:
            out.append(HealthEvent(step, "nonfinite", CRITICAL,
                                   detail="in-jit NaN/Inf guard skipped "
                                          "the update"))

    def _detect_spike(self, step, loss, out):
        c = self.cfg
        if len(self._losses) >= c.spike_min_samples:
            mu = statistics.fmean(self._losses)
            sd = statistics.pstdev(self._losses) or 1e-9
            if loss > mu + c.spike_z * sd and loss - mu > c.spike_min_delta:
                out.append(HealthEvent(
                    step, "loss_spike", CRITICAL, value=loss,
                    detail=f"loss {loss:.4g} > {mu:.4g} + "
                           f"{c.spike_z}*{sd:.4g}"))
                return True
        return False

    def _detect_grad_spike(self, step, grad_norm, out):
        """Raw (pre-clip) gradient-norm spike: grad_clip neutralizes the
        update magnitude, but a spiked gradient still poisons the clipped
        direction and the low-rank momenta — this is the detector that sees
        it.  The 10× relative guard keeps normal warmup drift quiet."""
        c = self.cfg
        if len(self._gnorms) >= c.spike_min_samples and grad_norm > 0:
            mu = statistics.fmean(self._gnorms)
            sd = statistics.pstdev(self._gnorms) or 1e-9
            if grad_norm > mu + c.spike_z * sd and grad_norm > 10.0 * mu:
                out.append(HealthEvent(
                    step, "grad_spike", CRITICAL, value=grad_norm,
                    detail=f"raw grad norm {grad_norm:.4g} > {mu:.4g} + "
                           f"{c.spike_z}*{sd:.4g} (pre-clip)"))
                return True
        return False

    def _detect_blowup(self, step, loss, out):
        c = self.cfg
        if self._trend and loss > self._trend[-1]:
            self._trend.append(loss)
        else:
            self._trend = [loss]
        if (len(self._trend) > c.blowup_k
                and self._trend[-1] > c.blowup_factor * self._trend[0]):
            out.append(HealthEvent(
                step, "blowup", CRITICAL, value=loss,
                detail=f"{len(self._trend) - 1} consecutive increases, "
                       f"{self._trend[0]:.4g} -> {loss:.4g}"))
            self._trend = [loss]
            return True
        return False

    def _detect_collapse(self, step, grad_norm, update_norm, out):
        c = self.cfg
        if update_norm is None:
            return False
        if len(self._unorms) >= c.collapse_min_samples and grad_norm > 1e-12:
            med = statistics.median(self._unorms)
            if med > 0 and update_norm < c.collapse_tol * med:
                out.append(HealthEvent(
                    step, "dead_subspace", CRITICAL, value=update_norm,
                    detail=f"update norm {update_norm:.3g} < "
                           f"{c.collapse_tol} * median {med:.3g} "
                           f"(grad norm {grad_norm:.3g})"))
                return True
        return False

    def _detect_energy(self, step, probes, out):
        """Per-family captured-energy fraction from the spectrum probes
        (only meaningful right after a refresh; callers gather them on
        refresh boundaries).  A starved subspace is a rank-policy problem,
        not a transient fault, so this warns rather than escalates."""
        c = self.cfg
        for (m, n), pr in sorted((probes or {}).items()):
            g2 = float(pr.get("g2", 0.0))
            if g2 <= 0.0:
                continue
            frac = float(sum(pr["sv2"])) / g2
            if frac < c.energy_min:
                out.append(HealthEvent(
                    step, "subspace_energy", WARN, value=frac,
                    detail=f"family {m}x{n} captures {frac:.3f} "
                           f"< {c.energy_min} of gradient energy"))

    # ------------------------------------------------------------- observe

    def observe(self, step: int, *, loss: float, applied: bool,
                grad_norm: float = 0.0, update_norm: Optional[float] = None,
                dt: Optional[float] = None,
                probes: Optional[dict] = None) -> HealthReport:
        events: list = []
        self._detect_nonfinite(step, applied, events)
        healthy_loss = True
        if applied:
            spiked = self._detect_spike(step, loss, events)
            blew = self._detect_blowup(step, loss, events)
            healthy_loss = not (spiked or blew)
        gspiked = (applied
                   and self._detect_grad_spike(step, grad_norm, events))
        collapsed = self._detect_collapse(step, grad_norm, update_norm,
                                          events)
        self._detect_energy(step, probes, events)
        if dt is not None and self.step_monitor is not None:
            if self.step_monitor.record(step, dt):
                events.append(HealthEvent(step, "straggler", WARN, value=dt))

        # Fold only healthy samples into the windows.
        if applied and healthy_loss:
            self._losses.append(loss)
        if applied and not gspiked and grad_norm > 0:
            self._gnorms.append(grad_norm)
        if update_norm is not None and not collapsed and applied:
            self._unorms.append(update_norm)

        status = "ok"
        if any(e.severity == CRITICAL for e in events):
            status = CRITICAL
        elif events:
            status = WARN
        for e in events:
            self.counts[e.kind] += 1
        self.events.extend(events)
        return HealthReport(step=step, status=status, events=events,
                            loss=loss, grad_norm=grad_norm,
                            update_norm=update_norm)
