"""Recovery controller: a declarative escalation ladder over live training.

The ladder (documented in README "Resilience"):

    rung 0  ``skip``      the in-jit NaN/Inf guard already zeroed the
                          update — count it; after ``max_skips``
                          consecutive skips escalate to ``rollback``
    rung 1  ``refresh``   force an off-cycle projector refresh: advance the
                          ``lowrank()`` step count to the next period
                          boundary so the very next update recomputes every
                          projector from live gradients (clears a poisoned
                          or collapsed subspace; GUM-style
                          ``reset_on_refresh`` inners also re-zero momenta)
    rung 2  ``rollback``  restore the last in-memory snapshot — params,
                          optimizer state and controller extras (rank-policy
                          state rides along so floors/TTLs don't desync) —
                          and rewind the data stream to the snapshot step
    rung 3  ``restore``   reload the last *verified* durable checkpoint
                          through :class:`repro.checkpoint.CheckpointManager`
                          (checksum-verified, falling back past corrupt
                          saves)

Each critical :class:`~repro.resilience.health.HealthEvent` kind enters the
ladder at its base rung (see ``BASE_RUNG``); a further critical report
within ``escalation_window`` steps of the previous action escalates one
rung, so a fault the cheaper rung could not clear climbs deterministically.
Every decision lands in ``RecoveryController.trace`` — with a seeded
:class:`~repro.resilience.inject.FaultPlan` the whole
detect→decide→recover sequence is reproducible run to run."""
from __future__ import annotations

import copy
import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

RUNGS = ("skip", "refresh", "rollback", "restore")
BASE_RUNG = {
    "nonfinite": "skip",
    "dead_subspace": "refresh",
    "loss_spike": "rollback",
    "grad_spike": "rollback",
    "blowup": "rollback",
}


@dataclasses.dataclass
class ResilienceConfig:
    """Knobs for the health monitor + recovery controller (CLI spec form:
    ``"ring=3,snapshot_every=5,spike_z=4"`` — any field by name)."""

    # snapshot ring (rung 2)
    ring: int = 2                  # in-memory snapshots kept
    snapshot_every: int = 8        # steps between snapshots (healthy only)
    # loss-spike detector
    spike_z: float = 8.0
    spike_window: int = 32
    spike_min_samples: int = 8
    spike_min_delta: float = 0.5   # absolute guard: tiny-σ windows can't flag noise
    # blowup detector
    blowup_k: int = 5
    blowup_factor: float = 2.0
    # dead-subspace detector
    collapse_tol: float = 0.05
    collapse_window: int = 16
    collapse_min_samples: int = 4
    # captured-energy floor (warn only)
    energy_min: float = 0.05
    probe_health: bool = True      # gather spectrum probes when available
    # escalation
    escalation_window: int = 8     # steps within which a recurrence escalates
    max_skips: int = 3             # consecutive rung-0 skips before rollback

    @staticmethod
    def parse(spec) -> "ResilienceConfig":
        """``None | bool | spec string | ResilienceConfig`` → config."""
        if isinstance(spec, ResilienceConfig):
            return spec
        cfg = ResilienceConfig()
        if spec is None or spec is True or spec == "":
            return cfg
        for part in str(spec).split(","):
            part = part.strip()
            if not part:
                continue
            k, _, v = part.partition("=")
            k = k.strip()
            if not hasattr(cfg, k):
                raise ValueError(f"unknown resilience knob {k!r}")
            cur = getattr(cfg, k)
            setattr(cfg, k, type(cur)(float(v)) if isinstance(cur, (int, float))
                    and not isinstance(cur, bool) else v.strip() == "1"
                    if isinstance(cur, bool) else v)
        return cfg


# ---------------------------------------------------------------------------
# snapshot ring (rung 2)
# ---------------------------------------------------------------------------


def _to_host(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x: np.array(jax.device_get(x)), tree)


def _to_device(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.asarray, tree)


@dataclasses.dataclass
class Snapshot:
    step: int                     # the next step to run after restoring
    params: PyTree                # host (numpy) copies — jit donation safe
    opt_state: PyTree
    extra: Optional[dict] = None  # controller extras (rank-policy state…)


class SnapshotRing:
    """Last-K in-memory ``(params, opt_state, extras)`` snapshots.

    Buffers are copied to host numpy at capture (the live device buffers
    are donated to the next step, so they cannot be kept) and re-uploaded
    on restore; round-trip is bit-exact."""

    def __init__(self, k: int = 2):
        self.k = int(k)
        self._ring: list = []

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def steps(self) -> list:
        return [s.step for s in self._ring]

    def add(self, step: int, params: PyTree, opt_state: PyTree,
            extra: Optional[dict] = None) -> None:
        snap = Snapshot(step=int(step), params=_to_host(params),
                        opt_state=_to_host(opt_state),
                        extra=copy.deepcopy(extra))
        self._ring.append(snap)
        del self._ring[: -self.k]

    def latest(self) -> Optional[Snapshot]:
        return self._ring[-1] if self._ring else None

    def pop_latest(self) -> Optional[Snapshot]:
        """Take the newest snapshot *out* of the ring (a second rollback
        for the same incident should land on an older state, not loop on
        one that already failed to clear the fault)."""
        return self._ring.pop() if self._ring else None

    def restore(self, snap: Snapshot) -> tuple:
        return _to_device(snap.params), _to_device(snap.opt_state)


# ---------------------------------------------------------------------------
# forced off-cycle refresh (rung 1)
# ---------------------------------------------------------------------------


def force_refresh(opt_state: PyTree, period: int) -> PyTree:
    """Advance every ``LowRankState`` step count to its next period
    boundary so the next update recomputes all projectors from live
    gradients (``lowrank()`` refreshes when ``count % period == 0`` on
    entry).  This shifts the refresh clock forward by up to ``period - 1``
    counts — deterministic, and exactly what an off-cycle refresh means:
    the subspace is re-derived *now* instead of at the scheduled boundary."""
    from repro.core.combinators import LowRankState

    period = int(period)

    def node(s):
        if isinstance(s, LowRankState):
            c = np.asarray(jax.device_get(s.count))
            bump = (-int(c)) % period
            return s._replace(count=s.count + jnp.asarray(bump, c.dtype))
        return s

    return jax.tree_util.tree_map(
        node, opt_state, is_leaf=lambda x: isinstance(x, LowRankState))


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str                     # none | skip | refresh | rollback | restore
    step: int                     # step the triggering report came from
    event: str = ""               # triggering event kind
    target: Optional[int] = None  # filled by the trainer (snapshot/ckpt step)


class RecoveryController:
    """Maps critical health reports to ladder actions with escalation.

    The controller is pure host-side bookkeeping — the trainer owns the
    actual state surgery (it has the snapshot ring, checkpoint manager and
    jit caches).  ``decide`` returns at most one action per report;
    ``record`` is called by the trainer after executing it (with the
    resolved target step) so the trace carries what actually happened."""

    def __init__(self, cfg: Optional[ResilienceConfig] = None):
        self.cfg = cfg or ResilienceConfig()
        self.counts = {r: 0 for r in RUNGS}
        self.trace: list = []
        self._last_action_step: Optional[int] = None
        self._last_rung: int = -1
        self._skip_streak: int = 0

    def _escalate(self, step: int, base: int) -> int:
        recent = (self._last_action_step is not None
                  and step - self._last_action_step
                  <= self.cfg.escalation_window)
        if recent and base <= self._last_rung:
            return min(self._last_rung + 1, len(RUNGS) - 1)
        return base

    def decide(self, report) -> Action:
        crit = report.critical
        if not crit:
            if report.status == "ok":
                self._skip_streak = 0
            return Action("none", report.step)
        # Highest-base-rung event wins the decision for this step.
        ev = max(crit, key=lambda e: RUNGS.index(BASE_RUNG.get(e.kind,
                                                               "rollback")))
        base = RUNGS.index(BASE_RUNG.get(ev.kind, "rollback"))
        if ev.kind == "nonfinite":
            self._skip_streak += 1
            if self._skip_streak <= self.cfg.max_skips:
                # rung 0 — already handled in-jit, just count it
                self.counts["skip"] += 1
                self.trace.append({"step": report.step, "event": ev.kind,
                                   "action": "skip", "target": None})
                return Action("skip", report.step, ev.kind)
            base = RUNGS.index("rollback")
            self._skip_streak = 0
        rung = self._escalate(report.step, base)
        return Action(RUNGS[rung], report.step, ev.kind)

    def record(self, action: Action, target: Optional[int] = None) -> None:
        """Log an executed action (trainer callback)."""
        self.counts[action.kind] += 1
        self._last_action_step = action.step
        self._last_rung = RUNGS.index(action.kind)
        self.trace.append({"step": action.step, "event": action.event,
                           "action": action.kind, "target": target})
