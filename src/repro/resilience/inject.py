"""Declarative, seeded fault injection — every recovery path gets a
reproducible trigger.

A :class:`FaultPlan` is a list of :class:`FaultEvent` entries, each naming a
fault class and the train step it fires at.  Events fire **once** (so a
rollback past a fired event does not re-trigger it — recovery converges)
and every firing is appended to ``plan.log``, which together with the
recovery trace makes an injected run reproducible end to end: the same plan
and seed produce the identical sequence of faults, detections and recovery
rungs.

Fault classes and how they are delivered:

``grad_nan`` / ``grad_inf`` / ``grad_spike``
    gradient corruption *inside* the jitted train step via a traced
    :class:`FaultGate`: the step takes an extra ``{"mode", "scale"}`` scalar
    input, so arming a fault is a host-side value change, not a recompile,
    and the disarmed gate (mode 0) is elementwise-identical to the stock
    step.  ``grad_spike`` multiplies by ``scale`` (default 1e6); the leaf
    set is chosen statically by the plan's ``leaf_filter``.

``refresh_zero`` / ``refresh_illcond``
    projector-refresh sabotage: :func:`poison_projectors` surgically
    replaces every projector in the optimizer state's ``LowRankState``
    nodes — all-zeros (a refresh that returned a degenerate sketch: the
    whole update back-projects to zero) or ill-conditioned (every column a
    copy of the first: the subspace collapses to one direction).  This is
    exactly the state a sabotaged external-refresh hook
    (``lowrank(external_refresh=True)``'s ``update.refresh``) would leave
    behind; the surgical form works on per-leaf *and* family-stacked
    layouts and inside chains, where the hook is not reachable.

``ckpt_truncate`` / ``ckpt_bitflip``
    durable-checkpoint corruption after the next committed save at or after
    ``step``: :func:`truncate_checkpoint` cuts a shard file short,
    :func:`bitflip_checkpoint` flips one bit of one leaf (position drawn
    from the plan's seeded RNG) — both must be caught by the manifest
    checksums on the next verify/restore.

``kill_save``
    preemption mid-save: a save observer that SIGKILLs the process after
    ``arg`` leaves of the next save at or after ``step`` have been written
    — the atomic tmp+rename commit must leave the previous checkpoint as
    the restorable one.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

GRAD_KINDS = ("grad_nan", "grad_inf", "grad_spike")
STATE_KINDS = ("refresh_zero", "refresh_illcond")
CKPT_KINDS = ("ckpt_truncate", "ckpt_bitflip")
KILL_KINDS = ("kill_save",)
ALL_KINDS = GRAD_KINDS + STATE_KINDS + CKPT_KINDS + KILL_KINDS

_GRAD_MODE = {"grad_nan": 1, "grad_inf": 2, "grad_spike": 3}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault: ``kind`` fired at train step ``step``.

    ``scale`` is the spike multiplier (``grad_spike``) or truncation
    fraction kept (``ckpt_truncate``); ``arg`` is the leaf count written
    before a ``kill_save`` fires; ``leaves`` restricts checkpoint
    corruption to paths containing any of the substrings."""

    step: int
    kind: str
    scale: float = 1e6
    arg: int = 0
    leaves: tuple = ()

    def __post_init__(self):
        if self.kind not in ALL_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {ALL_KINDS}")

    def to_json(self) -> dict:
        return {"step": self.step, "kind": self.kind, "scale": self.scale,
                "arg": self.arg, "leaves": list(self.leaves)}


class FaultGate:
    """Traced gradient-corruption gate compiled into the train step.

    The step takes an extra ``fault = {"mode": int32, "scale": float32}``
    input; :meth:`apply` rewrites every selected gradient leaf as a
    function of those scalars, so the same compiled step serves clean and
    faulty steps (mode 0 is elementwise-identical to no gate).  Leaf
    selection (``leaf_filter``: path-substring tuple, empty = every float
    leaf) is static — it is part of the compiled program."""

    def __init__(self, leaf_filter: tuple = ()):
        self.leaf_filter = tuple(leaf_filter)

    def _match(self, path: str) -> bool:
        return not self.leaf_filter or any(s in path for s in self.leaf_filter)

    def apply(self, grads: PyTree, fault: dict) -> PyTree:
        from repro.core.api import tree_paths

        mode = fault["mode"]
        scale = fault["scale"]
        paths = tree_paths(grads)

        def one(path, g):
            if g is None or not jnp.issubdtype(jnp.asarray(g).dtype,
                                               jnp.floating):
                return g
            if not self._match(path):
                return g
            g = jnp.where(mode == 1, jnp.nan, g)
            g = jnp.where(mode == 2, jnp.inf, g)
            return jnp.where(mode == 3, g * scale.astype(g.dtype), g)

        return jax.tree_util.tree_map(one, paths, grads)

    @staticmethod
    def disarmed() -> dict:
        return {"mode": jnp.int32(0), "scale": jnp.float32(1.0)}

    @staticmethod
    def armed(event: FaultEvent) -> dict:
        return {"mode": jnp.int32(_GRAD_MODE[event.kind]),
                "scale": jnp.float32(event.scale)}


class FaultPlan:
    """A seeded schedule of :class:`FaultEvent` entries.

    Events are consumed (fire once); ``log`` records every firing as
    ``(step, kind)`` so tests can assert the exact injection trace.  The
    seeded RNG drives only corruption internals (bit positions), never
    *whether* an event fires — reproducibility is structural."""

    def __init__(self, events, seed: int = 0,
                 leaf_filter: tuple = ()):
        self.events = sorted(
            (e if isinstance(e, FaultEvent) else FaultEvent(**e)
             for e in events),
            key=lambda e: (e.step, e.kind),
        )
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        self.leaf_filter = tuple(leaf_filter)
        self._fired: set = set()
        self.log: list = []

    # ------------------------------------------------------------- parsing

    @staticmethod
    def parse(spec: str, seed: int = 0) -> "FaultPlan":
        """CLI form: ``kind@step[*scale][#arg]`` joined by ``;`` — e.g.
        ``"grad_nan@5;grad_spike@9*1e6;refresh_zero@13;kill_save@20#3"``."""
        events = []
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            kind, _, rest = part.partition("@")
            if not rest:
                raise ValueError(f"fault spec {part!r} needs '@step'")
            arg = 0
            scale = 1e6
            if "#" in rest:
                rest, _, a = rest.partition("#")
                arg = int(a)
            if "*" in rest:
                rest, _, s = rest.partition("*")
                scale = float(s)
            events.append(FaultEvent(step=int(rest), kind=kind.strip(),
                                     scale=scale, arg=arg))
        return FaultPlan(events, seed=seed)

    def to_json(self) -> dict:
        return {"seed": self.seed, "leaf_filter": list(self.leaf_filter),
                "events": [e.to_json() for e in self.events]}

    @staticmethod
    def from_json(d: dict) -> "FaultPlan":
        return FaultPlan([FaultEvent(step=e["step"], kind=e["kind"],
                                     scale=e.get("scale", 1e6),
                                     arg=e.get("arg", 0),
                                     leaves=tuple(e.get("leaves", ())))
                          for e in d.get("events", [])],
                         seed=d.get("seed", 0),
                         leaf_filter=tuple(d.get("leaf_filter", ())))

    # ------------------------------------------------------------- firing

    def _take(self, predicate) -> list:
        out = []
        for i, e in enumerate(self.events):
            if i in self._fired or not predicate(e):
                continue
            self._fired.add(i)
            self.log.append((e.step, e.kind))
            out.append(e)
        return out

    def needs_gate(self) -> bool:
        return any(e.kind in GRAD_KINDS for e in self.events)

    def gate(self) -> Optional[FaultGate]:
        return FaultGate(self.leaf_filter) if self.needs_gate() else None

    def grad_event(self, step: int) -> Optional[FaultEvent]:
        """The gradient fault firing at exactly this step, if any."""
        ev = self._take(lambda e: e.kind in GRAD_KINDS and e.step == step)
        return ev[0] if ev else None

    def state_events(self, step: int) -> list:
        """Projector-sabotage events firing at exactly this step."""
        return self._take(lambda e: e.kind in STATE_KINDS and e.step == step)

    def ckpt_events(self, saved_step: int) -> list:
        """Checkpoint-corruption events due at a save committed for
        ``saved_step`` (fires at the first save at or after ``e.step``)."""
        return self._take(
            lambda e: e.kind in CKPT_KINDS and e.step <= saved_step)

    def save_observer(self, saved_step: int) -> Optional[Callable]:
        """A per-leaf save hook that SIGKILLs the process mid-save, or None
        when no ``kill_save`` event is due for this save."""
        ev = self._take(
            lambda e: e.kind in KILL_KINDS and e.step <= saved_step)
        if not ev:
            return None
        after = ev[0].arg

        def observer(leaf_index: int, total: int):
            if leaf_index >= after:
                os.kill(os.getpid(), signal.SIGKILL)

        return observer

    def apply_ckpt_events(self, ckpt_dir: str, saved_step: int) -> list:
        """Run any due checkpoint-corruption events against the committed
        checkpoint for ``saved_step``; returns the fired events."""
        fired = self.ckpt_events(saved_step)
        for e in fired:
            if e.kind == "ckpt_truncate":
                truncate_checkpoint(ckpt_dir, saved_step, rng=self.rng,
                                    keep_frac=min(abs(e.scale), 0.9)
                                    if e.scale < 1.0 else 0.5,
                                    leaves=e.leaves)
            else:
                bitflip_checkpoint(ckpt_dir, saved_step, rng=self.rng,
                                   leaves=e.leaves)
        return fired

    def __repr__(self) -> str:
        return (f"FaultPlan(seed={self.seed}, "
                f"events={[ (e.step, e.kind) for e in self.events ]})")


# ---------------------------------------------------------------------------
# projector sabotage (state surgery)
# ---------------------------------------------------------------------------


def poison_projectors(opt_state: PyTree, mode: str = "refresh_zero") -> PyTree:
    """Replace every projector in the state's ``LowRankState`` nodes with a
    degenerate one — the state a sabotaged refresh would leave behind.

    ``refresh_zero``: all-zeros projectors — ``PᵀG = 0``, the projected
    momenta decay and every back-projected update is exactly zero (the
    dead-subspace signature the health monitor's collapse detector keys
    on).  ``refresh_illcond``: every column a copy of the first — the
    subspace collapses to a single direction.  Works on per-leaf and
    family-stacked layouts (projectors are the ``projs`` leaves either
    way)."""
    from repro.core.combinators import LowRankState

    if isinstance(mode, FaultEvent):
        mode = mode.kind
    if mode not in STATE_KINDS:
        raise ValueError(f"unknown projector poison mode {mode!r}")

    def poison_leaf(p):
        if p is None:
            return None
        if mode == "refresh_zero":
            return jnp.zeros_like(p)
        first = p[..., :, :1]
        return jnp.broadcast_to(first, p.shape).astype(p.dtype)

    def node(s):
        if isinstance(s, LowRankState):
            projs = jax.tree_util.tree_map(poison_leaf, s.projs,
                                           is_leaf=lambda x: x is None)
            return s._replace(projs=projs)
        return s

    return jax.tree_util.tree_map(
        node, opt_state, is_leaf=lambda x: isinstance(x, LowRankState))


# ---------------------------------------------------------------------------
# checkpoint corruption
# ---------------------------------------------------------------------------


def _shard_files(ckpt_dir: str, step: int, leaves: tuple = ()) -> list:
    from repro.checkpoint.manager import CheckpointManager

    d = CheckpointManager(ckpt_dir)._step_dir(step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    out = []
    for meta in manifest["leaves"]:
        if leaves and not any(s in meta["path"] for s in leaves):
            continue
        for fn in meta["shards"]:
            out.append((os.path.join(d, fn), meta["path"]))
    if not out:
        raise ValueError(f"no shard files match leaves={leaves} in {d}")
    return out


def truncate_checkpoint(ckpt_dir: str, step: int, *, rng=None,
                        keep_frac: float = 0.5, leaves: tuple = ()) -> str:
    """Truncate one committed shard file to ``keep_frac`` of its bytes (a
    partial write that slipped past the commit).  Returns the leaf path."""
    rng = rng or np.random.default_rng(0)
    files = _shard_files(ckpt_dir, step, leaves)
    fn, path = files[int(rng.integers(len(files)))]
    size = os.path.getsize(fn)
    with open(fn, "r+b") as f:
        f.truncate(max(int(size * keep_frac), 1))
    return path


def bitflip_checkpoint(ckpt_dir: str, step: int, *, rng=None,
                       leaves: tuple = ()) -> str:
    """Flip one bit of one committed shard file (seeded position) — silent
    media corruption that only a content checksum can catch.  Returns the
    leaf path.  The flip lands in the array payload, not the .npy header,
    so the file still *loads* — the checksum is the only defense."""
    rng = rng or np.random.default_rng(0)
    files = _shard_files(ckpt_dir, step, leaves)
    fn, path = files[int(rng.integers(len(files)))]
    size = os.path.getsize(fn)
    # .npy v1 headers are 128 bytes for these arrays; flip past them (any
    # file this small has no payload worth flipping).
    lo = min(128, size - 1)
    pos = int(rng.integers(lo, size))
    bit = int(rng.integers(8))
    with open(fn, "r+b") as f:
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ (1 << bit)]))
    return path
