"""Telemetry subsystem — the sensor layer of the probe-driven control plane.

One training run produces one coherent, schema-versioned ``events.jsonl``:
scalar metrics (loss, grad norms, per-family captured energy / projector
drift / bias residual / rank), discrete events (health, recovery, fault
injection, rank-policy decisions, checkpoint save/verify/GC, audit
summaries), host-side timing spans (steady step vs refresh boundary vs rank
migration vs checkpoint save) and closing counters.

Three layers:

  * :mod:`repro.telemetry.bus` — the structured record bus: typed records
    with pluggable sinks (stdout pretty-printer, append-only JSONL with a
    versioned schema, in-memory ring for tests).  Every former ad-hoc
    ``print()`` emitter in the trainer routes through it, so console output
    and ``events.jsonl`` can never disagree.
  * :mod:`repro.telemetry.instrument` — host-side gatherers over the live
    optimizer state: per-family probe metrics (captured-energy fraction,
    projector drift, sampled bias residual — stored in-jit by
    ``lowrank(telemetry=True)``), layerwise-unbias gamma-slot sampling
    distribution, and the runtime launch-count cross-check against the
    closed-form model of :mod:`repro.analysis.launch_model`.
  * :mod:`repro.telemetry.report` — the run-report/diff CLI:
    ``python -m repro.telemetry.report RUN_DIR [--diff OTHER]``.

The in-jit half lives in ``repro.core.combinators.lowrank(telemetry=True)``
(riding the spectrum-probe mechanism — zero extra state leaves when off,
loss-trajectory bit-exact when on) and is budgeted at <= 2% step time in
``benchmarks/telemetry.py`` / ``results/BENCH_telemetry.json``.
"""
from .bus import (
    SCHEMA_VERSION,
    JsonlSink,
    MemorySink,
    StdoutSink,
    Telemetry,
    TelemetryConfig,
)
from .instrument import (
    GammaSlotTracker,
    lowrank_family_metrics,
)

__all__ = [
    "SCHEMA_VERSION",
    "Telemetry",
    "TelemetryConfig",
    "JsonlSink",
    "StdoutSink",
    "MemorySink",
    "GammaSlotTracker",
    "lowrank_family_metrics",
]
