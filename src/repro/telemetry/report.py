"""Run-report / diff CLI over a telemetry ``events.jsonl``.

    python -m repro.telemetry.report RUN_DIR            # summary
    python -m repro.telemetry.report RUN_DIR --diff B   # compare two runs

``RUN_DIR`` is either a directory containing ``events.jsonl`` (the trainer's
checkpoint dir) or a direct path to a jsonl file.  The summary renders: run
header, loss-curve stats, per-family rank / captured-energy / drift / bias
trajectories, the event timeline (warn+ always, info folded into counts),
span breakdown, and recovery/fault counters.  ``--diff`` lines the two runs'
loss stats, span means, and event counts up side by side.

Pure stdlib + :mod:`repro.telemetry.bus` — usable on a machine without jax.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Optional

from .bus import read_jsonl


def _resolve(path: str) -> str:
    if os.path.isdir(path):
        cand = os.path.join(path, "events.jsonl")
        if not os.path.exists(cand):
            raise FileNotFoundError(f"{path}: no events.jsonl inside")
        return cand
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    return path


def _stats(values: list[float]) -> dict:
    if not values:
        return {}
    s = sorted(values)
    return {
        "n": len(s),
        "first": s and values[0],
        "last": values[-1],
        "min": s[0],
        "max": s[-1],
        "median": s[len(s) // 2],
        "mean": sum(s) / len(s),
    }


class Run:
    """Parsed view of one events.jsonl."""

    def __init__(self, path: str):
        self.path = _resolve(path)
        self.records = read_jsonl(self.path)
        self.header: dict = {}
        self.counters: dict = {}
        self.span_agg: dict = {}
        self.metrics: dict[str, list[tuple[Optional[int], float]]] = {}
        self.events: list[dict] = []
        self.spans: dict[str, list[float]] = {}
        # family tag -> metric name -> [(step, value)]
        self.families: dict[str, dict[str, list[tuple[int, float]]]] = {}
        for rec in self.records:
            kind = rec.get("kind")
            if kind == "header":
                self.header = rec
            elif kind == "counters":
                self.counters = rec.get("counts", {})
                self.span_agg = rec.get("spans", {})
            elif kind == "metric":
                name, value = rec.get("name", "?"), rec.get("value", 0.0)
                step = rec.get("step")
                fam = (rec.get("tags") or {}).get("family")
                if fam is not None:
                    self.families.setdefault(fam, {}).setdefault(
                        name, []).append((step, value))
                else:
                    self.metrics.setdefault(name, []).append((step, value))
            elif kind == "event":
                self.events.append(rec)
            elif kind == "span":
                self.spans.setdefault(rec.get("name", "?"), []).append(
                    rec.get("dur_us", 0.0))

    # ------------------------------------------------------------ accessors

    def metric_values(self, name: str) -> list[float]:
        return [v for _, v in self.metrics.get(name, [])]

    def span_summary(self) -> dict[str, dict]:
        if self.span_agg:
            return self.span_agg
        out = {}
        for name, durs in sorted(self.spans.items()):
            out[name] = {"count": len(durs),
                         "total_us": round(sum(durs), 1),
                         "mean_us": round(sum(durs) / len(durs), 1)}
        return out

    def event_counts(self) -> dict[str, int]:
        if self.counters:
            return {k: v for k, v in self.counters.items()
                    if k.startswith("event.")}
        counts: dict[str, int] = {}
        for ev in self.events:
            key = f"event.{ev.get('name', '?')}"
            counts[key] = counts.get(key, 0) + 1
        return counts


# ---------------------------------------------------------------- rendering

def _fmt(v, width: int = 10) -> str:
    if isinstance(v, float):
        return f"{v:{width}.4g}"
    return f"{str(v):>{width}}"


def summarize(run: Run, out=None) -> None:
    out = out or sys.stdout
    w = lambda s="": print(s, file=out)
    w(f"# telemetry report: {run.path}")
    hdr = run.header
    if hdr:
        meta = hdr.get("run", {})
        pairs = " ".join(f"{k}={v}" for k, v in sorted(meta.items()))
        w(f"schema {hdr.get('schema', '?')}  {pairs}")
    w()

    loss = run.metric_values("loss")
    if loss:
        st = _stats(loss)
        w("## loss")
        w(f"  steps={st['n']} first={st['first']:.4f} last={st['last']:.4f} "
          f"min={st['min']:.4f} median={st['median']:.4f}")
        w()

    other = sorted(n for n in run.metrics if n != "loss")
    if other:
        w("## metrics")
        for name in other:
            st = _stats(run.metric_values(name))
            w(f"  {name:24s} n={st['n']:<5d} last={_fmt(st['last'])} "
              f"mean={_fmt(st['mean'])} max={_fmt(st['max'])}")
        w()

    if run.families:
        w("## families")
        for fam in sorted(run.families):
            series = run.families[fam]
            parts = []
            for name in ("rank", "energy", "drift", "bias"):
                pts = series.get(name)
                if not pts:
                    continue
                first, last = pts[0][1], pts[-1][1]
                if name == "rank":
                    parts.append(f"rank {int(first)}->{int(last)}"
                                 if first != last else f"rank {int(last)}")
                else:
                    parts.append(f"{name} {last:.4f}")
            w(f"  {fam:16s} {'  '.join(parts)}")
        w()

    spans = run.span_summary()
    if spans:
        w("## spans")
        for name, st in sorted(spans.items()):
            w(f"  {name:24s} count={st['count']:<6d} "
          f"mean={st['mean_us'] / 1e3:9.3f}ms total={st['total_us'] / 1e3:9.1f}ms")
        w()

    counts = run.event_counts()
    if counts:
        w("## events")
        for name, n in sorted(counts.items()):
            w(f"  {name[len('event.'):]:24s} {n}")
        w()

    noisy = [ev for ev in run.events
             if ev.get("severity", "info") not in ("info", "debug")]
    if noisy:
        w("## timeline (warn+)")
        for ev in noisy:
            step = ev.get("step")
            at = f"step {step:6d}" if step is not None else " " * 11
            name, detail = ev.get("name", ""), ev.get("detail", "")
            prefix = "" if detail.startswith(name) else f"{name}: "
            w(f"  {at} [{ev.get('severity')}] {prefix}{detail}")
        w()


def diff(a: Run, b: Run, out=None) -> None:
    out = out or sys.stdout
    w = lambda s="": print(s, file=out)
    w(f"# telemetry diff\n#   A: {a.path}\n#   B: {b.path}")
    w()

    w("## loss")
    for name, run in (("A", a), ("B", b)):
        st = _stats(run.metric_values("loss"))
        if st:
            w(f"  {name}: steps={st['n']} first={st['first']:.4f} "
              f"last={st['last']:.4f} min={st['min']:.4f}")
        else:
            w(f"  {name}: no loss metrics")
    la, lb = a.metric_values("loss"), b.metric_values("loss")
    if la and lb:
        n = min(len(la), len(lb))
        deltas = [abs(x - y) for x, y in zip(la[:n], lb[:n])]
        w(f"  max |A-B| over first {n} steps: {max(deltas):.6g}"
          + ("  (identical)" if max(deltas) == 0 else ""))
    w()

    w("## span means (us)")
    sa, sb = a.span_summary(), b.span_summary()
    for name in sorted(set(sa) | set(sb)):
        ma = sa.get(name, {}).get("mean_us")
        mb = sb.get(name, {}).get("mean_us")
        delta = ""
        if ma and mb:
            delta = f"{(mb - ma) / ma * 100:+8.1f}%"
        w(f"  {name:24s} A={_fmt(ma)} B={_fmt(mb)} {delta}")
    w()

    w("## event counts")
    ca, cb = a.event_counts(), b.event_counts()
    for name in sorted(set(ca) | set(cb)):
        na, nb = ca.get(name, 0), cb.get(name, 0)
        mark = "" if na == nb else "   <-- differs"
        w(f"  {name[len('event.'):]:24s} A={na:<6d} B={nb:<6d}{mark}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="Summarize or diff telemetry events.jsonl run logs.")
    ap.add_argument("run", help="run directory (containing events.jsonl) "
                    "or a jsonl path")
    ap.add_argument("--diff", metavar="OTHER", default=None,
                    help="second run to compare against")
    ns = ap.parse_args(argv)
    try:
        run_a = Run(ns.run)
        if ns.diff is None:
            summarize(run_a)
        else:
            diff(run_a, Run(ns.diff))
    except (FileNotFoundError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
