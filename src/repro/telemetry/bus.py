"""Structured event/metric bus with pluggable sinks.

Record types (one JSON object per ``events.jsonl`` line, ``kind`` tagged):

  header    — first line of every log: ``schema`` version + run metadata
  metric    — scalar sample:  {step, name, value, tags?}
  event     — discrete occurrence: {step?, name, severity, detail, data?}
  span      — host-side timing: {step?, name, dur_us, tags?}
  counters  — closing summary: cumulative event counts + per-span
              aggregates (count / total_us / mean_us)

Every record carries ``t`` (seconds from the bus clock — wall time in
production, an injected deterministic clock in tests/golden files).  The
schema is versioned through :data:`SCHEMA_VERSION`; readers
(:mod:`repro.telemetry.report`) refuse logs from a newer schema rather than
misparse them.

Sinks:

  :class:`JsonlSink`   — append-only JSONL file (the durable run log)
  :class:`StdoutSink`  — pretty-prints *event* records in the trainer's
                         historical console format (``step  N detail`` /
                         bare ``detail``), so migrating a ``print()`` onto
                         the bus keeps the console byte-compatible while
                         guaranteeing the JSONL saw the same record
  :class:`MemorySink`  — bounded in-memory ring (tests, report unit checks)

The bus itself is synchronous and dependency-free; emitting with no sinks
attached is a no-op, so call sites never need a null-object guard.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import sys
import time
from collections import deque
from typing import Any, Optional, TextIO

SCHEMA_VERSION = 1


def _clean(rec: dict) -> dict:
    """Drop empty optional fields so records stay one short line each."""
    return {k: v for k, v in rec.items()
            if v is not None and not (isinstance(v, dict) and not v)}


class JsonlSink:
    """Append-only JSONL writer — one run, one file, flushed per record
    (a crashed run keeps every record up to the crash)."""

    def __init__(self, path: str):
        self.path = path
        self._f: Optional[TextIO] = open(path, "a")

    def write(self, record: dict) -> None:
        if self._f is None:
            return
        json.dump(record, self._f, separators=(",", ":"), sort_keys=True,
                  default=str)
        self._f.write("\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class StdoutSink:
    """Console renderer for ``event`` records.

    Formats match the trainer's pre-bus ``print()`` lines exactly
    (``step {step:6d} {detail}``, or bare ``detail`` for step-less events),
    so the console log is unchanged by the migration — but now every line
    the user sees is a record the JSONL sink also received."""

    def __init__(self, stream: Optional[TextIO] = None,
                 min_severity: str = "info"):
        self.stream = stream
        # "debug" events (checkpoint save/gc — things the pre-bus trainer
        # never printed) land in the JSONL but stay off the console
        self._rank = {"debug": -1, "info": 0, "warn": 1, "error": 2,
                      "critical": 3}
        self.min_rank = self._rank.get(min_severity, 0)

    def write(self, record: dict) -> None:
        if record.get("kind") != "event":
            return
        if self._rank.get(record.get("severity", "info"), 0) < self.min_rank:
            return
        stream = self.stream or sys.stdout
        detail = record.get("detail") or record.get("name", "")
        step = record.get("step")
        if step is None:
            print(detail, file=stream, flush=True)
        else:
            print(f"step {step:6d} {detail}", file=stream, flush=True)

    def close(self) -> None:
        pass


class MemorySink:
    """Bounded in-memory record ring (newest ``maxlen`` records)."""

    def __init__(self, maxlen: int = 4096):
        self.records: deque = deque(maxlen=maxlen)

    def write(self, record: dict) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass


@dataclasses.dataclass
class TelemetryConfig:
    """Knobs behind ``--telemetry[=spec]`` (spec = ``k=v,k=v`` like the
    resilience flag): ``every`` is the step-metric emission cadence,
    ``events`` overrides the JSONL path (default ``<ckpt_dir>/events.jsonl``),
    ``stdout`` keeps/drops the console pretty-printer, ``memory`` attaches an
    in-memory ring of that size (tests)."""

    every: int = 1
    stdout: bool = True
    events: str = ""
    memory: int = 0

    @classmethod
    def parse(cls, spec) -> Optional["TelemetryConfig"]:
        if spec is None or spec is False:
            return None
        if isinstance(spec, cls):
            return spec
        if spec is True:
            spec = ""
        cfg = cls()
        for part in str(spec).split(","):
            part = part.strip()
            if not part:
                continue
            k, _, v = part.partition("=")
            k, v = k.strip(), v.strip()
            if not hasattr(cfg, k):
                raise ValueError(
                    f"unknown telemetry knob {k!r} (have: "
                    f"{', '.join(f.name for f in dataclasses.fields(cls))})")
            cur = getattr(cfg, k)
            if isinstance(cur, bool):
                setattr(cfg, k, v.lower() in ("1", "true", "yes", "on", ""))
            elif isinstance(cur, int):
                setattr(cfg, k, int(v))
            else:
                setattr(cfg, k, v)
        return cfg


class Telemetry:
    """The bus: every emitter calls one of :meth:`metric` / :meth:`event` /
    :meth:`span` (or :meth:`record_span`) / :meth:`count`; every attached
    sink sees every record.  ``clock`` is injectable for deterministic
    logs (golden-file tests)."""

    def __init__(self, sinks, *, run: Optional[dict] = None, clock=time.time):
        self.sinks = list(sinks)
        self.clock = clock
        self.counters: dict[str, int] = {}
        self._spans: dict[str, list[float]] = {}
        self._closed = False
        self._emit({"kind": "header", "schema": SCHEMA_VERSION,
                    "run": run or {}, "t": self.clock()})

    # ------------------------------------------------------------- plumbing

    def _emit(self, record: dict) -> None:
        for sink in self.sinks:
            sink.write(record)

    def add_sink(self, sink) -> None:
        self.sinks.append(sink)

    # ------------------------------------------------------------- records

    def metric(self, step: int, name: str, value, **tags) -> None:
        self._emit(_clean({"kind": "metric", "t": self.clock(), "step": step,
                           "name": name, "value": float(value),
                           "tags": tags or None}))

    def event(self, name: str, detail: str = "", *, step: Optional[int] = None,
              severity: str = "info", **data) -> None:
        self.counters[f"event.{name}"] = self.counters.get(
            f"event.{name}", 0) + 1
        self._emit(_clean({"kind": "event", "t": self.clock(), "step": step,
                           "name": name, "severity": severity,
                           "detail": detail, "data": data or None}))

    def record_span(self, name: str, dur_s: float, *,
                    step: Optional[int] = None, **tags) -> None:
        self._spans.setdefault(name, []).append(dur_s)
        self._emit(_clean({"kind": "span", "t": self.clock(), "step": step,
                           "name": name, "dur_us": round(dur_s * 1e6, 1),
                           "tags": tags or None}))

    @contextlib.contextmanager
    def span(self, name: str, *, step: Optional[int] = None, **tags):
        t0 = self.clock()
        try:
            yield
        finally:
            self.record_span(name, self.clock() - t0, step=step, **tags)

    def count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    # ------------------------------------------------------------- close

    def span_stats(self) -> dict[str, dict]:
        out = {}
        for name, durs in sorted(self._spans.items()):
            total = sum(durs)
            out[name] = {"count": len(durs),
                         "total_us": round(total * 1e6, 1),
                         "mean_us": round(total / len(durs) * 1e6, 1)}
        return out

    def emit_counters(self, step: Optional[int] = None) -> None:
        """Emit a ``counters`` summary record (cumulative counts + span
        aggregates) without closing the bus — end-of-train() marker for a
        Trainer that may train again (benchmark reps, resume tests)."""
        self._emit(_clean({"kind": "counters", "t": self.clock(),
                           "step": step,
                           "counts": dict(sorted(self.counters.items())),
                           "spans": self.span_stats() or None}))

    def close(self, step: Optional[int] = None) -> None:
        """Emit the closing ``counters`` record and close every sink.
        Idempotent — a second close is a no-op."""
        if self._closed:
            return
        self._closed = True
        self.emit_counters(step)
        for sink in self.sinks:
            sink.close()


def read_jsonl(path: str) -> list[dict]:
    """Parse an events.jsonl; raises on a newer-schema header (readers must
    not silently misparse a future format), skips unparseable lines of a
    partially-written (crashed) log instead of dying."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except ValueError:
                continue  # truncated final line of a crashed writer
    for rec in records:
        if rec.get("kind") == "header" and rec.get("schema", 0) > SCHEMA_VERSION:
            raise ValueError(
                f"{path}: schema {rec['schema']} is newer than this reader "
                f"({SCHEMA_VERSION}) — upgrade repro.telemetry")
    return records
