"""Host-side gatherers over the live optimizer state.

``lowrank(telemetry=True)`` stores its in-jit measurements inside the
existing spectrum-probe dicts (``LowRankState.probes``) — this module reads
them out between steps and turns them into bus metrics:

  * :func:`lowrank_family_metrics` — per shape family: captured-energy
    fraction at rank r (sum of the top-r squared singular values of PᵀG over
    total gradient energy), projector drift since the previous refresh
    (1 − mean subspace overlap via the r×r Gram), the sampled per-step bias
    residual (1 − ‖PᵀG‖²/‖G‖²) with the step it was sampled at, and the
    current rank.
  * :class:`GammaSlotTracker` — the layerwise-unbias gamma-slot sampling
    distribution: which blocks the debiasing currently runs full-rank, plus
    cumulative per-block visit counts across refreshes (the paper's
    uniform-knowledge claim made observable).

Everything here is read-only over the state and runs on the host at
refresh-boundary cadence — nothing is traced, nothing recompiles.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _is_probe(x) -> bool:
    return isinstance(x, dict) and "sv2" in x and "g2" in x


def lowrank_family_metrics(opt_state: PyTree) -> list[dict]:
    """Per-(m, n) family telemetry read from the probe dicts; one record per
    shape family, averaged over same-shape leaves on the per-leaf path.
    Keys ``drift`` / ``bias`` / ``bias_step`` appear only when the state was
    built with ``lowrank(telemetry=True)``; energy/rank work with plain
    ``probe_spectrum=True`` probes too.  Empty list when no probes exist."""
    from repro.core.combinators import find_lowrank_states

    acc: dict[tuple[int, int], dict] = {}
    for st in find_lowrank_states(opt_state):
        if st.probes is None:
            continue
        for pr in jax.tree_util.tree_leaves(st.probes, is_leaf=_is_probe):
            if not _is_probe(pr):
                continue
            host = {k: np.asarray(jax.device_get(v)) for k, v in pr.items()}
            mn = (int(host["mn"][0]), int(host["mn"][1]))
            sv2 = host["sv2"].astype(np.float64)
            cur = acc.setdefault(mn, {
                "m": mn[0], "n": mn[1], "rank": int(sv2.shape[0]),
                "sv2_sum": 0.0, "g2": 0.0, "leaves": 0,
                "drift": 0.0, "bias": 0.0, "bias_step": -1,
                "has_telemetry": False,
            })
            cur["sv2_sum"] += float(sv2.sum())
            cur["g2"] += float(host["g2"])
            cur["leaves"] += 1
            if "drift" in host:
                cur["has_telemetry"] = True
                cur["drift"] += float(host["drift"])
                cur["bias"] += float(host["bias"])
                cur["bias_step"] = max(cur["bias_step"],
                                       int(host["bias_step"]))

    out = []
    for mn in sorted(acc):
        cur = acc[mn]
        n_leaves = max(cur["leaves"], 1)
        rec = {
            "family": f"{mn[0]}x{mn[1]}",
            "m": cur["m"], "n": cur["n"], "rank": cur["rank"],
            "energy": (cur["sv2_sum"] / cur["g2"]) if cur["g2"] > 0 else 0.0,
        }
        if cur["has_telemetry"]:
            rec["drift"] = cur["drift"] / n_leaves
            rec["bias"] = cur["bias"] / n_leaves
            rec["bias_step"] = cur["bias_step"]
        out.append(rec)
    return out


def find_unbias_states(state: PyTree) -> list:
    """Every :class:`~repro.core.combinators.LayerwiseUnbiasState` inside an
    optimizer state (they live *inside* LowRankState.inner, which the plain
    tuple walk passes through)."""
    from repro.core.combinators import LayerwiseUnbiasState

    found: list = []

    def walk(s):
        if isinstance(s, LayerwiseUnbiasState):
            found.append(s)
            return
        if isinstance(s, tuple):
            for c in s:
                walk(c)
        elif isinstance(s, dict):
            for c in s.values():
                walk(c)

    walk(state)
    return found


class GammaSlotTracker:
    """Cumulative histogram of layerwise-unbias gamma-slot assignments.

    Call :meth:`observe` at refresh boundaries; it reads the current
    slot→block index arrays out of every ``LayerwiseUnbiasState`` and folds
    them into per-leaf visit counts.  The returned records expose both the
    live assignment and the cumulative distribution (min/max/mean visits per
    block), so a skewed sampler — blocks that never take their full-rank
    turn — is visible in one event."""

    def __init__(self):
        # (unbias-state index, idx-leaf index) -> np.ndarray of visit counts
        self.counts: dict[tuple[int, int], np.ndarray] = {}
        self.observations = 0

    def observe(self, opt_state: PyTree) -> list[dict]:
        records = []
        states = find_unbias_states(opt_state)
        if not states:
            return records
        self.observations += 1
        for si, st in enumerate(states):
            idx_leaves = [l for l in jax.tree_util.tree_leaves(st.idx)
                          if l is not None]
            for li, idx in enumerate(idx_leaves):
                slots = np.asarray(jax.device_get(idx)).astype(int).ravel()
                key = (si, li)
                hist = self.counts.get(key)
                size = int(slots.max()) + 1 if slots.size else 0
                if hist is None or hist.shape[0] < size:
                    grown = np.zeros(max(size, 1), dtype=np.int64)
                    if hist is not None:
                        grown[: hist.shape[0]] = hist
                    hist = grown
                    self.counts[key] = hist
                np.add.at(hist, slots, 1)
                records.append({
                    "leaf": li,
                    "slots": [int(s) for s in slots],
                    "visits_min": int(hist.min()),
                    "visits_max": int(hist.max()),
                    "visits_mean": round(float(hist.mean()), 3),
                })
        return records


def launch_crosscheck(transform, params, *, name: str = "optimizer") -> dict:
    """Runtime launch-counter cross-check: trace the live transform's update
    through the dispatch layer's launch counter and diff the recorded counts
    against the closed-form model from :mod:`repro.analysis.launch_model`
    (PR 6) — the static auditor's contract asserted again on the *actual*
    chain about to train, as a telemetry event instead of a hard failure.
    Returns ``{expected, traced, ok, unmodeled}``; ``ok`` is False when the
    counts diverge or the model could not account for a stage (RA303)."""
    from repro.analysis.launch_model import expected_launches
    from repro.kernels import launch_count

    expected, findings = expected_launches(transform, params, name=name)
    state = jax.eval_shape(transform.init, params)
    with launch_count.count_launches() as counts:
        jax.make_jaxpr(
            lambda g, s, w: transform.update(g, s, w))(params, state, params)
    traced = dict(counts)
    return {
        "expected": expected,
        "traced": traced,
        "ok": not findings and traced == expected,
        "unmodeled": [f.code for f in findings],
    }
