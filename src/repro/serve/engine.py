"""Batched serving engine with continuous batching over the decode step.

vLLM-style slot scheduling on top of the framework's jit'd serve_step:

  * a fixed pool of B cache slots (the jit'd decode step has static shapes);
  * requests queue up; free slots are filled as soon as they open
    (continuous batching — no waiting for the whole batch to finish);
  * per-slot positions: each slot decodes at its own offset, so mixed-length
    requests coexist in one batch (the attention mask comes from per-slot
    lengths, handled by a per-slot position vector);
  * prefill is token-by-token through the same step (simple and exactly the
    serving kernel; a fused prefill path exists in launch/steps.py and can
    populate slots in one shot for attention archs).

The engine is deliberately model-agnostic: anything with decode_step +
init_cache works (all 9 decodable archs).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def done(self) -> bool:
        return self.finished_at > 0


class ServeEngine:
    """Continuous-batching scheduler around a single jit'd decode step.

    The decode step processes all B slots every tick; idle slots carry a
    pad token and their outputs are discarded.  Per-slot positions are a
    vector, so slots advance independently.
    """

    def __init__(self, model: Model, params, *, slots: int = 4, max_seq: int = 256,
                 pad_id: int = 0, greedy: bool = True):
        self.model = model
        self.params = params
        self.B = slots
        self.max_seq = max_seq
        self.pad_id = pad_id
        self.cache = model.init_cache(batch=slots, max_seq=max_seq,
                                      dtype=jnp.float32)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)       # next position to write
        self.slot_phase = ["idle"] * slots              # idle | prefill | decode
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._uid = 0

        axes = _cache_axes(self.cache)

        def step(params, cache, tokens, pos_vec):
            # per-slot positions: decode each slot at its own offset by
            # vmapping the single-slot decode over the cache batch axes.
            def one(p, c, t, pos):
                # re-insert the (vmapped-out) batch dim where the model
                # layout expects it, run a B=1 decode, slice it back out.
                c1 = jax.tree_util.tree_map(
                    lambda x, a: jnp.expand_dims(x, a), c, axes
                )
                lg, c1 = model.decode_step(p, cache=c1, tokens=t[None], pos=pos)
                c1 = jax.tree_util.tree_map(
                    lambda x, a: jnp.squeeze(x, a), c1, axes
                )
                return lg[0], c1

            return jax.vmap(one, in_axes=(None, axes, 0, 0),
                            out_axes=(0, axes))(params, cache, tokens, pos_vec)

        self._step = jax.jit(step, donate_argnums=(1,))

    # ------------------------------------------------------------ API

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> Request:
        self._uid += 1
        req = Request(uid=self._uid, prompt=list(prompt),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      submitted_at=time.time())
        self.queue.append(req)
        return req

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        """Drive until queue + slots drain (or tick budget)."""
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self._fill_slots()
            self._tick()
        return self.finished

    # ------------------------------------------------------------ internals

    def _fill_slots(self):
        for s in range(self.B):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[s] = req
                self.slot_pos[s] = 0
                self.slot_phase[s] = "prefill"

    def _tick(self):
        tokens = np.full((self.B, 1), self.pad_id, np.int32)
        pos = np.zeros(self.B, np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            p = int(self.slot_pos[s])
            if self.slot_phase[s] == "prefill":
                tokens[s, 0] = req.prompt[p]
            else:
                tokens[s, 0] = req.output[-1]
            pos[s] = p

        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos)
        )
        next_tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1))

        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.slot_pos[s] += 1
            p = int(self.slot_pos[s])
            if self.slot_phase[s] == "prefill":
                if p >= len(req.prompt):
                    self.slot_phase[s] = "decode"
                    req.output.append(int(next_tok[s]))
            else:
                req.output.append(int(next_tok[s]))
            out_done = len(req.output) >= req.max_new_tokens
            eos_done = req.eos_id is not None and req.output and req.output[-1] == req.eos_id
            if self.slot_phase[s] == "decode" and (out_done or eos_done or p >= self.max_seq - 1):
                req.finished_at = time.time()
                self.finished.append(req)
                self.slot_req[s] = None
                self.slot_phase[s] = "idle"


def _cache_axes(cache):
    """vmap in_axes pytree: the batch axis position per cache leaf.

    Cache layouts in this repo put batch right after the stacked-layer
    dims: axis 1 for (L, B, ...) leaves — KV (L,B,S,KV,hd), mamba conv
    (L,B,W-1,C), mamba ssm (L,B,H,N,P), vlm xk (G,B,T,KV,hd) — and axis 2
    for the moe_every>1 dense stack (G, per, B, S, KV, hd)."""
    def ax(x):
        return 2 if x.ndim >= 6 else 1

    return jax.tree_util.tree_map(ax, cache)
