"""Deterministic, shardable, resumable data pipeline.

The environment is offline, so the corpus source is a synthetic token stream
with C4-like statistics (Zipf-distributed unigrams + short-range structure so
models actually have something learnable).  Everything *around* the source is
production-real:

  * per-host sharding: host h of H reads only its slice of each global batch
  * deterministic skip-ahead: ``state = resume(step)`` is O(1) — a counter,
    not a replay — so checkpoint-restart is exact
  * sequence packing: documents are packed into fixed-length rows with EOS
    separators (no padding waste)
  * infinite iteration with per-epoch reshuffling via counter-based RNG
    (threefry keyed on (seed, step)) — no mutable RNG state to checkpoint
    beyond the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 32
    seed: int = 0
    eos_id: int = 1
    mean_doc_len: int = 256
    zipf_a: float = 1.2
    num_hosts: int = 1
    host_id: int = 0


class SyntheticLMStream:
    """Counter-based synthetic LM stream.  ``batch_at(step)`` is a pure
    function of (config, step) — the core of exact resume."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        # fixed Zipf unigram table (small, regenerated identically everywhere)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()
        self._step = 0

    # ---------------------------------------------------------- core

    def _rng(self, step: int, row: int) -> np.random.Generator:
        # counter-based: unique stream per (seed, step, global row index)
        gr = self.cfg.host_id * self.local_batch + row
        seq = np.random.SeedSequence([self.cfg.seed, step, gr])
        return np.random.Generator(np.random.Philox(seq))

    def _sample_doc(self, rng: np.random.Generator, length: int) -> np.ndarray:
        # Zipf unigrams + short-range repetition structure (bigram-ish):
        toks = rng.choice(self.cfg.vocab, size=length, p=self._probs)
        # repeat-previous with p=0.2 at lag 1..4 gives learnable local stats
        lag = rng.integers(1, 5, size=length)
        rep = rng.random(length) < 0.2
        for i in range(1, length):
            if rep[i] and i - lag[i] >= 0:
                toks[i] = toks[i - lag[i]]
        return toks

    def _pack_row(self, rng: np.random.Generator) -> np.ndarray:
        """Pack EOS-separated documents into one seq_len row."""
        cfg = self.cfg
        row = np.empty(cfg.seq_len, dtype=np.int32)
        pos = 0
        while pos < cfg.seq_len:
            dlen = int(rng.exponential(cfg.mean_doc_len)) + 8
            dlen = min(dlen, cfg.seq_len - pos)
            doc = self._sample_doc(rng, dlen)
            row[pos : pos + dlen] = doc
            pos += dlen
            if pos < cfg.seq_len:
                row[pos] = cfg.eos_id
                pos += 1
        return row

    def batch_at(self, step: int) -> np.ndarray:
        """The local (per-host) batch for a given global step."""
        return np.stack(
            [self._pack_row(self._rng(step, r)) for r in range(self.local_batch)]
        )

    # ---------------------------------------------------------- iteration

    def resume(self, step: int) -> "SyntheticLMStream":
        self._step = step
        return self

    @property
    def step(self) -> int:
        return self._step

    def __iter__(self) -> Iterator[np.ndarray]:
        return self

    def __next__(self) -> np.ndarray:
        b = self.batch_at(self._step)
        self._step += 1
        return b


def build_stream(cfg: DataConfig) -> SyntheticLMStream:
    return SyntheticLMStream(cfg)
