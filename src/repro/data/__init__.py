from .pipeline import DataConfig, SyntheticLMStream, build_stream

__all__ = ["DataConfig", "SyntheticLMStream", "build_stream"]
