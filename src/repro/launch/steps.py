"""train_step / serve_step builders + ShapeDtypeStruct input specs.

The same builders serve the real trainer and the multi-pod dry-run:
``input_specs`` returns weak-type-correct, shardable stand-ins (no device
allocation) for every model input of a given (arch, shape) cell.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import api as core_api
from repro.core.api import Transform, apply_updates, clip_by_global_norm
from repro.models.transformer import Model, init_cache
from repro.sharding import resolve_spec, validate_spec

PyTree = Any


# ---------------------------------------------------------------------------
# batch construction / specs
# ---------------------------------------------------------------------------


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for one global batch of this cell."""
    B, S = shape.global_batch, shape.seq_len
    out: dict[str, jax.ShapeDtypeStruct] = {}
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    elif cfg.frontend == "frames":
        out["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        out["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["images"] = jax.ShapeDtypeStruct((B, cfg.n_image_tokens, cfg.d_model), dt)
    return out


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    fsdp = resolve_spec(("fsdp",), mesh)[0]
    out = {}
    for k, s in batch_struct(cfg, shape).items():
        spec = (fsdp,) + (None,) * (len(s.shape) - 1)
        out[k] = NamedSharding(mesh, validate_spec(s.shape, P(*spec), mesh))
    return out


def cache_struct(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    )


def cache_shardings(cache: PyTree, cfg: ModelConfig, mesh: Mesh) -> PyTree:
    """KV caches: batch over fsdp, cache-seq over the model axis (sequence-
    sharded cache — DESIGN.md §5); mamba states: heads/channels over tp."""
    from repro.core.api import tree_paths

    fsdp = resolve_spec(("fsdp",), mesh)[0]
    tp = resolve_spec(("tp",), mesh)[0]
    paths = tree_paths(cache)

    def one(path, x):
        nd = len(x.shape)
        leaf = path.rsplit("/", 1)[-1]
        spec = [None] * nd
        if leaf in ("k", "v", "xk", "xv"):
            # (..., B, S, KV, hd): batch -> fsdp, cache-seq -> model
            spec[-4] = fsdp
            spec[-3] = tp
        elif leaf == "conv":
            spec[-3] = fsdp  # (L, B, W-1, C): batch
            spec[-1] = tp
        elif leaf == "ssm":
            spec[-4] = fsdp  # (L, B, H, N, P): batch, heads
            spec[-3] = tp
        return NamedSharding(mesh, validate_spec(x.shape, P(*spec), mesh))

    return jax.tree_util.tree_map(one, paths, cache)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def _loss_from_batch(model: Model, params, batch, cfg: ModelConfig):
    from repro.models.transformer import chunked_lm_loss

    kwargs = {}
    if "images" in batch:
        kwargs["images"] = batch["images"]
    if cfg.frontend == "frames":
        inputs, targets, shift = None, batch["targets"], False
        kwargs["frames"] = batch["frames"]
    else:
        inputs, targets, shift = batch["tokens"], batch["tokens"], True

    if cfg.logit_chunk > 0:
        hidden, aux, _ = model.forward(params, inputs, return_hidden=True, **kwargs)
        return chunked_lm_loss(params, cfg, hidden, targets, aux, shift=shift)
    logits, aux, _ = model.forward(params, inputs, **kwargs)
    return model.loss(logits, targets, aux, shift=shift)


def make_train_step(
    model: Model,
    optimizer: Transform,
    *,
    grad_clip: float = 0.0,
    microbatches: int = 1,
    lowrank_accum=None,
    fault_gate=None,
    extra_metrics: bool = False,
):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``optimizer`` is any :class:`repro.core.api.Transform` — in practice a
    combinator chain from :mod:`repro.core.combinators` (built by
    ``build_optimizer`` or composed by hand, e.g.
    ``chain(lowrank(layerwise_unbias(scale_by_muon())), scale_by_lr(lr))``).

    ``microbatches > 1`` runs gradient accumulation via lax.scan over
    microbatch slices (fp32 accumulator), preserving the global batch size.

    ``lowrank_accum`` (a :class:`repro.core.gum.GUMAccumTools`) switches the
    accumulator to the PROJECTED space (beyond-paper): low-rank families
    accumulate Pᵀ G (+ the gamma sampled full blocks) instead of full-shape
    fp32 gradients — update-equivalent by Property I (see gum.py).  The
    tools' project/reconstruct and the refresh hook run through the same
    kernel dispatch layer as the optimizer itself (``kernel_impl`` /
    ``pad_rank_to`` are threaded in by the caller, e.g. launch/dryrun.py),
    so accumulating steps lower the same hot path as plain training.

    **Fault tolerance — the in-jit NaN/Inf guard (resilience rung 0).**
    Buffers are donated to the jitted step, so by the time the host sees a
    bad loss the old params/opt_state are gone — a non-finite loss or
    gradient therefore has to be neutralized *inside* the step: the guard
    zeroes the gradients AND the emitted updates and reverts every
    optimizer-state array to its pre-step value (``jnp.where(finite, ...)``
    elementwise), so a poisoned step is a pure no-op that still returns a
    metrics dict (``update_applied=False``).  The low-rank step counter
    does not advance on a skipped step, which keeps projector-refresh and
    rank-policy boundaries aligned with *applied* updates.  Detection and
    escalation beyond rung 0 (loss spikes, subspace collapse, rollback /
    restore) live host-side in :mod:`repro.resilience` — see the README
    "Resilience" section for the full fault→detector→recovery table.

    ``fault_gate`` (a :class:`repro.resilience.inject.FaultGate`) compiles a
    traced gradient-corruption gate into the step: the returned function
    takes a fourth argument ``fault = {"mode": int32, "scale": float32}``
    and corrupts the raw gradients pre-clip (mode 0 is elementwise-identical
    to the stock step — arming a fault is a host value, not a recompile).

    ``extra_metrics=True`` adds the health monitor's in-jit signals:
    ``grad_norm_raw`` (pre-clip — reused as the clip's own norm, so it is
    free when ``grad_clip`` is on), ``update_norm`` (global norm of the
    applied parameter delta) and ``update_norm_lowrank`` (the same norm
    restricted to the leaves ``default_lowrank_filter`` routes through the
    low-rank stage — the dead-subspace detector's signal).  Both update
    norms share one fused subtract-square-reduce pass over the delta, so
    the whole monitor costs a single extra pass per step.
    """
    cfg = model.cfg

    def single_grad(params, batch):
        return jax.value_and_grad(lambda p: _loss_from_batch(model, p, batch, cfg))(params)

    if lowrank_accum is not None and microbatches > 1:
        if fault_gate is not None:
            raise NotImplementedError(
                "fault injection is not wired into the projected-space "
                "accumulation step")
        return _make_lowrank_accum_step(
            model, lowrank_accum, single_grad, grad_clip, microbatches
        )

    def _step(params, opt_state, batch, fault):
        if microbatches > 1:
            def slice_mb(x):
                B = x.shape[0]
                return x.reshape((microbatches, B // microbatches) + x.shape[1:])

            mb = jax.tree_util.tree_map(slice_mb, batch)
            # Seed the fp32 accumulator from the first microbatch's real
            # gradients so the accumulator inherits the gradients' sharding
            # (a fresh zeros tree can end up replicated under GSPMD).
            first = jax.tree_util.tree_map(lambda x: x[0], mb)
            loss0, g0 = single_grad(params, first)
            acc0 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), g0)

            def acc_body(carry, mbatch):
                loss_acc, grad_acc = carry
                loss, g = single_grad(params, mbatch)
                grad_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), grad_acc, g
                )
                return (loss_acc + loss, grad_acc), None

            rest = jax.tree_util.tree_map(lambda x: x[1:], mb)
            (loss, grads), _ = jax.lax.scan(acc_body, (loss0, acc0), rest)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        else:
            loss, grads = single_grad(params, batch)

        if fault_gate is not None:
            grads = fault_gate.apply(grads, fault)
        if extra_metrics:
            # The clip computes this exact reduction internally; doing it
            # here and clipping inline keeps grad_norm_raw free of an extra
            # pass over the gradients (bit-identical to clip_by_global_norm).
            gnorm_raw = core_api.global_norm(grads)
            if grad_clip > 0:
                scale = jnp.minimum(1.0, grad_clip / (gnorm_raw + 1e-12))
                grads = jax.tree_util.tree_map(
                    lambda g: g * scale.astype(g.dtype), grads)
        else:
            gnorm_raw = None
            if grad_clip > 0:
                grads = clip_by_global_norm(grads, grad_clip)

        # NaN/Inf guard — resilience rung 0; see the docstring above.
        gnorm = core_api.global_norm(grads)
        finite = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        grads = jax.tree_util.tree_map(
            lambda g: jnp.where(finite, g, jnp.zeros_like(g)), grads
        )
        updates, new_opt_state = optimizer.update(grads, opt_state, params)
        updates = jax.tree_util.tree_map(
            lambda u: None if u is None else jnp.where(finite, u, jnp.zeros_like(u)),
            updates,
            is_leaf=lambda x: x is None,
        )
        opt_state = jax.tree_util.tree_map(
            lambda new, old: jnp.where(finite, new, old) if hasattr(new, "shape") else new,
            new_opt_state, opt_state,
        )
        new_params = apply_updates(params, updates)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": gnorm,
                   "update_applied": finite}
        if extra_metrics:
            from repro.core import default_lowrank_filter

            metrics["grad_norm_raw"] = gnorm_raw
            # One fused subtract-square-reduce pass per leaf; both norms
            # combine the same per-leaf partial sums.
            delta_sq = jax.tree_util.tree_map(
                lambda a, b: jnp.sum(jnp.square((a - b).astype(jnp.float32))),
                new_params, params)
            metrics["update_norm"] = jnp.sqrt(
                sum(jax.tree_util.tree_leaves(delta_sq)))
            # Restricted to the leaves the low-rank stage treats
            # (default_lowrank_filter): a dead subspace zeroes exactly these
            # while embeddings/norms keep updating, so the global norm would
            # mask the collapse.
            lr_paths = core_api.tree_paths(new_params)
            lr_sq = jax.tree_util.tree_map(
                lambda p, s, a: s if default_lowrank_filter(p, a)
                else jnp.zeros((), s.dtype),
                lr_paths, delta_sq, new_params)
            metrics["update_norm_lowrank"] = jnp.sqrt(
                sum(jax.tree_util.tree_leaves(lr_sq)))
        return new_params, opt_state, metrics

    if fault_gate is not None:
        def train_step(params, opt_state, batch, fault):
            return _step(params, opt_state, batch, fault)
    else:
        def train_step(params, opt_state, batch):
            return _step(params, opt_state, batch, None)

    return train_step


def _make_lowrank_accum_step(model, tools, single_grad, grad_clip, microbatches):
    def train_step(params, opt_state, batch):
        def slice_mb(x):
            B = x.shape[0]
            return x.reshape((microbatches, B // microbatches) + x.shape[1:])

        mb = jax.tree_util.tree_map(slice_mb, batch)
        first = jax.tree_util.tree_map(lambda x: x[0], mb)

        # microbatch 0: raw grads -> (cond'd) projector refresh -> project
        loss0, g0 = single_grad(params, first)
        opt_state = tools.refresh(g0, opt_state, params)
        acc0 = tools.project(g0, opt_state, params)

        def body(carry, mbatch):
            loss_acc, acc = carry
            loss, g = single_grad(params, mbatch)
            c = tools.project(g, opt_state, params)
            acc = jax.tree_util.tree_map(
                lambda a, b: a if b is None else a + b, acc, c,
                is_leaf=lambda x: x is None,
            )
            return (loss_acc + loss, acc), None

        rest = jax.tree_util.tree_map(lambda x: x[1:], mb)
        (loss, acc), _ = jax.lax.scan(body, (loss0, acc0), rest)
        loss = loss / microbatches
        acc = jax.tree_util.tree_map(
            lambda a: a / microbatches, acc
        )
        grads = tools.reconstruct(acc, opt_state, params)
        if grad_clip > 0:
            grads = clip_by_global_norm(grads, grad_clip)
        gnorm = core_api.global_norm(grads)
        finite = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        grads = jax.tree_util.tree_map(
            lambda g: jnp.where(finite, g, jnp.zeros_like(g)), grads
        )
        updates, new_opt_state = tools.transform.update(grads, opt_state, params)
        updates = jax.tree_util.tree_map(
            lambda u: None if u is None else jnp.where(finite, u, jnp.zeros_like(u)),
            updates,
            is_leaf=lambda x: x is None,
        )
        opt_state = jax.tree_util.tree_map(
            lambda new, old: jnp.where(finite, new, old) if hasattr(new, "shape") else new,
            new_opt_state, opt_state,
        )
        params = apply_updates(params, updates)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "update_applied": finite}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model):
    """Forward pass producing logits + populated KV cache (inference prefill)."""
    cfg = model.cfg

    def prefill_step(params, batch):
        kwargs = {}
        if "images" in batch:
            kwargs["images"] = batch["images"]
        want_cache = cfg.family in ("dense", "moe", "vlm")
        if cfg.frontend == "frames":
            logits, _, cache = model.forward(
                params, frames=batch["frames"], return_cache=want_cache, **kwargs
            )
        else:
            logits, _, cache = model.forward(
                params, batch["tokens"], return_cache=want_cache, **kwargs
            )
        return logits, cache

    return prefill_step


def make_serve_step(model: Model):
    """One decode step: (params, cache, tokens (B,1), pos) -> (logits, cache)."""

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache=cache, tokens=tokens, pos=pos)

    return serve_step
