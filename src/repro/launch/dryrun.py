"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a process entry point (``python -m repro.launch.dryrun``) —
the first import below forces 512 placeholder host devices (via the shared
:func:`repro.launch.devices.force_host_device_count` helper, which preserves
any other ``XLA_FLAGS``) BEFORE jax initializes, so ``make_production_mesh``
can build the production meshes.

Per cell this script:
  1. builds the model + GUM optimizer (the paper's technique, first-class),
  2. lowers the appropriate step (train_step / prefill / serve_step) with
     explicit in/out shardings on the requested mesh,
  3. ``.compile()``s it (proving the distribution config is coherent),
  4. records memory_analysis / cost_analysis / the 3 roofline terms parsed
     from the post-SPMD HLO into a JSON next to EXPERIMENTS.md.
"""
from repro.launch.devices import force_host_device_count

force_host_device_count(512, verify=False)  # before jax backend init

import argparse  # noqa: E402
import json  # noqa: E402
import os  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import all_cells, cell_supported, get_config, get_shape  # noqa: E402
from repro.core import OptimizerConfig, build_optimizer  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import (  # noqa: E402
    ICI_BW,
    ICI_LINKS,
    HBM_BW,
    PEAK_FLOPS,
    model_flops,
    roofline_from_text,
    xla_cost_dict,
)
from repro.launch.steps import (  # noqa: E402
    batch_shardings,
    batch_struct,
    cache_shardings,
    cache_struct,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models import build_model  # noqa: E402
from repro.sharding import named_sharding_tree, opt_state_sharding, use_mesh  # noqa: E402

# Per-arch gradient-accumulation factors for train_4k so activations fit HBM
# (chosen from memory_analysis iterations; see EXPERIMENTS.md §Dry-run).
TRAIN_MICROBATCHES = {
    "nemotron-4-340b": 8,
    "llama4-maverick-400b-a17b": 4,
    "dbrx-132b": 4,
    "llama-3.2-vision-11b": 2,
    "starcoder2-7b": 2,
}


def default_optimizer(arch: str, kernel_impl: str = "auto",
                      pad_rank_to: int = 0, fuse_families: bool = False,
                      fused_epilogue: bool = False,
                      rank_policy: str | None = None,
                      rank_ladder: tuple[int, ...] = (),
                      telemetry: bool = False) -> OptimizerConfig:
    # GUM (the paper's method) with the TPU-native subspace projector.
    # kernel_impl is threaded into the compiled cell so dry runs lower the
    # SAME hot path as training ("pallas" forces the fused kernels into the
    # HLO even on the host-CPU placeholder devices); the fusion knobs do the
    # same for the family-stacked engine; a rank policy lowers the cell at
    # the policy's INITIAL RankMap (rank changes re-lower per ladder rank).
    return OptimizerConfig(
        name="gum", lr=1e-3, rank=128, gamma=2, period=200,
        projector="subspace", base="muon", kernel_impl=kernel_impl,
        pad_rank_to=pad_rank_to, fuse_families=fuse_families,
        fused_epilogue=fused_epilogue, rank_policy=rank_policy,
        rank_ladder=rank_ladder, telemetry=telemetry,
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, opt_name: str = "gum",
             overrides: dict | None = None, microbatches: int | None = None,
             lowrank_accum: bool = False, kernel_impl: str = "auto",
             pad_rank_to: int = 0, fuse_families: bool = False,
             fused_epilogue: bool = False, rank_policy: str | None = None,
             rank_ladder: tuple[int, ...] = (), audit: bool = False,
             telemetry: bool = False):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = get_shape(shape_name)
    ok, reason = cell_supported(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "optimizer": opt_name, "status": "skipped", "reason": reason,
    }
    if not ok:
        return result

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = build_model(cfg)
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_sh = named_sharding_tree(params_struct, mesh)

    with use_mesh(mesh):
        if shape.kind == "train":
            ocfg = default_optimizer(arch, kernel_impl, pad_rank_to,
                                     fuse_families, fused_epilogue,
                                     rank_policy, rank_ladder, telemetry)
            if opt_name != "gum":
                ocfg = OptimizerConfig(name=opt_name, rank=128, gamma=2,
                                       period=200, projector="subspace",
                                       kernel_impl=kernel_impl,
                                       pad_rank_to=pad_rank_to,
                                       fuse_families=fuse_families,
                                       fused_epilogue=fused_epilogue,
                                       rank_policy=rank_policy,
                                       rank_ladder=rank_ladder,
                                       telemetry=telemetry)
            tools = None
            if lowrank_accum:
                from repro.core.gum import gum_accum_tools

                tools = gum_accum_tools(
                    ocfg.lr, rank=ocfg.rank, gamma=ocfg.gamma,
                    period=ocfg.period, projector=ocfg.projector,
                    kernel_impl=ocfg.kernel_impl,
                    pad_rank_to=ocfg.pad_rank_to,
                    fuse_families=ocfg.fuse_families,
                    fused_epilogue=ocfg.fused_epilogue,
                )
                opt = tools.transform
            else:
                opt = build_optimizer(ocfg)
            audit_report = None
            if audit:
                # Full static audit of this cell's optimizer over the real
                # model's param structs (chain lint, launch model vs traced
                # dispatch counts, dtype flow, recompile hazards) — abstract
                # tracing only, before the expensive XLA compile below.
                # The buffer pass (donation / replication) is appended after
                # the lowering exists.
                from repro.analysis import audit_optimizer

                audit_report = audit_optimizer(ocfg, params_struct,
                                               ladder=ocfg.rank_ladder)
                result["audit"] = audit_report.to_json()
                print("  " + audit_report.format().replace("\n", "\n  "),
                      flush=True)
            opt_struct = jax.eval_shape(opt.init, params_struct)
            opt_sh = opt_state_sharding(opt_struct, mesh)
            batch = batch_struct(cfg, shape)
            batch_sh = batch_shardings(cfg, shape, mesh)
            mb = microbatches or TRAIN_MICROBATCHES.get(arch, 1)
            step = make_train_step(model, opt, grad_clip=1.0, microbatches=mb,
                                   lowrank_accum=tools)
            jit_step = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jit_step.lower(params_struct, opt_struct, batch)
            result["microbatches"] = mb
            if audit_report is not None:
                # Buffer-lifetime pass on the lowered module: donated
                # params/opt_state must alias outputs (RA604) and the batch
                # must actually be sharded, not replicated per device
                # (RA605) — the lowering is already paid, so this is free.
                from repro.analysis import (
                    donation_findings,
                    parse_main_args,
                    replication_findings,
                )

                infos = parse_main_args(lowered.as_text())
                n_p = len(jax.tree_util.tree_leaves(params_struct))
                n_o = len(jax.tree_util.tree_leaves(opt_struct))
                cell = f"{arch}/{shape_name}"
                buf_findings = donation_findings(
                    infos, n_params=n_p, n_opt=n_o, where=cell)
                buf_findings += replication_findings(
                    infos, n_params=n_p, n_opt=n_o, n_shards=chips,
                    where=cell)
                audit_report.extend(buf_findings)
                from repro.sharding import per_shard_bytes

                audit_report.summary["buffers"] = {
                    "donated_args": sum(a.aliased for a in infos),
                    "expected_donated": n_p + n_o,
                    "total_args": len(infos),
                    # static per-shard (not per-replica) footprint under the
                    # param rules — the number RA605 keeps honest
                    "params_bytes_per_shard": per_shard_bytes(
                        params_struct, mesh),
                    "opt_state_bytes_per_shard": per_shard_bytes(
                        opt_struct, mesh),
                }
                result["audit"] = audit_report.to_json()
                print(f"  buffers: donated "
                      f"{audit_report.summary['buffers']['donated_args']}"
                      f"/{n_p + n_o} args alias outputs", flush=True)
                for f in buf_findings:
                    print("  " + f.format().replace("\n", "\n  "),
                          flush=True)
        elif shape.kind == "prefill":
            batch = batch_struct(cfg, shape)
            batch_sh = batch_shardings(cfg, shape, mesh)
            step = make_prefill_step(model)
            jit_step = jax.jit(step, in_shardings=(param_sh, batch_sh))
            lowered = jit_step.lower(params_struct, batch)
        else:  # decode
            cache = cache_struct(cfg, shape)
            cache_sh = cache_shardings(cache, cfg, mesh)
            tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            tok_sh = batch_shardings(cfg, shape, mesh)["tokens"]
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            step = make_serve_step(model)
            jit_step = jax.jit(
                step,
                in_shardings=(param_sh, cache_sh, tok_sh, None),
                out_shardings=None,
                donate_argnums=(1,),
            )
            lowered = jit_step.lower(params_struct, cache, tokens, pos)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_info = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_info[attr] = int(v)
        # cost_analysis() is a dict on old JAX, a list-of-dicts on newer.
        cost = xla_cost_dict(compiled)

        mf = model_flops(cfg, shape) / chips
        report = roofline_from_text(compiled.as_text(), model_flops_per_device=mf)

    result.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=mem_info,
        xla_cost={k: float(v) for k, v in cost.items()
                  if k in ("flops", "bytes accessed", "transcendentals")},
        roofline=report.to_dict(),
        hw={"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
            "ici_bw": ICI_BW, "ici_links": ICI_LINKS},
    )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--opt", default="gum")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for result filenames")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--lowrank-accum", action="store_true",
                    help="accumulate microbatch grads in projected space")
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "jnp", "pallas", "interpret"],
                    help="optimizer hot-loop impl threaded into the compiled "
                         "cell (OptimizerConfig.kernel_impl) so dry runs "
                         "lower the same hot path as training")
    ap.add_argument("--pad-rank-to", type=int, default=0,
                    help="opt-in lane-aligned rank padding for the low-rank "
                         "Pallas kernels (e.g. 128)")
    ap.add_argument("--fuse-families", action="store_true",
                    help="family-stacked fused optimizer execution (one "
                         "batched launch per shape family)")
    ap.add_argument("--fused-epilogue", action="store_true",
                    help="fold chain-tail epilogues into the back-projection "
                         "GEMM (back_project_epilogue kernel)")
    ap.add_argument("--rank-policy", default=None,
                    help="rank-policy spec (repro.core.rank_policy) — the "
                         "cell lowers at the policy's initial RankMap, e.g. "
                         "'spectral:0.99' or 'family:1024x4096=64'")
    ap.add_argument("--rank-ladder", default="",
                    help="comma-separated ladder for adaptive policies, "
                         "e.g. 32,64,128")
    ap.add_argument("--audit", action="store_true",
                    help="run the repro.analysis static audit on each train "
                         "cell's optimizer (findings land in the result "
                         "JSON under 'audit')")
    ap.add_argument("--telemetry", action="store_true",
                    help="lower each train cell with the in-jit telemetry "
                         "instrumentation compiled in "
                         "(OptimizerConfig.telemetry) and write per-cell "
                         "lower/compile spans + memory metrics to "
                         "<out>/dryrun_events.jsonl — span/metric summaries "
                         "for giant configs without executing a real run")
    ap.add_argument(
        "--set", action="append", default=[],
        help="ModelConfig overrides, e.g. --set attn_impl=xla_chunked "
             "--set logit_chunk=512 --set remat_policy=dots",
    )
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "False"):
            v = v == "True"
        overrides[k] = v

    if args.list:
        for a, s in all_cells():
            cfg, shape = get_config(a), get_shape(s)
            ok, reason = cell_supported(cfg, shape)
            print(f"{a:28s} {s:12s} {'RUN' if ok else 'SKIP: ' + reason}")
        return

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    tele = None
    if args.telemetry:
        from repro.telemetry import JsonlSink, Telemetry

        tele = Telemetry(
            [JsonlSink(os.path.join(args.out, "dryrun_events.jsonl"))],
            run={"mode": "dryrun", "opt": args.opt, "mesh": args.mesh})

    for arch, shape in cells:
        for multi_pod in meshes:
            mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
            tag = f"{arch}__{shape}__{mesh_name}__{args.opt}"
            if args.tag:
                tag += f"__{args.tag}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[cached] {tag}")
                continue
            print(f"[run] {tag}", flush=True)
            try:
                res = run_cell(arch, shape, multi_pod, args.opt,
                               overrides=overrides or None,
                               microbatches=args.microbatches or None,
                               lowrank_accum=args.lowrank_accum,
                               kernel_impl=args.kernel_impl,
                               pad_rank_to=args.pad_rank_to,
                               fuse_families=args.fuse_families,
                               fused_epilogue=args.fused_epilogue,
                               rank_policy=args.rank_policy,
                               rank_ladder=tuple(
                                   int(r) for r in args.rank_ladder.split(",")
                                   if r),
                               audit=args.audit,
                               telemetry=args.telemetry)
                res["overrides"] = overrides
                res["tag"] = args.tag
                if tele is not None and res["status"] == "ok":
                    tele.record_span("lower", res["lower_s"], cell=tag)
                    tele.record_span("compile", res["compile_s"], cell=tag)
                    for k, v in (res.get("memory") or {}).items():
                        tele.metric(0, f"memory.{k}", v, cell=tag)
                    tele.event("cell", f"dryrun: {tag} ok", cell=tag)
            except Exception as e:  # record failures — they are bugs to fix
                res = {
                    "arch": arch, "shape": shape, "mesh": mesh_name,
                    "optimizer": args.opt, "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-4000:],
                }
            with open(path, "w") as f:
                json.dump(res, f, indent=2)
            print(f"  -> {res['status']}"
                  + (f" ({res.get('error','')[:200]})" if res["status"] == "error" else "")
                  + (f" compile={res.get('compile_s')}s" if res["status"] == "ok" else ""),
                  flush=True)
    if tele is not None:
        tele.close()


if __name__ == "__main__":
    main()
