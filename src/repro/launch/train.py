"""Training launcher: ``python -m repro.launch.train --arch llama-130m ...``

Runs a real training loop on whatever devices exist (CPU here, TPU pod in
production — the mesh flag switches pjit on).  For the production meshes use
dryrun.py first to verify the cell compiles and fits.

``--audit`` runs the full static audit before step 0 (chain lint, launch
model, dtype flow, recompile hazards, and — when ``--mesh`` is set — the
sharded collective-schedule and donation/buffer passes) and exits non-zero
on any error finding, so a misconfigured launch dies before it burns a
single step.  ``--mesh data=8`` trains pjit'ed over a data mesh, forcing
host CPU devices when the backend has fewer.
"""
from __future__ import annotations

import argparse
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--opt", default="gum")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--rank", type=int, default=128)
    ap.add_argument("--gamma", type=int, default=2)
    ap.add_argument("--period", type=int, default=200)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "jnp", "pallas", "interpret"],
                    help="optimizer hot-loop implementation "
                         "(OptimizerConfig.kernel_impl): auto = fused Pallas "
                         "kernels on TPU, jnp reference elsewhere")
    ap.add_argument("--pad-rank-to", type=int, default=0,
                    help="opt-in lane-aligned rank padding for the low-rank "
                         "Pallas kernels (e.g. 128)")
    ap.add_argument("--fuse-families", action="store_true",
                    help="family-stacked fused optimizer execution: one "
                         "batched launch per shape family instead of one "
                         "per parameter leaf (trajectory-identical)")
    ap.add_argument("--shard-state", action="store_true",
                    help="ZeRO-style sharding of the family-stacked low-rank "
                         "optimizer state over the data axis (requires "
                         "--fuse-families and --mesh): steady steps stay "
                         "fully sharded; full gradients are gathered only "
                         "at projector-refresh boundaries")
    ap.add_argument("--fused-epilogue", action="store_true",
                    help="fold chain-tail epilogues (-lr, weight decay) into "
                         "the back-projection GEMM (back_project_epilogue "
                         "kernel; not bit-exact vs the unfused tail; applies "
                         "to galore-family optimizers — inert for gum/fira, "
                         "whose inners emit full-shape updates)")
    ap.add_argument("--rank-policy", default=None,
                    help="time-varying / per-family rank "
                         "(repro.core.rank_policy): 'fixed:64', "
                         "'stepwise:0=128,500=64', 'family:512x512=32,...', "
                         "'spectral[:target_energy]' — decisions land on "
                         "projector-refresh boundaries; the trainer migrates "
                         "optimizer state and re-jits (bounded by the ladder); "
                         "policy state rides in checkpoint extras so resume "
                         "is exact across rank changes")
    ap.add_argument("--rank-ladder", default="",
                    help="comma-separated ranks an adaptive policy may emit, "
                         "e.g. 32,64,128 (bounds recompilation; empty = "
                         "powers of two up to --rank)")
    ap.add_argument("--mesh", default="", metavar="AXIS=N",
                    help="train pjit'ed over a data mesh, e.g. data=8 "
                         "(forces host CPU devices when the backend has "
                         "fewer; production passes the real device mesh)")
    ap.add_argument("--resilience", nargs="?", const="", default=None,
                    metavar="SPEC",
                    help="turn on the health monitor + recovery ladder "
                         "(repro.resilience): bare flag = defaults, or a "
                         "knob spec like 'ring=3,snapshot_every=5,spike_z=4' "
                         "(any ResilienceConfig field)")
    ap.add_argument("--inject", default=None, metavar="PLAN",
                    help="deterministic fault injection (requires/implies "
                         "nothing about --resilience; combine them to "
                         "exercise recovery): 'kind@step[*scale][#arg];...' "
                         "e.g. 'grad_nan@5;grad_spike@9*1e6;refresh_zero@13;"
                         "ckpt_bitflip@20;kill_save@40#3'")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="seed for the fault plan's corruption RNG "
                         "(bit positions etc.)")
    ap.add_argument("--telemetry", nargs="?", const="", default=None,
                    metavar="SPEC",
                    help="turn on the telemetry run log (repro.telemetry): "
                         "bare flag = defaults, or a knob spec like "
                         "'every=10,stdout=0,memory=256' (any "
                         "TelemetryConfig field).  One run writes one "
                         "schema-versioned events.jsonl (step metrics, "
                         "health/recovery/fault/rank-policy/checkpoint "
                         "events, timing spans) plus in-jit subspace "
                         "instrumentation (captured energy, projector "
                         "drift, sampled bias residual); summarize with "
                         "python -m repro.telemetry.report")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="events.jsonl path override "
                         "(default <ckpt-dir>/events.jsonl)")
    ap.add_argument("--profile-steps", default=None, metavar="A:B",
                    help="jax.profiler trace window covering steps [A, B), "
                         "written under <ckpt-dir>/profile")
    ap.add_argument("--audit", action="store_true",
                    help="run the full static audit — including the sharded "
                         "collective/buffer passes when --mesh is set — "
                         "before step 0, exiting non-zero on any error "
                         "finding (parity with dryrun.py --audit)")
    args = ap.parse_args()

    # device forcing must precede the first jax backend use below
    mesh_axes = None
    if args.mesh:
        from repro.analysis.audit import _parse_mesh
        from repro.launch.devices import force_host_device_count

        mesh_axes = _parse_mesh(args.mesh)
        total = 1
        for _, size in mesh_axes:
            total *= size
        force_host_device_count(total)

    import jax

    from repro.configs import RunConfig, get_config, get_smoke
    from repro.core import OptimizerConfig
    from repro.data import DataConfig
    from repro.models import build_model
    from repro.train import Trainer

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(
        name=args.opt, lr=args.lr, rank=args.rank, gamma=args.gamma,
        period=args.period, kernel_impl=args.kernel_impl,
        pad_rank_to=args.pad_rank_to,
        fuse_families=args.fuse_families or args.shard_state,
        fused_epilogue=args.fused_epilogue,
        shard_state=args.shard_state,
        rank_policy=args.rank_policy,
        rank_ladder=tuple(int(r) for r in args.rank_ladder.split(",") if r),
        telemetry=args.telemetry is not None,
    )
    run_cfg = RunConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir, resume=not args.no_resume,
        ckpt_every=max(args.steps // 4, 1), log_every=10,
    )
    data_cfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        num_hosts=jax.process_count(), host_id=jax.process_index(),
    )

    mesh = None
    if mesh_axes is not None:
        sizes = tuple(size for _, size in mesh_axes)
        names = tuple(axis for axis, _ in mesh_axes)
        mesh = jax.make_mesh(sizes, names)

    if args.audit:
        # The full static audit of exactly what is about to train, before
        # step 0: chain lint + launch model + dtype flow + recompile pass on
        # the optimizer, and — when a mesh is configured — the sharded
        # collective-schedule / donation / per-shard-buffer passes.  Any
        # error finding aborts the launch (parity with dryrun.py --audit).
        from repro.analysis import audit_optimizer, audit_sharded

        params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        reports = [audit_optimizer(opt_cfg, params_abs,
                                   ladder=opt_cfg.rank_ladder)]
        if mesh_axes is not None:
            reports.append(audit_sharded(
                opt_cfg, model=model, mesh_axes=mesh_axes,
                grad_clip=run_cfg.grad_clip,
                batch_size=args.batch))
        for rep in reports:
            print(rep.format(), flush=True)
        if not all(rep.ok for rep in reports):
            print("audit: error finding(s) before step 0 — not training",
                  flush=True)
            sys.exit(1)

    inject = None
    if args.inject:
        from repro.resilience import FaultPlan

        inject = FaultPlan.parse(args.inject, seed=args.inject_seed)

    trainer = Trainer(model, opt_cfg, run_cfg, data_cfg, mesh=mesh,
                      microbatches=args.microbatches,
                      resilience=args.resilience, inject=inject,
                      telemetry=args.telemetry, events_out=args.events_out,
                      profile_steps=args.profile_steps)
    result = trainer.train()
    print(
        f"done: step={result.final_step} "
        f"first_loss={result.losses[0]:.4f} last_loss={result.losses[-1]:.4f} "
        f"skipped={result.skipped_nonfinite} stragglers={len(result.straggler_steps)}"
        + (f" resumed_from={result.resumed_from}" if result.resumed_from else "")
    )
    if result.recovery_counts:
        fired = {k: v for k, v in result.recovery_counts.items() if v}
        print(f"resilience: recoveries={fired or '{}'} "
              f"health_events={len(result.health_events)} "
              f"faults_fired={len(result.fault_log)}")
    if result.events_path:
        # train() already emitted the closing counters record; only the
        # sink handles remain, and process exit covers those.
        print(f"telemetry: {result.events_path} "
              f"(python -m repro.telemetry.report {args.ckpt_dir})")


if __name__ == "__main__":
    main()
