"""Manual-collective FSDP train step via shard_map (beyond-paper §Perf).

The GSPMD findings in EXPERIMENTS.md §Perf: (a) the gradient all-reduce is
pinned at fp32 because the accumulator's convert fuses into the AR producer,
and (b) Megatron-style sequence parallelism cannot be expressed with
constraints alone.  Both need MANUAL collectives.  This module provides the
shard_map data-parallel step with explicit control of the reduction dtype:

  * params live fully replicated inside the per-shard body (pure-DP FSDP
    variant: the weight all-gather is done once by the caller's sharding);
  * each data shard computes LOCAL gradients (no automatic psum — the loss
    is per-shard mean);
  * gradients are cast to **bf16 BEFORE the cross-shard reduction**
    (`jax.lax.psum` on bf16 = half the wire bytes of the GSPMD fp32 AR),
    then accumulated into fp32 for the optimizer.

For a (data,)-sharded mesh this is exact data parallelism with a 2x cheaper
gradient reduction; numerics change only by bf16 rounding of the per-shard
gradient (the same trade every bf16-reduce production stack makes).
Correctness vs the pjit step is asserted in tests/test_shardmap_fsdp.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.api import Transform, apply_updates, clip_by_global_norm, global_norm
from repro.core.combinators import family_sharding
from repro.models.transformer import Model
from repro.sharding import family_state_sharding

PyTree = Any


def make_shardmap_train_step(
    model: Model,
    optimizer: Transform,
    mesh: Mesh,
    *,
    grad_clip: float = 0.0,
    reduce_dtype=jnp.bfloat16,
    data_axis: str = "data",
    shard_state: bool = False,
):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    Params replicated; batch sharded on axis 0 over ``data_axis``.

    ``shard_state=False`` (pure DP): opt_state replicated too.

    ``shard_state=True`` (ZeRO-style, requires a ``fuse_families=True``
    optimizer): family-stacked projectors and projected moments partition on
    ``data_axis`` along the member-stack dim.  The steady-state collective
    schedule is UNCHANGED — still exactly one reduce-dtype gradient psum plus
    one loss pmean, zero gathers (the per-family optimizer math is
    leading-axis-parallel, so GSPMD partitions it from the state shardings
    alone); the only addition is one cond-gated ``all_gather`` per shardable
    family at projector-refresh boundaries, re-materializing the full stacked
    gradient for the SVD before the new projectors are sliced back out
    sharded (see ``combinators.family_sharding``).
    """
    cfg = model.cfg

    def local_loss(params, batch):
        logits, aux, _ = model.forward(params, batch["tokens"])
        return model.loss(logits, batch["tokens"], aux)

    def grad_body(params, batch):
        # runs PER SHARD: local grads, then an explicitly-bf16 psum.  The
        # optimization_barrier pins the convert: without it XLA's
        # excess-precision pass re-promotes the all-reduce to fp32
        # (convert-around-collective reassociation), silently undoing the
        # 2x wire saving.  The psum is tree-level on purpose — one
        # multi-operand reduction for the whole gradient, not one per leaf,
        # which is both fewer collectives on the wire and the exact
        # "one gradient reduction per steady-state step" contract the
        # collective-schedule auditor (repro.analysis.collectives) asserts.
        loss, grads = jax.value_and_grad(local_loss)(params, batch)
        grads = jax.tree_util.tree_map(lambda g: g.astype(reduce_dtype), grads)
        grads = jax.lax.optimization_barrier(grads)
        grads = jax.lax.psum(grads, data_axis)
        grads = jax.lax.optimization_barrier(grads)
        loss = jax.lax.pmean(loss, data_axis)
        return loss, grads

    n_shards = mesh.shape[data_axis]
    replicated = P()
    batch_spec = {"tokens": P(data_axis)}

    sharded_grad = shard_map(
        grad_body,
        mesh=mesh,
        in_specs=(replicated, batch_spec),
        out_specs=(replicated, replicated),
        check_rep=False,
    )

    def train_step(params, opt_state, batch):
        loss, grads = sharded_grad(params, batch)
        # fp32 accumulate AFTER the bf16 wire reduction
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32) / n_shards, grads
        )
        if grad_clip > 0:
            grads = clip_by_global_norm(grads, grad_clip)
        gnorm = global_norm(grads)
        if shard_state:
            with family_sharding(mesh, data_axis):
                updates, opt_state = optimizer.update(grads, opt_state, params)
        else:
            updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss.astype(jnp.float32),
                                   "grad_norm": gnorm,
                                   "update_applied": jnp.bool_(True)}

    def jit_step(params, opt_state):
        psh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params)
        if shard_state:
            osh = family_state_sharding(opt_state, mesh, data_axis)
        else:
            osh = jax.tree_util.tree_map(
                lambda x: NamedSharding(mesh, P()) if hasattr(x, "shape") else None,
                opt_state,
            )
        bsh = {"tokens": NamedSharding(mesh, P(data_axis))}
        return jax.jit(
            train_step,
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1),
        )

    # Static contract read by the collective/buffer auditor
    # (repro.analysis.collectives / .buffers): the declared reduction dtype,
    # mesh axis, shard count and donation wiring this step was built with.
    step_info = {
        "reduce_dtype": jnp.dtype(reduce_dtype),
        "data_axis": data_axis,
        "n_shards": int(n_shards),
        "grad_clip": float(grad_clip),
        "donate_argnums": (0, 1),
        "shard_state": bool(shard_state),
    }
    train_step.sharded_step_info = step_info
    jit_step.sharded_step_info = step_info

    return train_step, jit_step
