"""Production mesh construction (TPU v5e pods).

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips across 2 pods; the "pod"
axis carries only data parallelism + FSDP (cheap DCN-friendly collectives),
"model" stays intra-pod (ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run under "
            "dryrun.py (it sets --xla_force_host_platform_device_count=512)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_data_mesh(n_shards: int, axis: str = "data"):
    """1-D data-parallel mesh over the first ``n_shards`` devices — the mesh
    the shard_map FSDP step and the ZeRO-sharded fused step run on (tests and
    benchmarks pair it with ``devices.force_host_device_count``)."""
    devices = jax.devices()
    if len(devices) < n_shards:
        raise RuntimeError(
            f"data mesh needs {n_shards} devices, found {len(devices)} — "
            "call launch.devices.force_host_device_count first"
        )
    return jax.make_mesh((n_shards,), (axis,), devices=devices[:n_shards])
