"""Shared control of the forced host-platform device count.

Several entry points (the sharded-audit CLI, ``launch/dryrun.py``, the
shard_map subprocess tests) need a multi-device CPU "mesh" backed by
``--xla_force_host_platform_device_count``.  Historically each call site
wrote ``os.environ["XLA_FLAGS"] = ...`` directly, clobbering whatever flags
the caller had set.  This helper is the one place that edits the flag: it
replaces any existing ``force_host_platform_device_count`` entry while
preserving every other flag, and (optionally) verifies the backend actually
came up with enough devices.

This module must stay importable without touching jax — callers import it
*before* jax initializes its backends.
"""
from __future__ import annotations

import os

_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int, *, verify: bool = True) -> None:
    """Force ``n`` host (CPU) devices via ``XLA_FLAGS``, preserving other flags.

    Must be called before jax initializes its backends (i.e. before the first
    device/array/jit use in the process — importing jax is fine).  With
    ``verify=True`` the backend is initialized immediately and a
    ``RuntimeError`` is raised if fewer than ``n`` devices came up, which is
    the symptom of calling this too late.

    ``n <= 1`` removes any forced count (single-device default).
    """
    n = int(n)
    parts = [f for f in os.environ.get("XLA_FLAGS", "").split() if not f.startswith(_FLAG)]
    if n > 1:
        parts.append(f"{_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(parts)
    if verify and n > 1:
        import jax

        have = jax.device_count()
        if have < n:
            raise RuntimeError(
                f"requested {n} host devices but the jax backend is already "
                f"initialized with {have}; call force_host_device_count({n}) "
                "before any jax device/array/jit use in this process"
            )
