"""Roofline analysis from compiled (post-SPMD, post-fusion) HLO text.

Why not just ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts a
while-loop body ONCE, but our models scan over layers, so a 96-layer model
would be under-counted 96x.  This analyzer parses the optimized HLO, builds
the computation call graph, extracts while-loop trip counts from their
condition computations, and accumulates

  * FLOPs            — exact for dot ops (2 · prod(out) · prod(contracted)),
                       1 flop/elt for elementwise & reduces (negligible tail)
  * HBM bytes        — per top-level (non-fused-interior) instruction:
                       output + operand buffer bytes
  * collective bytes — operand bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute

all multiplied by the instruction's execution multiplicity.  Values are
*per-device* (the HLO is the per-device SPMD program).

Roofline terms (TPU v5e):
  compute    = flops / PEAK_FLOPS
  memory     = hbm_bytes / HBM_BW
  collective = coll_bytes / (ICI_LINKS · ICI_BW)
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

# --- TPU v5e hardware constants (per chip) ---
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link
ICI_LINKS = 4              # v5e: 4 ICI links per chip (2D torus x2 dirs)

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0, "tuple": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(
    r"(?:to_apply|condition|body|calls)=\s*%?([\w.\-]+)"
)
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPCODE_RE = re.compile(r"^(?:\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([\w\-]+)\(")


def xla_cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across JAX versions.

    Older JAX returns a dict; newer versions return a list with one dict per
    executable module (and may return None when analysis is unavailable).
    Always yields a plain {metric: value} dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def shape_bytes(text: str) -> float:
    """Sum of bytes of every dtype[shape] token in ``text``."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def first_shape(text: str) -> tuple[Optional[str], tuple[int, ...]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None, ()
    dt, dims = m.groups()
    return dt, tuple(int(d) for d in dims.split(",") if d)


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    defline: str          # full text after '='
    out_text: str         # the output shape portion
    operands_text: str    # inside the parens
    called: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list[Instruction]
    shapes: dict = dataclasses.field(default_factory=dict)  # %name -> out_text


_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def operand_bytes(inst: Instruction, comp: "Computation") -> float:
    total = 0.0
    for name in _OPERAND_NAME_RE.findall(inst.operands_text):
        total += shape_bytes(comp.shapes.get(name, ""))
    # inline-typed operands (older dialect) are covered too:
    if not _OPERAND_NAME_RE.search(inst.operands_text):
        total += shape_bytes(inst.operands_text)
    return total


def _split_def(rhs: str) -> tuple[str, str, str]:
    """rhs like 'bf16[8,16]{1,0} dot(f32[..] %a, ...), attrs' ->
    (out_text, opcode, operands_text)."""
    m = _OPCODE_RE.match(rhs)
    if not m:
        return rhs, "unknown", ""
    opcode = m.group(1)
    out_text = rhs[: m.start(1)]
    # operands: balanced-paren scan from the opcode's '('
    start = rhs.index("(", m.start(1))
    depth, i = 0, start
    while i < len(rhs):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                break
        i += 1
    return out_text, opcode, rhs[start + 1 : i]


_INSTR_START_RE = re.compile(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")


def parse_hlo(text: str) -> tuple[dict[str, Computation], Optional[str]]:
    """Returns (computations, entry_name)."""
    comps: dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        s = line.strip()
        if not s or s.startswith(("//", "#", "HloModule")):
            continue
        # computation header: `[ENTRY] %name (args) -> shape {`
        if s.endswith("{") and "->" in s and not _INSTR_START_RE.match(s):
            hm = _HEADER_RE.match(s)
            if hm:
                cur = Computation(name=hm.group(2), instructions=[])
                comps[cur.name] = cur
                if hm.group(1):
                    entry = cur.name
                continue
        if s.startswith("}"):
            continue
        m = _DEF_RE.match(s)
        if m and cur is not None:
            name, rhs = m.groups()
            out_text, opcode, operands = _split_def(rhs)
            called = _CALLED_RE.findall(rhs)
            bm = _BRANCH_RE.search(rhs)
            if bm:
                called += [c.strip().lstrip("%") for c in bm.group(1).split(",")]
            inst = Instruction(name, opcode, rhs, out_text, operands, called)
            cur.instructions.append(inst)
            cur.shapes[name] = out_text
    return comps, entry


_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')


def _while_trip_count(inst: Instruction, comps: dict[str, Computation]) -> int:
    """XLA annotates scans with backend_config known_trip_count; fall back to
    the largest constant in the condition computation."""
    m = _TRIP_RE.search(inst.defline)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=\s*%?([\w.\-]+)", inst.defline)
    if not cm or cm.group(1) not in comps:
        return 1
    consts = [
        int(x.group(1))
        for ci in comps[cm.group(1)].instructions
        for x in [re.search(r"constant\((\d+)\)", ci.defline)]
        if x
    ]
    return max(consts) if consts else 1


_DOT_DIM_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    """2 · prod(output dims) · prod(lhs contracting dims)."""
    _, out_dims = first_shape(inst.out_text)
    n_out = 1
    for d in out_dims:
        n_out *= d
    names = _OPERAND_NAME_RE.findall(inst.operands_text)
    lhs_text = comp.shapes.get(names[0], "") if names else inst.operands_text
    _, lhs_dims = first_shape(lhs_text)
    m = _DOT_DIM_RE.search(inst.defline)
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    return 2.0 * n_out * contract


_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "rsqrt", "sqrt", "tanh", "negate", "abs", "power", "select", "compare",
    "and", "or", "xor", "convert", "floor", "ceil", "sign", "cosine", "sine",
    "logistic", "expm1", "log1p", "atan2", "remainder",
}


@dataclasses.dataclass
class RooflineCounts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(default_factory=dict)

    def merge_scaled(self, other: "RooflineCounts", k: float):
        self.flops += other.flops * k
        self.hbm_bytes += other.hbm_bytes * k
        self.collective_bytes += other.collective_bytes * k
        for op, b in other.per_collective.items():
            self.per_collective[op] = self.per_collective.get(op, 0.0) + b * k


def analyze_hlo(text: str) -> RooflineCounts:
    comps, entry = parse_hlo(text)
    if not comps:
        return RooflineCounts()

    if entry is None:
        # fallback: a computation nobody calls (prefer one named like 'main')
        called_by = set()
        for c in comps.values():
            for inst in c.instructions:
                for callee in inst.called:
                    called_by.add(callee)
        entries = [n for n in comps if n not in called_by]
        for n in entries:
            if "main" in n:
                entry = n
                break
        entry = entry or (entries[0] if entries else next(iter(comps)))

    # fusion-interior computations contribute FLOPs but not HBM bytes
    fusion_bodies = set()
    for c in comps.values():
        for inst in c.instructions:
            if inst.opcode == "fusion":
                fusion_bodies.update(inst.called)

    memo: dict[tuple[str, bool], RooflineCounts] = {}

    def walk(name: str, inside_fusion: bool) -> RooflineCounts:
        key = (name, inside_fusion)
        if key in memo:
            return memo[key]
        rc = RooflineCounts()
        comp = comps.get(name)
        if comp is None:
            memo[key] = rc
            return rc
        for inst in comp.instructions:
            op = inst.opcode
            # --- flops
            if op == "dot":
                rc.flops += _dot_flops(inst, comp)
            elif op in _ELEMENTWISE:
                _, dims = first_shape(inst.out_text)
                n = 1
                for d in dims:
                    n *= d
                rc.flops += n
            elif op in ("reduce", "reduce-window"):
                rc.flops += operand_bytes(inst, comp) / 4.0  # ~1 flop/elt

            # --- hbm bytes: top-level materialized buffers only
            if not inside_fusion and op not in (
                "parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "while", "conditional",
            ):
                rc.hbm_bytes += shape_bytes(inst.out_text)
                rc.hbm_bytes += operand_bytes(inst, comp)

            # --- collectives
            if op in COLLECTIVES:
                b = operand_bytes(inst, comp)
                rc.collective_bytes += b
                rc.per_collective[op] = rc.per_collective.get(op, 0.0) + b

            # --- recurse
            if inst.called:
                mult = 1.0
                if op == "while":
                    mult = float(_while_trip_count(inst, comps))
                    body = re.search(r"body=\s*%?([\w.\-]+)", inst.defline)
                    cond = re.search(r"condition=\s*%?([\w.\-]+)", inst.defline)
                    if body:
                        rc.merge_scaled(walk(body.group(1), inside_fusion), mult)
                    if cond:
                        rc.merge_scaled(walk(cond.group(1), inside_fusion), mult)
                    continue
                if op == "conditional":
                    # execute ONE branch; take the max-cost branch (upper bound)
                    branches = [walk(c, inside_fusion) for c in inst.called]
                    if branches:
                        best = max(branches, key=lambda r: r.flops + r.hbm_bytes)
                        rc.merge_scaled(best, 1.0)
                    continue
                child_fusion = inside_fusion or op == "fusion"
                for callee in inst.called:
                    rc.merge_scaled(walk(callee, child_fusion), 1.0)
        memo[key] = rc
        return rc

    return walk(entry, False)


@dataclasses.dataclass
class RooflineReport:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    per_collective: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0
    useful_flops_frac: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_from_text(
    hlo_text: str, *, model_flops_per_device: float = 0.0
) -> RooflineReport:
    rc = analyze_hlo(hlo_text)
    compute_s = rc.flops / PEAK_FLOPS
    memory_s = rc.hbm_bytes / HBM_BW
    collective_s = rc.collective_bytes / (ICI_LINKS * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    frac = (model_flops_per_device / rc.flops) if rc.flops else 0.0
    return RooflineReport(
        flops=rc.flops,
        hbm_bytes=rc.hbm_bytes,
        collective_bytes=rc.collective_bytes,
        per_collective=rc.per_collective,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops_per_device,
        useful_flops_frac=frac,
    )


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) per config
# ---------------------------------------------------------------------------


def count_params(cfg, active_only: bool = False) -> float:
    """Analytic parameter count from the config (matches init to ~1%)."""
    d, L = cfg.d_model, cfg.n_layers
    H, KV, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    n = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    gated = cfg.act in ("swiglu", "geglu")
    mlp_mult = 3 if gated else 2

    def attn_p():
        return d * H * hd + 2 * d * KV * hd + H * hd * d

    def mlp_p(ff):
        return mlp_mult * d * ff

    if cfg.family in ("dense", "audio", "vlm"):
        per = attn_p() + mlp_p(cfg.d_ff)
        n += L * per
        if cfg.family == "vlm":
            G = L // cfg.cross_attn_every
            n += G * (attn_p() + mlp_p(cfg.d_ff))  # cross blocks
    elif cfg.family == "moe":
        E, k = cfg.n_experts, cfg.top_k
        moe_layers = L // cfg.moe_every
        dense_layers = L - moe_layers
        n += L * attn_p() + dense_layers * mlp_p(cfg.d_ff)
        expert = mlp_mult * d * (cfg.moe_dff or cfg.d_ff)
        n_all = moe_layers * (E * expert + cfg.n_shared_experts * expert + d * E)
        n_act = moe_layers * (k * expert + cfg.n_shared_experts * expert + d * E)
        n += n_act if active_only else n_all
    elif cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.ssm_expand * d
        Hs = d_inner // cfg.ssm_headdim
        in_dim = 2 * d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state + Hs
        per = d * in_dim + d_inner * d
        n += L * per
        if cfg.family == "hybrid":
            n += attn_p() + mlp_p(cfg.d_ff)  # one shared block
    return float(n)


def model_flops(cfg, shape) -> float:
    """6·N·D for training; 2·N·D per generated batch-step for decode."""
    n = count_params(cfg, active_only=(cfg.family == "moe"))
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
