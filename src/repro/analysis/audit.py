"""Audit orchestrator + CLI: run every analyzer over a built optimizer.

One audit cell = one ``OptimizerConfig``: chain lint, closed-form launch
model vs trace-time dispatch counts, dtype-flow pass, recompilation-hazard
pass across the rank ladder, and the static memory accountant — all on the
abstract program, nothing executes.

CLI::

    PYTHONPATH=src python -m repro.analysis.audit --optimizer gum \
        --fuse-families --fused-epilogue --rank-ladder 8,16
    PYTHONPATH=src python -m repro.analysis.audit --matrix --json
    PYTHONPATH=src python -m repro.analysis.audit --optimizer gum \
        --check-memory          # cross-check results/BENCH_rank_policy.json

Exit status 1 iff any error-severity finding survives.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp

from repro.core.api import OptimizerConfig, Transform, state_bytes
from repro.core.combinators import chain_info, find_lowrank_states
from repro.core.factory import build_optimizer
from repro.core.rank_policy import RankMap
from repro.kernels import launch_count

from .chain_lint import lint_chain
from .findings import AuditReport, Finding
from .jaxpr_passes import (
    dtype_flow_findings,
    memory_crosscheck,
    recompile_findings,
    signature_hash,
    trace_update,
)
from .launch_model import expected_launches, lowrank_plan_stats

# Factory optimizers that route matrices through lowrank() — audited across
# the full fuse_families x fused_epilogue grid — vs. full-rank baselines
# (one cell each; the fuse knobs are no-ops for them).
LOWRANK_OPTIMIZERS = ("gum", "galore", "galore_muon", "golore", "fira",
                      "unbiased_galore_adam")
FULLRANK_OPTIMIZERS = ("muon", "adamw", "sgdm", "lisa")


def default_params(dtype=jnp.float32):
    """The audit's reference tree: three hidden-matrix shape families
    (4x 64x64, 2x 64x128, 2x 128x64) plus an embedding and a norm vector so
    the matrix/fallback routing is exercised.  ShapeDtypeStructs only."""
    shapes = {
        "layers/0/attn/wq": (64, 64), "layers/0/attn/wo": (64, 64),
        "layers/1/attn/wq": (64, 64), "layers/1/attn/wo": (64, 64),
        "layers/0/mlp/up": (64, 128), "layers/1/mlp/up": (64, 128),
        "layers/0/mlp/down": (128, 64), "layers/1/mlp/down": (128, 64),
        "embed/table": (256, 64),
        "norm/scale": (64,),
    }
    return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in shapes.items()}


def arch_params(arch: str):
    """Abstract param tree of a registered model config (``eval_shape``'d
    init — nothing allocates).  ``name-smoke`` selects the tiny variant."""
    from repro.configs import get_config, get_smoke
    from repro.models import build_model

    if arch.endswith("-smoke"):
        cfg = get_smoke(arch[: -len("-smoke")])
    else:
        cfg = get_config(arch)
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def _cell_name(cfg: OptimizerConfig) -> str:
    bits = [cfg.name]
    if cfg.fuse_families:
        bits.append("fused")
    if cfg.fused_epilogue:
        bits.append("epilogue")
    return "+".join(bits)


def launch_findings(expected: dict, traced: dict, *, fused_epilogue: bool,
                    where: str = "") -> list[Finding]:
    """Classify an expected-vs-traced launch-count diff into findings.

    Back-projection diffs under ``fused_epilogue=True`` are RA302 (the
    epilogue failed to fold — stray unfused back_projects); every other
    diff is RA301 (the one-launch-set-per-family contract broke, or the
    model's coefficient table is stale)."""
    if traced == expected:
        return []
    stray, other = [], []
    for op in sorted(set(traced) | set(expected)):
        e, a = expected.get(op, 0), traced.get(op, 0)
        if e != a:
            line = f"{op}: expected {e}, traced {a}"
            (stray if fused_epilogue and op.startswith("back_project")
             else other).append(line)
    out = []
    if stray:
        out.append(Finding(
            code="RA302", where=where,
            message="fused_epilogue=True left unfused back-projection "
                    "launches: " + "; ".join(stray),
            hint="the chain tail is not folding into "
                 "back_project_epilogue — check that scale_by_lr is "
                 "terminal and the inner emits a projected update",
            detail={"expected": expected, "traced": traced},
        ))
    if other:
        out.append(Finding(
            code="RA301", where=where,
            message="traced launch counts diverge from the closed-form "
                    "FamilyPlan expectation: " + "; ".join(other),
            hint="either the fused engine regressed (launches per leaf "
                 "instead of per family) or the launch model's "
                 "coefficient table is stale",
            detail={"expected": expected, "traced": traced},
        ))
    return out


def audit_optimizer(
    cfg: OptimizerConfig,
    params=None,
    *,
    ladder=None,
    check_memory: bool = False,
) -> AuditReport:
    """Run every analyzer over ``build_optimizer(cfg)``; nothing executes."""
    name = _cell_name(cfg)
    report = AuditReport(name=name)
    params = default_params() if params is None else params
    ladder = tuple(ladder if ladder is not None else cfg.rank_ladder)

    transform = build_optimizer(cfg)
    report.extend(lint_chain(transform, ladder=ladder, name=name))
    if not report.ok:
        return report  # a malformed chain traces garbage (or TypeErrors)

    expected, model_findings = expected_launches(transform, params, name=name)
    report.extend(model_findings)

    state = jax.eval_shape(transform.init, params)
    with launch_count.count_launches() as counts:
        jaxpr = jax.make_jaxpr(
            lambda g, s, w: transform.update(g, s, w))(params, state, params)
    traced = dict(counts)

    if not model_findings:
        report.extend(launch_findings(
            expected, traced, fused_epilogue=cfg.fused_epilogue, where=name))

    report.extend(dtype_flow_findings(jaxpr, where=name))

    hashes = {}
    if ladder:
        def at_rank(r: int) -> Transform:
            return build_optimizer(cfg, rank_map=RankMap(r))

        rec, hashes = recompile_findings(at_rank, params, ladder, where=name)
        report.extend(rec)

    if check_memory:
        report.extend(memory_crosscheck())

    proj = sum(state_bytes(lr)
               for lr in find_lowrank_states(
                   jax.eval_shape(transform.init, params)))
    report.summary.update({
        "launches_per_step": sum(traced.values()),
        "launch_counts": launch_count.format_counts(traced),
        "proj_state_bytes": proj,
        "signature": signature_hash(jaxpr),
        "ladder_signatures": hashes,
        "family_plans": lowrank_plan_stats(transform, params, name=name),
    })
    return report


def audit_summary(transform: Transform, params, *, name: str = "optimizer") -> str:
    """One-line startup summary for the Trainer log: per-step launch counts,
    projected-state bytes and the abstract signature hash — from a single
    abstract trace."""
    state = jax.eval_shape(transform.init, params)
    with launch_count.count_launches() as counts:
        jaxpr = jax.make_jaxpr(
            lambda g, s, w: transform.update(g, s, w))(params, state, params)
    proj = sum(state_bytes(lr) for lr in find_lowrank_states(state))
    return (f"audit[{name}]: launches/step="
            f"{launch_count.format_counts(dict(counts))} "
            f"proj_state={proj}B sig={signature_hash(jaxpr)}")


def matrix_configs(rank: int = 16, period: int = 10,
                   ladder=(8, 16)) -> list[OptimizerConfig]:
    """The full audit pass matrix: every lowrank factory optimizer across
    fuse_families x fused_epilogue, plus the full-rank baselines."""
    cells = []
    for opt in LOWRANK_OPTIMIZERS:
        for fuse in (False, True):
            for epi in (False, True):
                cells.append(OptimizerConfig(
                    name=opt, rank=rank, period=period, gamma=1,
                    kernel_impl="jnp", fuse_families=fuse,
                    fused_epilogue=epi, rank_ladder=tuple(ladder),
                ))
    for opt in FULLRANK_OPTIMIZERS:
        cells.append(OptimizerConfig(name=opt, period=period, gamma=1))
    return cells


def run_matrix(params=None, *, rank: int = 16, period: int = 10,
               ladder=(8, 16), check_memory: bool = False,
               ) -> dict[str, AuditReport]:
    """Audit every matrix cell; returns ``{cell_name: AuditReport}``."""
    params = default_params() if params is None else params
    out: dict[str, AuditReport] = {}
    for cfg in matrix_configs(rank=rank, period=period, ladder=ladder):
        out[_cell_name(cfg)] = audit_optimizer(
            cfg, params, ladder=cfg.rank_ladder, check_memory=False)
    if check_memory:
        mem = AuditReport(name="memory_crosscheck")
        mem.extend(memory_crosscheck())
        out[mem.name] = mem
    return out


def _parse_ladder(text: str) -> tuple[int, ...]:
    return tuple(int(x) for x in text.split(",") if x.strip())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="Static audit of the traced optimizer step "
                    "(nothing executes).",
    )
    ap.add_argument("--optimizer", default="gum",
                    help="factory optimizer name (default: gum)")
    ap.add_argument("--arch", default=None, metavar="NAME",
                    help="audit against a registered model config's real "
                         "param tree (eval_shape'd, nothing allocates) "
                         "instead of the synthetic reference tree; append "
                         "-smoke for the tiny variant")
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--period", type=int, default=10)
    ap.add_argument("--fuse-families", action="store_true")
    ap.add_argument("--fused-epilogue", action="store_true")
    ap.add_argument("--rank-ladder", type=_parse_ladder, default=(8, 16),
                    metavar="R1,R2,...")
    ap.add_argument("--matrix", action="store_true",
                    help="audit the full optimizer x fuse x epilogue matrix")
    ap.add_argument("--check-memory", action="store_true",
                    help="also cross-check results/BENCH_rank_policy.json")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    params = arch_params(args.arch) if args.arch else None
    if args.matrix:
        reports = run_matrix(params, rank=args.rank, period=args.period,
                             ladder=args.rank_ladder,
                             check_memory=args.check_memory)
    else:
        cfg = OptimizerConfig(
            name=args.optimizer, rank=args.rank, period=args.period,
            gamma=1, kernel_impl="jnp",
            fuse_families=args.fuse_families,
            fused_epilogue=args.fused_epilogue,
            rank_ladder=args.rank_ladder,
        )
        reports = {_cell_name(cfg): audit_optimizer(
            cfg, params, ladder=args.rank_ladder,
            check_memory=args.check_memory)}

    ok = all(r.ok for r in reports.values())
    if args.as_json:
        print(json.dumps({k: r.to_json() for k, r in reports.items()},
                         indent=2, default=str))
    else:
        for r in reports.values():
            print(r.format(verbose=args.verbose))
        print(f"audit matrix: {sum(r.ok for r in reports.values())}"
              f"/{len(reports)} cells clean")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
