"""Audit orchestrator + CLI: run every analyzer over a built optimizer.

One audit cell = one ``OptimizerConfig``: chain lint, closed-form launch
model vs trace-time dispatch counts, dtype-flow pass, recompilation-hazard
pass across the rank ladder, and the static memory accountant — all on the
abstract program, nothing executes.

CLI::

    PYTHONPATH=src python -m repro.analysis.audit --optimizer gum \
        --fuse-families --fused-epilogue --rank-ladder 8,16
    PYTHONPATH=src python -m repro.analysis.audit --matrix --json
    PYTHONPATH=src python -m repro.analysis.audit --optimizer gum \
        --check-memory          # cross-check results/BENCH_rank_policy.json
    PYTHONPATH=src python -m repro.analysis.audit --sharded --mesh data=8
                                # collective schedule + donation on the
                                # shard_map step (forces host CPU devices)

Exit status 1 iff any error-severity finding survives.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp

from repro.core.api import OptimizerConfig, Transform, state_bytes
from repro.core.combinators import chain_info, find_lowrank_states
from repro.core.factory import build_optimizer
from repro.core.rank_policy import RankMap
from repro.kernels import launch_count

from .buffers import (
    donation_findings,
    parse_main_args,
    per_shard_memory,
    replication_findings,
)
from .chain_lint import lint_chain
from .collectives import (
    collective_schedule_findings,
    expected_collective_schedule,
    trace_sharded_step,
    wire_bytes_model,
)
from .findings import AuditReport, Finding
from .jaxpr_passes import (
    dtype_flow_findings,
    memory_crosscheck,
    recompile_findings,
    signature_hash,
    trace_update,
)
from .launch_model import expected_launches, lowrank_plan_stats

# Factory optimizers that route matrices through lowrank() — audited across
# the full fuse_families x fused_epilogue grid — vs. full-rank baselines
# (one cell each; the fuse knobs are no-ops for them).
LOWRANK_OPTIMIZERS = ("gum", "galore", "galore_muon", "golore", "fira",
                      "unbiased_galore_adam")
FULLRANK_OPTIMIZERS = ("muon", "adamw", "sgdm", "lisa")


def default_params(dtype=jnp.float32):
    """The audit's reference tree: three hidden-matrix shape families
    (4x 64x64, 2x 64x128, 2x 128x64) plus an embedding and a norm vector so
    the matrix/fallback routing is exercised.  ShapeDtypeStructs only."""
    shapes = {
        "layers/0/attn/wq": (64, 64), "layers/0/attn/wo": (64, 64),
        "layers/1/attn/wq": (64, 64), "layers/1/attn/wo": (64, 64),
        "layers/0/mlp/up": (64, 128), "layers/1/mlp/up": (64, 128),
        "layers/0/mlp/down": (128, 64), "layers/1/mlp/down": (128, 64),
        "embed/table": (256, 64),
        "norm/scale": (64,),
    }
    return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in shapes.items()}


def arch_model(arch: str):
    """Built model for a registered config name (``name-smoke`` selects the
    tiny variant).  Building is pure metadata — nothing allocates."""
    from repro.configs import get_config, get_smoke
    from repro.models import build_model

    if arch.endswith("-smoke"):
        cfg = get_smoke(arch[: -len("-smoke")])
    else:
        cfg = get_config(arch)
    return build_model(cfg)


def arch_params(arch: str):
    """Abstract param tree of a registered model config (``eval_shape``'d
    init — nothing allocates).  ``name-smoke`` selects the tiny variant."""
    return jax.eval_shape(arch_model(arch).init, jax.random.PRNGKey(0))


def _cell_name(cfg: OptimizerConfig) -> str:
    bits = [cfg.name]
    if cfg.fuse_families:
        bits.append("fused")
    if cfg.fused_epilogue:
        bits.append("epilogue")
    return "+".join(bits)


def launch_findings(expected: dict, traced: dict, *, fused_epilogue: bool,
                    where: str = "") -> list[Finding]:
    """Classify an expected-vs-traced launch-count diff into findings.

    Back-projection diffs under ``fused_epilogue=True`` are RA302 (the
    epilogue failed to fold — stray unfused back_projects); every other
    diff is RA301 (the one-launch-set-per-family contract broke, or the
    model's coefficient table is stale)."""
    if traced == expected:
        return []
    stray, other = [], []
    for op in sorted(set(traced) | set(expected)):
        e, a = expected.get(op, 0), traced.get(op, 0)
        if e != a:
            line = f"{op}: expected {e}, traced {a}"
            (stray if fused_epilogue and op.startswith("back_project")
             else other).append(line)
    out = []
    if stray:
        out.append(Finding(
            code="RA302", where=where,
            message="fused_epilogue=True left unfused back-projection "
                    "launches: " + "; ".join(stray),
            hint="the chain tail is not folding into "
                 "back_project_epilogue — check that scale_by_lr is "
                 "terminal and the inner emits a projected update",
            detail={"expected": expected, "traced": traced},
        ))
    if other:
        out.append(Finding(
            code="RA301", where=where,
            message="traced launch counts diverge from the closed-form "
                    "FamilyPlan expectation: " + "; ".join(other),
            hint="either the fused engine regressed (launches per leaf "
                 "instead of per family) or the launch model's "
                 "coefficient table is stale",
            detail={"expected": expected, "traced": traced},
        ))
    return out


def audit_optimizer(
    cfg: OptimizerConfig,
    params=None,
    *,
    ladder=None,
    check_memory: bool = False,
) -> AuditReport:
    """Run every analyzer over ``build_optimizer(cfg)``; nothing executes."""
    name = _cell_name(cfg)
    report = AuditReport(name=name)
    params = default_params() if params is None else params
    ladder = tuple(ladder if ladder is not None else cfg.rank_ladder)

    transform = build_optimizer(cfg)
    report.extend(lint_chain(transform, ladder=ladder, name=name))
    if not report.ok:
        return report  # a malformed chain traces garbage (or TypeErrors)

    expected, model_findings = expected_launches(transform, params, name=name)
    report.extend(model_findings)

    state = jax.eval_shape(transform.init, params)
    with launch_count.count_launches() as counts:
        jaxpr = jax.make_jaxpr(
            lambda g, s, w: transform.update(g, s, w))(params, state, params)
    traced = dict(counts)

    if not model_findings:
        report.extend(launch_findings(
            expected, traced, fused_epilogue=cfg.fused_epilogue, where=name))

    report.extend(dtype_flow_findings(jaxpr, where=name))

    hashes = {}
    if ladder:
        def at_rank(r: int) -> Transform:
            return build_optimizer(cfg, rank_map=RankMap(r))

        rec, hashes = recompile_findings(at_rank, params, ladder, where=name)
        report.extend(rec)

    if check_memory:
        report.extend(memory_crosscheck())

    proj = sum(state_bytes(lr)
               for lr in find_lowrank_states(
                   jax.eval_shape(transform.init, params)))
    report.summary.update({
        "launches_per_step": sum(traced.values()),
        "launch_counts": launch_count.format_counts(traced),
        "proj_state_bytes": proj,
        "signature": signature_hash(jaxpr),
        "ladder_signatures": hashes,
        "family_plans": lowrank_plan_stats(transform, params, name=name),
    })
    return report


def audit_sharded(
    cfg: OptimizerConfig,
    *,
    arch: str = "llama-60m-smoke",
    model=None,
    mesh_axes=(("data", 8),),
    reduce_dtype=jnp.bfloat16,
    grad_clip: float = 1.0,
    batch_size: int = 8,
    lower: bool | None = None,
) -> AuditReport:
    """Audit the ``shard_map`` train step: collective schedule (RA601/602/
    603/606) + wire-bytes accountant on an ``AbstractMesh`` trace (no
    devices needed), and — when enough real devices exist — donation /
    replication of the lowered jit step (RA604/RA605) plus the per-shard
    peak-memory model.

    ``lower=None`` lowers iff ``jax.device_count()`` covers the mesh;
    ``lower=False`` keeps the cell fully device-free (what the benchmark
    matrix uses so its numbers don't depend on forced host devices).
    """
    (data_axis, n_shards), = mesh_axes  # pure-DP path: exactly one axis
    n_shards = int(n_shards)
    shard_state = bool(cfg.shard_state)
    name = f"sharded:{_cell_name(cfg)}@{data_axis}={n_shards}"
    if shard_state:
        name += "+zero"
    report = AuditReport(name=name)

    transform = build_optimizer(cfg)
    report.extend(lint_chain(transform, ladder=cfg.rank_ladder, name=name))
    if not report.ok:
        return report

    model = arch_model(arch) if model is None else model
    batch_size = n_shards * -(-int(batch_size) // n_shards)  # round up to /N
    jaxpr, records, counts, (params, opt_state, batch) = trace_sharded_step(
        model, transform, n_shards=n_shards, batch_size=batch_size,
        reduce_dtype=reduce_dtype, grad_clip=grad_clip, data_axis=data_axis,
        shard_state=shard_state,
    )

    expected = expected_collective_schedule(
        transform, params, n_shards=n_shards, reduce_dtype=reduce_dtype,
        data_axis=data_axis, shard_state=shard_state)
    report.extend(collective_schedule_findings(
        records, expected, reduce_dtype=reduce_dtype, params=params,
        where=name))

    # the dispatch-launch contract holds under shard_map too: the optimizer
    # runs once, replicated, after the reduction.
    exp_launch, model_findings = expected_launches(
        transform, params, name=name)
    report.extend(model_findings)
    dispatch_traced = {op: n for op, n in counts.items()
                       if op in launch_count.DISPATCH_OPS}
    if not model_findings:
        report.extend(launch_findings(
            exp_launch, dispatch_traced,
            fused_epilogue=cfg.fused_epilogue, where=name))

    wire = wire_bytes_model(records, n_shards)
    mem = per_shard_memory(params, opt_state, batch,
                           n_shards=n_shards, reduce_dtype=reduce_dtype,
                           shard_state=shard_state)
    report.summary.update({
        "n_shards": n_shards,
        "collectives": launch_count.format_counts(
            {op: n for op, n in counts.items()
             if op in launch_count.COLLECTIVE_OPS}),
        "expected_schedule": expected,
        "wire": wire,
        "per_shard_memory": mem,
        "launch_counts": launch_count.format_counts(dict(counts)),
    })

    if lower is None:
        lower = jax.device_count() >= n_shards
    if not lower:
        report.summary["buffers"] = (
            f"skipped (lowering needs {n_shards} devices; "
            "run the CLI with --sharded to force host devices)")
        return report

    import numpy as np
    from jax.sharding import Mesh

    from repro.launch.shardmap_fsdp import make_shardmap_train_step

    mesh = Mesh(np.asarray(jax.devices()[:n_shards]), (data_axis,))
    _, jit_builder = make_shardmap_train_step(
        model, transform, mesh,
        grad_clip=grad_clip, reduce_dtype=reduce_dtype, data_axis=data_axis,
        shard_state=shard_state)
    lowered = jit_builder(params, opt_state).lower(
        params, opt_state, batch).as_text()
    args_info = parse_main_args(lowered)
    n_params = len(jax.tree_util.tree_leaves(params))
    n_opt = len(jax.tree_util.tree_leaves(opt_state))
    report.extend(donation_findings(
        args_info, n_params=n_params, n_opt=n_opt, where=name))
    report.extend(replication_findings(
        args_info, n_params=n_params, n_opt=n_opt, n_shards=n_shards,
        where=name))
    report.summary["buffers"] = {
        "donated_args": sum(a.aliased for a in args_info),
        "expected_donated": n_params + n_opt,
        "total_args": len(args_info),
    }
    return report


def audit_summary(transform: Transform, params, *, name: str = "optimizer") -> str:
    """One-line startup summary for the Trainer log: per-step launch counts,
    projected-state bytes and the abstract signature hash — from a single
    abstract trace."""
    state = jax.eval_shape(transform.init, params)
    with launch_count.count_launches() as counts:
        jaxpr = jax.make_jaxpr(
            lambda g, s, w: transform.update(g, s, w))(params, state, params)
    proj = sum(state_bytes(lr) for lr in find_lowrank_states(state))
    return (f"audit[{name}]: launches/step="
            f"{launch_count.format_counts(dict(counts))} "
            f"proj_state={proj}B sig={signature_hash(jaxpr)}")


def matrix_configs(rank: int = 16, period: int = 10,
                   ladder=(8, 16)) -> list[OptimizerConfig]:
    """The full audit pass matrix: every lowrank factory optimizer across
    fuse_families x fused_epilogue, plus the full-rank baselines."""
    cells = []
    for opt in LOWRANK_OPTIMIZERS:
        for fuse in (False, True):
            for epi in (False, True):
                cells.append(OptimizerConfig(
                    name=opt, rank=rank, period=period, gamma=1,
                    kernel_impl="jnp", fuse_families=fuse,
                    fused_epilogue=epi, rank_ladder=tuple(ladder),
                ))
    for opt in FULLRANK_OPTIMIZERS:
        cells.append(OptimizerConfig(name=opt, period=period, gamma=1))
    return cells


def run_matrix(params=None, *, rank: int = 16, period: int = 10,
               ladder=(8, 16), check_memory: bool = False,
               ) -> dict[str, AuditReport]:
    """Audit every matrix cell; returns ``{cell_name: AuditReport}``."""
    params = default_params() if params is None else params
    out: dict[str, AuditReport] = {}
    for cfg in matrix_configs(rank=rank, period=period, ladder=ladder):
        out[_cell_name(cfg)] = audit_optimizer(
            cfg, params, ladder=cfg.rank_ladder, check_memory=False)
    if check_memory:
        mem = AuditReport(name="memory_crosscheck")
        mem.extend(memory_crosscheck())
        out[mem.name] = mem
    return out


def _parse_ladder(text: str) -> tuple[int, ...]:
    return tuple(int(x) for x in text.split(",") if x.strip())


def _parse_mesh(text: str) -> tuple[tuple[str, int], ...]:
    """``"data=8"`` (comma-separable) -> ``(("data", 8),)``."""
    axes = []
    for part in text.split(","):
        if not part.strip():
            continue
        axis, _, size = part.partition("=")
        axes.append((axis.strip(), int(size)))
    if not axes:
        raise ValueError(f"unparseable mesh spec: {text!r}")
    return tuple(axes)


_REDUCE_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32,
                  "fp32": jnp.float32, "f16": jnp.float16}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="Static audit of the traced optimizer step "
                    "(nothing executes).",
    )
    ap.add_argument("--optimizer", default="gum",
                    help="factory optimizer name (default: gum)")
    ap.add_argument("--arch", default=None, metavar="NAME",
                    help="audit against a registered model config's real "
                         "param tree (eval_shape'd, nothing allocates) "
                         "instead of the synthetic reference tree; append "
                         "-smoke for the tiny variant")
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--period", type=int, default=10)
    ap.add_argument("--fuse-families", action="store_true")
    ap.add_argument("--fused-epilogue", action="store_true")
    ap.add_argument("--rank-ladder", type=_parse_ladder, default=(8, 16),
                    metavar="R1,R2,...")
    ap.add_argument("--matrix", action="store_true",
                    help="audit the full optimizer x fuse x epilogue matrix")
    ap.add_argument("--check-memory", action="store_true",
                    help="also cross-check results/BENCH_rank_policy.json")
    ap.add_argument("--sharded", action="store_true",
                    help="audit the shard_map train step instead: collective "
                         "schedule + wire bytes (abstract trace) and "
                         "donation / per-shard buffers (lowered module; "
                         "forces host CPU devices to cover the mesh)")
    ap.add_argument("--mesh", default="data=8", metavar="AXIS=N",
                    help="mesh spec for --sharded (default: data=8)")
    ap.add_argument("--shard-state", action="store_true",
                    help="audit the ZeRO-sharded fused step (implies "
                         "--fuse-families): family-stacked projected state "
                         "partitioned over the data axis, boundary gathers "
                         "expected per shardable family")
    ap.add_argument("--reduce-dtype", default="bf16",
                    choices=sorted(_REDUCE_DTYPES),
                    help="declared gradient-reduction dtype for --sharded")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.sharded:
        # must happen before ANY jax device use in this process
        from repro.launch.devices import force_host_device_count

        mesh_axes = _parse_mesh(args.mesh)
        total = 1
        for _, size in mesh_axes:
            total *= size
        force_host_device_count(total)
        cfg = OptimizerConfig(
            name=args.optimizer, rank=args.rank, period=args.period,
            gamma=1, kernel_impl="jnp",
            fuse_families=args.fuse_families or args.shard_state,
            fused_epilogue=args.fused_epilogue,
            rank_ladder=args.rank_ladder,
            shard_state=args.shard_state,
        )
        rep = audit_sharded(
            cfg, arch=args.arch or "llama-60m-smoke", mesh_axes=mesh_axes,
            reduce_dtype=_REDUCE_DTYPES[args.reduce_dtype])
        reports = {rep.name: rep}
        if args.as_json:
            print(json.dumps({k: r.to_json() for k, r in reports.items()},
                             indent=2, default=str))
        else:
            for r in reports.values():
                print(r.format(verbose=args.verbose))
        return 0 if rep.ok else 1

    params = arch_params(args.arch) if args.arch else None
    if args.matrix:
        reports = run_matrix(params, rank=args.rank, period=args.period,
                             ladder=args.rank_ladder,
                             check_memory=args.check_memory)
    else:
        cfg = OptimizerConfig(
            name=args.optimizer, rank=args.rank, period=args.period,
            gamma=1, kernel_impl="jnp",
            fuse_families=args.fuse_families,
            fused_epilogue=args.fused_epilogue,
            rank_ladder=args.rank_ladder,
        )
        reports = {_cell_name(cfg): audit_optimizer(
            cfg, params, ladder=args.rank_ladder,
            check_memory=args.check_memory)}

    ok = all(r.ok for r in reports.values())
    if args.as_json:
        print(json.dumps({k: r.to_json() for k, r in reports.items()},
                         indent=2, default=str))
    else:
        for r in reports.values():
            print(r.format(verbose=args.verbose))
        print(f"audit matrix: {sum(r.ok for r in reports.values())}"
              f"/{len(reports)} cells clean")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
