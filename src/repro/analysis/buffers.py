"""Buffer-lifetime auditor for the jitted sharded step (RA604/RA605).

``make_shardmap_train_step``'s jit wrapper donates ``params`` and
``opt_state`` (``donate_argnums=(0, 1)``) so the update happens in place —
without donation every step holds two full copies of the model plus two of
the optimizer state.  Donation is easy to lose silently: drop the argnums,
change an output dtype, or reorder outputs, and XLA just stops aliasing
with no error.  This pass reads the *lowered* StableHLO module (no
compile, no devices beyond the mesh used to lower) and verifies the
aliasing actually happened:

  * :func:`parse_main_args` extracts every ``%argN`` of the module's public
    ``@main`` — shape, dtype, bytes, ``tf.aliasing_output`` (the
    input→output alias XLA records for donated buffers) and the
    ``mhlo.sharding`` attribute.
  * :func:`donation_findings` — RA604 when a params / opt-state argument
    does not alias an output.
  * :func:`replication_findings` — RA605 when the per-shard batch input is
    actually replicated on a >1 mesh (the accountant's bytes/N model would
    silently become bytes×1).
  * :func:`per_shard_memory` — static per-shard peak-memory model (params +
    grads at fp32 + wire-copy at ``reduce_dtype`` + opt state + batch/N),
    reusing the PR-6 accountant (:func:`repro.core.api.state_bytes`).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Iterable

import jax
import jax.numpy as jnp

from repro.core.api import state_bytes
from repro.core.combinators import find_lowrank_states

from .findings import Finding

PyTree = Any

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8E4M3FN": 1, "f8E5M2": 1,
    "i64": 8, "i32": 4, "i16": 2, "i8": 1, "i1": 1,
    "ui64": 8, "ui32": 4, "ui16": 2, "ui8": 1,
}


@dataclasses.dataclass(frozen=True)
class ArgInfo:
    """One ``%argN`` of the lowered module's ``@main`` signature."""

    index: int
    shape: tuple[int, ...]
    dtype: str
    nbytes: int
    aliased: bool            # carries tf.aliasing_output (donation happened)
    sharding: str | None     # raw mhlo.sharding attribute, if any

    @property
    def replicated(self) -> bool:
        return self.sharding is None or "replicated" in self.sharding


def _main_signature(lowered_text: str) -> str:
    m = re.search(r"func\.func\s+public\s+@main\(", lowered_text)
    if m is None:
        raise ValueError("no public @main function in lowered module text")
    i, depth = m.end(), 1
    while depth and i < len(lowered_text):
        c = lowered_text[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    return lowered_text[m.end():i - 1]


def parse_main_args(lowered_text: str) -> list[ArgInfo]:
    """Parse the ``@main`` signature of ``jitted.lower(...).as_text()``."""
    sig = _main_signature(lowered_text)
    args: list[ArgInfo] = []
    chunks = re.split(r"(?=%arg\d+:)", sig)
    for chunk in chunks:
        m = re.match(r"%arg(\d+):", chunk)
        if not m:
            continue
        t = re.search(r"tensor<([^>]*)>", chunk)
        if not t:
            continue
        toks = t.group(1).split("x")
        dtype = toks[-1]
        dims = tuple(int(d) for d in toks[:-1])
        size = 1
        for d in dims:
            size *= d
        itemsize = _DTYPE_BYTES.get(dtype, 4)
        sh = re.search(r'mhlo\.sharding\s*=\s*"([^"]*)"', chunk)
        args.append(ArgInfo(
            index=int(m.group(1)),
            shape=dims,
            dtype=dtype,
            nbytes=size * itemsize,
            aliased="tf.aliasing_output" in chunk,
            sharding=sh.group(1) if sh else None,
        ))
    args.sort(key=lambda a: a.index)
    return args


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------


def donation_findings(args: Iterable[ArgInfo], *, n_params: int, n_opt: int,
                      where: str = "sharded-step") -> list[Finding]:
    """RA604: every params / opt-state argument (the first
    ``n_params + n_opt`` flat args, jit's flattening order) must carry
    ``tf.aliasing_output`` — i.e. ``donate_argnums=(0, 1)`` survived all the
    way into the lowered module."""
    args = list(args)
    n_donated = n_params + n_opt
    if len(args) < n_donated:
        return [Finding(
            code="RA604", where=where,
            message=f"lowered module has {len(args)} args but "
                    f"{n_donated} donated leaves were expected — signature "
                    "parse / flattening mismatch",
            detail={"n_args": len(args), "expected_donated": n_donated},
        )]
    missing = [a for a in args[:n_donated] if not a.aliased]
    if not missing:
        return []
    lost = sum(a.nbytes for a in missing)
    kinds = sorted({"params" if a.index < n_params else "opt_state"
                    for a in missing})
    return [Finding(
        code="RA604", where=where,
        message=f"{len(missing)}/{n_donated} donated buffer(s) "
                f"({'+'.join(kinds)}) do not alias an output — "
                f"{lost} extra bytes live per step (double-buffered "
                "instead of updated in place)",
        hint="restore donate_argnums=(0, 1) on the jit wrapper and keep "
             "output dtypes/shapes identical to the donated inputs "
             "(XLA silently drops mismatched aliases)",
        detail={"missing_indices": [a.index for a in missing[:8]],
                "missing_bytes": lost},
    )]


def replication_findings(args: Iterable[ArgInfo], *, n_params: int,
                         n_opt: int, n_shards: int,
                         where: str = "sharded-step") -> list[Finding]:
    """RA605: on a >1 mesh the batch argument(s) must be sharded over the
    data axis; a replicated batch means every shard holds (and the memory
    model should have charged) per-replica bytes, not per-shard.

    Params / opt state are replicated BY DESIGN in the pure-DP variant, so
    only the trailing (batch) args are checked."""
    if n_shards <= 1:
        return []
    args = list(args)
    batch = [a for a in args[n_params + n_opt:] if a.nbytes > 0]
    bad = [a for a in batch if a.replicated]
    if not bad:
        return []
    total = sum(a.nbytes for a in bad)
    return [Finding(
        code="RA605", where=where,
        message=f"{len(bad)} batch buffer(s) are replicated on the "
                f"{n_shards}-way mesh — per-replica bytes on every shard "
                f"({total}B each) where the per-shard model charges "
                f"{total // n_shards}B",
        hint="shard the batch over the data axis "
             "(NamedSharding(mesh, P('data')) on the tokens input)",
        detail={"indices": [a.index for a in bad], "bytes": total,
                "n_shards": n_shards},
    )]


# ---------------------------------------------------------------------------
# static per-shard peak-memory model
# ---------------------------------------------------------------------------


def per_shard_memory(params: PyTree, opt_state: PyTree, batch: PyTree, *,
                     n_shards: int, reduce_dtype=jnp.bfloat16,
                     shard_state: bool = False) -> dict:
    """Static per-shard peak bytes for one sharded train step, from
    ``ShapeDtypeStruct`` trees (nothing allocates).  Reuses the PR-6
    accountant (:func:`repro.core.api.state_bytes`) for every tree term.

    Model: params are replicated, gradients exist once at fp32 (the
    accumulate) plus once at ``reduce_dtype`` (the wire copy inside the
    psum), and the batch is split 1/N over the data axis — the per-SHARD
    number, which is the whole point (RA605 guards the accountant against
    silently reporting per-replica).  Opt state is replicated in the pure-DP
    variant; with ``shard_state=True`` (ZeRO-sharded fused step) the
    family-stacked low-rank leaves are charged 1/N
    (:func:`repro.sharding.family_state_bytes` — the same divisibility rule
    the runtime shards by)."""
    from repro.sharding import family_state_bytes

    rd = jnp.dtype(reduce_dtype)
    n = max(int(n_shards), 1)
    p_leaves = [x for x in jax.tree_util.tree_leaves(params)
                if hasattr(x, "shape")]
    p_elems = sum(int(_size(x)) for x in p_leaves)
    opt_total = state_bytes(opt_state)
    proj_total = sum(state_bytes(lr) for lr in find_lowrank_states(opt_state))
    fam_total, fam_per_shard = family_state_bytes(opt_state, n)
    saved = (fam_total - fam_per_shard) if shard_state else 0
    out = {
        "n_shards": n,
        "shard_state": bool(shard_state),
        "params_bytes": state_bytes(params),
        "opt_state_bytes": opt_total,
        "opt_state_bytes_per_shard": opt_total - saved,
        "proj_state_bytes": proj_total,
        "proj_state_bytes_per_shard": proj_total - saved,
        "grad_bytes_fp32": p_elems * 4,
        "grad_wire_bytes": p_elems * rd.itemsize,
        "batch_bytes_per_shard": -(-state_bytes(batch) // n),
    }
    out["peak_bytes_per_shard"] = (
        out["params_bytes"] + out["opt_state_bytes_per_shard"]
        + out["grad_bytes_fp32"] + out["grad_wire_bytes"]
        + out["batch_bytes_per_shard"]
    )
    return out


def _size(x) -> int:
    nelem = 1
    for d in jnp.shape(x):
        nelem *= int(d)
    return nelem
