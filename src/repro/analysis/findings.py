"""Structured findings with stable lint codes.

Codes are append-only API: tests, CI filters and allowlists key on them, so
a code's meaning never changes and retired codes are not reused.

  RC1xx — chain linter (static combinator composition)
  RA2xx — dtype-flow auditor (jaxpr)
  RA3xx — launch/fusion auditor (dispatch trace vs closed-form model)
  RA4xx — recompilation-hazard detector (abstract signatures)
  RA5xx — static memory accountant
  RA6xx — collective-schedule / buffer-lifetime auditor (sharded step)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable

CODES: dict[str, str] = {
    # chain linter
    "RC101": "lowrank() nested inside another lowrank()",
    "RC102": "protocol combinator (layerwise_unbias / with_fira_residual) "
             "outside lowrank()",
    "RC103": "scale_by_lr missing or not the terminal chain stage",
    "RC104": "declared rank ladder not strictly increasing",
    "RC105": "initial rank assignment not on the declared ladder",
    "RC106": "pad_rank_to not TPU-lane aligned",
    # dtype-flow auditor
    "RA201": "f32 -> f64 dtype leak in the update path",
    "RA202": "bf16 round-trip inside f32 update math",
    # launch/fusion auditor
    "RA301": "traced kernel-launch counts diverge from the closed-form "
             "FamilyPlan expectation",
    "RA302": "fused_epilogue=True left stray unfused back-projection ops",
    "RA303": "chain contains stages the launch model cannot account for",
    # recompilation-hazard detector
    "RA401": "abstract step signature unstable across retraces at a fixed "
             "rank (unbounded recompilation hazard)",
    "RA402": "weak-typed Python-scalar capture in the traced step",
    # static memory accountant
    "RA501": "static projected-state bytes disagree with recorded runtime "
             "numbers",
    # collective-schedule / buffer-lifetime auditor (sharded step)
    "RA601": "gradient reduction not pinned at the declared reduce_dtype "
             "(wider-dtype collective, or convert not barrier-pinned so "
             "XLA re-promotes the all-reduce)",
    "RA602": "collective executes unconditionally that the schedule model "
             "says is boundary-only",
    "RA603": "full-gradient gather in the steady-state step",
    "RA604": "donated input buffer (params / opt state) does not alias an "
             "output in the lowered module",
    "RA605": "per-replica instead of per-shard buffer in the sharded step "
             "(memory accountant would over-count by the mesh size)",
    "RA606": "traced collective schedule diverges from the closed-form "
             "expectation (count / operands / payload bytes)",
}

_SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer result: a stable code plus human-readable context."""

    code: str
    message: str
    severity: str = "error"
    hint: str = ""              # fix-it suggestion, shown after the message
    where: str = ""             # chain path / op / rank the finding is about
    detail: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unregistered lint code: {self.code!r}")
        if self.severity not in _SEVERITIES:
            raise ValueError(f"bad severity: {self.severity!r}")

    def format(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        hint = f"\n    fix: {self.hint}" if self.hint else ""
        return f"{self.code} {self.severity}{loc}: {self.message}{hint}"


class AuditReport:
    """Findings from one audit run plus the derived summary numbers."""

    def __init__(self, findings: Iterable[Finding] = (),
                 summary: dict[str, Any] | None = None, name: str = ""):
        self.findings = list(findings)
        self.summary = dict(summary or {})
        self.name = name

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def codes(self) -> set[str]:
        return {f.code for f in self.findings}

    def format(self, verbose: bool = False) -> str:
        head = f"audit {self.name}: " if self.name else "audit: "
        head += "clean" if self.ok else f"{len(self.errors)} error(s)"
        lines = [head]
        for f in self.findings:
            if f.severity == "info" and not verbose:
                continue
            lines.append("  " + f.format().replace("\n", "\n  "))
        for k, v in self.summary.items():
            lines.append(f"  {k}={v}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "ok": self.ok,
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "summary": self.summary,
        }
