"""Collective-schedule auditor for the shard_map'ped train step (RA6xx).

The sharded data-parallel step (:mod:`repro.launch.shardmap_fsdp`) encodes
wire-level invariants that silently rot: the gradient reduction must happen
exactly once per step, at the *declared* ``reduce_dtype`` (the
``optimization_barrier`` pin is what keeps XLA's excess-precision pass from
re-promoting the bf16 all-reduce to fp32), and nothing may gather a full
gradient in the steady state.  This pass makes those invariants
machine-checked the same way :mod:`repro.analysis.launch_model` checks
kernel-launch counts:

  * :func:`collect_collectives` walks a ``jax.make_jaxpr`` trace of the step
    — recursing into ``shard_map`` / ``cond`` / ``pjit`` sub-jaxprs — and
    extracts every collective equation (primitive, mesh axes, operand
    dtypes, per-shard payload bytes, whether it is gated behind a refresh
    ``cond``, whether its operands are barrier-pinned).  Each collective
    also records into :mod:`repro.kernels.launch_count` counters, so a
    single ``count_launches()`` context sees dispatch ops and collectives
    side by side.
  * :func:`expected_collective_schedule` derives the closed-form schedule
    from ``chain_info`` × :class:`~repro.core.family_plan.FamilyPlan` ×
    mesh shape: one gradient psum at ``reduce_dtype`` over all param
    leaves, one scalar loss psum (the ``pmean``), and — until ZeRO-style
    sharded projected state lands — zero refresh-boundary gathers (the
    per-family geometry is still reported, since it is exactly what the
    sharded-projector PR will turn into boundary all-gathers).
  * :func:`collective_schedule_findings` diffs traced vs expected and emits
    RA601 (reduction not pinned at the declared dtype), RA602
    (boundary-only collective running unconditionally), RA603
    (full-gradient gather in steady state) and RA606 (schedule divergence).
  * :func:`wire_bytes_model` is the per-step wire-bytes accountant — ring
    coefficients per collective kind, analogous to ``launch_model``'s
    launch-coefficient table.

Everything works on abstract traces over ``ShapeDtypeStruct`` trees and an
``AbstractMesh`` — no devices are needed to audit an N-way mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable

import jax
import jax.numpy as jnp

from repro.core.api import Transform
from repro.kernels import launch_count

from .findings import Finding
from .jaxpr_passes import _subjaxprs, abstract_tree
from .launch_model import lowrank_plan_stats

PyTree = Any

# Primitives treated as collectives when walking the trace.  ``pmean`` never
# appears as its own primitive — jax lowers it to psum + div — so a scalar
# psum is how the loss mean shows up.
COLLECTIVE_PRIMS = frozenset(launch_count.COLLECTIVE_OPS)

# Primitives whose equations gate their sub-jaxprs behind a predicate; a
# collective under one of these runs only when the branch is taken (the
# refresh-boundary pattern), not every step.
_GATED_PRIMS = ("cond",)


@dataclasses.dataclass(frozen=True)
class CollectiveRecord:
    """One collective equation extracted from the traced step."""

    primitive: str                       # psum / all_gather / ...
    axes: tuple[str, ...]                # mesh axis names reduced/gathered over
    dtypes: tuple[str, ...]              # distinct operand element dtypes
    shapes: tuple[tuple[int, ...], ...]  # operand shapes (as seen per shard)
    n_operands: int
    payload_bytes: int                   # sum over operands of shard bytes
    under_cond: bool                     # gated behind a cond => boundary-only
    pinned: bool                         # every operand produced by an
                                         # optimization_barrier equation
    path: tuple[str, ...]                # enclosing primitive names

    @property
    def scalar_only(self) -> bool:
        return all(s == () for s in self.shapes)


def _eqn_axes(eqn) -> tuple[str, ...]:
    for key in ("axes", "axis_name", "axis_index_groups_axis_name"):
        val = eqn.params.get(key)
        if val is None:
            continue
        if isinstance(val, (tuple, list)):
            return tuple(str(a) for a in val)
        return (str(val),)
    return ()


def collect_collectives(jaxpr) -> list[CollectiveRecord]:
    """Every collective equation in ``jaxpr``, recursing into ``shard_map`` /
    ``cond`` / ``pjit`` / ``scan`` sub-jaxprs.  Also records one
    ``launch_count.record(primitive)`` per collective, so active
    ``count_launches()`` contexts count collectives alongside dispatch ops."""
    records: list[CollectiveRecord] = []

    def walk(j, under_cond: bool, path: tuple[str, ...]) -> None:
        core = j.jaxpr if hasattr(j, "jaxpr") else j
        producer: dict[int, Any] = {}
        for eqn in core.eqns:
            name = eqn.primitive.name
            if name in COLLECTIVE_PRIMS:
                avals = [getattr(v, "aval", None) for v in eqn.invars]
                avals = [a for a in avals if a is not None]
                shapes = tuple(tuple(a.shape) for a in avals)
                dtypes = tuple(sorted({a.dtype.name for a in avals}))
                payload = sum(
                    int(a.size) * a.dtype.itemsize for a in avals
                )
                pinned = bool(avals) and all(
                    producer.get(id(v), "") == "optimization_barrier"
                    for v in eqn.invars
                )
                launch_count.record(name)
                records.append(CollectiveRecord(
                    primitive=name,
                    axes=_eqn_axes(eqn),
                    dtypes=dtypes,
                    shapes=shapes,
                    n_operands=len(eqn.invars),
                    payload_bytes=payload,
                    under_cond=under_cond,
                    pinned=pinned,
                    path=path,
                ))
            for v in eqn.outvars:
                producer[id(v)] = name
            gated = under_cond or name in _GATED_PRIMS
            for val in eqn.params.values():
                for sub in _subjaxprs(val):
                    walk(sub, gated, path + (name,))

    walk(jaxpr, False, ())
    return records


# ---------------------------------------------------------------------------
# closed-form schedule model
# ---------------------------------------------------------------------------


def expected_collective_schedule(
    transform: Transform | dict,
    params: PyTree,
    *,
    n_shards: int,
    reduce_dtype=jnp.bfloat16,
    data_axis: str = "data",
    shard_state: bool = False,
) -> dict:
    """The collective schedule the shard_map step must show, derived
    statically from the param tree, the optimizer's ``chain_info`` ×
    :class:`~repro.core.family_plan.FamilyPlan` geometry, and the mesh.

    Steady state (both variants): exactly ONE gradient psum (tree-level, one
    operand per param leaf) at ``reduce_dtype`` plus one scalar f32 loss
    psum (the ``pmean``) — the ZeRO-sharded family math is
    leading-axis-parallel, so sharding the projected state adds nothing to
    the steady schedule.

    Boundary: with replicated state (``shard_state=False``) a projector
    refresh implies no extra wire traffic — zero gathers.  With ZeRO-style
    sharded projected state (``shard_state=True``) the refresh
    re-materializes each shardable family's full stacked gradient: exactly
    one cond-gated ``all_gather`` per fused family whose stack divides the
    mesh axis (``lowrank_common.stack_shardable`` — the same rule the
    runtime applies), with the per-shard fp32 gradient slice as payload.
    """
    from repro.core.lowrank_common import stack_shardable

    rd = jnp.dtype(reduce_dtype)
    leaves = [x for x in jax.tree_util.tree_leaves(params)
              if hasattr(x, "shape")]
    grad_payload = sum(int(_size(x)) * rd.itemsize for x in leaves)
    try:
        plan_rows = lowrank_plan_stats(transform, params)
        n_families = sum(int(r.get("n_families", 0)) for r in plan_rows)
    except Exception:
        plan_rows, n_families = [], 0
    n_gather = gather_payload = 0
    if shard_state:
        for row in plan_rows:
            if not row.get("fused"):
                continue
            for L, m, n in row.get("stack_dims", []):
                if stack_shardable(int(L), int(n_shards)):
                    n_gather += 1
                    # payload as the trace accounts it: the per-shard operand
                    # (the local fp32 gradient slice) of the all_gather
                    gather_payload += int(L) * int(m) * int(n) * 4 \
                        // max(int(n_shards), 1)
    return {
        "grad_psum": {
            "count": 1,
            "dtype": rd.name,
            "operands": len(leaves),
            "payload_bytes": int(grad_payload),
            "axis": data_axis,
            "phase": "steady",
        },
        "loss_psum": {
            "count": 1,
            "dtype": "float32",
            "operands": 1,
            "payload_bytes": 4,
            "axis": data_axis,
            "phase": "steady",
        },
        "boundary_gather": {
            # replicated projected state => refresh implies no gathers;
            # sharded state => one all_gather per shardable fused family.
            "count": int(n_gather),
            "families": int(n_families),
            "payload_bytes": int(gather_payload),
            "phase": "boundary",
        },
        "n_shards": int(n_shards),
        "shard_state": bool(shard_state),
    }


def _size(x) -> int:
    n = 1
    for d in jnp.shape(x):
        n *= int(d)
    return n


# ---------------------------------------------------------------------------
# traced-vs-model findings (RA601/602/603/606)
# ---------------------------------------------------------------------------


def collective_schedule_findings(
    records: Iterable[CollectiveRecord],
    expected: dict,
    *,
    reduce_dtype=jnp.bfloat16,
    params: PyTree | None = None,
    where: str = "sharded-step",
) -> list[Finding]:
    """Diff the traced collectives against the closed-form schedule."""
    records = list(records)
    rd = jnp.dtype(reduce_dtype)
    out: list[Finding] = []

    steady = [r for r in records if not r.under_cond]
    boundary = [r for r in records if r.under_cond]
    grad_red = [r for r in steady if r.primitive == "psum"
                and not r.scalar_only]
    loss_red = [r for r in steady if r.primitive == "psum" and r.scalar_only]
    gathers = [r for r in steady
               if r.primitive in ("all_gather", "all_to_all", "ppermute")]

    param_shapes = set()
    if params is not None:
        param_shapes = {tuple(jnp.shape(x))
                        for x in jax.tree_util.tree_leaves(params)
                        if hasattr(x, "shape")}

    # RA601 — gradient reduction must run at the declared reduce_dtype and,
    # when that dtype is narrower than f32, be barrier-pinned so XLA's
    # excess-precision pass cannot re-promote it on the wire.
    for r in grad_red:
        wide = [dt for dt in r.dtypes if jnp.dtype(dt).itemsize > rd.itemsize]
        if wide:
            out.append(Finding(
                code="RA601", where=where,
                message=f"gradient psum carries {'/'.join(wide)} operands "
                        f"where reduce_dtype={rd.name} was declared — "
                        f"{_bytes(r.payload_bytes)} on the wire instead of "
                        f"{_bytes(r.payload_bytes * rd.itemsize // max(jnp.dtype(wide[0]).itemsize, 1))}",
                hint="cast gradients to the declared reduce_dtype before "
                     "jax.lax.psum (see launch/shardmap_fsdp.grad_body)",
                detail={"dtypes": list(r.dtypes), "declared": rd.name},
            ))
        elif rd.itemsize < 4 and not r.pinned:
            out.append(Finding(
                code="RA601", where=where,
                message=f"gradient psum at {rd.name} is not "
                        "optimization_barrier-pinned — XLA's excess-precision "
                        "pass may fold the convert into the all-reduce and "
                        "re-promote it to fp32, silently doubling wire bytes",
                hint="wrap the casted gradients in "
                     "jax.lax.optimization_barrier before the psum "
                     "(the guard launch/shardmap_fsdp.grad_body documents)",
                detail={"dtypes": list(r.dtypes), "declared": rd.name},
            ))

    # RA602/RA603 — no gathers in steady state on this path.
    for r in gathers:
        shapes = set(r.shapes)
        full = bool(param_shapes and (
            shapes & param_shapes
            or {s[1:] for s in shapes if len(s) > 1} & param_shapes))
        if full:
            out.append(Finding(
                code="RA603", where=where,
                message=f"steady-state {r.primitive} materializes a "
                        "full-gradient/param-shaped buffer "
                        f"({_bytes(r.payload_bytes)}) every step — gathers "
                        "belong at refresh boundaries only",
                hint="gate the gather behind the refresh cond (one gather "
                     "per family per boundary), compute sharded otherwise",
                detail={"shapes": [list(s) for s in r.shapes]},
            ))
        else:
            out.append(Finding(
                code="RA602", where=where,
                message=f"unconditional {r.primitive} over "
                        f"axes={list(r.axes)} in the steady-state step — the "
                        "schedule model marks this collective boundary-only",
                hint="move it under the refresh cond / boundary branch",
                detail={"primitive": r.primitive,
                        "payload_bytes": r.payload_bytes},
            ))

    # RA606 — counts / operands / payload must match the closed-form model.
    exp_g = expected["grad_psum"]
    got = {
        "count": len(grad_red),
        "operands": sum(r.n_operands for r in grad_red),
        "payload_bytes": sum(r.payload_bytes for r in grad_red),
    }
    want = {k: exp_g[k] for k in got}
    # dtype mismatches are RA601's finding; exclude their payload delta so a
    # single root cause doesn't double-report.
    dtype_ok = all(
        not [dt for dt in r.dtypes if jnp.dtype(dt).itemsize > rd.itemsize]
        for r in grad_red
    )
    if got["count"] != want["count"] or got["operands"] != want["operands"] \
            or (dtype_ok and got["payload_bytes"] != want["payload_bytes"]):
        out.append(Finding(
            code="RA606", where=where,
            message="traced gradient-reduction schedule diverges from the "
                    f"closed-form model: traced {got}, expected {want}",
            hint="one tree-level psum over every param leaf at reduce_dtype "
                 "is the contract; per-leaf psums or dropped leaves break it",
            detail={"traced": got, "expected": want},
        ))
    if len(loss_red) != expected["loss_psum"]["count"]:
        out.append(Finding(
            code="RA606", where=where,
            message=f"{len(loss_red)} scalar loss reduction(s) traced, "
                    f"expected {expected['loss_psum']['count']} (the pmean)",
            detail={"traced": len(loss_red)},
        ))
    exp_b = expected.get("boundary_gather", {"count": 0})
    n_boundary = len([r for r in boundary
                      if r.primitive in ("all_gather", "reduce_scatter",
                                         "all_to_all")])
    if n_boundary != exp_b["count"]:
        out.append(Finding(
            code="RA606", where=where,
            message=f"{n_boundary} boundary-gated gather(s) traced, expected "
                    f"{exp_b['count']} (refresh implies "
                    f"{exp_b['count']} per boundary on this path)",
            detail={"traced": n_boundary, "expected": exp_b["count"]},
        ))
    return out


# ---------------------------------------------------------------------------
# wire-bytes accountant
# ---------------------------------------------------------------------------

# Bytes each shard moves over the wire per payload byte, ring algorithms
# (the coefficient table — launch_model.py's _BASE_COEFFS analogue).
_RING_COEFF = {
    "psum": lambda n: 2.0 * (n - 1) / n,            # reduce-scatter+all-gather
    "all_gather": lambda n: (n - 1) / n,
    "reduce_scatter": lambda n: (n - 1) / n,
    "all_to_all": lambda n: (n - 1) / n,
    "ppermute": lambda n: 1.0 if n > 1 else 0.0,
}


def wire_bytes_model(records: Iterable[CollectiveRecord],
                     n_shards: int) -> dict:
    """Per-step wire bytes each shard sends, from the traced collectives and
    ring coefficients.  ``steady_bytes_per_step`` counts unconditional
    collectives; ``boundary_bytes`` counts the cond-gated ones (paid only on
    refresh steps)."""
    n = max(int(n_shards), 1)
    per: list[dict] = []
    steady = boundary = 0
    for r in records:
        coeff = _RING_COEFF.get(r.primitive)
        if coeff is None:
            continue
        wire = int(r.payload_bytes * coeff(n)) if n > 1 else 0
        per.append({
            "primitive": r.primitive,
            "payload_bytes": r.payload_bytes,
            "wire_bytes": wire,
            "phase": "boundary" if r.under_cond else "steady",
            "dtypes": list(r.dtypes),
        })
        if r.under_cond:
            boundary += wire
        else:
            steady += wire
    return {
        "n_shards": n,
        "steady_bytes_per_step": steady,
        "boundary_bytes": boundary,
        "per_collective": per,
    }


def _bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n}B"
        n /= 1024
    return f"{n}B"


# ---------------------------------------------------------------------------
# tracing the sharded step without devices
# ---------------------------------------------------------------------------


def trace_sharded_step(model, optimizer: Transform, *, n_shards: int,
                       batch_size: int = 8, seq_len: int | None = None,
                       reduce_dtype=jnp.bfloat16, grad_clip: float = 1.0,
                       data_axis: str = "data", shard_state: bool = False):
    """Abstractly trace :func:`repro.launch.shardmap_fsdp.make_shardmap_train_step`
    on an ``AbstractMesh`` of ``n_shards`` devices — no real devices needed.

    Returns ``(jaxpr, records, counts, structs)`` where ``records`` are the
    extracted :class:`CollectiveRecord`s, ``counts`` the launch counter over
    the whole step (dispatch ops + collectives), and ``structs`` the
    ``(params, opt_state, batch)`` ShapeDtypeStructs the trace used.
    """
    from jax.sharding import AbstractMesh

    from repro.launch.shardmap_fsdp import make_shardmap_train_step

    mesh = AbstractMesh(((data_axis, int(n_shards)),))
    step, _ = make_shardmap_train_step(
        model, optimizer, mesh,
        grad_clip=grad_clip, reduce_dtype=reduce_dtype, data_axis=data_axis,
        shard_state=shard_state,
    )
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params = abstract_tree(params)
    opt_state = jax.eval_shape(optimizer.init, params)
    if batch_size % int(n_shards):
        raise ValueError(
            f"batch_size={batch_size} not divisible by n_shards={n_shards}")
    seq = int(seq_len if seq_len is not None else min(64, model.cfg.max_seq))
    batch = {"tokens": jax.ShapeDtypeStruct((int(batch_size), seq),
                                            jnp.int32)}
    with launch_count.count_launches() as counts:
        jaxpr = jax.make_jaxpr(step)(params, opt_state, batch)
        records = collect_collectives(jaxpr)
    return jaxpr, records, counts, (params, opt_state, batch)
