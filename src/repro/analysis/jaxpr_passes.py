"""Jaxpr-level analyzer passes: trace the update, never execute it.

Everything here works on ``jax.make_jaxpr`` / ``jax.eval_shape`` output over
``ShapeDtypeStruct`` trees — no real arrays are materialized and no kernel
runs, so the passes are safe to run at build time on any host.

Passes (stable codes in :mod:`repro.analysis.findings`):

  * dtype-flow audit — ``RA201`` flags f64 creeping into the update path
    (silently doubling state bytes and halving MXU throughput), ``RA202``
    flags bf16 round-trips *inside* the f32 update math (a downcast whose
    result is upcast again lost 16 bits of mantissa for nothing).
  * recompilation hazards — ``RA401`` retraces the step at a fixed rank and
    compares abstract signatures (a mismatch means every step recompiles);
    ``RA402`` flags weak-typed 0-d closure captures, the classic way Python
    scalars leak into the cache key.
  * static memory accountant — projected-state bytes straight from the
    ``eval_shape``'d optimizer state, cross-checked (``RA501``) against the
    runtime numbers recorded in ``results/BENCH_rank_policy.json``.
"""
from __future__ import annotations

import hashlib
import json
import re
from pathlib import Path
from typing import Callable, Iterable, Iterator

import jax
import jax.numpy as jnp

from repro.core.api import Transform, state_bytes
from repro.core.combinators import find_lowrank_states

from .findings import Finding

# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def abstract_tree(tree):
    """The ``ShapeDtypeStruct`` skeleton of a pytree (identity on structs)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)), tree
    )


def trace_update(transform: Transform, params):
    """Trace one optimizer step abstractly.

    Returns ``(closed_jaxpr, state_structs)`` where the jaxpr is of
    ``update(grads, state, params)`` over gradient structs shaped like
    ``params``.  Nothing executes."""
    p = abstract_tree(params)
    state = jax.eval_shape(transform.init, p)
    jaxpr = jax.make_jaxpr(
        lambda g, s, w: transform.update(g, s, w))(p, state, p)
    return jaxpr, state


def _subjaxprs(value) -> Iterator:
    if isinstance(value, jax.extend.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jax.extend.core.Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _subjaxprs(v)


def iter_eqns(jaxpr) -> Iterator:
    """All equations of a (Closed)Jaxpr, recursing into control-flow /
    pjit / scan sub-jaxprs, in trace order."""
    if hasattr(jaxpr, "jaxpr"):           # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub)


# ---------------------------------------------------------------------------
# dtype flow (RA2xx)
# ---------------------------------------------------------------------------

_LOW = (jnp.bfloat16, jnp.float16)
_HIGH32 = (jnp.float32, jnp.float64)


def dtype_flow_findings(jaxpr, *, allow_bf16_roundtrip: bool = False,
                        where: str = "step") -> list[Finding]:
    """RA201 (f32 -> f64 leaks) and RA202 (bf16 round-trips) over a traced
    step.  ``allow_bf16_roundtrip`` is the per-optimizer allowlist knob for
    transforms that deliberately stage through bf16."""
    out: list[Finding] = []
    f64_prims: dict[str, int] = {}
    downcast: set[int] = set()       # ids of vars produced by f32->bf16/f16
    roundtrips = 0
    for eqn in iter_eqns(jaxpr):
        prim = eqn.primitive.name
        for v in eqn.outvars:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and dt == jnp.float64:
                f64_prims[prim] = f64_prims.get(prim, 0) + 1
        if prim == "convert_element_type":
            src = getattr(eqn.invars[0].aval, "dtype", None)
            dst = getattr(eqn.outvars[0].aval, "dtype", None)
            if src in _HIGH32 and dst in _LOW:
                downcast.add(id(eqn.outvars[0]))
            elif (src in _LOW and dst in _HIGH32
                  and id(eqn.invars[0]) in downcast):
                roundtrips += 1
    if f64_prims:
        total = sum(f64_prims.values())
        tops = ", ".join(f"{k}x{v}" for k, v in sorted(f64_prims.items())[:4])
        out.append(Finding(
            code="RA201", where=where,
            message=f"{total} f64 value(s) in the traced update ({tops}) — "
                    "the update path is f32-by-contract",
            hint="find the float64 promotion (usually a numpy scalar or "
                 "x64-enabled constant) and cast to jnp.float32",
            detail={"per_primitive": f64_prims},
        ))
    if roundtrips and not allow_bf16_roundtrip:
        out.append(Finding(
            code="RA202", where=where,
            message=f"{roundtrips} bf16/f16 round-trip(s) inside f32 update "
                    "math — a downcast immediately re-upcast loses mantissa "
                    "for no memory win",
            hint="keep optimizer math in f32 end-to-end, or allowlist the "
                 "optimizer (allow_bf16_roundtrip=True) if the staging is "
                 "deliberate",
            detail={"roundtrips": roundtrips},
        ))
    return out


# ---------------------------------------------------------------------------
# recompilation hazards (RA4xx)
# ---------------------------------------------------------------------------


def signature_hash(jaxpr) -> str:
    """Stable digest of a traced step's abstract signature: input/output
    avals plus the full program text.  Equal hashes => jit cache hit."""
    core = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    h = hashlib.sha256()
    for v in list(core.invars) + list(core.outvars):
        h.update(str(getattr(v, "aval", v)).encode())
    h.update(str(core).encode())
    return h.hexdigest()[:16]


def recompile_findings(
    make_transform: Callable[[int], Transform],
    params,
    ladder: Iterable[int],
    *,
    where: str = "step",
) -> tuple[list[Finding], dict[int, str]]:
    """Trace the step twice per ladder rank and compare signatures.

    Returns ``(findings, {rank: signature_hash})``.  RA401 (error): the two
    traces of the *same* rank disagree — something non-deterministic or
    Python-id-dependent is in the trace, so every step would recompile.
    RA402 (warning): weak-typed 0-d constvars closed over by the step — a
    Python scalar captured as a weak constant re-keys the jit cache whenever
    its producing code path changes."""
    out: list[Finding] = []
    hashes: dict[int, str] = {}
    for rank in ladder:
        t = make_transform(int(rank))
        j1, _ = trace_update(t, params)
        j2, _ = trace_update(t, params)
        h1, h2 = signature_hash(j1), signature_hash(j2)
        hashes[int(rank)] = h1
        if h1 != h2:
            out.append(Finding(
                code="RA401", where=f"{where}@rank{rank}",
                message=f"abstract step signature unstable across retraces "
                        f"at rank {rank} ({h1} != {h2}) — every jit call "
                        "would recompile",
                hint="hunt for trace-order nondeterminism (dict iteration "
                     "over id()s, fresh closures per trace) in the chain",
            ))
        weak = [v for v in j1.jaxpr.constvars
                if getattr(v.aval, "weak_type", False)
                and getattr(v.aval, "shape", None) == ()]
        if weak:
            out.append(Finding(
                code="RA402", severity="warning", where=f"{where}@rank{rank}",
                message=f"{len(weak)} weak-typed 0-d constant(s) captured by "
                        "the traced step — Python scalars in the closure "
                        "re-key the jit cache on unrelated code changes",
                hint="materialize captured scalars with an explicit dtype, "
                     "e.g. jnp.asarray(x, jnp.float32)",
                detail={"count": len(weak)},
            ))
    return out, hashes


# ---------------------------------------------------------------------------
# static memory accountant (RA5xx)
# ---------------------------------------------------------------------------


def projected_state_bytes(transform: Transform, params) -> int:
    """Bytes of every LowRankState (projectors + projected momenta + probe
    slots) in the ``eval_shape``'d optimizer state — the Table-1 quantity,
    computed without allocating anything."""
    state = jax.eval_shape(transform.init, abstract_tree(params))
    return sum(state_bytes(lr) for lr in find_lowrank_states(state))


_RANKMAP_RE = re.compile(r"RankMap\(default=(\d+), overrides=\{([^}]*)\}\)")
_OVERRIDE_RE = re.compile(r"'(\d+)x(\d+)':\s*(\d+)")


def _parse_rank_map(text: str):
    from repro.core.rank_policy import RankMap

    m = _RANKMAP_RE.match(text)
    if not m:
        raise ValueError(f"unparseable RankMap repr: {text!r}")
    overrides = {(int(a), int(b)): int(r)
                 for a, b, r in _OVERRIDE_RE.findall(m.group(2))}
    return RankMap(int(m.group(1)), overrides)


def memory_crosscheck(
    bench_path: str | Path = "results/BENCH_rank_policy.json",
) -> list[Finding]:
    """RA501: recompute each policy's final projected-state bytes statically
    (eval_shape at the recorded final RankMap) and require exact agreement
    with the runtime ``proj_bytes_final`` committed by the rank-policy
    benchmark.  Skips (info finding) when the benchmark JSON is absent."""
    path = Path(bench_path)
    if not path.exists():
        return [Finding(
            code="RA501", severity="info", where=str(path),
            message="no recorded rank-policy benchmark to cross-check "
                    "against",
            hint="run PYTHONPATH=src python benchmarks/rank_policy.py to "
                 "record one",
        )]

    from repro.configs import get_smoke
    from repro.core import OptimizerConfig, build_optimizer
    from repro.core.rank_policy import RankMap
    from repro.models import build_model

    data = json.loads(path.read_text())
    cfg = data["config"]
    model = build_model(get_smoke(cfg["arch"].replace("-smoke", "")))
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    out: list[Finding] = []
    for policy, res in data["results"].items():
        history = res.get("rank_history") or []
        final_map = (_parse_rank_map(history[-1][1]) if history
                     else RankMap(int(cfg["rank"])))
        opt_cfg = OptimizerConfig(
            name=cfg["opt"], lr=1e-2, rank=int(cfg["rank"]), gamma=1,
            period=int(cfg["period"]), base="muon",
            rank_policy=cfg.get("policies", {}).get(policy),
            rank_ladder=tuple(cfg.get("ladder", ())),
        )
        opt = build_optimizer(opt_cfg, rank_map=final_map)
        static = projected_state_bytes(opt, params)
        recorded = int(res["proj_bytes_final"])
        if static != recorded:
            out.append(Finding(
                code="RA501", where=f"{path.name}:{policy}",
                message=f"static projected-state bytes {static} != recorded "
                        f"proj_bytes_final {recorded} "
                        f"(final map {final_map!r})",
                hint="the state layout changed since the benchmark was "
                     "recorded — re-run benchmarks/rank_policy.py or fix "
                     "the regression",
                detail={"static": static, "recorded": recorded},
            ))
    return out
