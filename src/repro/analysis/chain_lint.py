"""Chain linter: combinator-composition rules checked statically.

Walks the ``chain_info`` metadata every combinator attaches (see
:func:`repro.core.combinators.chain_info`) — no tracing, no arrays.  Rules
(stable codes, see :mod:`repro.analysis.findings`):

  RC101  ``lowrank()`` must not nest: the projection owns the leaf protocol
         end-to-end; a nested projection would project projected gradients.
  RC102  ``layerwise_unbias`` / ``with_fira_residual`` consume the
         ProjGrad/ProjInit protocol, so they only work inside ``lowrank()``.
  RC103  ``scale_by_lr`` is the terminal stage of a chain: it materializes
         deferred epilogues and owns the -lr sign; a stage after it would
         scale an already-signed update, and inside ``lowrank()`` it would
         double-count steps.
  RC104  a declared rank ladder must be strictly increasing.
  RC105  the initial rank assignment must lie on the declared ladder —
         otherwise the first policy decision forces an extra, unplanned
         recompilation.
  RC106  ``pad_rank_to`` must be a multiple of the TPU lane width (128) —
         any other value mis-tiles the MXU without removing raggedness.

The rank-declaration checks (RC104/RC105) see the *declared* values; the
per-leaf ``min(rank, m, n)`` clamp is shape-dependent and out of scope here
(the jaxpr passes see the clamped shapes).
"""
from __future__ import annotations

from typing import Iterable, Optional

from repro.core.api import Transform
from repro.core.combinators import chain_info as _chain_info
from repro.kernels.dispatch import _LANE as LANE

from .findings import Finding

_PROTOCOL_KINDS = ("layerwise_unbias", "with_fira_residual")


class ChainLintError(ValueError):
    """Raised by ``build_optimizer(..., audit=True)`` on lint errors."""

    def __init__(self, findings: list[Finding]):
        self.findings = findings
        super().__init__(
            "chain lint failed:\n" + "\n".join(f.format() for f in findings)
        )


def _declared_ranks(rank) -> tuple[int, ...]:
    """Every rank an ``int | RankMap`` assignment declares."""
    if isinstance(rank, int):
        return (rank,)
    ranks = {rank.default}
    ranks.update(r for _, r in rank.overrides)
    return tuple(sorted(ranks))


def _lint_ladder(ladder, where: str, out: list[Finding]) -> None:
    lad = tuple(int(r) for r in ladder)
    if any(b <= a for a, b in zip(lad, lad[1:])):
        out.append(Finding(
            code="RC104", where=where,
            message=f"rank ladder {lad} is not strictly increasing",
            hint="declare the ladder sorted ascending with no duplicates, "
                 f"e.g. {tuple(sorted(set(lad)))}",
        ))


def _lint_lowrank(info: dict, where: str,
                  ladder: Optional[tuple[int, ...]], out: list[Finding]):
    pad = int(info.get("pad_rank_to") or 0)
    if pad and pad % LANE != 0:
        out.append(Finding(
            code="RC106", where=where,
            message=f"pad_rank_to={pad} is not a multiple of the TPU lane "
                    f"width {LANE}",
            hint=f"use pad_rank_to={((pad + LANE - 1) // LANE) * LANE} "
                 "(or 0 for the minimal sublane granule)",
        ))
    policy = info.get("rank_policy")
    # The ladder the initial assignment is held against: an explicitly
    # declared one always wins; otherwise adaptive policies are checked
    # against their own ladder (static policies like stepwise may start at
    # the config rank off-ladder by design — at most one extra compile).
    check = None
    if ladder:
        check = tuple(int(r) for r in ladder)
    elif policy is not None and getattr(policy, "wants_probes", False):
        check = tuple(policy.ladder())
    if check:
        declared = _declared_ranks(info.get("rank"))
        off = [r for r in declared if r not in check]
        if off:
            out.append(Finding(
                code="RC105", where=where,
                message=f"initial rank(s) {off} not on the declared ladder "
                        f"{check}",
                hint="start on a ladder rank (or add the rank to "
                     "rank_ladder) so the first policy decision does not "
                     "force an unplanned recompilation",
            ))


def _contains_kind(info: dict, kind: str) -> bool:
    if info.get("kind") == kind:
        return True
    for child in info.get("stages", []):
        if _contains_kind(child, kind):
            return True
    for child in info.get("branches", {}).values():
        if _contains_kind(child, kind):
            return True
    inner = info.get("inner")
    return bool(inner) and _contains_kind(inner, kind)


def _walk(info: dict, where: str, inside_lowrank: bool,
          ladder: Optional[tuple[int, ...]], out: list[Finding]) -> None:
    kind = info.get("kind", "opaque")
    if kind == "multi_transform":
        for label, branch in info.get("branches", {}).items():
            _walk(branch, f"{where}/{label}", inside_lowrank, ladder, out)
    elif kind == "chain":
        stages = info.get("stages", [])
        for i, stage in enumerate(stages):
            if stage.get("kind") == "scale_by_lr":
                if inside_lowrank:
                    out.append(Finding(
                        code="RC103", where=f"{where}/stage{i}",
                        message="scale_by_lr composed inside lowrank() — "
                                "it would scale the projected-space update "
                                "and keep its own step count",
                        hint="move scale_by_lr to the end of the outer "
                             "chain, after the lowrank() stage",
                    ))
                elif i != len(stages) - 1:
                    out.append(Finding(
                        code="RC103", where=f"{where}/stage{i}",
                        message=f"scale_by_lr at stage {i} of "
                                f"{len(stages)} — stages after it rescale "
                                "an already-signed update and deferred "
                                "epilogues are materialized too early",
                        hint="make scale_by_lr the last stage of the chain",
                    ))
        if (not inside_lowrank
                and _contains_kind(info, "lowrank")
                and not any(s.get("kind") == "scale_by_lr" for s in stages)):
            out.append(Finding(
                code="RC103", severity="warning", where=where,
                message="chain has a lowrank() stage but no terminal "
                        "scale_by_lr — fused epilogues fall back to "
                        "per-leaf materialization in apply_updates",
                hint="end the chain with scale_by_lr(lr)",
            ))
        for i, stage in enumerate(stages):
            _walk(stage, f"{where}/stage{i}", inside_lowrank, ladder, out)
    elif kind == "lowrank":
        if inside_lowrank:
            out.append(Finding(
                code="RC101", where=where,
                message="lowrank() nested inside another lowrank() — the "
                        "inner projection would re-project already-projected "
                        "gradients and double the projector state",
                hint="compose exactly one lowrank() per chain; put the "
                     "inner transform directly inside it",
            ))
        _lint_lowrank(info, where, ladder, out)
        _walk(info.get("inner", {}), f"{where}/inner", True, ladder, out)
    elif kind in _PROTOCOL_KINDS:
        if not inside_lowrank:
            out.append(Finding(
                code="RC102", where=where,
                message=f"{kind} outside lowrank() — it consumes the "
                        "ProjGrad/ProjInit leaf protocol that only "
                        "lowrank() emits (TypeError at the first step)",
                hint=f"wrap it: lowrank({kind}(...), rank=..., period=...)",
            ))
        _walk(info.get("inner", {}), f"{where}/inner", inside_lowrank,
              ladder, out)
    elif "inner" in info:
        _walk(info["inner"], f"{where}/inner", inside_lowrank, ladder, out)


def lint_chain(
    transform: Transform | dict,
    *,
    ladder: Iterable[int] = (),
    name: str = "chain",
) -> list[Finding]:
    """Lint a combinator-built transform (or a raw ``chain_info`` dict).

    ``ladder`` is the externally declared rank ladder
    (``OptimizerConfig.rank_ladder`` / ``--rank-ladder``) the initial rank
    assignment is held against; adaptive policies are additionally checked
    against their own ladder."""
    info = transform if isinstance(transform, dict) else _chain_info(transform)
    out: list[Finding] = []
    lad = tuple(int(r) for r in ladder)
    if lad:
        _lint_ladder(lad, name, out)
    _walk(info, name, False, lad or None, out)
    return out
