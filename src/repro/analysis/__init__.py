"""repro.analysis — static analysis of the optimizer step (PR 6).

The paper's promise — unbiased low-rank updates at GaLore-class memory cost
— only holds if the implementation keeps its invariants: debiasing stays in
the compute dtype, the fused engine launches once per shape family, and the
projected-state bytes match the Table-1 accounting.  This package *proves*
those invariants on the traced program, before a single real step runs:

  * :mod:`~repro.analysis.chain_lint` — combinator-composition rules checked
    on the static ``chain_info`` metadata (``RC1xx`` codes): ``lowrank()``
    not nested, ``layerwise_unbias`` inside the projection, ``scale_by_lr``
    terminal, ladder monotone and containing the initial rank,
    ``pad_rank_to`` lane-aligned.
  * :mod:`~repro.analysis.launch_model` — the closed-form expected kernel
    launch count derived from the chain composition and the
    :class:`~repro.core.family_plan.FamilyPlan`, asserted against the
    dispatch layer's trace-time counter (``RA3xx``).
  * :mod:`~repro.analysis.jaxpr_passes` — jaxpr-level passes over
    ``jax.make_jaxpr`` of the update (no real arrays, nothing executes):
    dtype-flow audit (``RA2xx``), recompilation-hazard detection across a
    declared rank ladder (``RA4xx``), and the static memory accountant
    (``RA5xx``) cross-checked against ``results/BENCH_rank_policy.json``.
  * :mod:`~repro.analysis.collectives` — collective-schedule auditor for
    the ``shard_map`` FSDP step (``RA601/602/603/606``): every collective
    extracted from the traced step (on an ``AbstractMesh`` — no devices),
    diffed against the closed-form schedule (one barrier-pinned
    ``reduce_dtype`` gradient psum + one loss pmean per steady-state step,
    gathers only at refresh boundaries), with a ring-coefficient wire-bytes
    accountant per step.
  * :mod:`~repro.analysis.buffers` — buffer-lifetime auditor on the lowered
    jit module (``RA604/605``): donated params/opt_state really alias
    outputs (``tf.aliasing_output``), the batch is per-shard not
    per-replica, and a static per-shard peak-memory model.
  * :mod:`~repro.analysis.audit` — the orchestrator and CLI::

        PYTHONPATH=src python -m repro.analysis.audit --optimizer gum \
            --fuse-families --fused-epilogue --rank-ladder 16,32,64
        PYTHONPATH=src python -m repro.analysis.audit --matrix
        PYTHONPATH=src python -m repro.analysis.audit --sharded --mesh data=8

Wired into ``build_optimizer(..., audit=True)`` (chain lint at build time),
``launch/dryrun.py --audit`` (full audit per compiled cell),
``launch/train.py --audit`` (full audit incl. sharded passes before step 0)
and the ``Trainer`` startup log (one-line summary: launches/step, state
bytes, signature hash, donation when a mesh is configured).
"""
from .audit import audit_optimizer, audit_sharded, audit_summary, run_matrix
from .buffers import (
    ArgInfo,
    donation_findings,
    parse_main_args,
    per_shard_memory,
    replication_findings,
)
from .chain_lint import ChainLintError, lint_chain
from .collectives import (
    CollectiveRecord,
    collect_collectives,
    collective_schedule_findings,
    expected_collective_schedule,
    trace_sharded_step,
    wire_bytes_model,
)
from .findings import CODES, AuditReport, Finding
from .jaxpr_passes import (
    dtype_flow_findings,
    memory_crosscheck,
    projected_state_bytes,
    recompile_findings,
    signature_hash,
    trace_update,
)
from .launch_model import expected_launches, lowrank_plan_stats

__all__ = [
    "ArgInfo", "AuditReport", "CODES", "ChainLintError",
    "CollectiveRecord", "Finding",
    "audit_optimizer", "audit_sharded", "audit_summary",
    "collect_collectives", "collective_schedule_findings",
    "donation_findings", "dtype_flow_findings",
    "expected_collective_schedule", "expected_launches", "lint_chain",
    "lowrank_plan_stats", "memory_crosscheck", "parse_main_args",
    "per_shard_memory", "projected_state_bytes", "recompile_findings",
    "replication_findings", "run_matrix", "signature_hash",
    "trace_sharded_step", "trace_update", "wire_bytes_model",
]
