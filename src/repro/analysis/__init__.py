"""repro.analysis — static analysis of the optimizer step (PR 6).

The paper's promise — unbiased low-rank updates at GaLore-class memory cost
— only holds if the implementation keeps its invariants: debiasing stays in
the compute dtype, the fused engine launches once per shape family, and the
projected-state bytes match the Table-1 accounting.  This package *proves*
those invariants on the traced program, before a single real step runs:

  * :mod:`~repro.analysis.chain_lint` — combinator-composition rules checked
    on the static ``chain_info`` metadata (``RC1xx`` codes): ``lowrank()``
    not nested, ``layerwise_unbias`` inside the projection, ``scale_by_lr``
    terminal, ladder monotone and containing the initial rank,
    ``pad_rank_to`` lane-aligned.
  * :mod:`~repro.analysis.launch_model` — the closed-form expected kernel
    launch count derived from the chain composition and the
    :class:`~repro.core.family_plan.FamilyPlan`, asserted against the
    dispatch layer's trace-time counter (``RA3xx``).
  * :mod:`~repro.analysis.jaxpr_passes` — jaxpr-level passes over
    ``jax.make_jaxpr`` of the update (no real arrays, nothing executes):
    dtype-flow audit (``RA2xx``), recompilation-hazard detection across a
    declared rank ladder (``RA4xx``), and the static memory accountant
    (``RA5xx``) cross-checked against ``results/BENCH_rank_policy.json``.
  * :mod:`~repro.analysis.audit` — the orchestrator and CLI::

        PYTHONPATH=src python -m repro.analysis.audit --optimizer gum \
            --fuse-families --fused-epilogue --rank-ladder 16,32,64
        PYTHONPATH=src python -m repro.analysis.audit --matrix

Wired into ``build_optimizer(..., audit=True)`` (chain lint at build time),
``launch/dryrun.py --audit`` (full audit per compiled cell) and the
``Trainer`` startup log (one-line summary: launches/step, state bytes,
signature hash).
"""
from .audit import audit_optimizer, audit_summary, run_matrix
from .chain_lint import ChainLintError, lint_chain
from .findings import CODES, AuditReport, Finding
from .jaxpr_passes import (
    dtype_flow_findings,
    memory_crosscheck,
    projected_state_bytes,
    recompile_findings,
    signature_hash,
    trace_update,
)
from .launch_model import expected_launches, lowrank_plan_stats

__all__ = [
    "AuditReport", "CODES", "ChainLintError", "Finding",
    "audit_optimizer", "audit_summary", "dtype_flow_findings",
    "expected_launches", "lint_chain", "lowrank_plan_stats",
    "memory_crosscheck", "projected_state_bytes", "recompile_findings",
    "run_matrix", "signature_hash", "trace_update",
]
