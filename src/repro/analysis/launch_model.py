"""Closed-form expected kernel-launch counts.

The fused step engine's contract is "one pipeline launch set per shape
family" (per matrix leaf on the per-leaf path).  This module derives the
*expected* per-step dispatch counts purely from static structure — the
``chain_info`` composition metadata plus the
:class:`~repro.core.family_plan.FamilyPlan` of an abstract params tree — so
the audit can assert them against the dispatch layer's recorded counts
(:mod:`repro.kernels.launch_count`) without running a step.

Per *unit* (family when ``fuse_families=True``, lowrank-routed leaf
otherwise) the inner transform determines the op mix:

  ====================================  =======================================
  inner                                 launches / unit
  ====================================  =======================================
  ``scale_by_adam``                     project, back_project
  ``scale_by_muon``                     lowrank_update, newton_schulz,
                                        back_project
  ``scale_by_momentum``                 lowrank_update, back_project
  ``layerwise_unbias(x)``               x's mix with lowrank_update -> project
                                        (the unbias needs the explicit
                                        projected gradient and emits a
                                        FullUpdate, so no epilogue fusion);
                                        units with sampling ratio
                                        ``q = gamma/L < 1`` (leaves with lead
                                        blocks) additionally run the plain
                                        low-rank branch, adding x's mix as-is
  ``with_fira_residual(x)``             x's mix + 1 back_project (the
                                        norm-matched residual)
  ====================================  =======================================

``fused_epilogue=True`` rewrites ``back_project`` ->
``back_project_epilogue`` for epilogue-able inners (those that return a
projected update rather than a FullUpdate).  Outside ``lowrank()``, plain
``scale_by_muon`` contributes one ``newton_schulz`` per >=2-D routed leaf;
every other combinator is elementwise jnp (zero dispatch launches).

Stages the model cannot account for produce an ``RA303`` finding instead of
a silently wrong expectation.
"""
from __future__ import annotations

import jax

from repro.core.api import Transform
from repro.core.combinators import chain_info as _chain_info
from repro.core.family_plan import build_family_plan, plan_stats
from repro.core.lowrank_common import family_shape

from .findings import Finding

# Combinators that never touch the dispatch layer (pure jnp elementwise).
_ELEMENTWISE = frozenset({
    "scale_by_lr", "scale_by_factor", "add_decayed_weights",
    "clip_by_global_norm",
})
# Zero-launch leaf optimizers when applied to raw (unprojected) gradients.
_RAW_ZERO = frozenset({"scale_by_adam", "scale_by_momentum", "lisa"})

_BASE_COEFFS = {
    "scale_by_adam": ({"project": 1, "back_project": 1}, True),
    "scale_by_muon": (
        {"lowrank_update": 1, "newton_schulz": 1, "back_project": 1}, True),
    "scale_by_momentum": ({"lowrank_update": 1, "back_project": 1}, True),
}


def _ra303(where: str, what: str) -> Finding:
    return Finding(
        code="RA303", where=where,
        message=f"launch model cannot account for {what}",
        hint="tag the transform with chain_info metadata (see "
             "repro.core.combinators) or extend the coefficient table in "
             "repro.analysis.launch_model",
    )


def _inner_coeffs(info: dict, where: str, out: list[Finding]):
    """Per-unit op coefficients of a lowrank() inner -> (coeffs, epilogue_able).

    ``epilogue_able`` means the inner returns a projected update that
    ``fused_epilogue`` can defer; protocol wrappers that emit a FullUpdate
    (layerwise_unbias, with_fira_residual) are not."""
    kind = info.get("kind", "opaque")
    if kind == "chain":
        cores = [s for s in info.get("stages", [])
                 if s.get("kind") not in _ELEMENTWISE]
        if len(cores) != 1:
            out.append(_ra303(where, f"a lowrank() inner chain with "
                                     f"{len(cores)} non-elementwise stages"))
            return None, False
        return _inner_coeffs(cores[0], where, out)
    if kind == "layerwise_unbias":
        coeffs, _ = _inner_coeffs(info.get("inner", {}), f"{where}/inner", out)
        if coeffs is None:
            return None, False
        coeffs = dict(coeffs)
        coeffs["project"] = coeffs.get("project", 0) + coeffs.pop(
            "lowrank_update", 0)
        return coeffs, False
    if kind == "with_fira_residual":
        coeffs, _ = _inner_coeffs(info.get("inner", {}), f"{where}/inner", out)
        if coeffs is None:
            return None, False
        coeffs = dict(coeffs)
        coeffs["back_project"] = coeffs.get("back_project", 0) + 1
        return coeffs, False
    if kind in _BASE_COEFFS:
        coeffs, able = _BASE_COEFFS[kind]
        return dict(coeffs), able
    out.append(_ra303(where, f"inner stage kind {kind!r} inside lowrank()"))
    return None, False


def _core(info: dict) -> dict | None:
    """Unwrap a chain down to its single non-elementwise core stage (or the
    node itself when it isn't a chain); ``None`` when ambiguous."""
    if info.get("kind") == "chain":
        cores = [s for s in info.get("stages", [])
                 if s.get("kind") not in _ELEMENTWISE]
        return cores[0] if len(cores) == 1 else None
    return info


def _add(total: dict, coeffs: dict, units: int) -> None:
    for op, c in coeffs.items():
        if c * units:
            total[op] = total.get(op, 0) + c * units


def _leaves(params):
    return [p for p in jax.tree_util.tree_leaves(params) if p is not None]


def _walk(info: dict, params, where: str, total: dict,
          out: list[Finding]) -> None:
    kind = info.get("kind", "opaque")
    if kind == "multi_transform":
        label_fn = info.get("label_fn")
        if label_fn is None:
            out.append(_ra303(where, "a multi_transform without a label_fn"))
            return
        labels = label_fn(params)
        for name, branch in info.get("branches", {}).items():
            masked = jax.tree_util.tree_map(
                lambda p, l, name=name: p if l == name else None,
                params, labels,
            )
            _walk(branch, masked, f"{where}/{name}", total, out)
    elif kind == "chain":
        for i, stage in enumerate(info.get("stages", [])):
            _walk(stage, params, f"{where}/stage{i}", total, out)
    elif kind == "lowrank":
        inner = info.get("inner", {})
        coeffs, epilogue_able = _inner_coeffs(inner, f"{where}/inner", out)
        if coeffs is None:
            return
        if info.get("fused_epilogue") and epilogue_able:
            coeffs["back_project_epilogue"] = coeffs.pop("back_project", 0)
        leaves = _leaves(params)
        rank = info.get("rank")
        if info.get("fuse_families"):
            # sampling unit under stacking is the MEMBER leaf, so L_eff is
            # the member's own block count, not the stacked lead
            unit_Ls = [f.member_fs.L
                       for f in build_family_plan(leaves, rank).families]
        else:
            unit_Ls = [family_shape(p, rank).L for p in leaves]
        _add(total, coeffs, len(unit_Ls))
        if info.get("probe_spectrum") and not info.get("external_refresh"):
            # The refresh-cond spectrum probe (rank policies / telemetry)
            # projects PᵀG through the dispatch layer once per unit — the
            # cond body traces on every step's jaxpr even though it only
            # runs at refresh boundaries, so the traced count includes it.
            _add(total, {"project": 1}, len(unit_Ls))
        core = _core(inner)
        if core is not None and core.get("kind") == "layerwise_unbias":
            # q = gamma/L < 1: the plain low-rank branch runs alongside the
            # compensated sample, adding the inner's own mix per such unit
            gamma = int(core.get("gamma", 0))
            if gamma <= 0:
                out.append(_ra303(where, "layerwise_unbias with gamma<=0"))
                return
            low_units = sum(1 for L in unit_Ls if gamma < L)
            if low_units:
                low_core = _core(core.get("inner", {})) or {}
                lk = low_core.get("kind")
                if lk in _BASE_COEFFS:
                    _add(total, dict(_BASE_COEFFS[lk][0]), low_units)
                else:
                    out.append(_ra303(
                        f"{where}/inner",
                        f"the q<1 low branch of layerwise_unbias over "
                        f"inner kind {lk!r}"))
    elif kind == "scale_by_muon":
        units = sum(1 for p in _leaves(params) if getattr(p, "ndim", 0) >= 2)
        _add(total, {"newton_schulz": 1}, units)
    elif kind in _ELEMENTWISE or kind in _RAW_ZERO:
        pass
    elif "inner" in info:
        _walk(info["inner"], params, f"{where}/inner", total, out)
    else:
        out.append(_ra303(where, f"stage kind {kind!r}"))


def expected_launches(
    transform: Transform | dict, params, *, name: str = "chain",
) -> tuple[dict[str, int], list[Finding]]:
    """Expected per-step dispatch-launch counts for ``transform`` applied to
    an (abstract or concrete) ``params`` tree.

    Returns ``(counts, findings)`` where ``counts`` maps dispatch op name to
    launches/step and ``findings`` holds ``RA303`` entries for any stage the
    model could not account for (in which case ``counts`` is a lower bound
    and must not be asserted)."""
    info = transform if isinstance(transform, dict) else _chain_info(transform)
    total: dict[str, int] = {}
    out: list[Finding] = []
    _walk(info, params, name, total, out)
    return total, out


def lowrank_plan_stats(
    transform: Transform | dict, params, *, name: str = "chain",
) -> list[dict]:
    """Family-plan geometry of every ``lowrank()`` node the chain routes:
    one :func:`~repro.core.family_plan.plan_stats` dict per node (plus
    ``where`` / ``fused``), on the same masked-leaf view ``_walk`` uses for
    the launch counts.  Purely static; unknown stages are skipped."""
    info = transform if isinstance(transform, dict) else _chain_info(transform)
    out: list[dict] = []

    def visit(node: dict, params, where: str) -> None:
        kind = node.get("kind", "opaque")
        if kind == "multi_transform":
            label_fn = node.get("label_fn")
            if label_fn is None:
                return
            labels = label_fn(params)
            for bname, branch in node.get("branches", {}).items():
                masked = jax.tree_util.tree_map(
                    lambda p, l, bname=bname: p if l == bname else None,
                    params, labels,
                )
                visit(branch, masked, f"{where}/{bname}")
        elif kind == "chain":
            for i, stage in enumerate(node.get("stages", [])):
                visit(stage, params, f"{where}/stage{i}")
        elif kind == "lowrank":
            plan = build_family_plan(_leaves(params), node.get("rank"))
            out.append({"where": where,
                        "fused": bool(node.get("fuse_families")),
                        **plan_stats(plan)})
        elif "inner" in node:
            visit(node["inner"], params, f"{where}/inner")

    visit(info, params, name)
    return out
