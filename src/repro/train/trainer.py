"""Training loop with fault tolerance and the resilience subsystem.

Features (DESIGN.md §5 + repro.resilience):
  * auto-resume: newest *verified* committed checkpoint + exact data-stream
    skip-ahead (corrupt/partial latest saves are skipped automatically)
  * periodic checkpointing (params + optimizer state + step) via atomic
    commit with per-leaf checksums
  * NaN/Inf guard: non-finite losses skip the update inside the jitted step
    (counted + logged) — rung 0 of the recovery ladder
  * health monitor (``resilience=...``): windowed loss-spike / blowup /
    dead-subspace detectors over in-jit signals, unified with the
    straggler :class:`StepTimeMonitor` into per-step
    :class:`~repro.resilience.health.HealthReport`s
  * recovery controller: skip → forced off-cycle projector refresh →
    rollback to an in-memory snapshot ring (params, optimizer state AND
    rank-policy controller extras, so floors/TTLs stay in sync) → restore
    of the last verified durable checkpoint; every event lands in
    :class:`TrainResult`
  * fault injection (``inject=...``): a seeded declarative
    :class:`~repro.resilience.inject.FaultPlan` arms gradient corruption,
    projector sabotage, checkpoint corruption and mid-save kills — every
    recovery path has a reproducible trigger
  * optional pjit over a mesh with the repo's sharding rules.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import statistics
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import RunConfig
from repro.core import OptimizerConfig, build_optimizer, resolve_rank_policy
from repro.core.rank_policy import RankPolicyController
from repro.data import DataConfig, build_stream
from repro.launch.steps import make_train_step
from repro.models.transformer import Model
from repro.sharding import named_sharding_tree, opt_state_sharding, use_mesh
from repro.telemetry import (
    JsonlSink,
    MemorySink,
    StdoutSink,
    Telemetry,
    TelemetryConfig,
)


class StepTimeMonitor:
    """Flags straggling steps: wall time > mean + z·std over a window."""

    def __init__(self, window: int = 50, z: float = 3.0, min_samples: int = 10):
        self.times = collections.deque(maxlen=window)
        self.z = z
        self.min_samples = min_samples
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= self.min_samples:
            mu = statistics.fmean(self.times)
            sd = statistics.pstdev(self.times) or 1e-9
            if dt > mu + self.z * sd:
                is_straggler = True
                self.flagged.append((step, dt))
        self.times.append(dt)
        return is_straggler


@dataclasses.dataclass
class TrainResult:
    final_step: int
    losses: list[float]
    skipped_nonfinite: int
    straggler_steps: list[tuple[int, float]]
    resumed_from: Optional[int]
    # Resilience accounting (empty when the subsystem is off):
    health_events: list = dataclasses.field(default_factory=list)
    recovery_counts: dict = dataclasses.field(default_factory=dict)
    recovery_trace: list = dataclasses.field(default_factory=list)
    fault_log: list = dataclasses.field(default_factory=list)
    # Path of the run's events.jsonl (None when telemetry is off).
    events_path: Optional[str] = None


class Trainer:
    def __init__(
        self,
        model: Model,
        opt_cfg: OptimizerConfig,
        run_cfg: RunConfig,
        data_cfg: DataConfig,
        mesh=None,
        microbatches: int = 1,
        optimizer=None,
        resilience=None,
        inject=None,
        telemetry=None,
        events_out: Optional[str] = None,
        profile_steps: Optional[str] = None,
    ):
        """``optimizer`` (a :class:`repro.core.api.Transform`) overrides the
        ``opt_cfg`` factory path — pass a hand-composed combinator chain
        (repro.core.combinators) to train with compositions the factory does
        not name, e.g. ``chain(combinators.clip_by_global_norm(1.0),
        lowrank(layerwise_unbias(scale_by_adam())), scale_by_lr(sched))``
        (the transform-valued clip lives in the combinators namespace; the
        same name in repro.core is the plain (grads, max_norm) function).

        ``resilience`` turns on the health monitor + recovery ladder: True
        or "" for defaults, a spec string ("ring=3,snapshot_every=5"), or a
        :class:`~repro.resilience.recovery.ResilienceConfig`.

        ``inject`` arms deterministic fault injection: a
        :class:`~repro.resilience.inject.FaultPlan` or its spec string
        ("grad_nan@5;refresh_zero@13;kill_save@20#3").

        ``telemetry`` turns on the run log (repro.telemetry): True or ""
        for defaults, a spec string ("every=10,stdout=0,memory=256"), or a
        :class:`~repro.telemetry.TelemetryConfig`.  One run then writes one
        schema-versioned ``events.jsonl`` (``events_out`` overrides the
        default ``<ckpt_dir>/events.jsonl``) holding step metrics, every
        health / recovery / fault / rank-policy / checkpoint event, and
        timing spans.  The console is always driven through the same bus —
        with telemetry off it degrades to the historical print lines.

        ``profile_steps="A:B"`` opens a ``jax.profiler`` trace window
        covering steps [A, B) (written under ``<ckpt_dir>/profile``)."""
        self.model = model
        self.opt_cfg = opt_cfg
        self.run = run_cfg
        self.data_cfg = data_cfg
        self.mesh = mesh
        self.microbatches = microbatches
        # ZeRO-style sharded projected state: family-stacked low-rank leaves
        # partition over the data axis (combinators.family_sharding routes
        # the projector refresh through the boundary all_gather).  Only
        # meaningful with a mesh and the fused family layout.
        self.shard_state = bool(
            getattr(opt_cfg, "shard_state", False)
            and opt_cfg.fuse_families and mesh is not None)
        self._family_axis = None
        if self.shard_state:
            names = mesh.axis_names
            self._family_axis = "data" if "data" in names else names[0]

        # --- telemetry bus (repro.telemetry) ---
        # The bus always exists: with telemetry off it carries only the
        # stdout pretty-printer (console output unchanged from the print()
        # era); enabling telemetry adds the JSONL sink — so console and
        # events.jsonl are two sinks of ONE record stream and can never
        # disagree.
        self.tele_cfg = TelemetryConfig.parse(telemetry)
        self.events_path = None
        sinks = []
        if self.tele_cfg is None or self.tele_cfg.stdout:
            sinks.append(StdoutSink())
        self.memory_sink = None
        if self.tele_cfg is not None:
            path = (events_out or self.tele_cfg.events
                    or os.path.join(run_cfg.ckpt_dir, "events.jsonl"))
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            sinks.append(JsonlSink(path))
            self.events_path = path
            if self.tele_cfg.memory:
                self.memory_sink = MemorySink(self.tele_cfg.memory)
                sinks.append(self.memory_sink)
        self.tele = Telemetry(sinks, run={
            "optimizer": opt_cfg.name, "rank": str(opt_cfg.rank),
            "period": opt_cfg.period, "seed": run_cfg.seed,
            "steps": run_cfg.steps, "telemetry": self.tele_cfg is not None,
        })
        self._profile_window = None
        self._profiling = False
        if profile_steps:
            a, _, b = str(profile_steps).partition(":")
            self._profile_window = (int(a), int(b))

        self.ckpt = CheckpointManager(run_cfg.ckpt_dir,
                                      keep=run_cfg.keep_ckpts,
                                      telemetry=self.tele)
        self.monitor = StepTimeMonitor()

        # --- resilience wiring (repro.resilience) ---
        from repro.resilience import FaultPlan, HealthMonitor
        from repro.resilience.recovery import ResilienceConfig

        if resilience is None or resilience is False:
            self.resilience = None
            self.health = None
        else:
            self.resilience = ResilienceConfig.parse(resilience)
            self.health = HealthMonitor(self.resilience,
                                        step_monitor=self.monitor)
        self.fault_plan = (FaultPlan.parse(inject) if isinstance(inject, str)
                           else inject)
        self._fault_gate = (self.fault_plan.gate()
                            if self.fault_plan is not None else None)
        self.recovery = None  # built per train() run

        # Rank policy (repro.core.rank_policy): rank is a shape in JAX, so a
        # policy-driven rank change is a host-side event between steps — the
        # controller migrates the optimizer state and we re-jit (bounded by
        # the policy ladder via the per-map jit cache below).  Only active on
        # the factory path; a hand-passed `optimizer` owns its own rank.
        self.rank_ctrl: Optional[RankPolicyController] = None
        if optimizer is None:
            policy = resolve_rank_policy(opt_cfg)
            if policy is not None:
                self.rank_ctrl = RankPolicyController(
                    policy,
                    lambda m: build_optimizer(opt_cfg, rank_map=m),
                    period=opt_cfg.period, default_rank=opt_cfg.rank,
                    # A rank migration changes state shapes, so the sharding
                    # must be re-derived from the MIGRATED state and
                    # re-applied — otherwise the first spectral decision
                    # silently de-shards (or mis-shards) the optimizer state
                    # under a mesh.
                    reshard=(self._reshard_opt_state
                             if mesh is not None else None),
                )
                optimizer = self.rank_ctrl.transform()
        self._jit_cache: dict = {}
        self._has_probes: Optional[bool] = None
        self._set_optimizer(
            optimizer if optimizer is not None else build_optimizer(opt_cfg)
        )

    # ------------------------------------------------------------- setup

    def _set_optimizer(self, optimizer):
        self.optimizer = optimizer
        step_fn = make_train_step(
            self.model, optimizer, grad_clip=self.run.grad_clip,
            microbatches=self.microbatches,
            fault_gate=self._fault_gate,
            extra_metrics=self.resilience is not None,
        )
        if self.shard_state:
            from repro.core.combinators import family_sharding

            mesh, axis = self.mesh, self._family_axis
            inner_step = step_fn

            def step_fn(*args, _inner=inner_step):
                # entered at TRACE time: the fused lowrank path sees the
                # context and emits the sharded (all_gather-at-boundary)
                # projector refresh for shardable families
                with family_sharding(mesh, axis):
                    return _inner(*args)

        self._step_fn = step_fn

    def init_state(self):
        key = jax.random.PRNGKey(self.run.seed)
        params = self.model.init(key)
        opt_state = self.optimizer.init(params)
        return params, opt_state

    def _jit_step(self, params, opt_state):
        # One jitted step per rank assignment; without a controller there is
        # exactly one entry, with one the cache is bounded by the ladder.
        key = self.rank_ctrl.current_map if self.rank_ctrl else None
        cached = self._jit_cache.get(key)
        if cached is not None:
            return cached
        n_in = 4 if self._fault_gate is not None else 3
        if self.mesh is None:
            jitted = jax.jit(self._step_fn, donate_argnums=(0, 1))
        else:
            psh = named_sharding_tree(params, self.mesh)
            osh = opt_state_sharding(opt_state, self.mesh,
                                     family_axis=self._family_axis)
            jitted = jax.jit(
                self._step_fn,
                in_shardings=(psh, osh) + (None,) * (n_in - 2),
                out_shardings=(psh, osh, None),
                donate_argnums=(0, 1),
            )
        self._jit_cache[key] = jitted
        return jitted

    # ------------------------------------------------------------- helpers

    def _profile(self, step: int) -> None:
        """Opt-in ``jax.profiler`` trace window: start at step A, stop at
        step B (``profile_steps="A:B"``).  Best-effort — profiler failures
        must never take down training."""
        a, b = self._profile_window
        try:
            if step == a and not self._profiling:
                trace_dir = os.path.join(self.run.ckpt_dir, "profile")
                jax.profiler.start_trace(trace_dir)
                self._profiling = True
                self.tele.event("profile", f"profiler: trace started -> "
                                f"{trace_dir}", step=step)
            elif step == b and self._profiling:
                jax.profiler.stop_trace()
                self._profiling = False
                self.tele.event("profile", "profiler: trace stopped",
                                step=step)
                self._profile_window = None
        except Exception as e:  # pragma: no cover - platform dependent
            self.tele.event("profile", f"profiler: unavailable "
                            f"({type(e).__name__}: {e})", step=step,
                            severity="warn")
            self._profile_window = None
            self._profiling = False

    def _stop_profile(self) -> None:
        """Close a still-open trace window (run ended before step B)."""
        if not self._profiling:
            return
        self._profiling = False
        try:
            jax.profiler.stop_trace()
            self.tele.event("profile", "profiler: trace stopped at run end")
        except Exception:  # pragma: no cover - never started
            pass

    def _reshard_opt_state(self, opt_state):
        """Re-derive the optimizer-state sharding from the live (possibly
        just-migrated) state and re-apply it — the mesh counterpart of
        ``opt_state_sharding`` at jit time.  No-op without a mesh."""
        if self.mesh is None:
            return opt_state
        return jax.device_put(
            opt_state,
            opt_state_sharding(opt_state, self.mesh,
                               family_axis=self._family_axis))

    def _restore_shardings(self, params, opt_state):
        """Shardings to re-apply on checkpoint restore (None off-mesh):
        checkpoints hold host-gathered full arrays, so the restore must put
        every leaf back on its derived sharding — including the family-
        stacked ZeRO layout — or the first step pays a full reshard."""
        if self.mesh is None:
            return None
        return (named_sharding_tree(params, self.mesh),
                opt_state_sharding(opt_state, self.mesh,
                                   family_axis=self._family_axis))

    def _ckpt_extra(self) -> Optional[dict]:
        if self.rank_ctrl is None:
            return None
        return {"rank_policy": self.rank_ctrl.state_dict()}

    def _save(self, step: int, params, opt_state) -> None:
        """Checkpoint save with the fault plan's kill hook and post-commit
        corruption events attached (no-ops without a plan)."""
        observer = (self.fault_plan.save_observer(step)
                    if self.fault_plan is not None else None)
        self.ckpt.save(step, (params, opt_state), extra=self._ckpt_extra(),
                       observer=observer)
        if self.fault_plan is not None:
            for ev in self.fault_plan.apply_ckpt_events(self.ckpt.dir, step):
                self.tele.event(
                    "fault", f"fault-injection: {ev.kind} on the "
                    f"step-{step} checkpoint", step=step, severity="warn",
                    kind=ev.kind)

    def _load_checkpoint(self, step: int):
        """Restore params/opt_state at ``step``, rebuilding the rank-policy
        controller (and therefore the state template's shapes) from the
        saved extras first — the restore rung of the recovery ladder."""
        if self.rank_ctrl is not None:
            extra = self.ckpt.read_extra(step)
            if "rank_policy" in extra:
                self.rank_ctrl.load_state_dict(extra["rank_policy"])
                self._set_optimizer(self.rank_ctrl.transform())
        params, opt_state = self.init_state()
        (params, opt_state), _ = self.ckpt.restore(
            step, (params, opt_state),
            shardings=self._restore_shardings(params, opt_state))
        return params, opt_state

    def _gather_probes(self, opt_state, step: int) -> Optional[dict]:
        """Spectrum probes for the health monitor's captured-energy floor —
        gathered only on refresh-cadence steps and only when the optimizer
        actually stores probes (zero cost otherwise)."""
        if (self.resilience is None or not self.resilience.probe_health
                or self.opt_cfg.period <= 0
                or step % self.opt_cfg.period != 0):
            return None
        from repro.core import find_lowrank_states
        from repro.core.rank_policy import gather_probes

        if self._has_probes is None:
            self._has_probes = any(
                st.probes is not None
                for st in find_lowrank_states(opt_state))
        return gather_probes(opt_state) if self._has_probes else None

    # ------------------------------------------------------------- loop

    def train(self, steps: Optional[int] = None) -> TrainResult:
        from repro.resilience import poison_projectors
        from repro.resilience.inject import FaultGate
        from repro.resilience.recovery import (
            RecoveryController,
            SnapshotRing,
            force_refresh,
        )

        steps = steps or self.run.steps
        stream = build_stream(self.data_cfg)
        res, plan, health = self.resilience, self.fault_plan, self.health
        ring = SnapshotRing(res.ring) if res is not None else None
        recov = RecoveryController(res) if res is not None else None
        self.recovery = recov

        start_step, resumed_from = 0, None
        latest = None
        if self.run.resume:
            latest = self.ckpt.latest_verified_step()
            newest = self.ckpt.latest_step()
            if newest is not None and newest != latest:
                self.tele.event(
                    "checkpoint",
                    f"checkpoint: newest committed step {newest} failed "
                    f"verification — resuming from last verified "
                    f"{latest}", severity="warn", action="resume_fallback")
        if latest is not None and self.rank_ctrl is not None:
            # The controller state determines the optimizer-state SHAPES, so
            # it must be rebuilt from the saved extras before the restore
            # template exists — this is what makes resume exact across a
            # rank change.
            extra = self.ckpt.read_extra(latest)
            if "rank_policy" in extra:
                self.rank_ctrl.load_state_dict(extra["rank_policy"])
                self._set_optimizer(self.rank_ctrl.transform())
        params, opt_state = self.init_state()
        try:
            # One-line static audit of the step we are about to jit:
            # launches/step, projected-state bytes, abstract signature hash.
            # Purely abstract (trace only) and best-effort — a failure here
            # must never block training.
            from repro.analysis import audit_summary

            self.tele.event("audit", audit_summary(self.optimizer, params,
                                                   name=self.opt_cfg.name))
            if self.tele_cfg is not None:
                # Runtime launch-counter cross-check against the PR 6
                # closed-form model — the RA-style assertion, as an event.
                from repro.telemetry.instrument import launch_crosscheck

                xc = launch_crosscheck(self.optimizer, params,
                                       name=self.opt_cfg.name)
                self.tele.event(
                    "launch_crosscheck",
                    f"audit[{self.opt_cfg.name}]: launch cross-check "
                    f"{'ok' if xc['ok'] else 'MISMATCH'} "
                    f"(traced {sum(xc['traced'].values())}/step)",
                    severity="info" if xc["ok"] else "warn",
                    expected=xc["expected"], traced=xc["traced"],
                    unmodeled=xc["unmodeled"])
            if self.mesh is not None:
                # Mesh run: also verify the jitted step's donation wiring on
                # the lowered module (donated params/opt_state must alias
                # outputs — losing it double-buffers the whole model).
                from repro.analysis import donation_findings, parse_main_args

                opt_state0 = jax.eval_shape(self.optimizer.init, params)
                batch0 = {"tokens": jax.ShapeDtypeStruct(
                    (self.data_cfg.global_batch
                     // max(self.data_cfg.num_hosts, 1),
                     self.data_cfg.seq_len), jnp.int32)}
                args = (params, opt_state0, batch0)
                if self._fault_gate is not None:
                    args = args + (FaultGate.disarmed(),)
                infos = parse_main_args(
                    self._jit_step(params, opt_state0)
                    .lower(*args).as_text())
                n_donate = (len(jax.tree_util.tree_leaves(params))
                            + len(jax.tree_util.tree_leaves(opt_state0)))
                self.tele.event(
                    "audit", f"audit[{self.opt_cfg.name}]: mesh donation "
                    f"{sum(a.aliased for a in infos)}/{n_donate} args "
                    f"alias outputs")
                for f in donation_findings(
                        infos, n_params=len(jax.tree_util.tree_leaves(params)),
                        n_opt=len(jax.tree_util.tree_leaves(opt_state0)),
                        where=self.opt_cfg.name):
                    self.tele.event("audit", "  " + f.format(),
                                    severity="warn")
        except Exception as e:  # pragma: no cover - diagnostics only
            self.tele.event("audit", f"audit[{self.opt_cfg.name}]: "
                            f"unavailable ({type(e).__name__}: {e})",
                            severity="warn")
        if latest is not None:
            (params, opt_state), _ = self.ckpt.restore(
                latest, (params, opt_state),
                shardings=self._restore_shardings(params, opt_state),
            )
            start_step, resumed_from = latest, latest
            stream.resume(start_step)  # exact skip-ahead

        step_jit = self._jit_step(params, opt_state)

        loss_by_step: dict[int, float] = {}
        skipped = 0
        step = start_step
        tele, tcfg = self.tele, self.tele_cfg
        gamma_tracker = None
        if tcfg is not None:
            from repro.telemetry.instrument import (
                GammaSlotTracker,
                lowrank_family_metrics,
            )

            gamma_tracker = GammaSlotTracker()
        with use_mesh(self.mesh):
            while step < steps:
                if self._profile_window is not None:
                    self._profile(step)
                t0 = time.time()
                if self.rank_ctrl is not None:
                    opt_state, changed = self.rank_ctrl.maybe_update(
                        opt_state, params
                    )
                    if changed:
                        self._set_optimizer(self.rank_ctrl.transform())
                        step_jit = self._jit_step(params, opt_state)
                        tele.record_span("rank_migration", time.time() - t0,
                                         step=step)
                        tele.event("rank_policy",
                                   f"rank-policy -> "
                                   f"{self.rank_ctrl.current_map}", step=step,
                                   map=str(self.rank_ctrl.current_map))
                if plan is not None:
                    for ev in plan.state_events(step):
                        opt_state = poison_projectors(opt_state, ev.kind)
                        tele.event("fault", f"fault-injection: {ev.kind}",
                                   step=step, severity="warn", kind=ev.kind)
                tokens = jnp.asarray(next(stream))
                if self._fault_gate is not None:
                    ev = plan.grad_event(step)
                    if ev is not None:
                        tele.event("fault", f"fault-injection: {ev.kind}",
                                   step=step, severity="warn", kind=ev.kind)
                    fault = (FaultGate.armed(ev) if ev is not None
                             else FaultGate.disarmed())
                    new_params, new_opt, metrics = step_jit(
                        params, opt_state, {"tokens": tokens}, fault
                    )
                else:
                    new_params, new_opt, metrics = step_jit(
                        params, opt_state, {"tokens": tokens}
                    )
                loss = float(metrics["loss"])
                params, opt_state = new_params, new_opt
                applied = bool(metrics["update_applied"])
                if applied:
                    loss_by_step[step] = loss
                else:
                    # the step itself zeroed the update (in-jit NaN guard)
                    skipped += 1
                dt = time.time() - t0
                refresh_step = (self.opt_cfg.period > 0
                                and step % self.opt_cfg.period == 0)
                tele.record_span(
                    "step", dt, step=step + 1,
                    kind="refresh" if refresh_step else "steady")
                if tcfg is not None and (step + 1) % tcfg.every == 0:
                    tele.metric(step + 1, "loss", loss)
                    tele.metric(step + 1, "grad_norm",
                                float(metrics["grad_norm"]))
                if tcfg is not None and refresh_step:
                    for rec in lowrank_family_metrics(opt_state):
                        fam = rec["family"]
                        tele.metric(step + 1, "rank", rec["rank"], family=fam)
                        tele.metric(step + 1, "energy", rec["energy"],
                                    family=fam)
                        for k in ("drift", "bias"):
                            if k in rec:
                                tele.metric(step + 1, k, rec[k], family=fam)
                    slots = gamma_tracker.observe(opt_state)
                    if slots:
                        tele.event(
                            "gamma_slots",
                            f"gamma-slots: {len(slots)} leaves tracked",
                            step=step + 1, leaves=slots)

                if health is not None:
                    report = health.observe(
                        step, loss=loss, applied=applied,
                        grad_norm=float(metrics.get(
                            "grad_norm_raw", metrics["grad_norm"])),
                        # collapse detection watches the low-rank-leaf
                        # restricted norm: embeddings/norms keep updating
                        # through a dead subspace and would mask it globally
                        update_norm=(float(metrics["update_norm_lowrank"])
                                     if "update_norm_lowrank" in metrics
                                     else None),
                        dt=dt,
                        probes=self._gather_probes(opt_state, step),
                    )
                    for e in report.events:
                        tele.event("health",
                                   f"health[{e.severity}] "
                                   f"{e.kind}: {e.detail}", step=step,
                                   severity=e.severity, kind=e.kind)
                    action = recov.decide(report)
                    if action.kind == "refresh":
                        opt_state = force_refresh(opt_state,
                                                  self.opt_cfg.period)
                        recov.record(action, target=step + 1)
                        health.reset()
                        tele.event("recovery", "recovery: forced off-cycle "
                                   "projector refresh", step=step,
                                   severity="warn", action="refresh")
                    elif action.kind in ("rollback", "restore"):
                        target, kind = None, action.kind
                        if action.kind == "rollback":
                            snap = ring.pop_latest()
                            if snap is not None:
                                params, opt_state = ring.restore(snap)
                                if (self.rank_ctrl is not None and snap.extra
                                        and "rank_policy" in snap.extra):
                                    self.rank_ctrl.load_state_dict(
                                        snap.extra["rank_policy"])
                                    self._set_optimizer(
                                        self.rank_ctrl.transform())
                                target = snap.step
                        if target is None:
                            # no snapshot (or explicit restore rung): fall
                            # back to the last verified durable checkpoint
                            ck = self.ckpt.latest_verified_step()
                            if ck is not None:
                                params, opt_state = self._load_checkpoint(ck)
                                target, kind = ck, "restore"
                        recov.record(dataclasses.replace(action, kind=kind)
                                     if kind != action.kind else action,
                                     target=target)
                        if target is not None:
                            tele.event("recovery",
                                       f"recovery: {kind} -> step {target}",
                                       step=step, severity="warn",
                                       action=kind, target=target)
                            stream.resume(target)
                            loss_by_step = {k: v for k, v in
                                            loss_by_step.items()
                                            if k < target}
                            step = target
                            step_jit = self._jit_step(params, opt_state)
                            health.reset()
                            continue
                        tele.event("recovery",
                                   f"recovery: {action.kind} requested but "
                                   f"nothing restorable — continuing",
                                   step=step, severity="warn",
                                   action=action.kind)
                else:
                    self.monitor.record(step, dt)

                if (res is not None and res.snapshot_every
                        and (step + 1) % res.snapshot_every == 0
                        and (health is None or report.status == "ok")):
                    ring.add(step + 1, params, opt_state,
                             extra=self._ckpt_extra())

                if self.run.ckpt_every and (step + 1) % self.run.ckpt_every == 0:
                    with tele.span("ckpt_save", step=step + 1):
                        self._save(step + 1, params, opt_state)
                if self.run.log_every and (step + 1) % self.run.log_every == 0:
                    tele.event("log", f"loss {loss:.4f}", step=step + 1)
                step += 1

        # Final save — unless the loop's periodic save already committed
        # this exact step (a duplicate would also clobber any post-commit
        # state, e.g. injected corruption under test).
        if not (self.run.ckpt_every and steps % self.run.ckpt_every == 0
                and steps > start_step):
            with self.tele.span("ckpt_save", step=steps):
                self._save(steps, params, opt_state)
        self._stop_profile()
        if self.tele_cfg is not None:
            self.tele.emit_counters(steps)
        return TrainResult(
            final_step=steps,
            losses=[v for _, v in sorted(loss_by_step.items())],
            skipped_nonfinite=skipped,
            straggler_steps=self.monitor.flagged,
            resumed_from=resumed_from,
            health_events=([e.to_json() for e in health.events]
                           if health is not None else []),
            recovery_counts=dict(recov.counts) if recov is not None else {},
            recovery_trace=list(recov.trace) if recov is not None else [],
            fault_log=list(plan.log) if plan is not None else [],
            events_path=self.events_path,
        )
