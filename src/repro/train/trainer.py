"""Training loop with fault tolerance.

Features (DESIGN.md §5):
  * auto-resume: newest committed checkpoint + exact data-stream skip-ahead
  * periodic checkpointing (params + optimizer state + step) via atomic commit
  * NaN/Inf guard: non-finite losses skip the update (counted + logged)
  * straggler/step-time monitor: per-step wall-time ring buffer, z-score
    flagging — on a real fleet this triggers elastic resharding (restore the
    same checkpoint on a different mesh; the checkpoint layer supports it)
  * optional pjit over a mesh with the repo's sharding rules.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import RunConfig
from repro.core import OptimizerConfig, build_optimizer, resolve_rank_policy
from repro.core.rank_policy import RankPolicyController
from repro.data import DataConfig, build_stream
from repro.launch.steps import make_train_step
from repro.models.transformer import Model
from repro.sharding import named_sharding_tree, opt_state_sharding, use_mesh


class StepTimeMonitor:
    """Flags straggling steps: wall time > mean + z·std over a window."""

    def __init__(self, window: int = 50, z: float = 3.0, min_samples: int = 10):
        self.times = collections.deque(maxlen=window)
        self.z = z
        self.min_samples = min_samples
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= self.min_samples:
            mu = statistics.fmean(self.times)
            sd = statistics.pstdev(self.times) or 1e-9
            if dt > mu + self.z * sd:
                is_straggler = True
                self.flagged.append((step, dt))
        self.times.append(dt)
        return is_straggler


@dataclasses.dataclass
class TrainResult:
    final_step: int
    losses: list[float]
    skipped_nonfinite: int
    straggler_steps: list[tuple[int, float]]
    resumed_from: Optional[int]


class Trainer:
    def __init__(
        self,
        model: Model,
        opt_cfg: OptimizerConfig,
        run_cfg: RunConfig,
        data_cfg: DataConfig,
        mesh=None,
        microbatches: int = 1,
        optimizer=None,
    ):
        """``optimizer`` (a :class:`repro.core.api.Transform`) overrides the
        ``opt_cfg`` factory path — pass a hand-composed combinator chain
        (repro.core.combinators) to train with compositions the factory does
        not name, e.g. ``chain(combinators.clip_by_global_norm(1.0),
        lowrank(layerwise_unbias(scale_by_adam())), scale_by_lr(sched))``
        (the transform-valued clip lives in the combinators namespace; the
        same name in repro.core is the plain (grads, max_norm) function)."""
        self.model = model
        self.opt_cfg = opt_cfg
        self.run = run_cfg
        self.data_cfg = data_cfg
        self.mesh = mesh
        self.microbatches = microbatches
        self.ckpt = CheckpointManager(run_cfg.ckpt_dir, keep=run_cfg.keep_ckpts)
        self.monitor = StepTimeMonitor()
        # Rank policy (repro.core.rank_policy): rank is a shape in JAX, so a
        # policy-driven rank change is a host-side event between steps — the
        # controller migrates the optimizer state and we re-jit (bounded by
        # the policy ladder via the per-map jit cache below).  Only active on
        # the factory path; a hand-passed `optimizer` owns its own rank.
        self.rank_ctrl: Optional[RankPolicyController] = None
        if optimizer is None:
            policy = resolve_rank_policy(opt_cfg)
            if policy is not None:
                self.rank_ctrl = RankPolicyController(
                    policy,
                    lambda m: build_optimizer(opt_cfg, rank_map=m),
                    period=opt_cfg.period, default_rank=opt_cfg.rank,
                )
                optimizer = self.rank_ctrl.transform()
        self._jit_cache: dict = {}
        self._set_optimizer(
            optimizer if optimizer is not None else build_optimizer(opt_cfg)
        )

    # ------------------------------------------------------------- setup

    def _set_optimizer(self, optimizer):
        self.optimizer = optimizer
        self._step_fn = make_train_step(
            self.model, optimizer, grad_clip=self.run.grad_clip,
            microbatches=self.microbatches,
        )

    def init_state(self):
        key = jax.random.PRNGKey(self.run.seed)
        params = self.model.init(key)
        opt_state = self.optimizer.init(params)
        return params, opt_state

    def _jit_step(self, params, opt_state):
        # One jitted step per rank assignment; without a controller there is
        # exactly one entry, with one the cache is bounded by the ladder.
        key = self.rank_ctrl.current_map if self.rank_ctrl else None
        cached = self._jit_cache.get(key)
        if cached is not None:
            return cached
        if self.mesh is None:
            jitted = jax.jit(self._step_fn, donate_argnums=(0, 1))
        else:
            psh = named_sharding_tree(params, self.mesh)
            osh = opt_state_sharding(opt_state, self.mesh)
            jitted = jax.jit(
                self._step_fn,
                in_shardings=(psh, osh, None),
                out_shardings=(psh, osh, None),
                donate_argnums=(0, 1),
            )
        self._jit_cache[key] = jitted
        return jitted

    # ------------------------------------------------------------- loop

    def _ckpt_extra(self) -> Optional[dict]:
        if self.rank_ctrl is None:
            return None
        return {"rank_policy": self.rank_ctrl.state_dict()}

    def train(self, steps: Optional[int] = None) -> TrainResult:
        steps = steps or self.run.steps
        stream = build_stream(self.data_cfg)

        start_step, resumed_from = 0, None
        latest = self.ckpt.latest_step() if self.run.resume else None
        if latest is not None and self.rank_ctrl is not None:
            # The controller state determines the optimizer-state SHAPES, so
            # it must be rebuilt from the saved extras before the restore
            # template exists — this is what makes resume exact across a
            # rank change.
            extra = self.ckpt.read_extra(latest)
            if "rank_policy" in extra:
                self.rank_ctrl.load_state_dict(extra["rank_policy"])
                self._set_optimizer(self.rank_ctrl.transform())
        params, opt_state = self.init_state()
        try:
            # One-line static audit of the step we are about to jit:
            # launches/step, projected-state bytes, abstract signature hash.
            # Purely abstract (trace only) and best-effort — a failure here
            # must never block training.
            from repro.analysis import audit_summary

            print(audit_summary(self.optimizer, params,
                                name=self.opt_cfg.name), flush=True)
            if self.mesh is not None:
                # Mesh run: also verify the jitted step's donation wiring on
                # the lowered module (donated params/opt_state must alias
                # outputs — losing it double-buffers the whole model).
                from repro.analysis import donation_findings, parse_main_args

                opt_state0 = jax.eval_shape(self.optimizer.init, params)
                batch0 = {"tokens": jax.ShapeDtypeStruct(
                    (self.data_cfg.global_batch
                     // max(self.data_cfg.num_hosts, 1),
                     self.data_cfg.seq_len), jnp.int32)}
                infos = parse_main_args(
                    self._jit_step(params, opt_state0)
                    .lower(params, opt_state0, batch0).as_text())
                n_donate = (len(jax.tree_util.tree_leaves(params))
                            + len(jax.tree_util.tree_leaves(opt_state0)))
                print(f"audit[{self.opt_cfg.name}]: mesh donation "
                      f"{sum(a.aliased for a in infos)}/{n_donate} args "
                      f"alias outputs", flush=True)
                for f in donation_findings(
                        infos, n_params=len(jax.tree_util.tree_leaves(params)),
                        n_opt=len(jax.tree_util.tree_leaves(opt_state0)),
                        where=self.opt_cfg.name):
                    print("  " + f.format(), flush=True)
        except Exception as e:  # pragma: no cover - diagnostics only
            print(f"audit[{self.opt_cfg.name}]: unavailable "
                  f"({type(e).__name__}: {e})", flush=True)
        if latest is not None:
            (params, opt_state), _ = self.ckpt.restore(
                latest, (params, opt_state)
            )
            start_step, resumed_from = latest, latest
            stream.resume(start_step)  # exact skip-ahead

        step_jit = self._jit_step(params, opt_state)

        losses: list[float] = []
        skipped = 0
        with use_mesh(self.mesh):
            for step in range(start_step, steps):
                t0 = time.time()
                if self.rank_ctrl is not None:
                    opt_state, changed = self.rank_ctrl.maybe_update(
                        opt_state, params
                    )
                    if changed:
                        self._set_optimizer(self.rank_ctrl.transform())
                        step_jit = self._jit_step(params, opt_state)
                        print(f"step {step:6d} rank-policy -> "
                              f"{self.rank_ctrl.current_map}", flush=True)
                tokens = jnp.asarray(next(stream))
                new_params, new_opt, metrics = step_jit(
                    params, opt_state, {"tokens": tokens}
                )
                loss = float(metrics["loss"])
                params, opt_state = new_params, new_opt
                if not bool(metrics["update_applied"]):
                    # the step itself zeroed the update (in-jit NaN guard)
                    skipped += 1
                else:
                    losses.append(loss)
                self.monitor.record(step, time.time() - t0)

                if self.run.ckpt_every and (step + 1) % self.run.ckpt_every == 0:
                    self.ckpt.save(step + 1, (params, opt_state),
                                   extra=self._ckpt_extra())
                if self.run.log_every and (step + 1) % self.run.log_every == 0:
                    print(f"step {step + 1:6d} loss {loss:.4f}", flush=True)

        self.ckpt.save(steps, (params, opt_state), extra=self._ckpt_extra())
        return TrainResult(
            final_step=steps,
            losses=losses,
            skipped_nonfinite=skipped,
            straggler_steps=self.monitor.flagged,
            resumed_from=resumed_from,
        )
