from .trainer import StepTimeMonitor, Trainer, TrainResult

__all__ = ["StepTimeMonitor", "Trainer", "TrainResult"]
