"""Static family plan: group same-shape pytree leaves into stacked super-leaves.

The per-leaf Python loop in ``lowrank()`` issues separate project / momentum /
back-project launches per parameter leaf, with full HBM round-trips between
stages.  The dispatch layer already runs native ``(L, m, n)`` batch grids —
but only for leaves that arrive pre-stacked.  A :class:`FamilyPlan` closes the
gap: at ``init`` time it groups every leaf with the same *family signature*
``(lead, m, n, side, rank, dtype)`` into one stacked ``(M·prod(lead), m, n)``
super-leaf, so the whole optimizer pipeline runs one batched launch per shape
family instead of one per leaf, then scatters results back through the
treedef.

Only leaves with IDENTICAL signatures stack: equal ``lead`` keeps the
per-member block count ``L`` — and with it ``layerwise_unbias``'s sampling
ratio ``q = gamma/L`` and compensation coefficients — uniform across the
stack, which is what makes stacked execution trajectory-identical to the
per-leaf path (per-member PRNG keys are stacked, never merged; see
:class:`StackSeg`).

The stack flattens ``(M, *lead)`` into one leading axis.  That reshape is
exactly the one :func:`repro.kernels.dispatch._flatten_lead` already performs
for every Pallas call: the fused path runs per-device (replicated optimizer
math / under shard_map), so the no-lead-reshape GSPMD rule in
``lowrank_common`` does not apply here — which is why ``fuse_families`` is an
opt-in knob, not the default.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .lowrank_common import FamilyShape, family_shape


class StackSeg(NamedTuple):
    """Static segment geometry of a stacked super-leaf.

    ``members`` original leaves, each contributing ``member_L`` blocks
    (``member_L = prod(member_lead)``); global block ``j*member_L + b`` is
    block ``b`` of member ``j``.  Carried on ``ProjGrad``/``ProjInit`` leaves
    so protocol-aware wrappers (``layerwise_unbias``) sample per *member*,
    preserving the per-leaf trajectories exactly."""

    members: int
    member_L: int


class Family(NamedTuple):
    """One shape family: the stacked geometry plus its member leaf indices."""

    fs: FamilyShape           # stacked: lead = (members * member_L,)
    member_fs: FamilyShape    # geometry of ONE member leaf
    seg: StackSeg
    members: tuple[int, ...]  # flat leaf indices (order of first occurrence)


class FamilyPlan(NamedTuple):
    families: tuple[Family, ...]
    n_leaves: int


def family_signature(p, rank) -> tuple:
    """The static grouping key: leaves stack iff their signatures are equal.
    ``rank`` may be an int or a per-shape ``RankMap`` (resolved per leaf by
    ``family_shape``); the resolved rank is part of the signature, so a rank
    change re-plans the families — same-(m, n) leaves always share one rank,
    which keeps the grouping itself stable across rank migrations."""
    fs = family_shape(p, rank)
    return (fs.lead, fs.m, fs.n, fs.side, fs.rank, jnp.result_type(p).name)


def build_family_plan(leaves, rank) -> FamilyPlan:
    """Group the non-``None`` leaves of a flattened params list into families
    (first-occurrence order — deterministic across init/update/refresh, which
    all flatten the same params tree)."""
    groups: dict[tuple, list[int]] = {}
    member_fs: dict[tuple, FamilyShape] = {}
    for i, p in enumerate(leaves):
        if p is None:
            continue
        sig = family_signature(p, rank)
        groups.setdefault(sig, []).append(i)
        member_fs.setdefault(sig, family_shape(p, rank))
    families = []
    for sig, members in groups.items():
        mfs = member_fs[sig]
        seg = StackSeg(members=len(members), member_L=mfs.L)
        stacked = FamilyShape(
            lead=(seg.members * seg.member_L,), L=seg.members * seg.member_L,
            m=mfs.m, n=mfs.n, side=mfs.side, rank=mfs.rank,
        )
        families.append(Family(fs=stacked, member_fs=mfs, seg=seg,
                               members=tuple(members)))
    return FamilyPlan(families=tuple(families), n_leaves=len(leaves))


def plan_stats(plan: FamilyPlan) -> dict:
    """Static geometry summary of a plan, JSON-serializable — consumed by the
    analysis layer's audit summary so a one-line startup log can show how the
    routed leaves collapse into launch units."""
    return {
        "n_families": len(plan.families),
        "n_leaves": plan.n_leaves,
        "n_stacked": sum(f.seg.members for f in plan.families),
        "families": [
            f"{f.member_fs.m}x{f.member_fs.n}r{f.member_fs.rank}"
            f"x{f.seg.members}"
            for f in plan.families
        ],
        # Stacked super-leaf dims [L, m, n] per family — the geometry the
        # ZeRO-sharded schedule model needs: a family shards (and therefore
        # all-gathers its L*m*n fp32 gradient at refresh boundaries) iff
        # L % n_shards == 0 (see lowrank_common.stack_shardable).
        "stack_dims": [
            [f.fs.L, f.member_fs.m, f.member_fs.n] for f in plan.families
        ],
    }


def stack_family(fam: Family, leaves: list) -> jax.Array:
    """Stack member leaves ``(*lead, a, b)`` -> ``(members*member_L, a, b)``.
    Row-major, so member ``j``'s blocks occupy rows
    ``[j*member_L, (j+1)*member_L)`` in unravel order — matching
    :func:`jax.numpy.unravel_index` on the member's own lead dims."""
    parts = jnp.stack([leaves[i] for i in fam.members])
    return parts.reshape((fam.seg.members * fam.seg.member_L,)
                         + parts.shape[1 + len(fam.member_fs.lead):])


def unstack_family(fam: Family, stacked: jax.Array) -> list[jax.Array]:
    """Inverse of :func:`stack_family` on any ``(members*member_L, *tail)``
    result: a list of per-member ``(*lead, *tail)`` arrays in member order."""
    tail = stacked.shape[1:]
    parts = stacked.reshape((fam.seg.members,) + fam.member_fs.lead + tail)
    return [parts[j] for j in range(fam.seg.members)]


def member_keys(fam: Family, base_key: jax.Array) -> jax.Array:
    """Per-member PRNG keys, stacked ``(members, 2)`` — bit-identical to the
    per-leaf ``jax.random.fold_in(base_key, i)`` derivation (vmap is
    semantics-preserving per element)."""
    idx = jnp.asarray(fam.members, dtype=jnp.int32)
    return jax.vmap(lambda i: jax.random.fold_in(base_key, i))(idx)
