"""GaLore (Zhao et al., 2024) and GoLore — Algorithm 1 of the paper.

Low-rank-projected optimizer states with a periodically refreshed projector.
Any base optimizer runs *inside* the low-rank space:

  * base="adam"  — the original GaLore (biased; Property II does not hold,
                   states live in low-rank space, update is back-projected).
  * base="muon"  — GaLore-Muon, the paper's biased baseline (= GUM with q=0).
  * base="sgdm"  — GaLore with SGD momentum (He et al. analysis setting).

``projector="random"`` gives GoLore.  Non-matrix leaves (embeddings, norms,
biases) are routed to a full AdamW fallback, matching GaLore practice.

``kernel_impl`` ("auto" | "jnp" | "pallas" | "interpret") routes the
per-step hot loops (projected momentum update / projection GEMM /
Newton–Schulz) through the fused Pallas TPU kernels via
repro.kernels.dispatch; "auto" = Pallas on TPU, jnp reference elsewhere.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .adamw import adamw
from .api import PyTree, Schedule, Transform, multi_transform, schedule_value, tree_paths
from .lowrank_common import (
    back_project,
    compute_projectors,
    default_lowrank_filter,
    family_shape,
    lowrank_momentum_update,
    lowrank_state_shape,
    proj_shape,
    project_dispatched,
)
from .newton_schulz import newton_schulz


class GaLoreFamilyState(NamedTuple):
    p: jax.Array        # (L, s, r) projector
    m1: jax.Array       # (L, r, n)/(L, m, r) first moment (or momentum)
    m2: jax.Array | None  # second moment (adam only)


class GaLoreState(NamedTuple):
    count: jax.Array
    families: PyTree  # leaf -> GaLoreFamilyState


def galore_matrices(
    lr: Schedule,
    rank: int = 128,
    period: int = 200,
    projector: str = "svd",
    base: str = "adam",
    beta: float = 0.95,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    scale: float = 0.25,
    ns_steps: int = 5,
    weight_decay: float = 0.0,
    reset_on_update: bool = False,
    seed: int = 0,
    subspace_iters: int = 2,
    kernel_impl: str = "auto",
) -> Transform:
    """GaLore over matrix leaves only (route others via :func:`galore`)."""
    if base not in ("adam", "muon", "sgdm"):
        raise ValueError(f"unsupported base: {base}")
    use_m2 = base == "adam"

    def init_family(p_leaf: jax.Array) -> GaLoreFamilyState:
        fs = family_shape(p_leaf, rank)
        p0 = jnp.zeros(proj_shape(fs), jnp.float32)
        st = jnp.zeros(lowrank_state_shape(fs), jnp.float32)
        return GaLoreFamilyState(p=p0, m1=st, m2=st if use_m2 else None)

    def init(params: PyTree) -> GaLoreState:
        fams = jax.tree_util.tree_map(
            lambda p: None if p is None else init_family(p),
            params,
            is_leaf=lambda x: x is None,
        )
        return GaLoreState(count=jnp.zeros((), jnp.int32), families=fams)

    def update_family(
        g_leaf: jax.Array,
        st: GaLoreFamilyState,
        p_leaf: jax.Array,
        count: jax.Array,
        step_lr: jax.Array,
        key: jax.Array,
    ) -> tuple[jax.Array, GaLoreFamilyState]:
        fs = family_shape(p_leaf, rank)
        g = g_leaf.astype(jnp.float32)  # (*lead, m, n)

        refresh = (count - 1) % period == 0

        def do_refresh(_):
            p_new = compute_projectors(projector, g, fs.rank, key, fs.side, subspace_iters)
            if reset_on_update:
                z = jnp.zeros_like(st.m1)
                return p_new, z, (z if use_m2 else st.m2)
            return p_new, st.m1, st.m2

        def keep(_):
            return st.p, st.m1, st.m2

        p_proj, m1, m2 = jax.lax.cond(refresh, do_refresh, keep, None)

        if base == "adam":
            # Adam needs the projected gradient itself (second moment), so the
            # kernel fuses only the projection GEMM (beta=0 path).
            r_g = project_dispatched(p_proj, g, fs.side, kernel_impl)
            c = count.astype(jnp.float32)
            m1 = b1 * m1 + (1 - b1) * r_g
            m2 = b2 * m2 + (1 - b2) * jnp.square(r_g)
            mhat = m1 / (1.0 - b1 ** c)
            vhat = m2 / (1.0 - b2 ** c)
            s = mhat / (jnp.sqrt(vhat) + eps)
            upd_lr = scale * s
        elif base == "muon":
            m1 = lowrank_momentum_update(p_proj, g, m1, beta, 1.0, fs.side,
                                         kernel_impl)
            upd_lr = newton_schulz(m1, steps=ns_steps, impl=kernel_impl)
        else:  # sgdm
            m1 = lowrank_momentum_update(p_proj, g, m1, beta, 1.0, fs.side,
                                         kernel_impl)
            upd_lr = m1

        full = back_project(p_proj, upd_lr, fs.side)
        u = -step_lr * (full + weight_decay * p_leaf.astype(jnp.float32))
        return u, GaLoreFamilyState(p=p_proj, m1=m1, m2=m2)

    def update(grads: PyTree, state: GaLoreState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)

        leaves, treedef = jax.tree_util.tree_flatten(
            params, is_leaf=lambda x: x is None
        )
        g_leaves = treedef.flatten_up_to(grads)
        s_leaves = treedef.flatten_up_to(state.families)

        upds, new_states = [], []
        for i, (g, fst, p) in enumerate(zip(g_leaves, s_leaves, leaves)):
            if g is None or p is None:
                upds.append(None)
                new_states.append(None)
                continue
            key = jax.random.fold_in(base_key, i)
            u, ns = update_family(g, fst, p, count, step_lr, key)
            upds.append(u)
            new_states.append(ns)

        updates = jax.tree_util.tree_unflatten(treedef, upds)
        families = jax.tree_util.tree_unflatten(treedef, new_states)
        return updates, GaLoreState(count=count, families=families)

    return Transform(init, update)


def galore(
    lr: Schedule,
    rank: int = 128,
    period: int = 200,
    projector: str = "svd",
    base: str = "adam",
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    **kw,
) -> Transform:
    """Full GaLore: low-rank on hidden matrices, AdamW elsewhere."""
    inner = {
        "galore": galore_matrices(
            lr, rank=rank, period=period, projector=projector, base=base, **kw
        ),
        "adamw": adamw(lr, weight_decay=kw.get("weight_decay", 0.0)),
    }

    def label_fn(params: PyTree) -> PyTree:
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: "galore" if lowrank_filter(path, p) else "adamw",
            paths,
            params,
        )

    return multi_transform(inner, label_fn)


def golore(lr: Schedule, rank: int = 128, period: int = 200, base: str = "sgdm", **kw) -> Transform:
    """GoLore (He et al., 2024): GaLore with a gradient-independent random
    orthonormal projector — convergent but subspace-blind."""
    return galore(lr, rank=rank, period=period, projector="random", base=base, **kw)
