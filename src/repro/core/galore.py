"""GaLore (Zhao et al., 2024) and GoLore — Algorithm 1 of the paper.

Low-rank-projected optimizer states with a periodically refreshed projector;
any base runs *inside* the projected space.  Each variant is now a
combinator composition (see :mod:`repro.core.combinators`)::

    galore      = chain(lowrank(scale_by_adam(scale=alpha)), ...)   # biased
    galore_muon = chain(lowrank(scale_by_muon(...)), ...)           # = GUM q=0
    golore      = galore with projector="random" (He et al., convergent)

  * base="adam"  — the original GaLore (biased; Property II does not hold,
                   states live in low-rank space, update is back-projected).
  * base="muon"  — GaLore-Muon, the paper's biased baseline (= GUM with q=0).
  * base="sgdm"  — GaLore with SGD momentum (He et al. analysis setting).

Non-matrix leaves (embeddings, norms, biases) are routed to a full AdamW
fallback via :func:`with_matrix_routing`, matching GaLore practice.

``kernel_impl`` ("auto" | "jnp" | "pallas" | "interpret") routes the
per-step hot loops (fused projected momentum update / projection GEMM /
back-projection GEMM / Newton–Schulz) through the fused Pallas TPU kernels
via repro.kernels.dispatch; "auto" = Pallas on TPU, jnp reference elsewhere.
``pad_rank_to=128`` opts into lane-aligned rank padding for peak MXU
utilization at ragged ranks.
"""
from __future__ import annotations

from typing import Callable

import jax

from .adamw import adamw
from .api import Schedule, Transform
from .combinators import (
    add_decayed_weights,
    chain,
    lowrank,
    scale_by_adam,
    scale_by_lr,
    scale_by_momentum,
    scale_by_muon,
    with_matrix_routing,
)
from .lowrank_common import default_lowrank_filter


def galore_matrices(
    lr: Schedule,
    rank=128,
    period: int = 200,
    projector: str = "svd",
    base: str = "adam",
    beta: float = 0.95,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    scale: float = 0.25,
    ns_steps: int = 5,
    weight_decay: float = 0.0,
    reset_on_update: bool = False,
    seed: int = 0,
    subspace_iters: int = 2,
    kernel_impl: str = "auto",
    pad_rank_to: int = 0,
    fuse_families: bool = False,
    fused_epilogue: bool = False,
    rank_policy=None,
    telemetry: bool = False,
) -> Transform:
    """GaLore over matrix leaves only (route others via :func:`galore`).
    ``rank`` accepts an int or a per-shape RankMap; ``rank_policy`` (see
    :mod:`repro.core.rank_policy`) supplies the initial map and turns on
    spectrum probing for adaptive policies."""
    if base == "adam":
        inner = scale_by_adam(b1=b1, b2=b2, eps=eps, scale=scale)
    elif base == "muon":
        inner = scale_by_muon(beta=beta, ns_steps=ns_steps, nesterov=False,
                              kernel_impl=kernel_impl)
    elif base == "sgdm":
        inner = scale_by_momentum(beta=beta)
    else:
        raise ValueError(f"unsupported base: {base}")
    return chain(
        lowrank(
            inner, rank=rank, period=period, projector=projector, seed=seed,
            subspace_iters=subspace_iters, reset_on_refresh=reset_on_update,
            kernel_impl=kernel_impl, pad_rank_to=pad_rank_to,
            fuse_families=fuse_families, fused_epilogue=fused_epilogue,
            rank_policy=rank_policy, telemetry=telemetry,
        ),
        add_decayed_weights(weight_decay),
        scale_by_lr(lr),
    )


def galore(
    lr: Schedule,
    rank=128,
    period: int = 200,
    projector: str = "svd",
    base: str = "adam",
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    **kw,
) -> Transform:
    """Full GaLore: low-rank on hidden matrices, AdamW elsewhere."""
    return with_matrix_routing(
        galore_matrices(
            lr, rank=rank, period=period, projector=projector, base=base, **kw
        ),
        adamw(lr, weight_decay=kw.get("weight_decay", 0.0)),
        matrix_filter=lowrank_filter,
        matrix_label="galore",
    )


def golore(lr: Schedule, rank: int = 128, period: int = 200, base: str = "sgdm", **kw) -> Transform:
    """GoLore (He et al., 2024): GaLore with a gradient-independent random
    orthonormal projector — convergent but subspace-blind."""
    return galore(lr, rank=rank, period=period, projector="random", base=base, **kw)
