"""GUM — GaLore Unbiased with Muon (Algorithm 2 of the paper).

Layerwise-sampling debiasing of low-rank projection: every period ``K``, a
fixed count ``gamma`` of blocks per family (q = gamma/L, the LISA-style
fixed-count sampling the paper's experiments use, e.g. "2 + 128") is sampled
to run the *compensated full-rank* Muon update; the rest run the scaled
low-rank GaLore-Muon update.  In expectation the update equals full Muon with
an unbiased gradient estimate (Lemma 1).

GUM is now a pure composition over :mod:`repro.core.combinators` — the
debiasing is a combinator (:func:`~repro.core.combinators.layerwise_unbias`)
rather than a bespoke file::

    gum_matrices = chain(
        lowrank(layerwise_unbias(scale_by_muon(beta), gamma, compensation),
                rank, period, projector, ...),
        add_decayed_weights(wd), scale_by_lr(lr))
    gum = with_matrix_routing(gum_matrices, adamw, ...)

which also makes new unbiased variants one-liners — see
:func:`unbiased_galore_adam` below (``layerwise_unbias`` wrapping
``scale_by_adam``).

State layout per family (a stacked leaf ``(L, m, n)``), unchanged from the
paper's accounting:

  projs                (L, s, r)     projector (s = min(m, n) side)
  inner.low[leaf]      (L, r, n)     low-rank base momentum ((L, m, r) right)
  inner.full[leaf]     (gamma, m, n) full-rank base momentum *slots*
  inner.idx[leaf]      (gamma,)      slot -> block assignment, resampled
                                     each period

Memory per family = L·s·r + L·r·n + gamma·m·n  ==  O((2-q)·mr·L + q·L·m·n)
— exactly Table 1's GUM complexity (regression-checked in
tests/test_combinators.py via ``state_bytes``).

Update rules (left projection, block l, coefficients per ``compensation``):

  low-rank (unsampled):  R_l <- beta R_l + c_low  * P_lᵀ G_l
                         W_l <- W_l - lr * P_l NS(R_l)
  full-rank (sampled):   F_j <- beta F_j + c_full * (G_l - c_comp P_l P_lᵀ G_l)
                         W_l <- W_l - lr * NS(F_j)

  compensation="paper"    : c_low = 1/(1-q), c_full = 1/q, c_comp = 1
  compensation="finetune" : c_low = 1,       c_full = 1/q, c_comp = 1-q
                            (App. C.1 — recovers full Muon at q=1)

Both choices satisfy E[update] = Muon update with E[G_hat] = G.

``kernel_impl`` ("auto" | "jnp" | "pallas" | "interpret") routes the per-step
hot loops — the fused projected momentum update R <- beta R + c PᵀG, the
projection / back-projection GEMMs and the Newton–Schulz iteration — through
the fused Pallas TPU kernels (repro.kernels.dispatch); "auto" uses them on
TPU and the jnp reference elsewhere, so the default CPU trajectory is
unchanged.  ``use_muon_scale`` additionally applies Muon's sqrt(max(1, m/n))
RMS-matching factor to both branches' orthogonalized updates (off by default
— the paper's Algorithm 2 does not scale).  ``pad_rank_to=128`` opts into
lane-aligned rank padding.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .adamw import adamw
from .api import Schedule, Transform, tree_paths
from .combinators import (
    LowRankState,
    add_decayed_weights,
    chain,
    layerwise_unbias,
    lowrank,
    scale_by_adam,
    scale_by_lr,
    scale_by_momentum,
    scale_by_muon,
    with_matrix_routing,
)
from .lowrank_common import (
    default_lowrank_filter,
    family_shape,
    gather_blocks,
    scatter_blocks,
)


def gum_matrices(
    lr: Schedule,
    rank=128,
    gamma: int = 2,
    period: int = 200,
    projector: str = "svd",
    base: str = "muon",
    beta: float = 0.95,
    ns_steps: int = 5,
    weight_decay: float = 0.0,
    compensation: str = "paper",
    seed: int = 0,
    subspace_iters: int = 2,
    external_refresh: bool = False,
    kernel_impl: str = "auto",
    use_muon_scale: bool = False,
    pad_rank_to: int = 0,
    fuse_families: bool = False,
    fused_epilogue: bool = False,
    rank_policy=None,
    telemetry: bool = False,
) -> Transform:
    """GUM over matrix leaves (route 1-D/embedding leaves via :func:`gum`).

    ``external_refresh=True`` skips the in-update period refresh — used by
    the low-rank gradient-accumulation path, where :func:`gum_accum_tools`
    refreshes against a raw microbatch gradient before projection.

    ``kernel_impl`` selects the hot-loop implementation (see module
    docstring); ``use_muon_scale`` applies Muon's RMS-matching shape factor.
    ``fuse_families`` runs the whole pipeline family-stacked (one batched
    launch per shape family instead of per leaf, trajectory-identical);
    ``fused_epilogue`` folds chain-tail epilogues into the back-projection
    GEMM (see repro.core.combinators)."""
    if base == "muon":
        inner = scale_by_muon(beta=beta, ns_steps=ns_steps, nesterov=False,
                              use_muon_scale=use_muon_scale,
                              kernel_impl=kernel_impl)
    elif base == "sgdm":
        inner = scale_by_momentum(beta=beta, use_muon_scale=use_muon_scale)
    else:
        raise ValueError("GUM requires a Property-II base optimizer: muon | sgdm")
    lowrank_t = lowrank(
        layerwise_unbias(inner, gamma=gamma, compensation=compensation),
        rank=rank, period=period, projector=projector, seed=seed,
        subspace_iters=subspace_iters, reset_on_refresh=True,
        external_refresh=external_refresh, kernel_impl=kernel_impl,
        pad_rank_to=pad_rank_to, fuse_families=fuse_families,
        fused_epilogue=fused_epilogue, rank_policy=rank_policy,
        telemetry=telemetry,
    )
    t = chain(lowrank_t, add_decayed_weights(weight_decay), scale_by_lr(lr))
    # Hook for gum_accum_tools: the external-refresh entry point + the fact
    # that the lowrank state sits at chain position 0.
    t.update.lowrank_transform = lowrank_t
    return t


def gum(
    lr: Schedule,
    rank=128,
    gamma: int = 2,
    period: int = 200,
    projector: str = "svd",
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    **kw,
) -> Transform:
    """Full GUM: unbiased low-rank Muon on hidden matrices, AdamW elsewhere
    (embeddings / head / norms / biases), mirroring the paper's setup."""
    matrices = gum_matrices(
        lr, rank=rank, gamma=gamma, period=period, projector=projector, **kw
    )
    t = with_matrix_routing(
        matrices,
        adamw(lr, weight_decay=kw.get("weight_decay", 0.0)),
        matrix_filter=lowrank_filter,
        matrix_label="gum",
    )
    t.update.lowrank_transform = matrices.update.lowrank_transform
    return t


def unbiased_galore_adam(
    lr: Schedule,
    rank=128,
    gamma: int = 2,
    period: int = 200,
    projector: str = "svd",
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    scale: float = 0.25,
    weight_decay: float = 0.0,
    compensation: str = "paper",
    seed: int = 0,
    subspace_iters: int = 2,
    kernel_impl: str = "auto",
    pad_rank_to: int = 0,
    fuse_families: bool = False,
    fused_epilogue: bool = False,
    rank_policy=None,
    telemetry: bool = False,
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
) -> Transform:
    """Unbiased GaLore-Adam — a NEW method that is a pure composition:
    :func:`~repro.core.combinators.layerwise_unbias` wrapping
    ``scale_by_adam`` inside ``lowrank``.  The gamma sampled blocks per
    period run Adam on the compensated full-rank gradient (their own
    (gamma, m, n) moment slots); the rest run GaLore-Adam on the scaled
    projected gradient.  The *gradient estimate* is unbiased (Lemma 1);
    because Adam violates Property II the update itself is not exactly full
    Adam in expectation — the AdaRankGrad-style extension of the paradigm,
    previously inexpressible without writing a new optimizer file."""
    matrix = chain(
        lowrank(
            layerwise_unbias(
                scale_by_adam(b1=b1, b2=b2, eps=eps, scale=scale),
                gamma=gamma, compensation=compensation,
            ),
            rank=rank, period=period, projector=projector, seed=seed,
            subspace_iters=subspace_iters, reset_on_refresh=True,
            kernel_impl=kernel_impl, pad_rank_to=pad_rank_to,
            fuse_families=fuse_families, fused_epilogue=fused_epilogue,
            rank_policy=rank_policy, telemetry=telemetry,
        ),
        add_decayed_weights(weight_decay),
        scale_by_lr(lr),
    )
    return with_matrix_routing(
        matrix,
        adamw(lr, weight_decay=weight_decay),
        matrix_filter=lowrank_filter,
        matrix_label="unbiased_galore_adam",
    )


# ---------------------------------------------------------------------------
# Low-rank gradient ACCUMULATION (beyond-paper, DESIGN.md §3).
#
# Projection is linear, so sum_mb Pᵀ G_mb == Pᵀ (sum_mb G_mb): microbatch
# gradient accumulation can happen in the projected space.  The fp32
# accumulator for a family shrinks from (*lead, m, n) to (*lead, r, n) plus
# gamma full slots — the same (2-q)·mr + q·m² ratio the paper proves for
# optimizer states, now applied to the gradient accumulator.
#
# Exactness: GUM's update consumes the gradient ONLY through Pᵀ G (low-rank
# branch) and G[idx] (sampled full blocks).  With Property I,
#     project(P, back_project(P, acc_low)) == acc_low
# so the reconstruction
#     G_hat = scatter(back_project(P, acc_low), idx, acc_full)
# fed to the STANDARD update produces bit-equivalent updates to accumulating
# raw gradients — without ever holding a full-shape accumulator.
#
# The projector refresh needs one raw gradient; Algorithm 2 builds P from a
# *single stochastic gradient* G_{t,0} anyway, so refreshing from the first
# microbatch's gradient keeps the same estimator class (any Property-I P
# preserves unbiasedness).  The refresh itself is the ``lowrank`` combinator's
# external-refresh hook (``update.refresh``), so projector RNG and slot
# resampling stay in one place.  Hooks (all sharing the gum() label routing):
#
#   tools = gum_accum_tools(lr, rank=..., gamma=..., ...)
#   state = tools.transform.init(params)
#   state = tools.refresh(grads_mb0, state, params)     # cond'd on period
#   acc   = tools.project(grads_mb, state, params)      # per microbatch; sum
#   g_hat = tools.reconstruct(acc, state, params)       # compact -> grads
#   upd, state = tools.transform.update(g_hat, state, params)
# ---------------------------------------------------------------------------


class GUMAccumTools(NamedTuple):
    transform: Transform
    refresh: Callable          # (grads, state, params) -> state
    project: Callable          # (grads, state, params) -> compact pytree
    reconstruct: Callable      # (compact, state, params) -> grads pytree


def gum_accum_tools(
    lr: Schedule,
    rank=128,
    gamma: int = 2,
    period: int = 200,
    projector: str = "svd",
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    seed: int = 0,
    subspace_iters: int = 2,
    kernel_impl: str = "auto",
    pad_rank_to: int = 0,
    **kw,
) -> GUMAccumTools:
    fused = bool(kw.get("fuse_families"))
    transform = gum(
        lr, rank=rank, gamma=gamma, period=period, projector=projector,
        lowrank_filter=lowrank_filter, seed=seed, subspace_iters=subspace_iters,
        external_refresh=True, kernel_impl=kernel_impl,
        pad_rank_to=pad_rank_to, **kw,
    )
    lowrank_refresh = transform.update.lowrank_transform.update.refresh

    def labels(params):
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: lowrank_filter(path, p), paths, params
        )

    def mask(tree, is_low):
        return jax.tree_util.tree_map(
            lambda x, l: x if l else None, tree, is_low
        )

    def _lowrank_state(state) -> LowRankState:
        # gum state: MultiState.inner["gum"] = chain state
        #   (LowRankState, add_decayed_weights (), scale_by_lr state)
        return state.inner["gum"][0]

    def _dispatch():
        from repro.kernels import dispatch

        return dispatch

    def _per_leaf_state(lr_state, treedef, leaves, lab):
        """Per-leaf (projector, slot->block idx) views of the lowrank state,
        for BOTH layouts.  Per-leaf states flatten along the params treedef;
        the family-stacked state (``fuse_families=True``) holds one stacked
        projector and one global idx vector per family, so each member's
        slice is unstacked and its idx entries shifted back to member-local
        block ids (the inverse of layerwise_unbias's per-member offset)."""
        if not fused:
            return (treedef.flatten_up_to(lr_state.projs),
                    treedef.flatten_up_to(lr_state.inner.idx))
        from .family_plan import build_family_plan, unstack_family

        masked = [p if l else None for p, l in zip(leaves, lab)]
        plan = build_family_plan(masked, rank)
        proj_l = [None] * plan.n_leaves
        idx_l = [None] * plan.n_leaves
        for fi, fam in enumerate(plan.families):
            members_p = unstack_family(fam, lr_state.projs[fi])
            idx = lr_state.inner.idx[fi]
            g_f = (int(idx.shape[0]) // fam.seg.members
                   if idx is not None else 0)
            for j, i in enumerate(fam.members):
                proj_l[i] = members_p[j]
                if idx is not None:
                    idx_l[i] = (idx[j * g_f:(j + 1) * g_f]
                                - j * fam.seg.member_L)
        return proj_l, idx_l

    def refresh(grads, state, params):
        """Run the period-boundary projector/sampling refresh against raw
        (microbatch-0) gradients via the lowrank combinator's external-refresh
        hook, leaving count untouched (the subsequent transform.update call on
        the same step sees fresh P and, in external mode, never refreshes
        itself; key derivation matches the in-update path exactly)."""
        is_low = labels(params)
        chain_state = tuple(state.inner["gum"])
        new_lr = lowrank_refresh(
            mask(grads, is_low), chain_state[0], mask(params, is_low)
        )
        new_inner = dict(state.inner)
        new_inner["gum"] = (new_lr,) + chain_state[1:]
        return state._replace(inner=new_inner)

    def project_grads(grads, state, params):
        lr_state = _lowrank_state(state)
        is_low = labels(params)
        d = _dispatch()

        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        g_l = treedef.flatten_up_to(grads)
        lab = treedef.flatten_up_to(is_low)
        proj_l, idx_l = _per_leaf_state(lr_state, treedef, leaves, lab)

        def one(g, proj, idx, p, is_l):
            if g is None:
                return None
            if not is_l or proj is None:
                return {"raw": g.astype(jnp.float32)}
            fs = family_shape(p, rank)
            g32 = g.astype(jnp.float32)
            out = {"low": d.project(proj, g32, side=fs.side, impl=kernel_impl,
                                    pad_rank_to=pad_rank_to)}
            if idx is not None:
                out["full"] = gather_blocks(g32, idx, fs)
            return out

        return jax.tree_util.tree_unflatten(
            treedef,
            [one(g, pr, ix, p, il)
             for g, pr, ix, p, il in zip(g_l, proj_l, idx_l, leaves, lab)],
        )

    def reconstruct(compact, state, params):
        lr_state = _lowrank_state(state)
        is_low = labels(params)
        d = _dispatch()

        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        c_l = treedef.flatten_up_to(compact)
        lab = treedef.flatten_up_to(is_low)
        proj_l, idx_l = _per_leaf_state(lr_state, treedef, leaves, lab)

        def one(c, proj, idx, p, is_l):
            if c is None:
                return None
            if not is_l or proj is None:
                return c["raw"]
            fs = family_shape(p, rank)
            g_hat = d.back_project(proj, c["low"], side=fs.side,
                                   impl=kernel_impl, pad_rank_to=pad_rank_to)
            if "full" in c:
                g_hat = scatter_blocks(g_hat, idx, c["full"], fs)
            return g_hat

        return jax.tree_util.tree_unflatten(
            treedef,
            [one(c, pr, ix, p, il)
             for c, pr, ix, p, il in zip(c_l, proj_l, idx_l, leaves, lab)],
        )

    return GUMAccumTools(transform=transform, refresh=refresh,
                         project=project_grads, reconstruct=reconstruct)
