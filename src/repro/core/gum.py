"""GUM — GaLore Unbiased with Muon (Algorithm 2 of the paper).

Layerwise-sampling debiasing of low-rank projection: every period ``K``, a
fixed count ``gamma`` of blocks per family (q = gamma/L, the LISA-style
fixed-count sampling the paper's experiments use, e.g. "2 + 128") is sampled
to run the *compensated full-rank* Muon update; the rest run the scaled
low-rank GaLore-Muon update.  In expectation the update equals full Muon with
an unbiased gradient estimate (Lemma 1).

Static-shape formulation (DESIGN.md §3): per family (a stacked leaf
``(L, m, n)``) we store

  p       (L, s, r)     projector (s = min(m, n) side)
  r_low   (L, r, n)     low-rank momentum (or (L, m, r) for right projection)
  r_full  (gamma, m, n) full-rank momentum *slots*
  idx     (gamma,)      slot -> block assignment, resampled each period

Memory per family = L·s·r + L·r·n + gamma·m·n  ==  O((2-q)·mr·L + q·L·m·n)
— exactly Table 1's GUM complexity.

Update rules (left projection, block l, coefficients per ``compensation``):

  low-rank (unsampled):  R_l <- beta R_l + c_low  * P_lᵀ G_l
                         W_l <- W_l - lr * P_l NS(R_l)
  full-rank (sampled):   F_j <- beta F_j + c_full * (G_l - c_comp P_l P_lᵀ G_l)
                         W_l <- W_l - lr * NS(F_j)

  compensation="paper"    : c_low = 1/(1-q), c_full = 1/q, c_comp = 1
  compensation="finetune" : c_low = 1,       c_full = 1/q, c_comp = 1-q
                            (App. C.1 — recovers full Muon at q=1)

Both choices satisfy E[update] = Muon update with E[G_hat] = G.

``kernel_impl`` ("auto" | "jnp" | "pallas" | "interpret") routes the two
per-step hot loops — the projected momentum update R <- beta R + c PᵀG and
the Newton–Schulz iteration — through the fused Pallas TPU kernels
(repro.kernels.dispatch); "auto" uses them on TPU and the jnp reference
elsewhere, so the default CPU trajectory is unchanged.  ``use_muon_scale``
additionally applies Muon's sqrt(max(1, m/n)) RMS-matching factor to both
branches' orthogonalized updates (off by default — the paper's Algorithm 2
does not scale).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .adamw import adamw
from .api import PyTree, Schedule, Transform, multi_transform, schedule_value, tree_paths
from .lowrank_common import (
    back_project,
    compute_projectors,
    default_lowrank_filter,
    family_shape,
    gather_blocks,
    lowrank_momentum_update,
    lowrank_state_shape,
    project,
    proj_shape,
    scatter_blocks,
)
from .newton_schulz import muon_scale, newton_schulz


class GUMFamilyState(NamedTuple):
    p: jax.Array               # (L, s, r)
    r_low: jax.Array           # (L, r, n) | (L, m, r)
    r_full: Optional[jax.Array]  # (gamma, m, n) or None when gamma == 0
    idx: Optional[jax.Array]     # (gamma,) int32 or None


class GUMState(NamedTuple):
    count: jax.Array
    families: PyTree


def gum_matrices(
    lr: Schedule,
    rank: int = 128,
    gamma: int = 2,
    period: int = 200,
    projector: str = "svd",
    base: str = "muon",
    beta: float = 0.95,
    ns_steps: int = 5,
    weight_decay: float = 0.0,
    compensation: str = "paper",
    seed: int = 0,
    subspace_iters: int = 2,
    external_refresh: bool = False,
    kernel_impl: str = "auto",
    use_muon_scale: bool = False,
) -> Transform:
    """GUM over matrix leaves (route 1-D/embedding leaves via :func:`gum`).

    ``external_refresh=True`` skips the in-update period refresh — used by
    the low-rank gradient-accumulation path, where :func:`gum_accum_tools`
    refreshes against a raw microbatch gradient before projection.

    ``kernel_impl`` selects the hot-loop implementation (see module
    docstring); ``use_muon_scale`` applies Muon's RMS-matching shape factor."""
    if base not in ("muon", "sgdm"):
        raise ValueError("GUM requires a Property-II base optimizer: muon | sgdm")
    if compensation not in ("paper", "finetune"):
        raise ValueError(f"unknown compensation: {compensation}")
    use_ns = base == "muon"

    def fam_gamma(L: int) -> int:
        return min(gamma, L)

    def init_family(p_leaf: jax.Array) -> GUMFamilyState:
        fs = family_shape(p_leaf, rank)
        g_f = fam_gamma(fs.L)
        p0 = jnp.zeros(proj_shape(fs), jnp.float32)
        r_low = jnp.zeros(lowrank_state_shape(fs), jnp.float32)
        if g_f == 0:
            return GUMFamilyState(p=p0, r_low=r_low, r_full=None, idx=None)
        r_full = jnp.zeros((g_f, fs.m, fs.n), jnp.float32)
        idx = jnp.arange(g_f, dtype=jnp.int32)
        return GUMFamilyState(p=p0, r_low=r_low, r_full=r_full, idx=idx)

    def init(params: PyTree) -> GUMState:
        fams = jax.tree_util.tree_map(
            lambda p: None if p is None else init_family(p),
            params,
            is_leaf=lambda x: x is None,
        )
        return GUMState(count=jnp.zeros((), jnp.int32), families=fams)

    def update_family(
        g_leaf: jax.Array,
        st: GUMFamilyState,
        p_leaf: jax.Array,
        count: jax.Array,
        step_lr: jax.Array,
        key: jax.Array,
    ) -> tuple[jax.Array, GUMFamilyState]:
        fs = family_shape(p_leaf, rank)
        g_f = fam_gamma(fs.L)
        q = g_f / fs.L
        g = g_leaf.astype(jnp.float32)  # (*lead, m, n) — never reshaped

        refresh = (count - 1) % period == 0
        key_proj, key_idx = jax.random.split(key)

        # --- period boundary: new projector, resample blocks, restart momentum
        def do_refresh(_):
            p_new = compute_projectors(
                projector, g, fs.rank, key_proj, fs.side, subspace_iters
            )
            out = (p_new, jnp.zeros_like(st.r_low))
            if g_f > 0:
                idx_new = jax.random.choice(
                    key_idx, fs.L, (g_f,), replace=False
                ).astype(jnp.int32)
                out += (jnp.zeros_like(st.r_full), idx_new)
            return out

        def keep(_):
            out = (st.p, st.r_low)
            if g_f > 0:
                out += (st.r_full, st.idx)
            return out

        if external_refresh:
            refreshed = keep(None)
        else:
            refreshed = jax.lax.cond(refresh, do_refresh, keep, None)
        if g_f > 0:
            p_proj, r_low, r_full, idx = refreshed
        else:
            p_proj, r_low = refreshed
            r_full, idx = None, None

        c_low = 1.0 if compensation == "finetune" else 1.0 / max(1.0 - q, 1e-12)
        c_comp = (1.0 - q) if compensation == "finetune" else 1.0

        # --- low-rank branch (computed for all blocks; sampled blocks' output
        # is overwritten by the scatter below and their r_low restarts at the
        # next period boundary, so advancing it is trajectory-neutral).
        if q < 1.0:
            r_low = lowrank_momentum_update(
                p_proj, g, r_low, beta, c_low, fs.side, kernel_impl
            )
            s_low = (
                newton_schulz(r_low, steps=ns_steps, impl=kernel_impl)
                if use_ns else r_low
            )
            u = back_project(p_proj, s_low, fs.side)
        else:
            u = jnp.zeros_like(g)

        # --- compensated full-rank branch on the gamma sampled blocks.
        if g_f > 0:
            c_full = 1.0 / q
            g_s = gather_blocks(g, idx, fs)       # (gamma, m, n)
            p_s = gather_blocks(p_proj, idx, fs)  # (gamma, s, r)
            pptg = back_project(p_s, project(p_s, g_s, fs.side), fs.side)
            resid = g_s - c_comp * pptg
            r_full = beta * r_full + c_full * resid
            s_full = (
                newton_schulz(r_full, steps=ns_steps, impl=kernel_impl)
                if use_ns else r_full
            )
            u = scatter_blocks(u, idx, s_full, fs)

        if use_muon_scale:
            u = muon_scale((fs.m, fs.n)) * u
        u = -step_lr * (u + weight_decay * p_leaf.astype(jnp.float32))
        return u, GUMFamilyState(p=p_proj, r_low=r_low, r_full=r_full, idx=idx)

    def update(grads: PyTree, state: GUMState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)

        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        g_leaves = treedef.flatten_up_to(grads)
        s_leaves = treedef.flatten_up_to(state.families)

        upds, new_states = [], []
        for i, (g, fst, p) in enumerate(zip(g_leaves, s_leaves, leaves)):
            if g is None or p is None:
                upds.append(None)
                new_states.append(None)
                continue
            key = jax.random.fold_in(base_key, i)
            u, ns = update_family(g, fst, p, count, step_lr, key)
            upds.append(u)
            new_states.append(ns)

        updates = jax.tree_util.tree_unflatten(treedef, upds)
        families = jax.tree_util.tree_unflatten(treedef, new_states)
        return updates, GUMState(count=count, families=families)

    return Transform(init, update)


# ---------------------------------------------------------------------------
# Low-rank gradient ACCUMULATION (beyond-paper, DESIGN.md §3).
#
# Projection is linear, so sum_mb Pᵀ G_mb == Pᵀ (sum_mb G_mb): microbatch
# gradient accumulation can happen in the projected space.  The fp32
# accumulator for a family shrinks from (*lead, m, n) to (*lead, r, n) plus
# gamma full slots — the same (2-q)·mr + q·m² ratio the paper proves for
# optimizer states, now applied to the gradient accumulator.
#
# Exactness: GUM's update consumes the gradient ONLY through Pᵀ G (low-rank
# branch) and G[idx] (sampled full blocks).  With Property I,
#     project(P, back_project(P, acc_low)) == acc_low
# so the reconstruction
#     G_hat = scatter(back_project(P, acc_low), idx, acc_full)
# fed to the STANDARD update produces bit-equivalent updates to accumulating
# raw gradients — without ever holding a full-shape accumulator.
#
# The projector refresh needs one raw gradient; Algorithm 2 builds P from a
# *single stochastic gradient* G_{t,0} anyway, so refreshing from the first
# microbatch's gradient keeps the same estimator class (any Property-I P
# preserves unbiasedness).  Hooks (all sharing the gum() label routing):
#
#   tools = gum_accum_tools(lr, rank=..., gamma=..., ...)
#   state = tools.transform.init(params)
#   state = tools.refresh(grads_mb0, state, params)     # cond'd on period
#   acc   = tools.project(grads_mb, state, params)      # per microbatch; sum
#   g_hat = tools.reconstruct(acc, state, params)       # compact -> grads
#   upd, state = tools.transform.update(g_hat, state, params)
# ---------------------------------------------------------------------------


class GUMAccumTools(NamedTuple):
    transform: Transform
    refresh: Callable          # (grads, state, params) -> state
    project: Callable          # (grads, state, params) -> compact pytree
    reconstruct: Callable      # (compact, state, params) -> grads pytree


def gum_accum_tools(
    lr: Schedule,
    rank: int = 128,
    gamma: int = 2,
    period: int = 200,
    projector: str = "svd",
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    seed: int = 0,
    subspace_iters: int = 2,
    **kw,
) -> GUMAccumTools:
    transform = gum(
        lr, rank=rank, gamma=gamma, period=period, projector=projector,
        lowrank_filter=lowrank_filter, seed=seed, subspace_iters=subspace_iters,
        external_refresh=True, **kw,
    )

    def labels(params):
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: lowrank_filter(path, p), paths, params
        )

    def refresh(grads, state: "MultiStateLike", params):
        """Run the period-boundary projector/sampling refresh against raw
        (microbatch-0) gradients, leaving count untouched (the subsequent
        transform.update call on the same step sees fresh P and skips its own
        refresh because we advance its RNG deterministically from count)."""
        gum_state: GUMState = state.inner["gum"]
        count = gum_state.count + 1
        refresh_now = (count - 1) % period == 0
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)

        is_low = labels(params)
        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        g_leaves = treedef.flatten_up_to(grads)
        s_leaves = treedef.flatten_up_to(gum_state.families)
        lab_leaves = treedef.flatten_up_to(is_low)

        new_fams = []
        for i, (g, fam, p, is_l) in enumerate(zip(g_leaves, s_leaves, leaves, lab_leaves)):
            if not is_l or fam is None:
                new_fams.append(fam)
                continue
            fs = family_shape(p, rank)
            g_f = min(gamma, fs.L)
            key = jax.random.fold_in(base_key, i)
            key_proj, key_idx = jax.random.split(key)

            def do(_, g=g, fam=fam, fs=fs, g_f=g_f, key_proj=key_proj, key_idx=key_idx):
                p_new = compute_projectors(
                    projector, g.astype(jnp.float32), fs.rank, key_proj, fs.side,
                    subspace_iters,
                )
                out = (p_new, jnp.zeros_like(fam.r_low))
                if g_f > 0:
                    idx_new = jax.random.choice(key_idx, fs.L, (g_f,), replace=False
                                                ).astype(jnp.int32)
                    out += (jnp.zeros_like(fam.r_full), idx_new)
                return out

            def keep(_, fam=fam, g_f=g_f):
                out = (fam.p, fam.r_low)
                if g_f > 0:
                    out += (fam.r_full, fam.idx)
                return out

            res = jax.lax.cond(refresh_now, do, keep, None)
            if g_f > 0:
                new_fams.append(GUMFamilyState(*res))
            else:
                new_fams.append(GUMFamilyState(res[0], res[1], None, None))

        fams = jax.tree_util.tree_unflatten(treedef, new_fams)
        new_inner = dict(state.inner)
        new_inner["gum"] = GUMState(count=gum_state.count, families=fams)
        return state._replace(inner=new_inner)

    def project_grads(grads, state, params):
        gum_state: GUMState = state.inner["gum"]
        is_low = labels(params)

        def one(g, fam, p, is_l):
            if g is None:
                return None
            if not is_l or fam is None:
                return {"raw": g.astype(jnp.float32)}
            fs = family_shape(p, rank)
            g32 = g.astype(jnp.float32)
            out = {"low": project(fam.p, g32, fs.side)}
            if fam.idx is not None:
                out["full"] = gather_blocks(g32, fam.idx, fs)
            return out

        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        g_l = treedef.flatten_up_to(grads)
        s_l = treedef.flatten_up_to(gum_state.families)
        lab = treedef.flatten_up_to(is_low)
        return jax.tree_util.tree_unflatten(
            treedef, [one(g, f, p, il) for g, f, p, il in zip(g_l, s_l, leaves, lab)]
        )

    def reconstruct(compact, state, params):
        gum_state: GUMState = state.inner["gum"]
        is_low = labels(params)

        def one(c, fam, p, is_l):
            if c is None:
                return None
            if not is_l or fam is None:
                return c["raw"]
            fs = family_shape(p, rank)
            g_hat = back_project(fam.p, c["low"], fs.side)
            if "full" in c:
                g_hat = scatter_blocks(g_hat, fam.idx, c["full"], fs)
            return g_hat

        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        c_l = treedef.flatten_up_to(compact)
        s_l = treedef.flatten_up_to(gum_state.families)
        lab = treedef.flatten_up_to(is_low)
        return jax.tree_util.tree_unflatten(
            treedef, [one(c, f, p, il) for c, f, p, il in zip(c_l, s_l, leaves, lab)]
        )

    return GUMAccumTools(transform=transform, refresh=refresh,
                         project=project_grads, reconstruct=reconstruct)


def gum(
    lr: Schedule,
    rank: int = 128,
    gamma: int = 2,
    period: int = 200,
    projector: str = "svd",
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    **kw,
) -> Transform:
    """Full GUM: unbiased low-rank Muon on hidden matrices, AdamW elsewhere
    (embeddings / head / norms / biases), mirroring the paper's setup."""
    inner = {
        "gum": gum_matrices(
            lr, rank=rank, gamma=gamma, period=period, projector=projector, **kw
        ),
        "adamw": adamw(lr, weight_decay=kw.get("weight_decay", 0.0)),
    }

    def label_fn(params: PyTree) -> PyTree:
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: "gum" if lowrank_filter(path, p) else "adamw",
            paths,
            params,
        )

    return multi_transform(inner, label_fn)
