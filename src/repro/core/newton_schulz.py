"""Newton–Schulz orthogonalization (msign) used by Muon.

``newton_schulz(X)`` approximates ``msign(X) = U V^T`` for ``X = U Σ V^T``.
We use Keller Jordan's quintic iteration with the standard coefficients
(a, b, c) = (3.4445, -4.7750, 2.0315), 5 steps, computed in bf16-or-f32.

Implementation dispatch (the ``impl`` argument):

  * ``"jnp"`` / ``"xla"`` — the pure-jnp path below (bit-stable reference).
  * ``"auto"``            — :mod:`repro.kernels.dispatch` picks the fused
                            Pallas TPU kernels on TPU and this jnp path
                            elsewhere (shape-illegal inputs also fall back).
  * ``"pallas"``          — the Pallas kernels; off-TPU this degrades to the
                            Pallas interpreter so tests exercise the kernel
                            code on any backend.
  * ``"interpret"``       — the Pallas interpreter explicitly.

Key property for the paper (Lemma 1 / Property II):
``newton_schulz(P @ X) == P @ newton_schulz(X)`` whenever ``PᵀP = I`` —
tested exactly in tests/test_unbiasedness.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NS_COEFFS = (3.4445, -4.7750, 2.0315)
NS_STEPS = 5


def newton_schulz(
    x: jax.Array, *, steps: int = NS_STEPS, eps: float = 1e-7, impl: str = "jnp"
) -> jax.Array:
    """Quintic Newton–Schulz iteration toward the matrix sign/polar factor.

    Works on (..., m, n); iterates on the transposed problem when m > n so the
    Gram matrix XXᵀ is the small side (exactly Muon's reference trick).
    """
    if impl not in ("jnp", "xla"):
        # Lazy import: repro.kernels.newton_schulz imports NS_COEFFS from here.
        from repro.kernels import dispatch

        resolved = dispatch.resolve_impl(impl)
        if resolved != "jnp":
            return dispatch.newton_schulz(x, steps=steps, eps=eps, impl=resolved)

    # Lazy import: at module-load time repro.kernels.newton_schulz imports
    # NS_COEFFS from here, so a top-level kernels import would be circular.
    # (This does pull in the kernels package on first call.)
    from repro.kernels import launch_count

    launch_count.record("newton_schulz")
    a, b, c = NS_COEFFS
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)

    transposed = x.shape[-2] > x.shape[-1]
    if transposed:
        x = jnp.swapaxes(x, -1, -2)

    # Spectral-norm-ish normalization so singular values land in the basin.
    norm = jnp.linalg.norm(x, axis=(-2, -1), keepdims=True)
    x = x / (norm + eps)

    def body(_, x):
        xxt = x @ jnp.swapaxes(x, -1, -2)          # (..., m, m), m <= n
        bxx = b * xxt + c * (xxt @ xxt)            # quintic combination
        return a * x + bxx @ x

    x = jax.lax.fori_loop(0, steps, body, x)

    if transposed:
        x = jnp.swapaxes(x, -1, -2)
    return x.astype(orig_dtype)


def msign_exact(x: jax.Array) -> jax.Array:
    """Exact UVᵀ via SVD — the oracle for Assumption 4 and kernel tests."""
    u, _, vt = jnp.linalg.svd(x.astype(jnp.float32), full_matrices=False)
    return u @ vt


def muon_scale(shape: tuple[int, int]) -> float:
    """Muon's shape-dependent update scale: sqrt(max(1, m/n)) keeps the RMS of
    the orthogonalized update comparable across aspect ratios (Jordan et al.).
    Applied by ``muon`` (default on) and, behind ``use_muon_scale``, by GUM."""
    m, n = shape[-2], shape[-1]
    return max(1.0, m / n) ** 0.5
