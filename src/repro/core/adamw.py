"""AdamW (decoupled weight decay) — the paper's FT-AdamW baseline."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .api import PyTree, Schedule, Transform, schedule_value


class AdamWState(NamedTuple):
    count: jax.Array
    mu: PyTree
    nu: PyTree


def adamw(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Transform:
    def init(params: PyTree) -> AdamWState:
        zeros = lambda t: jax.tree_util.tree_map(
            lambda p: None if p is None else jnp.zeros_like(p, dtype=jnp.float32),
            t,
            is_leaf=lambda x: x is None,
        )
        return AdamWState(count=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))

    def update(grads: PyTree, state: AdamWState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(g, mu, nu, p):
            if g is None:
                return None, None, None
            g32 = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * jnp.square(g32)
            mhat = mu / bc1
            nhat = nu / bc2
            u = -step_lr * (mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u, mu, nu

        flat = jax.tree_util.tree_map(
            upd, grads, state.mu, state.nu, params, is_leaf=lambda x: x is None
        )
        # tree_map returned tuples at leaves; transpose into three trees.
        is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
        updates = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=is_triple)
        mu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_triple)
        nu = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=is_triple)
        return updates, AdamWState(count=count, mu=mu, nu=nu)

    return Transform(init, update)


def sgdm(lr: Schedule, beta: float = 0.9, weight_decay: float = 0.0) -> Transform:
    """SGD with (EMA) momentum — Property-II compliant base optimizer."""

    class SGDMState(NamedTuple):
        count: jax.Array
        mu: PyTree

    def init(params: PyTree) -> SGDMState:
        mu = jax.tree_util.tree_map(
            lambda p: None if p is None else jnp.zeros_like(p, dtype=jnp.float32),
            params,
            is_leaf=lambda x: x is None,
        )
        return SGDMState(count=jnp.zeros((), jnp.int32), mu=mu)

    def update(grads: PyTree, state: SGDMState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)

        def upd(g, mu, p):
            if g is None:
                return None, None
            mu = beta * mu + g.astype(jnp.float32)
            u = -step_lr * (mu + weight_decay * p.astype(jnp.float32))
            return u, mu

        flat = jax.tree_util.tree_map(upd, grads, state.mu, params, is_leaf=lambda x: x is None)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
        updates = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=is_pair)
        mu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_pair)
        return updates, SGDMState(count=count, mu=mu)

    return Transform(init, update)
