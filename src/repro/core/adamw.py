"""AdamW / SGDM — the paper's full-rank baselines, as combinator chains.

Both are now one-line compositions over :mod:`repro.core.combinators`::

    adamw = chain(scale_by_adam(b1, b2, eps), add_decayed_weights(wd),
                  scale_by_lr(lr))
    sgdm  = chain(scale_by_momentum(beta), add_decayed_weights(wd),
                  scale_by_lr(lr))

Public signatures and trajectories match the pre-combinator monoliths
(verified loss-for-loss against the recorded fixtures in
tests/test_legacy_fixtures.py)."""
from __future__ import annotations

from .api import Schedule, Transform
from .combinators import (
    add_decayed_weights,
    chain,
    scale_by_adam,
    scale_by_lr,
    scale_by_momentum,
)


def adamw(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Transform:
    """AdamW (decoupled weight decay) — the paper's FT-AdamW baseline."""
    return chain(
        scale_by_adam(b1=b1, b2=b2, eps=eps),
        add_decayed_weights(weight_decay),
        scale_by_lr(lr),
    )


def sgdm(lr: Schedule, beta: float = 0.9, weight_decay: float = 0.0) -> Transform:
    """SGD with (EMA) momentum — Property-II compliant base optimizer."""
    return chain(
        scale_by_momentum(beta=beta),
        add_decayed_weights(weight_decay),
        scale_by_lr(lr),
    )
