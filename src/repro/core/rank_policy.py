"""Rank-policy engine: WHEN and WHAT rank each shape family runs at.

The paper's debiasing fixes *bias* but keeps one static rank per run;
gradient rank decays during training (AdaRankGrad), so a fixed ``r`` either
wastes optimizer memory early or starves the subspace late.  This module
makes rank a first-class *time-varying, per-family* quantity on top of the
``lowrank()`` combinator:

declarative policies (each yields a :class:`RankMap` per decision point)
    fixed(r)                     one rank forever (the legacy behavior)
    stepwise({step: r})          piecewise-constant rank schedule over steps
    per_family({(m, n): r})      static per-shape-family rank assignment
    spectral(target_energy=...)  adaptive: estimate the captured spectral
                                 energy from the projected-gradient sketch
                                 the refresh already computes and shrink /
                                 grow rank within [r_min, r_max] along a
                                 declared ladder

In JAX, rank is a *shape* — it is baked into every traced array (projectors,
projected momenta, family signatures, kernel grids).  A rank change therefore
cannot happen inside ``jit``; it is a host-side event at a projector-refresh
boundary:

1. the policy decides a new :class:`RankMap` (for ``spectral``, from the
   per-family spectrum probes ``lowrank(probe_spectrum=True)`` stores in
   ``LowRankState.probes`` at each refresh),
2. :func:`migrate_opt_state` resizes the optimizer state in place — rank-axis
   leaves (projectors, projected momenta, probes) are truncated or zero-
   padded, everything else (counts, per-member PRNG-derived gamma slot
   assignments, ``layerwise_unbias`` full-rank slots, fallback AdamW state)
   is carried over bit-for-bit,
3. the transform is rebuilt at the new map (under ``fuse_families=True`` the
   family plan re-plans automatically — rank is part of the family
   signature) and the train step re-jitted.

Recompilation is bounded: policies only emit ranks from their declared
``ladder``, so a run compiles at most ``len(ladder)`` step variants (and
with ``pad_rank_to=128`` every ladder rank inside one 128-lane bucket lowers
to the same padded kernel shapes, so ladder steps of 128 are free at the
kernel level — only the state shapes change).

:class:`RankPolicyController` packages the whole loop for trainers: boundary
detection from the lowrank step count, probe aggregation, decision, state
migration, per-map transform/jit caching, and checkpoint round-tripping
(``state_dict``/``load_state_dict`` ride in ``CheckpointManager`` extras so
resume is exact even across a rank change).
"""
from __future__ import annotations

import copy
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# RankMap — a frozen, hashable per-shape rank assignment
# ---------------------------------------------------------------------------


class RankMap:
    """Static per-family rank assignment: ``(m, n) -> rank``.

    Everywhere the low-rank stack accepted an ``int`` rank it now also
    accepts a RankMap (``family_shape`` resolves it per leaf shape), so one
    map threads through ``lowrank()``, the family plan, kernel dispatch and
    checkpoint templates without widening any other signature.  Hashable and
    comparable so transform / jit caches can key on it."""

    __slots__ = ("default", "overrides")

    def __init__(self, default: int, overrides: dict | tuple = ()):
        self.default = int(default)
        items = overrides.items() if isinstance(overrides, dict) else overrides
        # Canonical form: overrides equal to the default are dropped, so maps
        # that assign identical ranks compare (and hash) equal — a policy
        # re-emitting the current assignment is a no-op, not a migration.
        self.overrides = tuple(sorted(
            ((int(m), int(n)), int(r)) for (m, n), r in items
            if int(r) != self.default
        ))

    def rank_for(self, m: int, n: int) -> int:
        for (om, on), r in self.overrides:
            if om == m and on == n:
                return r
        return self.default

    def with_override(self, m: int, n: int, r: int) -> "RankMap":
        d = dict(self.overrides)
        d[(int(m), int(n))] = int(r)
        return RankMap(self.default, d)

    def __eq__(self, other) -> bool:
        return (isinstance(other, RankMap)
                and self.default == other.default
                and self.overrides == other.overrides)

    def __hash__(self) -> int:
        return hash((self.default, self.overrides))

    def __repr__(self) -> str:
        ov = {f"{m}x{n}": r for (m, n), r in self.overrides}
        return f"RankMap(default={self.default}, overrides={ov})"

    # JSON round-trip (checkpoint extras are json.dump'd)
    def to_json(self) -> dict:
        return {"default": self.default,
                "overrides": [[m, n, r] for (m, n), r in self.overrides]}

    @staticmethod
    def from_json(d: dict) -> "RankMap":
        return RankMap(d["default"],
                       {(m, n): r for m, n, r in d.get("overrides", [])})


def resolve_rank(rank, m: int, n: int) -> int:
    """An ``int | RankMap`` rank argument resolved for one ``(m, n)`` shape
    (before the usual ``min(rank, m, n)`` clamp)."""
    if isinstance(rank, int):
        return rank
    return rank.rank_for(m, n)


def default_ladder(r_min: int, r_max: int) -> tuple[int, ...]:
    """Power-of-two ladder from ``r_min`` up to and including ``r_max``."""
    out = []
    r = int(r_min)
    while r < r_max:
        out.append(r)
        r *= 2
    out.append(int(r_max))
    return tuple(sorted(set(out)))


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


class RankPolicy:
    """Base: a policy maps (its own state, step/probes) -> RankMap.

    ``wants_probes`` turns on spectrum probing inside ``lowrank()``;
    ``ladder`` declares every rank the policy may ever emit (bounds
    recompilation); decisions are evaluated only at refresh boundaries."""

    wants_probes: bool = False

    def ladder(self) -> tuple[int, ...]:
        raise NotImplementedError

    def initial_map(self, default_rank: int) -> RankMap:
        raise NotImplementedError

    def init_state(self) -> dict:
        return {}

    def decide(self, pstate: dict, step: int, probes: dict,
               current: RankMap) -> tuple[dict, Optional[RankMap]]:
        """(policy state, lowrank step count, {(m, n): {"sv2", "g2"}},
        current map) -> (new policy state, new RankMap or None for "no
        change").  Emitting a map equal to ``current`` is also a no-op."""
        return pstate, None


class fixed(RankPolicy):
    """The legacy behavior as a policy: one rank, forever."""

    def __init__(self, rank: int):
        self.rank = int(rank)

    def ladder(self) -> tuple[int, ...]:
        return (self.rank,)

    def initial_map(self, default_rank: int) -> RankMap:
        return RankMap(self.rank)

    def __repr__(self) -> str:
        return f"fixed({self.rank})"


class stepwise(RankPolicy):
    """Piecewise-constant rank schedule ``{step: rank}``: at lowrank step
    count ``t`` the rank is the value at the largest key ``<= t``.  Before
    the first threshold the configured base rank applies (so
    ``stepwise({500: 64})`` with ``cfg.rank=128`` trains at 128 until step
    500, then drops).  Changes take effect at the first refresh boundary at
    or after each threshold (rank is only ever re-decided where the
    projector is about to be recomputed, so the new columns are immediately
    meaningful)."""

    def __init__(self, schedule: dict[int, int]):
        if not schedule:
            raise ValueError("stepwise needs a non-empty {step: rank} schedule")
        self.schedule = tuple(sorted((int(s), int(r))
                                     for s, r in schedule.items()))

    def _rank_at(self, step: int, default: int) -> int:
        r = default
        for s, v in self.schedule:
            if step >= s:
                r = v
        return r

    def ladder(self) -> tuple[int, ...]:
        # (plus the pre-first-threshold base rank, which is config-supplied
        # and unknown here — at most one extra compile beyond this ladder)
        return tuple(sorted({r for _, r in self.schedule}))

    def initial_map(self, default_rank: int) -> RankMap:
        return RankMap(self._rank_at(0, default_rank))

    def decide(self, pstate, step, probes, current):
        return pstate, RankMap(self._rank_at(step, current.default))

    def __repr__(self) -> str:
        return f"stepwise({dict(self.schedule)})"


class per_family(RankPolicy):
    """Static per-shape-family ranks: ``{(m, n): rank}`` with a default for
    unlisted shapes.  Never changes over time — the pure memory-shaping
    knob (big families low rank, small families full-ish rank)."""

    def __init__(self, ranks: dict[tuple[int, int], int],
                 default: Optional[int] = None):
        self.ranks = {(int(m), int(n)): int(r) for (m, n), r in ranks.items()}
        self.default = default

    def ladder(self) -> tuple[int, ...]:
        out = set(self.ranks.values())
        if self.default is not None:
            out.add(int(self.default))
        return tuple(sorted(out))

    def initial_map(self, default_rank: int) -> RankMap:
        d = default_rank if self.default is None else self.default
        return RankMap(d, self.ranks)

    def __repr__(self) -> str:
        return f"per_family({self.ranks}, default={self.default})"


class spectral(RankPolicy):
    """Spectrum-driven adaptive rank (the AdaRankGrad direction).

    At each refresh, ``lowrank(probe_spectrum=True)`` stores per family the
    squared singular values ``sv2`` of the *projected* gradient sketch
    ``PᵀG`` (the top-r spectrum estimate the svd/rsvd refresh already
    computes — see ``projectors.py``; summed over stacked blocks) and the
    total gradient energy ``g2 = ||G||_F²``.  The captured-energy curve

        E(k) = (sv2[0] + ... + sv2[k-1]) / g2

    then drives the decision per ``(m, n)`` family, snapped to the declared
    ``ladder`` within ``[r_min, r_max]``:

      * shrink to the smallest ladder rank ``k`` with ``E(k) >= target_energy``
        (gradient rank has decayed — the tail columns carry ~no energy), or
      * grow one ladder step above the current rank when even the full
        current rank misses the target (the subspace is starved — more
        columns are needed than the probe can see).

    ``probe_every`` rate-limits *decisions* to every that-many steps
    (probes themselves ride the refresh for free); None decides at every
    refresh boundary.

    Grow/shrink hysteresis: a starvation grow means the just-probed rank was
    too small to even *measure* the target energy — so the very next probe
    at the grown rank, which typically reports the target met within the old
    rank, must not immediately shrink back (the 4↔8 oscillation).  Growing
    therefore sets a per-family rank *floor* at the grown rank; shrink
    decisions clamp to the floor until it expires ``floor_ttl`` decisions
    later (long enough for the spectrum estimate at the grown rank to be
    trustworthy, finite so genuine rank decay can still win)."""

    wants_probes = True

    def __init__(
        self,
        target_energy: float = 0.99,
        probe_every: Optional[int] = None,
        r_min: int = 8,
        r_max: int = 256,
        ladder: Optional[tuple[int, ...]] = None,
        init_rank: Optional[int] = None,
        floor_ttl: int = 8,
    ):
        if not 0.0 < target_energy <= 1.0:
            raise ValueError(f"target_energy must be in (0, 1]: {target_energy}")
        self.target_energy = float(target_energy)
        self.probe_every = probe_every
        self.r_min = int(r_min)
        self.r_max = int(r_max)
        lad = tuple(sorted(ladder)) if ladder else default_ladder(r_min, r_max)
        self._ladder = tuple(r for r in lad if self.r_min <= r <= self.r_max)
        if not self._ladder:
            raise ValueError(f"empty ladder within [{r_min}, {r_max}]: {lad}")
        self.init_rank = init_rank
        self.floor_ttl = int(floor_ttl)

    def ladder(self) -> tuple[int, ...]:
        return self._ladder

    def _snap(self, r: int) -> int:
        """Smallest ladder rank >= r (largest ladder rank if none)."""
        for v in self._ladder:
            if v >= r:
                return v
        return self._ladder[-1]

    def initial_map(self, default_rank: int) -> RankMap:
        r0 = self.init_rank if self.init_rank is not None else default_rank
        return RankMap(self._snap(min(max(r0, self.r_min), self.r_max)))

    def init_state(self) -> dict:
        # "floors": {"MxN": [floor_rank, expires_at_decision]} — the
        # starvation-grow hysteresis state (JSON-serializable for
        # checkpoint extras).
        return {"last_decision_step": None, "decisions": 0, "floors": {}}

    def decide(self, pstate, step, probes, current):
        last = pstate.get("last_decision_step")
        if self.probe_every and last is not None \
                and step - last < self.probe_every:
            return pstate, None
        if not probes:
            return pstate, None
        new = dict(pstate)
        new["last_decision_step"] = int(step)
        decisions = int(pstate.get("decisions", 0)) + 1
        new["decisions"] = decisions
        floors = {k: [int(v[0]), int(v[1])]
                  for k, v in dict(pstate.get("floors", {})).items()
                  if int(v[1]) > decisions}
        new_map = current
        for (m, n), pr in sorted(probes.items()):
            g2 = float(pr["g2"])
            sv2 = np.sort(np.asarray(pr["sv2"], dtype=np.float64))[::-1]
            cur = int(pr["rank"])
            if g2 <= 0.0 or sv2.size == 0:
                continue
            key = f"{m}x{n}"
            energy = np.cumsum(sv2) / g2
            hit = np.nonzero(energy >= self.target_energy)[0]
            if hit.size:
                r_new = self._snap(int(hit[0]) + 1)
                if key in floors:
                    # A recent starvation grow owns this family: the
                    # shrink estimate comes from the same kind of probe
                    # that was just proven too small — hold the floor.
                    r_new = max(r_new, floors[key][0])
            else:
                # Even the full probed rank misses the target: grow one
                # ladder step above the current rank (bounded by r_max)
                # and floor the family there for floor_ttl decisions.
                above = [v for v in self._ladder if v > cur]
                r_new = above[0] if above else self._ladder[-1]
                floors[key] = [r_new, decisions + self.floor_ttl]
            # Never emit more rank than the family can hold.
            new_map = new_map.with_override(m, n, min(r_new, m, n))
        new["floors"] = floors
        return new, new_map

    def __repr__(self) -> str:
        return (f"spectral(target_energy={self.target_energy}, "
                f"ladder={self._ladder})")


# ---------------------------------------------------------------------------
# Spec parsing (CLI: --rank-policy / --rank-ladder)
# ---------------------------------------------------------------------------


def parse_rank_policy(
    spec: str,
    ladder: tuple[int, ...] = (),
    r_min: int = 8,
    r_max: int = 256,
) -> RankPolicy:
    """Parse a CLI policy spec:

      "fixed:64"  (or just "64")            -> fixed(64)
      "stepwise:0=128,500=64,2000=32"       -> stepwise({0:128,500:64,2000:32})
      "family:512x512=32,1024x4096=128"     -> per_family({...})
      "spectral" | "spectral:0.99"          -> spectral(target_energy=0.99,
                                               ladder=<--rank-ladder or
                                               powers of two in [r_min,r_max]>)
    """
    kind, _, arg = spec.partition(":")
    kind = kind.strip().lower()
    if kind.isdigit():
        return fixed(int(kind))
    if kind == "fixed":
        return fixed(int(arg))
    if kind == "stepwise":
        sched = {}
        for part in arg.split(","):
            s, _, r = part.partition("=")
            sched[int(s)] = int(r)
        return stepwise(sched)
    if kind == "family":
        ranks = {}
        for part in arg.split(","):
            mn, _, r = part.partition("=")
            m, _, n = mn.partition("x")
            ranks[(int(m), int(n))] = int(r)
        return per_family(ranks)
    if kind == "spectral":
        kw: dict = {"r_min": r_min, "r_max": r_max}
        if ladder:
            kw["ladder"] = tuple(ladder)
            kw["r_min"] = min(ladder)
            kw["r_max"] = max(ladder)
        if arg:
            kw["target_energy"] = float(arg)
        return spectral(**kw)
    raise ValueError(f"unknown rank-policy spec: {spec!r}")


def as_policy(
    policy, ladder: tuple[int, ...] = (), r_min: int = 8, r_max: int = 256
) -> Optional[RankPolicy]:
    """None | spec string | RankPolicy -> RankPolicy (None passes through);
    the OptimizerConfig entry point (config files carry the string form)."""
    if policy is None or isinstance(policy, RankPolicy):
        return policy
    if isinstance(policy, str):
        return parse_rank_policy(policy, ladder=ladder, r_min=r_min, r_max=r_max)
    raise TypeError(f"rank_policy must be None, a spec string or a "
                    f"RankPolicy, got {type(policy).__name__}")


# ---------------------------------------------------------------------------
# State migration
# ---------------------------------------------------------------------------


def _slice_copy(old, new_tmpl):
    """Copy the overlapping hyperrectangle of ``old`` into a zeros array
    shaped like ``new_tmpl`` (truncate / zero-pad per axis)."""
    if old.shape == tuple(new_tmpl.shape):
        return old if old.dtype == new_tmpl.dtype else old.astype(new_tmpl.dtype)
    if len(old.shape) != len(new_tmpl.shape):
        raise ValueError(
            f"cannot migrate leaf: rank-{len(old.shape)} array "
            f"{old.shape} -> rank-{len(new_tmpl.shape)} template "
            f"{tuple(new_tmpl.shape)}"
        )
    sl = tuple(slice(0, min(a, b)) for a, b in zip(old.shape, new_tmpl.shape))
    return (jnp.zeros(new_tmpl.shape, new_tmpl.dtype)
            .at[sl].set(old[sl].astype(new_tmpl.dtype)))


def migrate_opt_state(old_state: PyTree, new_template: PyTree) -> PyTree:
    """Resize an optimizer state onto a new rank assignment.

    ``new_template`` is ``new_transform.init(params)`` — the exact target
    shapes.  Leaves whose shapes match are carried over verbatim (step
    counts, gamma slot assignments, full-rank slots, fallback AdamW moments,
    per-member PRNG-derived indices); mismatched leaves — projectors
    ``(*lead, s, r)``, projected momenta ``(*lead, r, n)`` / ``(*lead, m,
    r)``, spectrum probes ``(r,)`` — are truncated (the projector's leading
    columns are its top singular directions, so truncation keeps the most
    energetic subspace) or zero-padded (grown columns stay inert until the
    next refresh recomputes the projector at full new rank).

    Both trees must have identical *structure* — rank changes shapes, never
    the chain/family layout (same-(m, n) leaves always share one rank, so
    the family plan regroups identically)."""
    old_leaves, old_def = jax.tree_util.tree_flatten(old_state)
    new_leaves, new_def = jax.tree_util.tree_flatten(new_template)
    if old_def != new_def:
        raise ValueError(
            "optimizer-state structure changed across the rank migration — "
            "rank policies may only change shapes, not the transform "
            f"composition (old: {old_def}, new: {new_def})"
        )
    out = [_slice_copy(o, n) for o, n in zip(old_leaves, new_leaves)]
    return jax.tree_util.tree_unflatten(new_def, out)


# ---------------------------------------------------------------------------
# Controller — the host-side decision/migration loop
# ---------------------------------------------------------------------------


def _is_probe(x) -> bool:
    return isinstance(x, dict) and "sv2" in x and "g2" in x


def gather_probes(opt_state: PyTree) -> dict[tuple[int, int], dict]:
    """Aggregate the spectrum probes out of every ``LowRankState`` in an
    optimizer state: ``{(m, n): {"sv2": (r,), "g2": float, "rank": int}}``,
    summed over leaves/families of the same shape (one rank decision per
    shape family)."""
    from .combinators import find_lowrank_states

    out: dict[tuple[int, int], dict] = {}
    for st in find_lowrank_states(opt_state):
        if st.probes is None:
            continue
        leaves = jax.tree_util.tree_leaves(st.probes, is_leaf=_is_probe)
        for pr in leaves:
            if not _is_probe(pr):
                continue
            mn = tuple(int(v) for v in np.asarray(jax.device_get(pr["mn"])))
            sv2 = np.asarray(jax.device_get(pr["sv2"]), dtype=np.float64)
            g2 = float(jax.device_get(pr["g2"]))
            cur = out.setdefault(
                mn, {"sv2": np.zeros_like(sv2), "g2": 0.0,
                     "rank": int(sv2.shape[0])})
            k = min(cur["sv2"].shape[0], sv2.shape[0])
            cur["sv2"][:k] += sv2[:k]
            cur["g2"] += g2
    return out


class RankPolicyController:
    """Drives a :class:`RankPolicy` over a live training run.

    ``build(rank_map) -> Transform`` rebuilds the optimizer at a given
    assignment (e.g. ``lambda m: build_optimizer(cfg, rank_map=m)`` or a
    hand-composed ``lowrank()`` chain closure).  Call :meth:`maybe_update`
    every step BEFORE the jitted train step: at refresh boundaries (decided
    from the lowrank step count, so NaN-skipped steps cannot desync it) the
    policy is consulted and, when the map changes, the optimizer state is
    migrated and :meth:`transform` returns the rebuilt chain.  Transforms
    are cached per map, so recompilation is bounded by the policy ladder."""

    def __init__(self, policy: RankPolicy, build: Callable[[RankMap], Any],
                 *, period: int, default_rank: int = 128,
                 reshard: Optional[Callable[[PyTree], PyTree]] = None):
        """``reshard(opt_state) -> opt_state`` is applied to every migrated
        state before it is returned: under a mesh the migrated leaves come
        out of ``migrate_opt_state`` with whatever placement the slicing ops
        produced, so the caller passes a re-derive-and-re-apply hook (the
        Trainer uses ``jax.device_put`` with a freshly derived
        ``opt_state_sharding``) — this is what makes spectral policies work
        under FSDP/ZeRO-sharded state instead of silently de-sharding on the
        first migration."""
        self.policy = policy
        self.build = build
        self.period = int(period)
        self.reshard = reshard
        self._pstate = policy.init_state()
        self._map = policy.initial_map(default_rank)
        self._cache: dict[RankMap, Any] = {}
        self.history: list[tuple[int, RankMap]] = [(0, self._map)]

    # ----------------------------------------------------------- access

    @property
    def current_map(self) -> RankMap:
        return self._map

    def transform(self, rank_map: Optional[RankMap] = None):
        m = rank_map if rank_map is not None else self._map
        t = self._cache.get(m)
        if t is None:
            t = self._cache[m] = self.build(m)
        return t

    # ----------------------------------------------------------- stepping

    def _count(self, opt_state) -> int:
        from .combinators import find_lowrank_states

        states = find_lowrank_states(opt_state)
        if not states:
            raise ValueError(
                "RankPolicyController found no LowRankState in the optimizer "
                "state — rank policies require a lowrank() stage"
            )
        return int(jax.device_get(states[0].count))

    def maybe_update(self, opt_state: PyTree,
                     params: PyTree) -> tuple[PyTree, bool]:
        """Consult the policy at a refresh boundary; migrate the state when
        the rank assignment changes.  Returns ``(opt_state, changed)`` —
        on ``changed`` the caller must re-fetch :meth:`transform` (and
        re-jit its step)."""
        count = self._count(opt_state)
        if count <= 0 or count % self.period != 0:
            return opt_state, False
        probes = (gather_probes(opt_state)
                  if self.policy.wants_probes else {})
        self._pstate, new_map = self.policy.decide(
            self._pstate, count, probes, self._map)
        if new_map is None or new_map == self._map:
            return opt_state, False
        new_t = self.transform(new_map)
        migrated = migrate_opt_state(opt_state, new_t.init(params))
        if self.reshard is not None:
            migrated = self.reshard(migrated)
        self._map = new_map
        self.history.append((count, new_map))
        return migrated, True

    # ----------------------------------------------------------- checkpoint

    def state_dict(self) -> dict:
        """JSON-serializable snapshot (rides in CheckpointManager extras) —
        restoring it before ``restore()`` makes resume exact across rank
        changes (the state template must be built at the saved map)."""
        # Deep-copied: pstate values can be nested (per-family floors/TTL
        # dicts) — a snapshot that aliased them would mutate along with the
        # live controller, breaking rollback.
        return copy.deepcopy({
            "map": self._map.to_json(),
            "pstate": {k: (int(v) if isinstance(v, (bool, np.integer)) else v)
                       for k, v in self._pstate.items()},
            "history": [[s, m.to_json()] for s, m in self.history],
        })

    def load_state_dict(self, d: dict) -> None:
        self._map = RankMap.from_json(d["map"])
        self._pstate = copy.deepcopy(dict(d.get("pstate", {})))
        self.history = [(int(s), RankMap.from_json(m))
                        for s, m in d.get("history", [])] or [(0, self._map)]
