"""Fira (Chen et al., 2024) — full-rank training under a low-rank constraint.

GaLore-Adam plus a norm-scaled residual: the part of the gradient outside the
projected subspace is added back, scaled by the ratio phi_t between the
low-rank Adam update norm and the low-rank gradient norm, with Fira's
norm-growth limiter on the residual term.  No unbiasedness guarantee (the
paper's point of comparison).

``kernel_impl`` routes the projection GEMM through the fused Pallas kernel
(repro.kernels.dispatch); the Adam moments and residual stay in jnp since
they consume the projected gradient elementwise.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .adamw import adamw
from .api import PyTree, Schedule, Transform, multi_transform, schedule_value, tree_paths
from .lowrank_common import (
    back_project,
    compute_projectors,
    default_lowrank_filter,
    family_shape,
    lowrank_state_shape,
    proj_shape,
    project_dispatched,
)


class FiraFamilyState(NamedTuple):
    p: jax.Array
    m1: jax.Array
    m2: jax.Array
    prev_resid_norm: jax.Array  # (L,) norm-growth limiter memory


class FiraState(NamedTuple):
    count: jax.Array
    families: PyTree


def fira_matrices(
    lr: Schedule,
    rank: int = 128,
    period: int = 200,
    projector: str = "svd",
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    scale: float = 0.25,
    limiter: float = 1.01,
    seed: int = 0,
    kernel_impl: str = "auto",
) -> Transform:
    def init(params: PyTree) -> FiraState:
        def init_family(p_leaf):
            if p_leaf is None:
                return None
            fs = family_shape(p_leaf, rank)
            st = jnp.zeros(lowrank_state_shape(fs), jnp.float32)
            return FiraFamilyState(
                p=jnp.zeros(proj_shape(fs), jnp.float32),
                m1=st,
                m2=st,
                prev_resid_norm=jnp.zeros(fs.lead, jnp.float32),
            )

        fams = jax.tree_util.tree_map(
            init_family, params, is_leaf=lambda x: x is None
        )
        return FiraState(count=jnp.zeros((), jnp.int32), families=fams)

    def update_family(g_leaf, st, p_leaf, count, step_lr, key):
        fs = family_shape(p_leaf, rank)
        g = g_leaf.astype(jnp.float32)  # (*lead, m, n)
        refresh = (count - 1) % period == 0

        p_proj = jax.lax.cond(
            refresh,
            lambda _: compute_projectors(projector, g, fs.rank, key, fs.side),
            lambda _: st.p,
            None,
        )

        r_g = project_dispatched(p_proj, g, fs.side, kernel_impl)
        c = count.astype(jnp.float32)
        m1 = b1 * st.m1 + (1 - b1) * r_g
        m2 = b2 * st.m2 + (1 - b2) * jnp.square(r_g)
        s = (m1 / (1 - b1**c)) / (jnp.sqrt(m2 / (1 - b2**c)) + eps)

        # Residual outside the subspace, scaled by ||s|| / ||r_g|| per block.
        resid = g - back_project(p_proj, r_g, fs.side)
        s_norm = jnp.linalg.norm(s, axis=(-2, -1))
        rg_norm = jnp.linalg.norm(r_g, axis=(-2, -1))
        phi = s_norm / (rg_norm + eps)
        scaled_resid = phi[..., None, None] * resid

        # Norm-growth limiter: cap per-block residual norm at limiter x prev.
        rnorm = jnp.linalg.norm(scaled_resid, axis=(-2, -1))
        cap = jnp.where(st.prev_resid_norm > 0, limiter * st.prev_resid_norm, rnorm)
        shrink = jnp.minimum(1.0, cap / (rnorm + eps))
        scaled_resid = scaled_resid * shrink[..., None, None]
        new_rnorm = rnorm * shrink

        u = -step_lr * scale * (back_project(p_proj, s, fs.side) + scaled_resid)
        return u, FiraFamilyState(
            p=p_proj, m1=m1, m2=m2, prev_resid_norm=new_rnorm
        )

    def update(grads: PyTree, state: FiraState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        g_leaves = treedef.flatten_up_to(grads)
        s_leaves = treedef.flatten_up_to(state.families)
        upds, news = [], []
        for i, (g, fst, p) in enumerate(zip(g_leaves, s_leaves, leaves)):
            if g is None or p is None:
                upds.append(None)
                news.append(None)
                continue
            u, ns = update_family(g, fst, p, count, step_lr, jax.random.fold_in(base_key, i))
            upds.append(u)
            news.append(ns)
        return (
            jax.tree_util.tree_unflatten(treedef, upds),
            FiraState(count=count, families=jax.tree_util.tree_unflatten(treedef, news)),
        )

    return Transform(init, update)


def fira(
    lr: Schedule,
    rank: int = 128,
    period: int = 200,
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    **kw,
) -> Transform:
    inner = {
        "fira": fira_matrices(lr, rank=rank, period=period, **kw),
        "adamw": adamw(lr),
    }

    def label_fn(params: PyTree) -> PyTree:
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: "fira" if lowrank_filter(path, p) else "adamw", paths, params
        )

    return multi_transform(inner, label_fn)
