"""Fira (Chen et al., 2024) — full-rank training under a low-rank constraint.

GaLore-Adam plus a norm-scaled residual: the part of the gradient outside the
projected subspace is added back, scaled by the ratio phi_t between the
low-rank Adam update norm and the low-rank gradient norm, with Fira's
norm-growth limiter on the residual term.  No unbiasedness guarantee (the
paper's point of comparison).

Now a pure composition (see :mod:`repro.core.combinators`)::

    fira = chain(lowrank(with_fira_residual(scale_by_adam())),
                 scale_by_factor(alpha), scale_by_lr(lr))

``kernel_impl`` routes the projection / back-projection GEMMs through the
fused Pallas kernels (repro.kernels.dispatch); the Adam moments and residual
stay in jnp since they consume the projected gradient elementwise.
"""
from __future__ import annotations

from typing import Callable

import jax

from .adamw import adamw
from .api import Schedule, Transform
from .combinators import (
    chain,
    lowrank,
    scale_by_adam,
    scale_by_factor,
    scale_by_lr,
    with_fira_residual,
    with_matrix_routing,
)
from .lowrank_common import default_lowrank_filter


def fira_matrices(
    lr: Schedule,
    rank=128,
    period: int = 200,
    projector: str = "svd",
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    scale: float = 0.25,
    limiter: float = 1.01,
    seed: int = 0,
    kernel_impl: str = "auto",
    pad_rank_to: int = 0,
    fuse_families: bool = False,
    fused_epilogue: bool = False,
    rank_policy=None,
    telemetry: bool = False,
) -> Transform:
    return chain(
        lowrank(
            with_fira_residual(
                scale_by_adam(b1=b1, b2=b2, eps=eps), limiter=limiter, eps=eps
            ),
            rank=rank, period=period, projector=projector, seed=seed,
            kernel_impl=kernel_impl, pad_rank_to=pad_rank_to,
            fuse_families=fuse_families, fused_epilogue=fused_epilogue,
            rank_policy=rank_policy, telemetry=telemetry,
        ),
        scale_by_factor(scale),
        scale_by_lr(lr),
    )


def fira(
    lr: Schedule,
    rank=128,
    period: int = 200,
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    **kw,
) -> Transform:
    return with_matrix_routing(
        fira_matrices(lr, rank=rank, period=period, **kw),
        adamw(lr),
        matrix_filter=lowrank_filter,
        matrix_label="fira",
    )
