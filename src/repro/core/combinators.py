"""Composable optimizer combinators — the paradigm as an API.

The paper's claim is that *layerwise sampling debiases any low-rank
projection mechanism*; GUM is merely the GaLore x Muon instantiation.  This
module makes that claim the API surface (optax-style, zero dependencies):

atomic gradient transforms
    scale_by_momentum   EMA momentum (SGDM direction; Property II holds)
    scale_by_muon       momentum + Newton-Schulz orthogonalization
    scale_by_adam       bias-corrected Adam direction (Property II does NOT
                        hold — documented per-composition)
    add_decayed_weights decoupled weight decay   u + wd * p
    scale_by_lr         -schedule(count) * u     (terminal step of a chain)
    scale_by_factor     constant multiplier (GaLore's alpha)
    clip_by_global_norm global-norm gradient clipping as a chain head

wrapper transforms
    lowrank(inner, ...)           owns ALL projector state: family stacking,
                                  periodic refresh, svd|subspace|random|grass
                                  choice, project / back-project through the
                                  Pallas dispatch layer (repro.kernels) —
                                  runs ``inner`` in the projected space.
                                  ``rank`` may be a per-family RankMap, and
                                  ``rank_policy`` / ``probe_spectrum`` hook
                                  in the adaptive-rank engine
                                  (repro.core.rank_policy)
    layerwise_unbias(base, ...)   the paper's sampling debiasing (gamma
                                  full-rank slots, paper/finetune
                                  compensation) as an independent combinator
    with_fira_residual(base, ...) Fira's norm-scaled out-of-subspace residual
    with_matrix_routing(m, f)     label routing: matrices -> ``m``, the rest
                                  (embeddings/norms/biases) -> ``f``

composition
    chain(*transforms)            sequential application, optax semantics

so the paper's optimizers are one-liners::

    gum = chain(lowrank(layerwise_unbias(scale_by_muon(beta=0.95))),
                add_decayed_weights(wd), scale_by_lr(lr))
    galore_adam = chain(lowrank(scale_by_adam(scale=0.25)),
                        add_decayed_weights(wd), scale_by_lr(lr))
    unbiased_galore_adam = chain(
        lowrank(layerwise_unbias(scale_by_adam(scale=0.25))),
        add_decayed_weights(wd), scale_by_lr(lr))   # a NEW method: no new file

Protocol between ``lowrank`` and the transforms it wraps
--------------------------------------------------------
``lowrank`` hands its inner transform a pytree whose low-rank leaves are
:class:`ProjGrad` objects — *lazy* projected gradients carrying the refreshed
projector, the raw fp32 gradient, the family geometry and the kernel-dispatch
knobs.  (``ProjGrad`` is deliberately NOT a registered pytree node, so
``tree_map`` treats it as an opaque leaf.)  Momentum-style transforms call
``ProjGrad.fused_momentum`` — the single fused Pallas kernel
``R' = beta R + coeff PᵀG`` — while elementwise consumers (Adam) call
``ProjGrad.materialize`` for the projected gradient itself.  A wrapped
transform may return either a projected-space array (``lowrank``
back-projects it through the fused ``back_project`` kernel) or a
:class:`FullUpdate`-wrapped full-shape array (returned as-is — how
``layerwise_unbias`` emits its scatter of sampled full-rank blocks).

At init time the same positions hold :class:`ProjInit` leaves carrying the
projected-space state template plus the :class:`~repro.core.lowrank_common.
FamilyShape`, so wrappers like ``layerwise_unbias`` can size their full-rank
slots without ever seeing real parameters.

Family-stacked fused execution (``fuse_families=True``)
-------------------------------------------------------
By default ``lowrank`` iterates the parameter leaves in Python, issuing one
project / momentum / back-project dispatch per leaf.  With
``fuse_families=True`` it instead computes a static :class:`~repro.core.
family_plan.FamilyPlan` grouping same-signature leaves into stacked
``(L, m, n)`` super-leaves and runs the WHOLE pipeline — projector refresh,
fused project+momentum, inner scale, back-projection — as one batched launch
per shape family.  The inner transform sees one :class:`ProjGrad` per family
whose ``seg`` field carries the member geometry; per-leaf PRNG keys are
stacked (never merged) and ``layerwise_unbias`` samples per *member*, so the
stacked trajectory is bit-identical to the per-leaf one on the jnp path
(tests/test_fused_step.py; at large threaded-GEMM shapes batched-vs-unbatched
reduction order can still round a value differently — observed ≤1 fp32 ulp
over 6 trainer steps on llama-60m, with sampling and projectors exactly
equal).  Optimizer-state layout changes (family lists instead of per-leaf
trees), so the knob is opt-in.

``fused_epilogue=True`` additionally defers the final back-projection into a
:class:`PendingBack` leaf so chain-tail elementwise epilogues (``scale_by_lr``,
``add_decayed_weights``, ``scale_by_factor``) fold into the back-projection
GEMM — one ``back_project_epilogue`` launch per family instead of a GEMM plus
per-leaf elementwise passes.  Not bit-exact (the epilogue redistributes the
multiplications), hence a separate knob.  Scope: it applies to inner
transforms whose output ``lowrank`` back-projects (galore / galore_muon /
golore); inners that emit full-shape :class:`FullUpdate` leaves
(``layerwise_unbias`` — gum/unbiased_galore_adam — and
``with_fira_residual``) already own their back-projection and pass through
unchanged, so the knob is inert there (they still get the stacking win).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import PartitionSpec as _P

from .api import (
    PyTree,
    Schedule,
    Transform,
    multi_transform,
    schedule_value,
    tree_paths,
)
from .api import clip_by_global_norm as _clip_tree
from .family_plan import (
    build_family_plan,
    member_keys,
    stack_family,
    unstack_family,
)
from .lowrank_common import (
    FamilyShape,
    compute_projectors,
    default_lowrank_filter,
    family_shape,
    gather_blocks,
    lowrank_state_shape,
    proj_shape,
    project as _raw_project,
    scatter_blocks,
    stack_shardable,
)
from .newton_schulz import muon_scale, newton_schulz

_IS_NONE = lambda x: x is None


def _dispatch():
    # Lazy: repro.kernels wants repro.core importable first (same convention
    # as lowrank_common).
    from repro.kernels import dispatch

    return dispatch


# ---------------------------------------------------------------------------
# Leaf protocol objects (opaque leaves — intentionally not pytree nodes)
# ---------------------------------------------------------------------------


class ProjInit:
    """Init-time stand-in for a low-rank leaf inside :func:`lowrank`.

    ``low`` is a ShapeDtypeStruct of the projected-space state — transforms
    allocate momenta with ``jnp.zeros_like(leaf.low)`` via
    :func:`_zeros_momentum`; ``fs`` carries the full family geometry.  Under
    family stacking ``seg`` carries the member geometry (None per-leaf)."""

    __slots__ = ("fs", "low", "seg")

    def __init__(self, fs: FamilyShape, low, seg=None):
        self.fs = fs
        self.low = low
        self.seg = seg


class ProjGrad:
    """Lazy projected gradient leaf handed to transforms inside ``lowrank``."""

    __slots__ = ("p", "g", "fs", "kernel_impl", "pad_rank_to", "coeff",
                 "reset", "refresh", "key", "seg")

    def __init__(self, p, g, fs, kernel_impl, pad_rank_to=0, coeff=1.0,
                 reset=None, refresh=False, key=None, seg=None):
        self.p = p                      # (*lead, s, r) refreshed projector
        self.g = g                      # (*lead, m, n) raw fp32 gradient
        self.fs = fs                    # FamilyShape (static)
        self.kernel_impl = kernel_impl
        self.pad_rank_to = pad_rank_to
        self.coeff = coeff              # static float on the projected grad
        self.reset = reset              # traced bool: zero momenta first (or None)
        self.refresh = refresh          # traced bool period boundary (False = external)
        self.key = key                  # sampling key; (members, 2) when stacked
        self.seg = seg                  # StackSeg under family stacking (or None)

    def with_coeff(self, coeff: float) -> "ProjGrad":
        return ProjGrad(self.p, self.g, self.fs, self.kernel_impl,
                        self.pad_rank_to, coeff, self.reset, self.refresh,
                        self.key, self.seg)

    def apply_reset(self, x):
        """Zero a momentum buffer at the period boundary (no-op if the
        wrapping ``lowrank`` was built with ``reset_on_refresh=False``)."""
        if self.reset is None:
            return x
        return jnp.where(self.reset, jnp.zeros_like(x), x)

    def materialize(self):
        """The projected gradient PᵀG / G P through the dispatch layer
        (coeff NOT applied — elementwise consumers fold it in themselves)."""
        return _dispatch().project(
            self.p, self.g, side=self.fs.side, impl=self.kernel_impl,
            pad_rank_to=self.pad_rank_to,
        )

    def fused_momentum(self, mu, beta: float):
        """``beta * mu + coeff * PᵀG`` via the single fused Pallas kernel —
        the per-step hot loop of every momentum-based low-rank optimizer."""
        return _dispatch().lowrank_update(
            self.p, self.g, self.apply_reset(mu), beta, self.coeff,
            side=self.fs.side, impl=self.kernel_impl,
            pad_rank_to=self.pad_rank_to,
        )

    def back(self, s):
        """Back-project a projected-space array to full shape."""
        return _dispatch().back_project(
            self.p, s, side=self.fs.side, impl=self.kernel_impl,
            pad_rank_to=self.pad_rank_to,
        )


class FullUpdate:
    """Marker a lowrank-inner transform returns for a leaf that is ALREADY in
    full (m, n) space and must not be back-projected again."""

    __slots__ = ("u",)

    def __init__(self, u):
        self.u = u


class RefreshMsg:
    """Per-leaf message for the external-refresh hook (see ``lowrank``).
    Under family stacking, one message per family: ``key`` is the stacked
    ``(members, 2)`` per-member sampling keys and ``seg`` the geometry."""

    __slots__ = ("fs", "key", "seg")

    def __init__(self, fs: FamilyShape, key, seg=None):
        self.fs = fs
        self.key = key
        self.seg = seg


class PendingBack:
    """Lazy scale-and-back-project epilogue leaf (``fused_epilogue=True``).

    Represents ``scale * back_project(p, s) + decay * W`` without
    materializing the full-shape update.  Protocol-aware tail transforms fold
    their elementwise epilogues into the two scalars (``scale_by_lr`` and
    ``scale_by_factor`` via :meth:`scaled`, ``add_decayed_weights`` via
    :meth:`decayed`); ``scale_by_lr`` — the terminal stage of every chain —
    then materializes the whole tree through
    :func:`repro.kernels.dispatch.back_project_epilogue`, ONE fused launch per
    family stack (the GEMM result never round-trips HBM before the epilogue).

    Under family stacking all member leaves share one ``(p, s, w)`` payload;
    ``member`` selects this leaf's slice after the grouped materialization.
    Grouped materialization reads the fold scalars from the first member, so
    chain tails must apply leaf-uniform scalars — which every built-in tail
    transform does.  A chain that ends without ``scale_by_lr`` still works
    when ``update`` and ``apply_updates`` are traced together (the usual
    train-step shape): :func:`repro.core.api.apply_updates` materializes
    stray PendingBack leaves one by one (correct, just unfused).  A
    PendingBack leaf is NOT a JAX type, so it cannot cross a jit boundary on
    its own — jitting ``opt.update`` alone with such a chain raises
    TypeError at the output; end the chain with ``scale_by_lr`` (or call
    :func:`materialize_pending`) before returning updates across a
    boundary."""

    __slots__ = ("p", "s", "w", "fs", "kernel_impl", "pad_rank_to",
                 "scale", "decay", "member", "members", "member_lead")

    def __init__(self, p, s, w, fs, kernel_impl, pad_rank_to, scale=1.0,
                 decay=0.0, member=None, members=1, member_lead=()):
        self.p = p                      # projector, possibly family-stacked
        self.s = s                      # projected-space update (payload key)
        self.w = w                      # params (for the decay term), stacked
        self.fs = fs
        self.kernel_impl = kernel_impl
        self.pad_rank_to = pad_rank_to
        self.scale = scale              # float | traced scalar
        self.decay = decay              # float | traced scalar
        self.member = member            # None = unstacked leaf
        self.members = members
        self.member_lead = member_lead

    def _replace(self, scale, decay) -> "PendingBack":
        return PendingBack(self.p, self.s, self.w, self.fs, self.kernel_impl,
                           self.pad_rank_to, scale, decay, self.member,
                           self.members, self.member_lead)

    def scaled(self, f) -> "PendingBack":
        # keep a never-decayed leaf's 0.0 static so materialization can skip
        # the W operand entirely
        zero = isinstance(self.decay, float) and self.decay == 0.0
        return self._replace(f * self.scale, 0.0 if zero else f * self.decay)

    def decayed(self, wd: float) -> "PendingBack":
        return self._replace(self.scale, self.decay + wd)

    def _use_w(self) -> bool:
        return not (isinstance(self.decay, float) and self.decay == 0.0)

    def _w_stack(self):
        """Resolve the (possibly thunked) stacked-params operand."""
        return self.w() if callable(self.w) else self.w

    def _resolved_impl(self) -> str:
        return _dispatch().resolve_impl(self.kernel_impl)

    def _materialize_stack(self):
        """The full (possibly stacked) ``(*lead, m, n)`` update through the
        fused ``back_project_epilogue`` kernel (Pallas/interpret path)."""
        use_w = self._use_w()
        return _dispatch().back_project_epilogue(
            self.p, self.s, w=(self._w_stack() if use_w else None),
            scale=self.scale, decay=self.decay, side=self.fs.side,
            impl=self.kernel_impl, pad_rank_to=self.pad_rank_to,
        )

    def _jnp_epilogue_slice(self, full, w):
        """Slice-then-scale epilogue for the jnp path: ``full`` is the
        UNSCALED back-projection of the whole stack; the scale/decay apply
        AFTER the member slice (see :func:`materialize_pending` for why that
        ordering wins on CPU)."""
        u = self.scale * _member_slice(full, self)
        if self._use_w():
            u = u + self.decay * _member_slice(w, self).astype(jnp.float32)
        return u

    def _jnp_full(self):
        """Unit-scale epilogue call (XLA folds the 1.0): the unscaled
        back-projection of the whole stack, recorded as the epilogue op."""
        return _dispatch().back_project_epilogue(
            self.p, self.s, side=self.fs.side, impl="jnp",
            pad_rank_to=self.pad_rank_to,
        )

    def materialize_update(self):
        """Materialize THIS leaf only (the ungrouped fallback used by
        ``apply_updates``; grouped chains go through
        :func:`materialize_pending` instead)."""
        if self._resolved_impl() == "jnp":
            return self._jnp_epilogue_slice(
                self._jnp_full(), self._w_stack() if self._use_w() else None
            )
        return _member_slice(self._materialize_stack(), self)


def _member_slice(stacked, leaf: PendingBack):
    """This leaf's ``(*member_lead, m, n)`` slice of a family-stacked array
    (identity for unstacked leaves)."""
    if leaf.member is None:
        return stacked
    parts = stacked.reshape((leaf.members,) + leaf.member_lead
                            + stacked.shape[-2:])
    return parts[leaf.member]


_is_pending = lambda x: x is None or isinstance(x, PendingBack)


def materialize_pending(updates: PyTree) -> PyTree:
    """Materialize every :class:`PendingBack` leaf, grouping the members of
    each family stack into a single ``back_project_epilogue`` launch.  No-op
    on trees without pending leaves.

    On the Pallas path the scale/decay epilogue rides inside the kernel (the
    GEMM tile never leaves VMEM).  On the jnp reference path the epilogue is
    deliberately applied AFTER the per-member slicing instead: pre-scaling
    the stack materializes an extra full-size intermediate that XLA CPU
    cannot fuse away, whereas a scalar multiply on each slice fuses into the
    slice's consumer — measured ~30% faster on the write-back."""
    leaves, treedef = jax.tree_util.tree_flatten(updates, is_leaf=_is_pending)
    if not any(isinstance(x, PendingBack) for x in leaves):
        return updates
    groups: dict[int, list[int]] = {}
    for pos, leaf in enumerate(leaves):
        if isinstance(leaf, PendingBack):
            groups.setdefault(id(leaf.s), []).append(pos)
    out = list(leaves)
    for positions in groups.values():
        head = leaves[positions[0]]
        if head._resolved_impl() == "jnp":
            full = head._jnp_full()
            w = head._w_stack() if any(
                leaves[p]._use_w() for p in positions
            ) else None
            for pos in positions:
                out[pos] = leaves[pos]._jnp_epilogue_slice(full, w)
            continue
        full = head._materialize_stack()
        for pos in positions:
            out[pos] = _member_slice(full, leaves[pos])
    return jax.tree_util.tree_unflatten(treedef, out)


def _zeros_momentum(leaf):
    if leaf is None:
        return None
    if isinstance(leaf, ProjInit):
        leaf = leaf.low
    return jnp.zeros(leaf.shape, jnp.float32)


def _reset_floats(tree: PyTree, refresh) -> PyTree:
    """Zero every inexact array leaf when ``refresh`` is true (ints — counts,
    indices — pass through untouched)."""

    def one(x):
        if x is None or not hasattr(x, "dtype"):
            return x
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        return jnp.where(refresh, jnp.zeros_like(x), x)

    return jax.tree_util.tree_map(one, tree, is_leaf=_IS_NONE)


def _transpose(flat: PyTree, n: int) -> tuple:
    is_tup = lambda x: isinstance(x, tuple) and len(x) == n
    return tuple(
        jax.tree_util.tree_map(lambda t, i=i: t[i], flat, is_leaf=is_tup)
        for i in range(n)
    )


# ---------------------------------------------------------------------------
# chain
# ---------------------------------------------------------------------------


def chain_info(t: Transform) -> dict:
    """Static composition metadata for a combinator-built transform.

    Every combinator in this module attaches a ``chain_info`` dict to its
    update function — ``{"kind": <combinator name>, ...}``, nesting through
    ``stages`` (chain), ``inner`` (lowrank / layerwise_unbias /
    with_fira_residual) and ``branches`` (multi_transform) — so the static
    analyzer (:mod:`repro.analysis`) can walk the composition without
    executing anything.  Transforms built outside this module read as
    ``{"kind": "opaque"}`` and are treated as unmodelable."""
    info = getattr(t.update, "chain_info", None) if t is not None else None
    return dict(info) if info else {"kind": "opaque"}


def chain(*transforms: Transform) -> Transform:
    """Sequentially compose gradient transforms (optax semantics): each
    transform maps (updates, state, params) -> (updates, state); state is the
    tuple of inner states.

    A chain whose FIRST transform speaks the lowrank leaf protocol (e.g.
    ``chain(layerwise_unbias(...), scale_by_factor(...))``) forwards that
    transform's ``wants_sample_key`` / ``refresh_state`` hooks, so such a
    chain can itself be the inner transform of :func:`lowrank`."""

    def init(params: PyTree) -> tuple:
        return tuple(t.init(params) for t in transforms)

    def update(updates: PyTree, state: tuple, params: PyTree):
        new_states = []
        for t, s in zip(transforms, state):
            updates, ns = t.update(updates, s, params)
            new_states.append(ns)
        return updates, tuple(new_states)

    if transforms and getattr(transforms[0].update, "wants_sample_key", False):
        update.wants_sample_key = True
    if transforms and getattr(transforms[0].update, "wants_params", False):
        update.wants_params = True
    head_refresh = transforms and getattr(transforms[0].update, "refresh_state", None)
    if head_refresh:
        def refresh_state(state, msgs, refresh_now):
            return (head_refresh(state[0], msgs, refresh_now),) + tuple(state[1:])

        update.refresh_state = refresh_state

    update.chain_info = {
        "kind": "chain", "stages": [chain_info(t) for t in transforms],
    }
    return Transform(init, update)


# ---------------------------------------------------------------------------
# atomic transforms
# ---------------------------------------------------------------------------


def scale_by_momentum(beta: float = 0.9, use_muon_scale: bool = False) -> Transform:
    """EMA momentum direction ``mu' = beta mu + g`` (Property-II compliant).
    On :class:`ProjGrad` leaves the update runs through the fused low-rank
    kernel.  ``use_muon_scale`` applies Muon's sqrt(max(1, m/n)) factor —
    only meaningful as the GUM ``base="sgdm"`` variant's scaling."""

    def init(params: PyTree) -> PyTree:
        return jax.tree_util.tree_map(_zeros_momentum, params, is_leaf=_IS_NONE)

    def update(updates: PyTree, mu: PyTree, params: PyTree):
        def upd(g, m, p):
            if g is None:
                return (None, None)
            if isinstance(g, ProjGrad):
                m2 = g.fused_momentum(m, beta)
                o = m2
                if use_muon_scale:
                    o = muon_scale((g.fs.m, g.fs.n)) * o
                return (o, m2)
            m2 = beta * m + g.astype(jnp.float32)
            o = m2
            if use_muon_scale:
                shape = p.shape if p is not None else g.shape
                o = muon_scale(shape) * o
            return (o, m2)

        flat = jax.tree_util.tree_map(upd, updates, mu, params, is_leaf=_IS_NONE)
        out, new_mu = _transpose(flat, 2)
        return out, new_mu

    update.chain_info = {"kind": "scale_by_momentum", "beta": beta}
    return Transform(init, update)


def scale_by_muon(
    beta: float = 0.95,
    ns_steps: int = 5,
    nesterov: bool = False,
    use_muon_scale: bool = False,
    kernel_impl: str = "auto",
) -> Transform:
    """Momentum + Newton-Schulz orthogonalization (the Muon direction).

    Full-rank leaves get plain EMA momentum (+ optional Nesterov); ProjGrad
    leaves run the fused low-rank momentum kernel, then NS in the projected
    space (Property II: NS(P X) = P NS(X) makes this exact)."""

    def init(params: PyTree) -> PyTree:
        return jax.tree_util.tree_map(_zeros_momentum, params, is_leaf=_IS_NONE)

    def update(updates: PyTree, mu: PyTree, params: PyTree):
        def upd(g, m, p):
            if g is None:
                return (None, None)
            if isinstance(g, ProjGrad):
                if nesterov:
                    r_g = g.materialize()
                    if g.coeff != 1.0:
                        r_g = g.coeff * r_g
                    m2 = beta * g.apply_reset(m) + r_g
                    mom = beta * m2 + r_g
                else:
                    m2 = g.fused_momentum(m, beta)
                    mom = m2
                o = newton_schulz(mom, steps=ns_steps, impl=kernel_impl)
                if use_muon_scale:
                    o = muon_scale((g.fs.m, g.fs.n)) * o
                return (o, m2)
            g32 = g.astype(jnp.float32)
            m2 = beta * m + g32
            mom = beta * m2 + g32 if nesterov else m2
            o = newton_schulz(mom, steps=ns_steps, impl=kernel_impl)
            if use_muon_scale:
                shape = p.shape if p is not None else g.shape
                o = muon_scale(shape) * o
            return (o, m2)

        flat = jax.tree_util.tree_map(upd, updates, mu, params, is_leaf=_IS_NONE)
        out, new_mu = _transpose(flat, 2)
        return out, new_mu

    update.chain_info = {"kind": "scale_by_muon", "beta": beta,
                         "ns_steps": ns_steps, "nesterov": nesterov}
    return Transform(init, update)


class ScaleByAdamState(NamedTuple):
    count: jax.Array
    mu: PyTree
    nu: PyTree


def scale_by_adam(
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    scale: float = 1.0,
) -> Transform:
    """Bias-corrected Adam direction, optionally pre-scaled (GaLore's alpha).

    Property II does NOT hold for Adam: inside ``lowrank`` this reproduces
    GaLore's (biased) semantics, and inside ``layerwise_unbias`` the
    *gradient estimate* is debiased even though the update is not exactly
    full Adam in expectation (the AdaRankGrad-style extension)."""

    def init(params: PyTree) -> ScaleByAdamState:
        zeros = lambda: jax.tree_util.tree_map(
            _zeros_momentum, params, is_leaf=_IS_NONE
        )
        return ScaleByAdamState(
            count=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros()
        )

    def update(updates: PyTree, state: ScaleByAdamState, params: PyTree):
        count = state.count + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(g, m, v, p):
            if g is None:
                return (None, None, None)
            if isinstance(g, ProjGrad):
                g32 = g.materialize()
                if g.coeff != 1.0:
                    g32 = g.coeff * g32
                m = g.apply_reset(m)
                v = g.apply_reset(v)
            else:
                g32 = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g32
            v2 = b2 * v + (1 - b2) * jnp.square(g32)
            s = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            if scale != 1.0:
                s = scale * s
            return (s, m2, v2)

        flat = jax.tree_util.tree_map(
            upd, updates, state.mu, state.nu, params, is_leaf=_IS_NONE
        )
        out, mu, nu = _transpose(flat, 3)
        return out, ScaleByAdamState(count=count, mu=mu, nu=nu)

    update.chain_info = {"kind": "scale_by_adam", "scale": scale}
    return Transform(init, update)


def add_decayed_weights(weight_decay: float = 0.0) -> Transform:
    """Decoupled weight decay ``u + wd * p`` (apply before scale_by_lr)."""

    def init(params: PyTree):
        return ()

    def update(updates: PyTree, state, params: PyTree):
        if weight_decay == 0.0:
            return updates, ()

        def one(u, p):
            if u is None:
                return None
            if isinstance(u, PendingBack):
                return u.decayed(weight_decay)
            return u + weight_decay * p.astype(jnp.float32)

        out = jax.tree_util.tree_map(one, updates, params, is_leaf=_IS_NONE)
        return out, ()

    update.chain_info = {"kind": "add_decayed_weights",
                         "weight_decay": weight_decay}
    return Transform(init, update)


class ScaleByLrState(NamedTuple):
    count: jax.Array


def scale_by_lr(lr: Schedule) -> Transform:
    """Terminal step: ``-schedule(count) * u`` (updates are *added* to
    params, so the minus sign lives here)."""

    def init(params: PyTree) -> ScaleByLrState:
        return ScaleByLrState(count=jnp.zeros((), jnp.int32))

    def update(updates: PyTree, state: ScaleByLrState, params: PyTree):
        count = state.count + 1
        step = schedule_value(lr, count)

        def one(u):
            if u is None:
                return None
            if isinstance(u, PendingBack):
                return u.scaled(-step)
            return (-step) * u

        out = jax.tree_util.tree_map(one, updates, is_leaf=_IS_NONE)
        # Terminal stage of every chain: materialize deferred epilogues here,
        # one fused launch per family stack.
        out = materialize_pending(out)
        return out, ScaleByLrState(count=count)

    update.chain_info = {"kind": "scale_by_lr"}
    return Transform(init, update)


def scale_by_factor(factor: float) -> Transform:
    """Constant multiplier (GaLore/Fira's alpha applied outside the base).
    Protocol-aware, so it also composes INSIDE lowrank(): ProjGrad leaves
    scale lazily through their coeff, FullUpdate leaves through the payload."""

    def init(params: PyTree):
        return ()

    def update(updates: PyTree, state, params: PyTree):
        def one(u):
            if u is None:
                return None
            if isinstance(u, ProjGrad):
                return u.with_coeff(factor * u.coeff)
            if isinstance(u, FullUpdate):
                return FullUpdate(factor * u.u)
            if isinstance(u, PendingBack):
                return u.scaled(factor)
            return factor * u

        out = jax.tree_util.tree_map(one, updates, is_leaf=_IS_NONE)
        return out, ()

    update.chain_info = {"kind": "scale_by_factor", "factor": factor}
    return Transform(init, update)


def clip_by_global_norm(max_norm: float) -> Transform:
    """Global-norm gradient clipping as a chain head (the transform twin of
    :func:`repro.core.api.clip_by_global_norm`)."""

    def init(params: PyTree):
        return ()

    def update(updates: PyTree, state, params: PyTree):
        return _clip_tree(materialize_pending(updates), max_norm), ()

    update.chain_info = {"kind": "clip_by_global_norm"}
    return Transform(init, update)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def with_matrix_routing(
    matrix: Transform,
    fallback: Transform,
    *,
    matrix_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    matrix_label: str = "matrix",
    fallback_label: str = "adamw",
) -> Transform:
    """Route hidden-matrix leaves to ``matrix`` and everything else
    (embeddings / head / norms / biases / routers) to ``fallback`` — the
    label plumbing every paper optimizer previously re-implemented."""

    def label_fn(params: PyTree) -> PyTree:
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: matrix_label if matrix_filter(path, p) else fallback_label,
            paths, params,
        )

    return multi_transform({matrix_label: matrix, fallback_label: fallback}, label_fn)


# ---------------------------------------------------------------------------
# ZeRO-style family-state sharding context
# ---------------------------------------------------------------------------

_FAMILY_SHARDING = threading.local()


@contextlib.contextmanager
def family_sharding(mesh, axis: str):
    """Declare that family-stacked low-rank state (projectors + projected
    moments) is partitioned on mesh ``axis`` along the stack dimension.

    Entered by the step builders (``launch.shardmap_fsdp`` /
    ``train.Trainer``) around ``optimizer.update`` at *trace* time; the fused
    path reads it via :func:`active_family_sharding` and routes each
    shardable family's projector refresh through a shard-local
    ``all_gather → SVD → slice`` (the ColossalAI ``distributed_galore``
    schedule) so the new projectors are born sharded.  Steady-state family
    math is leading-axis elementwise/batched and needs no collectives — GSPMD
    partitions it from the in/out shardings alone.  ``mesh`` may be a
    concrete :class:`jax.sharding.Mesh` or an ``AbstractMesh`` (the
    collective auditor traces device-free)."""
    prev = getattr(_FAMILY_SHARDING, "ctx", None)
    _FAMILY_SHARDING.ctx = (mesh, axis)
    try:
        yield
    finally:
        _FAMILY_SHARDING.ctx = prev


def active_family_sharding():
    """The active ``(mesh, axis)`` family-sharding declaration, or None."""
    return getattr(_FAMILY_SHARDING, "ctx", None)


def family_shard_count(shard_ctx) -> int:
    """Shard count of a ``(mesh, axis)`` context (1 when ctx is None)."""
    if shard_ctx is None:
        return 1
    mesh, axis = shard_ctx
    return int(mesh.shape[axis])


# ---------------------------------------------------------------------------
# lowrank — the projection wrapper
# ---------------------------------------------------------------------------


class LowRankState(NamedTuple):
    count: jax.Array
    projs: PyTree   # per-leaf projector (*lead, s, r) arrays (None elsewhere)
    inner: PyTree   # the wrapped transform's state (projected space)
    # Spectrum probes (``probe_spectrum=True``, None otherwise): per leaf /
    # family a dict {"sv2": (r,) squared singular values of PᵀG summed over
    # blocks, "g2": () total ||G||_F², "mn": (2,) family shape} captured at
    # each refresh — the raw material of the spectral() rank policy
    # (repro.core.rank_policy reads them host-side via gather_probes).
    probes: PyTree = None


def _spectrum_probe(p, g32, fs: FamilyShape):
    """Squared singular values of the projected gradient sketch ``PᵀG``
    (via the r x r Gram eigenvalues — no extra SVD), summed over stacked
    blocks and sorted descending, plus the total gradient energy.  Reuses
    the projector the refresh just computed, so the probe costs one thin
    GEMM + an r x r eigh per refresh."""
    s = _dispatch().project(p, g32, side=fs.side, impl="jnp")
    if fs.side == "left":
        gram = jnp.einsum("...ab,...cb->...ac", s, s)
    else:
        gram = jnp.einsum("...ba,...bc->...ac", s, s)
    ev = jnp.maximum(jnp.linalg.eigvalsh(gram), 0.0)     # (*lead, r)
    sv2 = jnp.sum(ev.reshape((-1, ev.shape[-1])), axis=0)
    sv2 = jnp.flip(jnp.sort(sv2))
    return {"sv2": sv2, "g2": jnp.sum(jnp.square(g32)),
            "mn": jnp.asarray((fs.m, fs.n), jnp.int32)}


def _probe_zeros(fs: FamilyShape, telemetry: bool = False):
    pr = {"sv2": jnp.zeros((fs.rank,), jnp.float32),
          "g2": jnp.zeros((), jnp.float32),
          "mn": jnp.asarray((fs.m, fs.n), jnp.int32)}
    if telemetry:
        pr["drift"] = jnp.zeros((), jnp.float32)
        pr["bias"] = jnp.zeros((), jnp.float32)
        pr["bias_step"] = jnp.zeros((), jnp.int32)
    return pr


def _subspace_drift(p_old, p_new):
    """How far the refreshed subspace moved: ``1 − mean squared overlap``
    of the two orthonormal projector stacks via the r×r cross-Gram
    ``P_oldᵀ P_new`` (0 = unchanged span, →1 = orthogonal).  Uses the raw
    einsum (not the dispatch layer) so telemetry never perturbs launch
    counts.  The very first refresh compares against the zero-initialised
    projector and therefore reads 1."""
    r = p_new.shape[-1]
    blocks = 1
    for d in p_new.shape[:-2]:
        blocks *= d
    gram = jnp.einsum("...sr,...sq->...rq", p_old.astype(jnp.float32),
                      p_new.astype(jnp.float32))
    overlap = jnp.sum(jnp.square(gram)) / (r * blocks)
    return jnp.clip(1.0 - overlap, 0.0, 1.0)


def _bias_residual(p, g32, side):
    """Fraction of this step's gradient energy OUTSIDE the current subspace,
    ``1 − ‖PᵀG‖²/‖G‖²`` — the live per-step counterpart of the offline
    bias-residual benchmark (zero iff the projection loses nothing).  Raw
    einsum again: launch-count neutral."""
    s = _raw_project(p, g32, side)
    g2 = jnp.sum(jnp.square(g32))
    return jnp.clip(1.0 - jnp.sum(jnp.square(s)) / jnp.maximum(g2, 1e-30),
                    0.0, 1.0)


def lowrank(
    inner: Transform,
    *,
    rank=128,
    period: int = 200,
    projector: str = "svd",
    seed: int = 0,
    subspace_iters: int = 2,
    reset_on_refresh: bool = False,
    external_refresh: bool = False,
    kernel_impl: str = "auto",
    pad_rank_to: int = 0,
    fuse_families: bool = False,
    fused_epilogue: bool = False,
    rank_policy=None,
    probe_spectrum: bool = False,
    telemetry: bool = False,
) -> Transform:
    """Run ``inner`` inside a periodically-refreshed low-rank subspace.

    Owns everything projection-related: per-family GaLore-side choice,
    projector computation (``svd | subspace | random | grass | rsvd``) every
    ``period`` steps, project / back-project through the Pallas dispatch
    layer (``kernel_impl``, opt-in ``pad_rank_to`` lane alignment), and the
    ProjGrad/FullUpdate leaf protocol described in the module docstring.

    ``reset_on_refresh`` zeroes the inner momenta at each period boundary
    (GUM always does; GaLore only with ``reset_on_update``).

    ``external_refresh=True`` skips the in-update refresh entirely; callers
    drive it through the attached ``update.refresh(grads, state, params)``
    hook instead (the projected-space gradient-accumulation path, which must
    refresh against a raw microbatch gradient *before* projecting).

    ``fuse_families=True`` executes the whole pipeline family-stacked — one
    batched launch per shape family instead of one per leaf (see the module
    docstring); trajectory-identical to the per-leaf path but with a
    different (family-list) state layout.  ``fused_epilogue=True``
    additionally defers the back-projection into :class:`PendingBack` leaves
    so chain tails fold into the GEMM.

    ``rank`` accepts an int or a per-shape :class:`~repro.core.rank_policy.
    RankMap`; ``rank_policy`` (a :class:`~repro.core.rank_policy.RankPolicy`)
    supplies the initial map and, for policies that need them, turns on
    ``probe_spectrum`` — storing per-family spectrum probes in
    ``LowRankState.probes`` at each refresh so a host-side
    :class:`~repro.core.rank_policy.RankPolicyController` can adapt the rank
    over training (rank is a *shape* in JAX, so the change itself happens
    outside jit via ``migrate_opt_state`` + a rebuild at the new map).

    ``telemetry=True`` (implies ``probe_spectrum``) additionally stores, in
    the same probe dicts: projector drift since the previous refresh
    (captured inside the refresh cond), and a per-step bias-residual
    estimate on one round-robin-sampled family (``lax.switch`` — only the
    selected family's thin GEMM executes each step).  The probes are
    write-only from the update's point of view — the parameter trajectory
    is bit-exact with ``telemetry=False`` — and add zero state leaves when
    off.  Host-side readout lives in :mod:`repro.telemetry.instrument`."""
    if telemetry:
        probe_spectrum = True
    if rank_policy is not None:
        probe_spectrum = probe_spectrum or bool(
            getattr(rank_policy, "wants_probes", False))
        if isinstance(rank, int):
            rank = rank_policy.initial_map(rank)
    wants_key = bool(getattr(inner.update, "wants_sample_key", False))
    inner_refresh_state = getattr(inner.update, "refresh_state", None)

    def _leaf_key(base_key, i):
        k = jax.random.fold_in(base_key, i)
        if wants_key:
            k_proj, k_samp = jax.random.split(k)
            return k_proj, k_samp
        return k, None

    def _family_keys(fam, base_key):
        """Stacked per-member (key_proj, key_samp) — bit-identical to
        ``_leaf_key`` per member."""
        keys = member_keys(fam, base_key)              # (M, 2)
        if wants_key:
            ks = jax.vmap(jax.random.split)(keys)      # (M, 2, 2)
            return ks[:, 0], ks[:, 1]
        return keys, None

    def _stacked_projectors(fam, g_stack, keys_proj):
        """Refresh a whole family: vmap ``compute_projectors`` over members
        (vmap is semantics-preserving per element, so each member's projector
        — including its RNG draws — matches the per-leaf path bit-for-bit),
        batching the SVD/QR linear algebra across the stack."""
        mfs = fam.member_fs
        g_mem = g_stack.reshape((fam.seg.members,) + mfs.lead + (mfs.m, mfs.n))
        p_mem = jax.vmap(
            lambda g, k: compute_projectors(
                projector, g, mfs.rank, k, mfs.side, subspace_iters
            )
        )(g_mem, keys_proj)
        return p_mem.reshape((fam.fs.L,) + p_mem.shape[1 + len(mfs.lead):])

    def _sharded_projectors(fam, g_stack, keys_proj, shard_ctx):
        """Sharded refresh of one family under :func:`family_sharding`.

        The stacked gradient arrives partitioned on its leading (stack) axis;
        each shard re-materializes the FULL stacked gradient with one
        ``all_gather`` (the only boundary collective — the count the schedule
        auditor asserts), computes every member's projector exactly as the
        replicated path would (same gradient, same keys → bit-identical
        values), and keeps only its own slice: the refreshed projectors are
        born sharded, no second collective to redistribute them."""
        mesh, axis = shard_ctx
        loc = fam.fs.L // family_shard_count(shard_ctx)

        def body(g_loc, keys):
            g_full = jax.lax.all_gather(g_loc, axis, axis=0, tiled=True)
            p_full = _stacked_projectors(fam, g_full, keys)
            k = jax.lax.axis_index(axis)
            return jax.lax.dynamic_slice_in_dim(p_full, k * loc, loc, axis=0)

        return _shard_map(
            body, mesh=mesh, in_specs=(_P(axis), _P()), out_specs=_P(axis),
            check_rep=False,
        )(g_stack, keys_proj)

    def _refresh_projectors(fam, g_stack, keys_proj):
        """Dispatch one family's projector refresh: sharded when a
        family-sharding context is active and the stack divides the axis,
        replicated otherwise (the non-divisible fallback keeps auditor
        expectation and runtime consistent — both count only divisible
        families as gathered)."""
        shard_ctx = active_family_sharding()
        if shard_ctx is not None \
                and stack_shardable(fam.fs.L, family_shard_count(shard_ctx)):
            return _sharded_projectors(fam, g_stack, keys_proj, shard_ctx)
        return _stacked_projectors(fam, g_stack, keys_proj)

    def _probe_fresh(p_new, p_old, g32, fs, old_probe):
        """Refresh-boundary probe: the spectrum sketch, plus (telemetry)
        projector drift vs the outgoing subspace and the carried-over bias
        fields — runs inside the refresh cond, so it costs nothing on
        steady steps."""
        pr = _spectrum_probe(p_new, g32, fs)
        if telemetry:
            pr["drift"] = _subspace_drift(p_old, p_new)
            pr["bias"] = old_probe["bias"]
            pr["bias_step"] = old_probe["bias_step"]
        return pr

    def _sample_bias(count, sites, probes):
        """Round-robin bias-residual sampling: ``sites`` is a list of
        (probe-index, projector, grad, side); one site's residual is
        measured per step via ``lax.switch`` (only the selected branch
        executes) and written into its probe dict.  Mutates ``probes`` in
        place; the update path never reads these fields, so the parameter
        trajectory is untouched."""
        if not sites:
            return
        sel = (count - 1) % len(sites)
        branches = [
            (lambda _, p=p, g=g, s=s: _bias_residual(p, g, s))
            for (_pi, p, g, s) in sites
        ]
        bias_val = jax.lax.switch(sel, branches, None)
        for k, (pi, _p, _g, _s) in enumerate(sites):
            pr = dict(probes[pi])
            hit = sel == k
            pr["bias"] = jnp.where(hit, bias_val, pr["bias"])
            pr["bias_step"] = jnp.where(hit, count, pr["bias_step"])
            probes[pi] = pr

    def _plan_leaves(params, grads=None):
        """Flatten params (and optionally grads up to them) and build the
        family plan.  Grad/param trees must mask together in fused mode."""
        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=_IS_NONE)
        plan = build_family_plan(leaves, rank)
        g_leaves = None
        if grads is not None:
            g_leaves = treedef.flatten_up_to(grads)
            for fam in plan.families:
                for i in fam.members:
                    if g_leaves[i] is None:
                        raise ValueError(
                            "fuse_families=True requires gradient leaves to "
                            "mask together with param leaves (param at flat "
                            f"index {i} has no gradient)"
                        )
        return leaves, treedef, plan, g_leaves

    def init_fused(params: PyTree) -> LowRankState:
        leaves, _, plan, _ = _plan_leaves(params)
        projs = [jnp.zeros(proj_shape(fam.fs), jnp.float32)
                 for fam in plan.families]
        tmpls = [
            ProjInit(
                fam.fs,
                jax.ShapeDtypeStruct(lowrank_state_shape(fam.fs), jnp.float32),
                seg=fam.seg,
            )
            for fam in plan.families
        ]
        probes = ([_probe_zeros(fam.fs, telemetry) for fam in plan.families]
                  if probe_spectrum else None)
        return LowRankState(
            count=jnp.zeros((), jnp.int32), projs=projs,
            inner=inner.init(tmpls), probes=probes,
        )

    def update_fused(updates: PyTree, state: LowRankState, params: PyTree):
        count = state.count + 1
        refresh = (count - 1) % period == 0
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)

        leaves, treedef, plan, g_leaves = _plan_leaves(params, updates)

        # Stacking the params costs a concat per family per step; only pay it
        # when the inner transform actually reads them (layerwise_unbias
        # gathers full-rank param blocks; the scale_by_* bases only use
        # shapes, which ProjGrad.fs already carries).
        inner_wants_params = bool(getattr(inner.update, "wants_params", False))
        fam_msgs, fam_projs, fam_params, fam_probes = [], [], [], []
        for fi, fam in enumerate(plan.families):
            g32 = stack_family(
                fam, [g if g is None else g.astype(jnp.float32)
                      for g in g_leaves]
            )
            keys_proj, keys_samp = _family_keys(fam, base_key)
            if external_refresh:
                p_proj = state.projs[fi]
            else:
                p_proj = jax.lax.cond(
                    refresh,
                    lambda _, fam=fam, g32=g32, kp=keys_proj:
                        _refresh_projectors(fam, g32, kp),
                    lambda _, fi=fi: state.projs[fi],
                    None,
                )
            if probe_spectrum and not external_refresh:
                fam_probes.append(jax.lax.cond(
                    refresh,
                    lambda _, p=p_proj, g=g32, fam=fam, fi=fi:
                        _probe_fresh(p, state.projs[fi], g, fam.fs,
                                     state.probes[fi]),
                    lambda _, fi=fi: state.probes[fi],
                    None,
                ))
            fam_msgs.append(ProjGrad(
                p=p_proj, g=g32, fs=fam.fs, kernel_impl=kernel_impl,
                pad_rank_to=pad_rank_to, coeff=1.0,
                reset=(refresh if (reset_on_refresh and not external_refresh) else None),
                refresh=(False if external_refresh else refresh),
                key=keys_samp, seg=fam.seg,
            ))
            fam_projs.append(p_proj)
            fam_params.append(
                stack_family(fam, leaves) if inner_wants_params else None
            )

        if telemetry and not external_refresh:
            _sample_bias(
                count,
                [(fi, m.p, m.g, fam.fs.side)
                 for fi, (m, fam) in enumerate(zip(fam_msgs, plan.families))],
                fam_probes,
            )

        inner_out, new_inner = inner.update(fam_msgs, state.inner, fam_params)

        out_leaves = [None] * plan.n_leaves
        for fam, msg, o, w in zip(plan.families, fam_msgs, inner_out, fam_params):
            if isinstance(o, FullUpdate):
                for i, part in zip(fam.members, unstack_family(fam, o.u)):
                    out_leaves[i] = part
            elif fused_epilogue:
                if w is None:
                    w = lambda fam=fam: stack_family(fam, leaves)
                for j, i in enumerate(fam.members):
                    out_leaves[i] = PendingBack(
                        p=msg.p, s=o, w=w, fs=fam.fs,
                        kernel_impl=kernel_impl, pad_rank_to=pad_rank_to,
                        member=j, members=fam.seg.members,
                        member_lead=fam.member_fs.lead,
                    )
            else:
                for i, part in zip(fam.members, unstack_family(fam, msg.back(o))):
                    out_leaves[i] = part

        return (
            jax.tree_util.tree_unflatten(treedef, out_leaves),
            LowRankState(
                count=count, projs=fam_projs, inner=new_inner,
                probes=(fam_probes if (probe_spectrum and not external_refresh)
                        else state.probes),
            ),
        )

    def refresh_fused(grads: PyTree, state: LowRankState, params: PyTree) -> LowRankState:
        count = state.count + 1
        refresh_now = (count - 1) % period == 0
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)

        _, _, plan, g_leaves = _plan_leaves(params, grads)

        new_projs, msgs, new_probes = [], [], []
        for fi, fam in enumerate(plan.families):
            g32 = stack_family(
                fam, [g if g is None else g.astype(jnp.float32)
                      for g in g_leaves]
            )
            keys_proj, keys_samp = _family_keys(fam, base_key)
            p_new = jax.lax.cond(
                refresh_now,
                lambda _, fam=fam, g32=g32, kp=keys_proj:
                    _refresh_projectors(fam, g32, kp),
                lambda _, fi=fi: state.projs[fi],
                None,
            )
            new_projs.append(p_new)
            if probe_spectrum:
                new_probes.append(jax.lax.cond(
                    refresh_now,
                    lambda _, p=p_new, g=g32, fam=fam, fi=fi:
                        _probe_fresh(p, state.projs[fi], g, fam.fs,
                                     state.probes[fi]),
                    lambda _, fi=fi: state.probes[fi],
                    None,
                ))
            msgs.append(RefreshMsg(fs=fam.fs, key=keys_samp, seg=fam.seg))

        if inner_refresh_state is not None:
            new_inner = inner_refresh_state(state.inner, msgs, refresh_now)
        elif reset_on_refresh:
            new_inner = _reset_floats(state.inner, refresh_now)
        else:
            new_inner = state.inner
        return LowRankState(
            count=state.count, projs=new_projs, inner=new_inner,
            probes=(new_probes if probe_spectrum else state.probes),
        )

    def init(params: PyTree) -> LowRankState:
        def init_leaf(p):
            if p is None:
                return (None, None)
            fs = family_shape(p, rank)
            proj = jnp.zeros(proj_shape(fs), jnp.float32)
            tmpl = ProjInit(
                fs, jax.ShapeDtypeStruct(lowrank_state_shape(fs), jnp.float32)
            )
            return (proj, tmpl)

        flat = jax.tree_util.tree_map(init_leaf, params, is_leaf=_IS_NONE)
        projs, tmpls = _transpose(flat, 2)
        probes = None
        if probe_spectrum:
            probes = jax.tree_util.tree_map(
                lambda p: None if p is None
                else _probe_zeros(family_shape(p, rank), telemetry),
                params, is_leaf=_IS_NONE,
            )
        return LowRankState(
            count=jnp.zeros((), jnp.int32), projs=projs,
            inner=inner.init(tmpls), probes=probes,
        )

    def update(updates: PyTree, state: LowRankState, params: PyTree):
        count = state.count + 1
        refresh = (count - 1) % period == 0
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)

        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=_IS_NONE)
        g_leaves = treedef.flatten_up_to(updates)
        p_leaves = treedef.flatten_up_to(state.projs)
        pr_leaves = (treedef.flatten_up_to(state.probes)
                     if probe_spectrum else None)

        msg_leaves, proj_leaves, probe_leaves, lr_sites = [], [], [], []
        for i, (g, proj, p) in enumerate(zip(g_leaves, p_leaves, leaves)):
            if g is None or p is None:
                msg_leaves.append(None)
                proj_leaves.append(proj)
                if probe_spectrum:
                    probe_leaves.append(pr_leaves[i])
                continue
            fs = family_shape(p, rank)
            key_proj, key_samp = _leaf_key(base_key, i)
            g32 = g.astype(jnp.float32)
            if external_refresh:
                p_proj = proj
            else:
                p_proj = jax.lax.cond(
                    refresh,
                    lambda _: compute_projectors(
                        projector, g32, fs.rank, key_proj, fs.side, subspace_iters
                    ),
                    lambda _: proj,
                    None,
                )
            if probe_spectrum:
                if external_refresh:
                    probe_leaves.append(pr_leaves[i])
                else:
                    probe_leaves.append(jax.lax.cond(
                        refresh,
                        lambda _, p=p_proj, old=proj, g=g32, fs=fs, i=i:
                            _probe_fresh(p, old, g, fs, pr_leaves[i]),
                        lambda _, i=i: pr_leaves[i],
                        None,
                    ))
                    lr_sites.append((i, p_proj, g32, fs.side))
            msg_leaves.append(ProjGrad(
                p=p_proj, g=g32, fs=fs, kernel_impl=kernel_impl,
                pad_rank_to=pad_rank_to, coeff=1.0,
                reset=(refresh if (reset_on_refresh and not external_refresh) else None),
                refresh=(False if external_refresh else refresh),
                key=key_samp,
            ))
            proj_leaves.append(p_proj)

        if telemetry and not external_refresh:
            _sample_bias(count, lr_sites, probe_leaves)

        inner_updates = jax.tree_util.tree_unflatten(treedef, msg_leaves)
        inner_out, new_inner = inner.update(inner_updates, state.inner, params)

        out_leaves = []
        for msg, o, p in zip(msg_leaves, treedef.flatten_up_to(inner_out), leaves):
            if msg is None or o is None:
                out_leaves.append(None)
            elif isinstance(o, FullUpdate):
                out_leaves.append(o.u)
            elif fused_epilogue:
                out_leaves.append(PendingBack(
                    p=msg.p, s=o, w=p, fs=msg.fs, kernel_impl=kernel_impl,
                    pad_rank_to=pad_rank_to,
                ))
            else:
                out_leaves.append(msg.back(o))

        return (
            jax.tree_util.tree_unflatten(treedef, out_leaves),
            LowRankState(
                count=count,
                projs=jax.tree_util.tree_unflatten(treedef, proj_leaves),
                inner=new_inner,
                probes=(jax.tree_util.tree_unflatten(treedef, probe_leaves)
                        if probe_spectrum else None),
            ),
        )

    def refresh(grads: PyTree, state: LowRankState, params: PyTree) -> LowRankState:
        """External period-boundary refresh against raw gradients: recompute
        projectors, resample the inner transform's block assignments, zero
        momenta — leaving ``count`` untouched (the subsequent ``update`` on
        the same step sees fresh state and, in external mode, never
        refreshes itself).  Key derivation matches the in-update path
        exactly, so trajectories are identical either way."""
        count = state.count + 1
        refresh_now = (count - 1) % period == 0
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)

        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=_IS_NONE)
        g_leaves = treedef.flatten_up_to(grads)
        p_leaves = treedef.flatten_up_to(state.projs)
        pr_leaves = (treedef.flatten_up_to(state.probes)
                     if probe_spectrum else None)

        new_projs, msgs, new_probes = [], [], []
        for i, (g, proj, p) in enumerate(zip(g_leaves, p_leaves, leaves)):
            if g is None or p is None or proj is None:
                new_projs.append(proj)
                msgs.append(None)
                if probe_spectrum:
                    new_probes.append(pr_leaves[i])
                continue
            fs = family_shape(p, rank)
            key_proj, key_samp = _leaf_key(base_key, i)
            g32 = g.astype(jnp.float32)
            p_new = jax.lax.cond(
                refresh_now,
                lambda _: compute_projectors(
                    projector, g32, fs.rank, key_proj, fs.side, subspace_iters
                ),
                lambda _: proj,
                None,
            )
            new_projs.append(p_new)
            if probe_spectrum:
                new_probes.append(jax.lax.cond(
                    refresh_now,
                    lambda _, p=p_new, old=proj, g=g32, fs=fs, i=i:
                        _probe_fresh(p, old, g, fs, pr_leaves[i]),
                    lambda _, i=i: pr_leaves[i],
                    None,
                ))
            msgs.append(RefreshMsg(fs=fs, key=key_samp))

        msgs_tree = jax.tree_util.tree_unflatten(treedef, msgs)
        if inner_refresh_state is not None:
            new_inner = inner_refresh_state(state.inner, msgs_tree, refresh_now)
        elif reset_on_refresh:
            new_inner = _reset_floats(state.inner, refresh_now)
        else:
            new_inner = state.inner
        return LowRankState(
            count=state.count,
            projs=jax.tree_util.tree_unflatten(treedef, new_projs),
            inner=new_inner,
            probes=(jax.tree_util.tree_unflatten(treedef, new_probes)
                    if probe_spectrum else None),
        )

    info = {
        "kind": "lowrank", "inner": chain_info(inner), "rank": rank,
        "period": period, "projector": projector,
        "kernel_impl": kernel_impl, "pad_rank_to": pad_rank_to,
        "fuse_families": fuse_families, "fused_epilogue": fused_epilogue,
        "external_refresh": external_refresh, "rank_policy": rank_policy,
        "probe_spectrum": probe_spectrum, "telemetry": telemetry,
    }
    if fuse_families:
        update_fused.refresh = refresh_fused
        update_fused.chain_info = info
        return Transform(init_fused, update_fused)
    update.refresh = refresh
    update.chain_info = info
    return Transform(init, update)


# ---------------------------------------------------------------------------
# layerwise_unbias — the paper's debiasing, as a combinator
# ---------------------------------------------------------------------------


class LayerwiseUnbiasState(NamedTuple):
    low: PyTree    # base state over the projected-space leaves
    full: PyTree   # base state over the (gamma, m, n) full-rank slots
    idx: PyTree    # per-leaf (gamma,) int32 slot -> block assignment


def layerwise_unbias(
    base: Transform,
    *,
    gamma: int = 2,
    compensation: str = "paper",
) -> Transform:
    """Layerwise-sampling debiasing (Lemma 1) around ANY base transform.

    Per period, a fixed count ``gamma`` of blocks per family runs the base
    on the *compensated full-rank* gradient (``gamma`` static slots,
    resampled at each projector refresh); the rest run it on the scaled
    projected gradient.  Coefficients per ``compensation``:

      paper    : c_low = 1/(1-q),  c_full = 1/q,  c_comp = 1
      finetune : c_low = 1,        c_full = 1/q,  c_comp = 1-q   (App. C.1)

    Must be composed inside :func:`lowrank` (it consumes the ProjGrad
    protocol and sizes its slots from the ProjInit templates).  With a
    Property-II base (scale_by_muon / scale_by_momentum) the expected update
    equals the full-rank base update — this is GUM; with scale_by_adam the
    *gradient estimate* is unbiased (the new unbiased GaLore-Adam)."""
    if compensation not in ("paper", "finetune"):
        raise ValueError(f"unknown compensation: {compensation}")

    def _coeffs(fs: FamilyShape, seg=None):
        # Under family stacking the sampling unit is the MEMBER leaf (q =
        # gamma / member_L, uniform across the stack by plan construction),
        # exactly as in the per-leaf path.
        L_eff = seg.member_L if seg is not None else fs.L
        g_f = min(gamma, L_eff)
        q = g_f / L_eff
        if q >= 1.0:
            c_low = 0.0  # low branch fully overwritten by the scatter
        elif compensation == "finetune":
            c_low = 1.0
        else:
            c_low = 1.0 / max(1.0 - q, 1e-12)
        c_comp = (1.0 - q) if compensation == "finetune" else 1.0
        c_full = (1.0 / q) if g_f > 0 else 0.0
        return g_f, q, c_low, c_comp, c_full

    def _member_sample(keys, members: int, member_L: int, g_f: int):
        """Stacked resampling: each member draws ``g_f`` of its own
        ``member_L`` blocks with its own key (bit-identical to the per-leaf
        ``jax.random.choice`` under vmap), offset to global stack indices."""
        fresh = jax.vmap(
            lambda k: jax.random.choice(k, member_L, (g_f,), replace=False)
        )(keys).astype(jnp.int32)
        offs = (jnp.arange(members, dtype=jnp.int32) * member_L)[:, None]
        return (fresh + offs).reshape(-1)

    _is_tmpl = lambda x: x is None or isinstance(x, ProjInit)

    def init(params: PyTree) -> LayerwiseUnbiasState:
        def full_tmpl(t):
            if t is None:
                return None
            if not isinstance(t, ProjInit):
                raise TypeError(
                    "layerwise_unbias must be composed inside lowrank() "
                    f"(init saw a {type(t).__name__} leaf, expected ProjInit)"
                )
            g_f, *_ = _coeffs(t.fs, t.seg)
            if g_f == 0:
                return None
            slots = (t.seg.members if t.seg is not None else 1) * g_f
            return jax.ShapeDtypeStruct((slots, t.fs.m, t.fs.n), jnp.float32)

        def idx0(t):
            if t is None:
                return None
            g_f, *_ = _coeffs(t.fs, t.seg)
            if g_f == 0:
                return None
            if t.seg is not None:
                offs = (jnp.arange(t.seg.members, dtype=jnp.int32)
                        * t.seg.member_L)[:, None]
                return (jnp.arange(g_f, dtype=jnp.int32)[None, :]
                        + offs).reshape(-1)
            return jnp.arange(g_f, dtype=jnp.int32)

        def low_tmpl(t):
            # q >= 1 (gamma covers every block): the scatter overwrites the
            # whole family, so the low branch carries no state and does no
            # work for this leaf (mirrors the monoliths' `if q < 1` guard).
            if t is None:
                return None
            g_f, q, *_ = _coeffs(t.fs, t.seg)
            if q >= 1.0:
                return None
            return t

        fulls = jax.tree_util.tree_map(full_tmpl, params, is_leaf=_is_tmpl)
        lows = jax.tree_util.tree_map(low_tmpl, params, is_leaf=_is_tmpl)
        idx = jax.tree_util.tree_map(idx0, params, is_leaf=_is_tmpl)
        return LayerwiseUnbiasState(
            low=base.init(lows), full=base.init(fulls), idx=idx
        )

    _is_pg = lambda x: x is None or isinstance(x, ProjGrad)

    def update(updates: PyTree, state: LayerwiseUnbiasState, params: PyTree):
        g_leaves, treedef = jax.tree_util.tree_flatten(updates, is_leaf=_is_pg)
        idx_leaves = treedef.flatten_up_to(state.idx)
        param_leaves = treedef.flatten_up_to(params)
        d = _dispatch()

        low_upds, new_idx, full_upds, full_params = [], [], [], []
        refresh_any = False
        for g, idx, p in zip(g_leaves, idx_leaves, param_leaves):
            if g is None:
                low_upds.append(None)
                new_idx.append(None)
                full_upds.append(None)
                full_params.append(None)
                continue
            if not isinstance(g, ProjGrad):
                raise TypeError(
                    "layerwise_unbias must be composed inside lowrank() "
                    f"(got a {type(g).__name__} leaf)"
                )
            fs = g.fs
            g_f, q, c_low, c_comp, c_full = _coeffs(fs, g.seg)
            # q >= 1: no low branch at all (state is None too — see init)
            low_upds.append(g.with_coeff(c_low) if q < 1.0 else None)
            if g_f == 0:
                new_idx.append(None)
                full_upds.append(None)
                full_params.append(None)
                continue
            if g.refresh is False:  # static: external-refresh mode
                idx2 = idx
            else:
                refresh_any = g.refresh
                if g.seg is not None:
                    fresh = _member_sample(
                        g.key, g.seg.members, g.seg.member_L, g_f
                    )
                else:
                    fresh = jax.random.choice(
                        g.key, fs.L, (g_f,), replace=False
                    ).astype(jnp.int32)
                idx2 = jnp.where(g.refresh, fresh, idx)
            new_idx.append(idx2)
            g_s = gather_blocks(g.g, idx2, fs)        # (gamma, m, n)
            p_s = gather_blocks(g.p, idx2, fs)        # (gamma, s, r)
            pptg = d.back_project(
                p_s,
                d.project(p_s, g_s, side=fs.side, impl=g.kernel_impl,
                          pad_rank_to=g.pad_rank_to),
                side=fs.side, impl=g.kernel_impl, pad_rank_to=g.pad_rank_to,
            )
            resid = g_s - c_comp * pptg
            full_upds.append(c_full * resid)
            full_params.append(gather_blocks(p, idx2, fs))

        # Slot -> block assignments change at the boundary, so the slots'
        # base momenta always reset there (independent of reset_on_refresh).
        full_state = state.full
        if refresh_any is not False:
            full_state = _reset_floats(state.full, refresh_any)

        low_out, new_low = base.update(
            jax.tree_util.tree_unflatten(treedef, low_upds), state.low, params
        )
        full_out, new_full = base.update(
            jax.tree_util.tree_unflatten(treedef, full_upds),
            full_state,
            jax.tree_util.tree_unflatten(treedef, full_params),
        )

        lo_leaves = treedef.flatten_up_to(low_out)
        fo_leaves = treedef.flatten_up_to(full_out)
        outs = []
        for g, lo, fo, idx2 in zip(g_leaves, lo_leaves, fo_leaves, new_idx):
            if g is None:
                outs.append(None)
                continue
            fs = g.fs
            g_f, q, *_ = _coeffs(fs, g.seg)
            if q < 1.0:
                u = g.back(lo)
            else:
                u = jnp.zeros(fs.lead + (fs.m, fs.n), jnp.float32)
            if g_f > 0:
                u = scatter_blocks(u, idx2, fo, fs)
            outs.append(FullUpdate(u))

        return (
            jax.tree_util.tree_unflatten(treedef, outs),
            LayerwiseUnbiasState(
                low=new_low,
                full=new_full,
                idx=jax.tree_util.tree_unflatten(treedef, new_idx),
            ),
        )

    _is_msg = lambda x: x is None or isinstance(x, RefreshMsg)

    def refresh_state(state: LayerwiseUnbiasState, msgs: PyTree, refresh_now):
        """External-refresh hook (driven by ``lowrank``'s refresh): resample
        slot assignments and zero both branches' momenta."""
        msg_leaves, treedef = jax.tree_util.tree_flatten(msgs, is_leaf=_is_msg)
        idx_leaves = treedef.flatten_up_to(state.idx)
        new_idx = []
        for msg, idx in zip(msg_leaves, idx_leaves):
            if msg is None or idx is None:
                new_idx.append(idx)
                continue
            if msg.seg is not None:
                g_f = int(idx.shape[0]) // msg.seg.members
                fresh = _member_sample(
                    msg.key, msg.seg.members, msg.seg.member_L, g_f
                )
            else:
                g_f = int(idx.shape[0])
                fresh = jax.random.choice(
                    msg.key, msg.fs.L, (g_f,), replace=False
                ).astype(jnp.int32)
            new_idx.append(jnp.where(refresh_now, fresh, idx))
        return LayerwiseUnbiasState(
            low=_reset_floats(state.low, refresh_now),
            full=_reset_floats(state.full, refresh_now),
            idx=jax.tree_util.tree_unflatten(treedef, new_idx),
        )

    update.wants_sample_key = True
    update.wants_params = True
    update.refresh_state = refresh_state
    update.chain_info = {"kind": "layerwise_unbias", "inner": chain_info(base),
                         "gamma": gamma, "compensation": compensation}
    return Transform(init, update)


# ---------------------------------------------------------------------------
# with_fira_residual — Fira's out-of-subspace residual, as a combinator
# ---------------------------------------------------------------------------


class FiraResidualState(NamedTuple):
    inner: PyTree
    prev_norm: PyTree  # per-leaf (*lead,) norm-growth-limiter memory


def with_fira_residual(
    base: Transform,
    *,
    limiter: float = 1.01,
    eps: float = 1e-8,
) -> Transform:
    """Fira (Chen et al., 2024): add back the gradient component OUTSIDE the
    projected subspace, scaled per block by phi = ||s|| / ||PᵀG|| (s = the
    base's projected-space update), with the norm-growth limiter.  Must be
    composed inside :func:`lowrank`; no unbiasedness guarantee (the paper's
    point of comparison)."""
    _is_tmpl = lambda x: x is None or isinstance(x, ProjInit)
    _is_pg = lambda x: x is None or isinstance(x, ProjGrad)

    def init(params: PyTree) -> FiraResidualState:
        def pn(t):
            return None if t is None else jnp.zeros(t.fs.lead, jnp.float32)

        return FiraResidualState(
            inner=base.init(params),
            prev_norm=jax.tree_util.tree_map(pn, params, is_leaf=_is_tmpl),
        )

    def update(updates: PyTree, state: FiraResidualState, params: PyTree):
        g_leaves, treedef = jax.tree_util.tree_flatten(updates, is_leaf=_is_pg)

        r_gs, reset = [], None
        for g in g_leaves:
            if g is None:
                r_gs.append(None)
                continue
            if not isinstance(g, ProjGrad):
                raise TypeError("with_fira_residual must be composed inside lowrank()")
            reset = g.reset if g.reset is not None else reset
            r_gs.append(g.materialize())

        # The base consumes plain arrays here, so lowrank's ProjGrad.reset
        # never reaches it — honor reset_on_refresh ourselves (keeps the
        # in-update and external-refresh paths trajectory-identical).
        inner_state, prev_norm = state.inner, state.prev_norm
        if reset is not None:
            inner_state = _reset_floats(inner_state, reset)
            prev_norm = _reset_floats(prev_norm, reset)
        state = FiraResidualState(inner=inner_state, prev_norm=prev_norm)

        s_out, new_inner = base.update(
            jax.tree_util.tree_unflatten(treedef, r_gs), state.inner, params
        )

        s_leaves = treedef.flatten_up_to(s_out)
        pn_leaves = treedef.flatten_up_to(state.prev_norm)
        outs, new_pn = [], []
        for g, r_g, s, prev in zip(g_leaves, r_gs, s_leaves, pn_leaves):
            if g is None:
                outs.append(None)
                new_pn.append(prev)
                continue
            resid = g.g - g.back(r_g)
            s_norm = jnp.linalg.norm(s, axis=(-2, -1))
            rg_norm = jnp.linalg.norm(r_g, axis=(-2, -1))
            phi = s_norm / (rg_norm + eps)
            scaled = phi[..., None, None] * resid

            rnorm = jnp.linalg.norm(scaled, axis=(-2, -1))
            cap = jnp.where(prev > 0, limiter * prev, rnorm)
            shrink = jnp.minimum(1.0, cap / (rnorm + eps))
            scaled = scaled * shrink[..., None, None]
            new_pn.append(rnorm * shrink)

            outs.append(FullUpdate(g.back(s) + scaled))

        return (
            jax.tree_util.tree_unflatten(treedef, outs),
            FiraResidualState(
                inner=new_inner,
                prev_norm=jax.tree_util.tree_unflatten(treedef, new_pn),
            ),
        )

    if getattr(base.update, "wants_params", False):
        update.wants_params = True
    update.chain_info = {"kind": "with_fira_residual",
                         "inner": chain_info(base)}
    return Transform(init, update)


# ---------------------------------------------------------------------------
# state introspection
# ---------------------------------------------------------------------------


def find_lowrank_states(state: PyTree) -> list[LowRankState]:
    """Every :class:`LowRankState` inside an optimizer state (benchmarks and
    tests read projectors through this instead of guessing chain indices)."""
    found: list[LowRankState] = []

    def walk(s):
        if isinstance(s, LowRankState):
            found.append(s)
            return
        if isinstance(s, tuple):
            for c in s:
                walk(c)
        elif isinstance(s, dict):
            for c in s.values():
                walk(c)

    walk(state)
    return found
