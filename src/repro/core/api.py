"""Minimal functional optimizer API (optax-style, zero dependencies).

A :class:`Transform` is a pair of pure functions:

    init(params)                     -> state
    update(grads, state, params)     -> (updates, new_state)

``updates`` are *added* to params (they already include the -lr sign), so

    params = apply_updates(params, updates)

All states are pytrees of arrays (jit/pjit friendly, checkpointable).  A step
counter is threaded through every optimizer's state as ``state.count``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Union[float, Callable[[jax.Array], jax.Array]]


class Transform(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def schedule_value(lr: Schedule, count: jax.Array) -> jax.Array:
    return jnp.asarray(lr(count) if callable(lr) else lr, dtype=jnp.float32)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    def one(p, u):
        if u is None:
            return p
        if hasattr(u, "materialize_update"):
            # A deferred-epilogue leaf (combinators.PendingBack) from a chain
            # that ended without scale_by_lr: materialize it leaf-by-leaf
            # (correct, just not family-grouped).
            u = u.materialize_update()
        return p + u.astype(p.dtype)

    return jax.tree_util.tree_map(
        one, params, updates, is_leaf=lambda x: x is None
    )


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads)


# ---------------------------------------------------------------------------
# Label-partitioned composition (like optax.multi_transform).
# ---------------------------------------------------------------------------


class MultiState(NamedTuple):
    inner: dict  # label -> state


def multi_transform(
    transforms: dict[str, Transform], label_fn: Callable[[PyTree], PyTree]
) -> Transform:
    """Route each leaf to the transform named by ``label_fn(params)``.

    ``label_fn`` returns a pytree of the same structure whose leaves are label
    strings.  Each inner transform sees the full tree with non-owned leaves
    replaced by ``None`` (masked), mirroring optax semantics.
    """

    def mask(tree: PyTree, labels: PyTree, label: str) -> PyTree:
        return jax.tree_util.tree_map(
            lambda x, l: x if l == label else None, tree, labels
        )

    def unmask_merge(trees: dict[str, PyTree], labels: PyTree) -> PyTree:
        def pick(l, *leaves_by_label):
            return leaves_by_label[list(transforms).index(l)]

        per_label = [trees[k] for k in transforms]
        return jax.tree_util.tree_map(
            pick, labels, *per_label, is_leaf=lambda x: x is None
        )

    def init(params: PyTree) -> MultiState:
        labels = label_fn(params)
        return MultiState(
            inner={k: t.init(mask(params, labels, k)) for k, t in transforms.items()}
        )

    def update(grads: PyTree, state: MultiState, params: PyTree):
        labels = label_fn(params)
        new_inner, upds = {}, {}
        for k, t in transforms.items():
            u, s = t.update(mask(grads, labels, k), state.inner[k], mask(params, labels, k))
            upds[k], new_inner[k] = u, s
        merged = unmask_merge(upds, labels)
        return merged, MultiState(inner=new_inner)

    # Static composition metadata for the analysis layer (repro.analysis):
    # per-branch chain_info plus the label_fn itself, so the chain linter /
    # launch model can resolve the actual leaf routing from a params tree.
    update.chain_info = {
        "kind": "multi_transform",
        "branches": {
            k: dict(getattr(t.update, "chain_info", None) or {"kind": "opaque"})
            for k, t in transforms.items()
        },
        "label_fn": label_fn,
    }
    return Transform(init, update)


def tree_paths(tree: PyTree) -> PyTree:
    """Pytree of '/'-joined key paths, same structure as ``tree``."""

    def name(kp) -> str:
        parts = []
        for k in kp:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            elif isinstance(k, jax.tree_util.GetAttrKey):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        return "/".join(parts)

    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    flat = [name(kp) for kp, _ in paths_leaves]
    treedef = jax.tree_util.tree_structure(tree)
    return jax.tree_util.tree_unflatten(treedef, flat)


def state_bytes(state: PyTree) -> int:
    """Total bytes of all arrays in an optimizer state (memory benchmarks)."""
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(state)
        if hasattr(x, "dtype")
    )


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Config resolved by :func:`repro.core.factory.build_optimizer`."""

    # gum | galore | galore_muon | golore | muon | adamw | sgdm | fira | lisa
    # | unbiased_galore_adam (combinator-only composition, PR 2)
    name: str = "gum"
    lr: float = 1e-3
    weight_decay: float = 0.0
    beta: float = 0.95          # momentum (muon-family)
    b1: float = 0.9             # adam
    b2: float = 0.999
    eps: float = 1e-8
    rank: int = 128             # low-rank projection rank
    q: float = 0.25             # full-rank sampling probability (gum) == gamma/L
    gamma: int = 2              # full-rank layers per period (gum/lisa)
    period: int = 200           # K, projector refresh / resampling period
    projector: str = "svd"      # svd | subspace | random | grass
    base: str = "muon"          # base optimizer inside low-rank space
    ns_steps: int = 5
    compensation: str = "paper"  # paper | finetune (App. C.1 variant)
    grad_clip: float = 0.0
    seed: int = 0
    # Hot-loop implementation: auto | jnp | pallas | interpret — "auto" runs
    # the fused Pallas kernels on TPU and the jnp reference elsewhere
    # (see repro.kernels.dispatch).
    kernel_impl: str = "auto"
    # Opt-in lane-aligned rank padding for the low-rank Pallas kernels:
    # 128 rounds ragged ranks (e.g. r=96) up to a full MXU lane multiple for
    # peak systolic-array utilization; 0 keeps the minimal sublane granule.
    pad_rank_to: int = 0
    # Family-stacked fused execution: group same-shape leaves into stacked
    # (L, m, n) super-leaves so the lowrank() pipeline launches once per
    # shape family instead of once per leaf.  Trajectory-identical to the
    # per-leaf path (per-member PRNG preserved) but the optimizer-state
    # layout changes — off by default so existing trajectories/checkpoints
    # are bit-for-bit unchanged.
    fuse_families: bool = False
    # Fold chain-tail elementwise epilogues (scale_by_lr /
    # add_decayed_weights / scale_by_factor) into the back-projection GEMM
    # via the fused back_project_epilogue kernel.  Not bit-exact (the
    # epilogue redistributes multiplications), hence a separate opt-in.
    fused_epilogue: bool = False
    # Muon's sqrt(max(1, m/n)) RMS-matching factor.  None = each optimizer's
    # default (muon: on, matching Jordan et al.; gum: off, matching Alg. 2).
    use_muon_scale: bool | None = None
    # Rank policy (repro.core.rank_policy): when and what rank each shape
    # family runs at.  None = static cfg.rank (unchanged behavior).  Accepts
    # a RankPolicy object or a CLI spec string — "fixed:64",
    # "stepwise:0=128,500=64", "family:512x512=32,...", "spectral:0.99".
    # Policies decide at projector-refresh boundaries; the Trainer migrates
    # optimizer state and re-jits (bounded by the policy's rank ladder).
    rank_policy: Any = None
    # Declared rank ladder for adaptive policies (bounds recompilation; with
    # pad_rank_to=128, ladder steps inside one 128-lane bucket share kernel
    # shapes).  Empty = the policy's default (powers of two).
    rank_ladder: tuple[int, ...] = ()
    # ZeRO-style sharded projected state (requires fuse_families=True and a
    # data-parallel mesh): partition each family's projectors and projected
    # moments across the data axis along the member-stack dim, all-gathering
    # full gradients only at projector-refresh boundaries.  Read by the step
    # builders (launch.shardmap_fsdp / train.Trainer) and the sharded
    # auditor — the factory-built transform itself is layout-agnostic.
    shard_state: bool = False
    # In-jit telemetry (repro.telemetry): store projector drift and a
    # sampled per-step bias residual in the spectrum-probe dicts (implies
    # probe_spectrum).  Write-only from the update's point of view — the
    # parameter trajectory is bit-exact with telemetry off, and the state
    # gains zero leaves when off.  Budgeted <=2% step time
    # (benchmarks/telemetry.py).
    telemetry: bool = False
