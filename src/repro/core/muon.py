"""Muon (Jordan et al., 2024) — momentum + Newton–Schulz orthogonalization.

Now a combinator chain (see :mod:`repro.core.combinators`)::

    muon_matrices = chain(scale_by_muon(beta, ns_steps, nesterov=True,
                                        use_muon_scale, kernel_impl),
                          add_decayed_weights(wd), scale_by_lr(lr))
    muon          = with_matrix_routing(muon_matrices, adamw, ...)

Applies to >=2-D parameters (leading axes are treated as stacked blocks, e.g.
scan-stacked layers ``(L, m, n)``).  1-D parameters (norm scales, biases) and
anything excluded by ``matrix_filter`` fall back to AdamW, as in practice.

``use_muon_scale`` (default True, matching Jordan et al. and this module's
historical behaviour) multiplies the orthogonalized update by
:func:`repro.core.newton_schulz.muon_scale` — sqrt(max(1, m/n)) — so update
RMS is comparable across aspect ratios.  ``kernel_impl`` routes the
Newton–Schulz hot loop through the fused Pallas TPU kernels
(repro.kernels.dispatch); "auto" = Pallas on TPU, jnp reference elsewhere.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from .adamw import adamw
from .api import Schedule, Transform
from .combinators import (
    add_decayed_weights,
    chain,
    scale_by_lr,
    scale_by_muon,
    with_matrix_routing,
)


def muon_matrices(
    lr: Schedule,
    beta: float = 0.95,
    weight_decay: float = 0.0,
    ns_steps: int = 5,
    nesterov: bool = True,
    use_muon_scale: bool = True,
    kernel_impl: str = "auto",
) -> Transform:
    """Muon over matrix leaves only (callers route 1-D leaves elsewhere)."""
    return chain(
        scale_by_muon(
            beta=beta, ns_steps=ns_steps, nesterov=nesterov,
            use_muon_scale=use_muon_scale, kernel_impl=kernel_impl,
        ),
        add_decayed_weights(weight_decay),
        scale_by_lr(lr),
    )


def default_matrix_filter(path: str, p: jax.Array) -> bool:
    """Hidden-layer matrices: >=2 trailing dims and not an embedding/head/norm."""
    if p.ndim < 2:
        return False
    lowered = path.lower()
    return not any(k in lowered for k in ("embed", "lm_head", "norm", "scale", "bias"))


def muon(
    lr: Schedule,
    beta: float = 0.95,
    weight_decay: float = 0.0,
    ns_steps: int = 5,
    adam_lr: Optional[Schedule] = None,
    matrix_filter: Callable[[str, jax.Array], bool] = default_matrix_filter,
    use_muon_scale: bool = True,
    kernel_impl: str = "auto",
) -> Transform:
    """Full Muon optimizer: Muon on hidden matrices, AdamW on the rest."""
    return with_matrix_routing(
        muon_matrices(
            lr, beta=beta, weight_decay=weight_decay, ns_steps=ns_steps,
            use_muon_scale=use_muon_scale, kernel_impl=kernel_impl,
        ),
        adamw(adam_lr if adam_lr is not None else lr, weight_decay=weight_decay),
        matrix_filter=matrix_filter,
        matrix_label="muon",
    )
