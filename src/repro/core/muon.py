"""Muon (Jordan et al., 2024) — momentum + Newton–Schulz orthogonalization.

Applies to >=2-D parameters (leading axes are treated as stacked blocks, e.g.
scan-stacked layers ``(L, m, n)``).  1-D parameters (norm scales, biases) and
anything excluded by ``matrix_filter`` fall back to AdamW, as in practice.

``use_muon_scale`` (default True, matching Jordan et al. and this module's
historical behaviour) multiplies the orthogonalized update by
:func:`repro.core.newton_schulz.muon_scale` — sqrt(max(1, m/n)) — so update
RMS is comparable across aspect ratios.  ``kernel_impl`` routes the
Newton–Schulz hot loop through the fused Pallas TPU kernels
(repro.kernels.dispatch); "auto" = Pallas on TPU, jnp reference elsewhere.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .adamw import adamw
from .api import PyTree, Schedule, Transform, multi_transform, schedule_value, tree_paths
from .newton_schulz import muon_scale, newton_schulz


class MuonState(NamedTuple):
    count: jax.Array
    mu: PyTree


def muon_matrices(
    lr: Schedule,
    beta: float = 0.95,
    weight_decay: float = 0.0,
    ns_steps: int = 5,
    nesterov: bool = True,
    use_muon_scale: bool = True,
    kernel_impl: str = "auto",
) -> Transform:
    """Muon over matrix leaves only (callers route 1-D leaves elsewhere)."""

    def init(params: PyTree) -> MuonState:
        mu = jax.tree_util.tree_map(
            lambda p: None if p is None else jnp.zeros_like(p, dtype=jnp.float32),
            params,
            is_leaf=lambda x: x is None,
        )
        return MuonState(count=jnp.zeros((), jnp.int32), mu=mu)

    def update(grads: PyTree, state: MuonState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)

        def upd(g, mu, p):
            if g is None:
                return None, None
            g32 = g.astype(jnp.float32)
            mu = beta * mu + g32
            mom = beta * mu + g32 if nesterov else mu
            o = newton_schulz(mom, steps=ns_steps, impl=kernel_impl)
            scale = muon_scale(p.shape) if use_muon_scale else 1.0
            u = -step_lr * (
                scale * o + weight_decay * p.astype(jnp.float32)
            )
            return u, mu

        flat = jax.tree_util.tree_map(upd, grads, state.mu, params, is_leaf=lambda x: x is None)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
        updates = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=is_pair)
        mu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_pair)
        return updates, MuonState(count=count, mu=mu)

    return Transform(init, update)


def default_matrix_filter(path: str, p: jax.Array) -> bool:
    """Hidden-layer matrices: >=2 trailing dims and not an embedding/head/norm."""
    if p.ndim < 2:
        return False
    lowered = path.lower()
    return not any(k in lowered for k in ("embed", "lm_head", "norm", "scale", "bias"))


def muon(
    lr: Schedule,
    beta: float = 0.95,
    weight_decay: float = 0.0,
    ns_steps: int = 5,
    adam_lr: Optional[Schedule] = None,
    matrix_filter: Callable[[str, jax.Array], bool] = default_matrix_filter,
    use_muon_scale: bool = True,
    kernel_impl: str = "auto",
) -> Transform:
    """Full Muon optimizer: Muon on hidden matrices, AdamW on the rest."""
    inner = {
        "muon": muon_matrices(lr, beta=beta, weight_decay=weight_decay,
                              ns_steps=ns_steps, use_muon_scale=use_muon_scale,
                              kernel_impl=kernel_impl),
        "adamw": adamw(adam_lr if adam_lr is not None else lr, weight_decay=weight_decay),
    }

    def label_fn(params: PyTree) -> PyTree:
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: "muon" if matrix_filter(path, p) else "adamw", paths, params
        )

    return multi_transform(inner, label_fn)
