"""Optimizer factory: OptimizerConfig -> combinator-composed Transform.

Every named optimizer resolves to a :mod:`repro.core.combinators` chain
(built by the thin shims in gum/galore/fira/muon/adamw) — public names and
signatures are unchanged from the monolith era, and the equivalence suite
(tests/test_combinators.py) proves loss-for-loss parity against
:mod:`repro.core.legacy`.

``cfg.kernel_impl`` is forwarded to every optimizer with a low-rank /
Newton–Schulz hot loop (gum, galore, galore_muon, golore, fira, muon,
unbiased_galore_adam); ``cfg.pad_rank_to`` and the family-fusion knobs
(``cfg.fuse_families`` / ``cfg.fused_epilogue``) to every low-rank
optimizer; ``cfg.use_muon_scale`` (None = per-optimizer default) to muon
and gum.
"""
from __future__ import annotations

from .adamw import adamw, sgdm
from .api import OptimizerConfig, Transform
from .fira import fira
from .galore import galore, golore
from .gum import gum, unbiased_galore_adam
from .lisa import lisa
from .muon import muon


def _fusion_kw(cfg: OptimizerConfig) -> dict:
    return {"fuse_families": cfg.fuse_families,
            "fused_epilogue": cfg.fused_epilogue}


def build_optimizer(cfg: OptimizerConfig) -> Transform:
    name = cfg.name.lower()
    if name == "adamw":
        return adamw(cfg.lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, weight_decay=cfg.weight_decay)
    if name == "sgdm":
        return sgdm(cfg.lr, beta=cfg.beta, weight_decay=cfg.weight_decay)
    if name == "muon":
        kw = {} if cfg.use_muon_scale is None else {"use_muon_scale": cfg.use_muon_scale}
        return muon(cfg.lr, beta=cfg.beta, weight_decay=cfg.weight_decay,
                    ns_steps=cfg.ns_steps, kernel_impl=cfg.kernel_impl, **kw)
    if name == "galore":
        return galore(
            cfg.lr, rank=cfg.rank, period=cfg.period, projector=cfg.projector,
            base="adam", weight_decay=cfg.weight_decay, seed=cfg.seed,
            kernel_impl=cfg.kernel_impl, pad_rank_to=cfg.pad_rank_to,
            **_fusion_kw(cfg),
        )
    if name == "galore_muon":
        return galore(
            cfg.lr, rank=cfg.rank, period=cfg.period, projector=cfg.projector,
            base="muon", beta=cfg.beta, ns_steps=cfg.ns_steps,
            weight_decay=cfg.weight_decay, seed=cfg.seed,
            kernel_impl=cfg.kernel_impl, pad_rank_to=cfg.pad_rank_to,
            **_fusion_kw(cfg),
        )
    if name == "golore":
        return golore(cfg.lr, rank=cfg.rank, period=cfg.period, base=cfg.base,
                      seed=cfg.seed, kernel_impl=cfg.kernel_impl,
                      pad_rank_to=cfg.pad_rank_to, **_fusion_kw(cfg))
    if name == "gum":
        kw = {} if cfg.use_muon_scale is None else {"use_muon_scale": cfg.use_muon_scale}
        return gum(
            cfg.lr, rank=cfg.rank, gamma=cfg.gamma, period=cfg.period,
            projector=cfg.projector, base=cfg.base, beta=cfg.beta,
            ns_steps=cfg.ns_steps, weight_decay=cfg.weight_decay,
            compensation=cfg.compensation, seed=cfg.seed,
            kernel_impl=cfg.kernel_impl, pad_rank_to=cfg.pad_rank_to,
            **_fusion_kw(cfg), **kw,
        )
    if name == "unbiased_galore_adam":
        return unbiased_galore_adam(
            cfg.lr, rank=cfg.rank, gamma=cfg.gamma, period=cfg.period,
            projector=cfg.projector, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
            weight_decay=cfg.weight_decay, compensation=cfg.compensation,
            seed=cfg.seed, kernel_impl=cfg.kernel_impl,
            pad_rank_to=cfg.pad_rank_to, **_fusion_kw(cfg),
        )
    if name == "fira":
        return fira(cfg.lr, rank=cfg.rank, period=cfg.period, seed=cfg.seed,
                    kernel_impl=cfg.kernel_impl, pad_rank_to=cfg.pad_rank_to,
                    **_fusion_kw(cfg))
    if name == "lisa":
        return lisa(cfg.lr, gamma=cfg.gamma, period=cfg.period, seed=cfg.seed)
    raise ValueError(f"unknown optimizer: {cfg.name!r}")
