"""Optimizer factory: OptimizerConfig -> combinator-composed Transform.

Every named optimizer resolves to a :mod:`repro.core.combinators` chain
(built by the thin shims in gum/galore/fira/muon/adamw) — public names and
signatures are unchanged from the monolith era, and the recorded-trajectory
suite (tests/test_legacy_fixtures.py) proves loss-for-loss parity against
the deleted monoliths.

``cfg.kernel_impl`` is forwarded to every optimizer with a low-rank /
Newton–Schulz hot loop (gum, galore, galore_muon, golore, fira, muon,
unbiased_galore_adam); ``cfg.pad_rank_to`` and the family-fusion knobs
(``cfg.fuse_families`` / ``cfg.fused_epilogue``) to every low-rank
optimizer; ``cfg.use_muon_scale`` (None = per-optimizer default) to muon
and gum.

``cfg.rank_policy`` / ``cfg.rank_ladder`` (see :mod:`repro.core.rank_policy`)
make rank a per-family, time-varying quantity: the policy supplies the
initial RankMap (and spectrum probing for adaptive policies); a live run's
:class:`~repro.core.rank_policy.RankPolicyController` rebuilds the chain at
each new assignment through :func:`build_optimizer`'s ``rank_map`` override.
"""
from __future__ import annotations

from typing import Optional

from .adamw import adamw, sgdm
from .api import OptimizerConfig, Transform
from .fira import fira
from .galore import galore, golore
from .gum import gum, unbiased_galore_adam
from .lisa import lisa
from .muon import muon
from .rank_policy import RankMap, RankPolicy, as_policy


def resolve_rank_policy(cfg: OptimizerConfig) -> Optional[RankPolicy]:
    """``cfg.rank_policy`` (None | spec string | RankPolicy) resolved to a
    policy object, with ``cfg.rank_ladder`` / ``cfg.rank`` as the ladder
    bounds for adaptive specs."""
    ladder = tuple(cfg.rank_ladder or ())
    return as_policy(
        cfg.rank_policy, ladder=ladder,
        r_min=min(ladder) if ladder else 8,
        r_max=max(ladder) if ladder else max(int(cfg.rank), 8),
    )


def _fusion_kw(cfg: OptimizerConfig) -> dict:
    return {"fuse_families": cfg.fuse_families,
            "fused_epilogue": cfg.fused_epilogue,
            "telemetry": cfg.telemetry}


def build_optimizer(
    cfg: OptimizerConfig, rank_map: Optional[RankMap] = None,
    *, audit: bool = False,
) -> Transform:
    """``rank_map`` overrides the rank assignment for this build — the
    :class:`~repro.core.rank_policy.RankPolicyController` re-entry point
    (``lambda m: build_optimizer(cfg, rank_map=m)``).  Without it the rank
    is ``cfg.rank`` (or the policy's initial map when one is configured).

    ``audit=True`` runs the static chain linter
    (:func:`repro.analysis.chain_lint.lint_chain`) on the composed chain and
    raises :class:`repro.analysis.chain_lint.ChainLintError` on any
    error-severity finding — malformed compositions fail at build time with
    a lint code and fix-it hint instead of a TypeError mid-step."""
    transform = _compose(cfg, rank_map)
    if audit:
        # Lazy import: repro.analysis sits on top of this module.
        from repro.analysis.chain_lint import ChainLintError, lint_chain

        findings = lint_chain(transform, ladder=cfg.rank_ladder,
                              name=cfg.name.lower())
        errors = [f for f in findings if f.severity == "error"]
        if errors:
            raise ChainLintError(errors)
    return transform


def _compose(cfg: OptimizerConfig, rank_map: Optional[RankMap]) -> Transform:
    name = cfg.name.lower()
    policy = resolve_rank_policy(cfg)
    rank = rank_map if rank_map is not None else cfg.rank
    rank_kw = {"rank": rank, "rank_policy": policy}
    if name == "adamw":
        return adamw(cfg.lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, weight_decay=cfg.weight_decay)
    if name == "sgdm":
        return sgdm(cfg.lr, beta=cfg.beta, weight_decay=cfg.weight_decay)
    if name == "muon":
        kw = {} if cfg.use_muon_scale is None else {"use_muon_scale": cfg.use_muon_scale}
        return muon(cfg.lr, beta=cfg.beta, weight_decay=cfg.weight_decay,
                    ns_steps=cfg.ns_steps, kernel_impl=cfg.kernel_impl, **kw)
    if name == "galore":
        return galore(
            cfg.lr, period=cfg.period, projector=cfg.projector,
            base="adam", weight_decay=cfg.weight_decay, seed=cfg.seed,
            kernel_impl=cfg.kernel_impl, pad_rank_to=cfg.pad_rank_to,
            **_fusion_kw(cfg), **rank_kw,
        )
    if name == "galore_muon":
        return galore(
            cfg.lr, period=cfg.period, projector=cfg.projector,
            base="muon", beta=cfg.beta, ns_steps=cfg.ns_steps,
            weight_decay=cfg.weight_decay, seed=cfg.seed,
            kernel_impl=cfg.kernel_impl, pad_rank_to=cfg.pad_rank_to,
            **_fusion_kw(cfg), **rank_kw,
        )
    if name == "golore":
        return golore(cfg.lr, period=cfg.period, base=cfg.base,
                      seed=cfg.seed, kernel_impl=cfg.kernel_impl,
                      pad_rank_to=cfg.pad_rank_to, **_fusion_kw(cfg),
                      **rank_kw)
    if name == "gum":
        kw = {} if cfg.use_muon_scale is None else {"use_muon_scale": cfg.use_muon_scale}
        return gum(
            cfg.lr, gamma=cfg.gamma, period=cfg.period,
            projector=cfg.projector, base=cfg.base, beta=cfg.beta,
            ns_steps=cfg.ns_steps, weight_decay=cfg.weight_decay,
            compensation=cfg.compensation, seed=cfg.seed,
            kernel_impl=cfg.kernel_impl, pad_rank_to=cfg.pad_rank_to,
            **_fusion_kw(cfg), **rank_kw, **kw,
        )
    if name == "unbiased_galore_adam":
        return unbiased_galore_adam(
            cfg.lr, gamma=cfg.gamma, period=cfg.period,
            projector=cfg.projector, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
            weight_decay=cfg.weight_decay, compensation=cfg.compensation,
            seed=cfg.seed, kernel_impl=cfg.kernel_impl,
            pad_rank_to=cfg.pad_rank_to, **_fusion_kw(cfg), **rank_kw,
        )
    if name == "fira":
        return fira(cfg.lr, period=cfg.period, seed=cfg.seed,
                    kernel_impl=cfg.kernel_impl, pad_rank_to=cfg.pad_rank_to,
                    **_fusion_kw(cfg), **rank_kw)
    if name == "lisa":
        return lisa(cfg.lr, gamma=cfg.gamma, period=cfg.period, seed=cfg.seed)
    raise ValueError(f"unknown optimizer: {cfg.name!r}")
