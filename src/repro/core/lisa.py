"""LISA (Pan et al., 2024) — layerwise importance sampling.

The origin of the paper's debiasing idea: per period, sample gamma layers and
train ONLY those (full AdamW), freezing the rest.  Embeddings / norms / head
are always trained.  Included as a baseline and as the conceptual ancestor of
GUM's full-rank branch.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .adamw import adamw
from .api import PyTree, Schedule, Transform, tree_paths
from .lowrank_common import default_lowrank_filter, family_shape


class LISAState(NamedTuple):
    count: jax.Array
    inner: PyTree  # AdamW state over all params
    # per-family active-layer indices live in `masks` keyed like params
    masks: PyTree


def lisa(
    lr: Schedule,
    gamma: int = 2,
    period: int = 200,
    seed: int = 0,
    layer_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    **adam_kw,
) -> Transform:
    base = adamw(lr, **adam_kw)

    def init(params: PyTree) -> LISAState:
        paths = tree_paths(params)

        def init_mask(path, p):
            if not layer_filter(path, p):
                return None  # always trained
            fs = family_shape(p, rank=1)
            return jnp.zeros(fs.lead if fs.lead else (1,), bool)

        masks = jax.tree_util.tree_map(init_mask, paths, params)
        return LISAState(count=jnp.zeros((), jnp.int32), inner=base.init(params), masks=masks)

    def update(grads: PyTree, state: LISAState, params: PyTree):
        count = state.count + 1
        refresh = (count - 1) % period == 0
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), (count - 1) // period)

        leaves, treedef = jax.tree_util.tree_flatten(
            state.masks, is_leaf=lambda x: x is None
        )
        new_masks = []
        for i, mask in enumerate(leaves):
            if mask is None:
                new_masks.append(None)
                continue
            L = mask.size
            g_f = min(gamma, L)
            key = jax.random.fold_in(base_key, i)
            idx = jax.random.choice(key, L, (g_f,), replace=False)
            fresh = jnp.zeros((L,), bool).at[idx].set(True).reshape(mask.shape)
            new_masks.append(jnp.where(refresh, fresh, mask))
        masks = jax.tree_util.tree_unflatten(treedef, new_masks)

        # Zero out gradients of frozen layers, then run AdamW.
        def mask_grad(g, m, p):
            if g is None:
                return None
            if m is None:
                return g
            fs = family_shape(p, rank=1)
            mm = m.reshape(fs.lead + (1, 1)) if fs.lead else m.reshape(())
            return g * mm.astype(g.dtype)

        masked = jax.tree_util.tree_map(
            mask_grad, grads, masks, params, is_leaf=lambda x: x is None
        )
        updates, inner = base.update(masked, state.inner, params)
        # Also zero the *updates* of frozen layers (AdamW momentum of frozen
        # layers keeps decaying; LISA freezes params entirely).
        updates = jax.tree_util.tree_map(
            mask_grad, updates, masks, params, is_leaf=lambda x: x is None
        )
        return updates, LISAState(count=count, inner=inner, masks=masks)

    update.chain_info = {"kind": "lisa", "gamma": gamma, "period": period,
                         "inner": dict(getattr(base.update, "chain_info",
                                               None) or {"kind": "opaque"})}
    return Transform(init, update)
