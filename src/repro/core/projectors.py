"""Low-rank projector constructions.

Every projector returns ``P in R^{m x r}`` with exactly orthonormal columns
(Property I of the paper: ``P^T P = I_r``).  Property I is what the unbiased
paradigm (Algorithm 3) needs — the *choice* of subspace only affects how much
of the gradient energy the low-rank branch captures, never unbiasedness.

Projectors:
  * ``svd``       — GaLore's top-r left singular vectors, ``U[:, :r]``.
  * ``subspace``  — randomized subspace (power) iteration; matmul + thin-QR
                    only.  TPU-native replacement for LAPACK SVD (DESIGN.md §3).
  * ``rsvd``      — randomized range finder (Halko et al.; the AdaRankGrad
                    refresh): ONE Gaussian sketch + one thin QR, no power
                    iterations — the cheapest gradient-aware refresh, so the
                    periodic projector recomputation stops paying a full
                    per-leaf float32 SVD.
  * ``random``    — GoLore's projector: orthonormalized Gaussian, independent
                    of the gradient.
  * ``grass``     — GRASS-style: rows sampled proportional to row norms;
                    columns of P are scaled one-hot vectors (orthonormal).

All functions operate on a single block ``G in R^{m x n}`` (``m <= n`` is NOT
assumed; we project the shorter side — see :func:`projection_side`).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

ProjectorKind = Literal["svd", "subspace", "rsvd", "random", "grass"]


def projection_side(shape: tuple[int, int]) -> str:
    """GaLore projects the smaller dimension: 'left' if m <= n else 'right'.

    'left'  : P in R^{m x r};   low-rank state is  P^T G in R^{r x n}
    'right' : P in R^{n x r};   low-rank state is  G P  in R^{m x r}
    """
    m, n = shape
    return "left" if m <= n else "right"


def svd_projector(g: jax.Array, rank: int) -> jax.Array:
    """Top-``rank`` left singular vectors of ``g`` (GaLore's projector)."""
    u, _, _ = jnp.linalg.svd(g.astype(jnp.float32), full_matrices=False)
    return u[:, :rank]


def subspace_projector(
    g: jax.Array, rank: int, key: jax.Array, *, iters: int = 2
) -> jax.Array:
    """Randomized subspace iteration: orth((G Gᵀ)^iters G Ω).

    Matmul-only sketch of the dominant left subspace; converges to the top-r
    singular subspace geometrically in the spectral-gap ratio.  Uses a thin QR
    on an (m, r) matrix, which is cheap relative to a full SVD and MXU-friendly.
    """
    m, n = g.shape
    g32 = g.astype(jnp.float32)
    omega = jax.random.normal(key, (n, rank), dtype=jnp.float32)
    y = g32 @ omega  # (m, r)
    for _ in range(iters):
        # Re-orthonormalize between power steps for numerical stability.
        y, _ = jnp.linalg.qr(y)
        y = g32 @ (g32.T @ y)
    q, _ = jnp.linalg.qr(y)
    return q


def rsvd_projector(g: jax.Array, rank: int, key: jax.Array) -> jax.Array:
    """Randomized range finder: ``orth(G Ω)``, Ω Gaussian ``(n, r)``.

    The zero-power-iteration member of the randomized-SVD family: one sketch
    GEMM plus one thin QR on an ``(m, r)`` matrix captures the dominant left
    range of ``G`` up to the tail-energy bound of Halko et al. (2011, Thm
    10.5) — no spectral-gap-dependent convergence loop, no LAPACK SVD.
    Property I (orthonormal columns) holds exactly via the QR, so
    unbiasedness of the sampling paradigm is untouched; only the captured
    gradient energy differs from ``svd``/``subspace``.  Mathematically this
    IS the subspace projector with zero power iterations — delegated so the
    sketch/QR math lives in exactly one place."""
    return subspace_projector(g, rank, key, iters=0)


def random_projector(shape: tuple[int, int], rank: int, key: jax.Array) -> jax.Array:
    """GoLore's gradient-independent random orthonormal projector."""
    m, _ = shape
    z = jax.random.normal(key, (m, rank), dtype=jnp.float32)
    q, _ = jnp.linalg.qr(z)
    return q


def grass_projector(g: jax.Array, rank: int, key: jax.Array) -> jax.Array:
    """GRASS-style sparse projector: sample ``rank`` rows ∝ row norms.

    P's columns are (scaled) one-hot row indicators, so P is orthonormal by
    construction; P^T G selects/reweights rows of G.  We sample *without*
    replacement via Gumbel top-k on the log-norm scores.
    """
    m, _ = g.shape
    row_norms = jnp.linalg.norm(g.astype(jnp.float32), axis=1)
    logits = jnp.log(row_norms + 1e-30)
    gumbel = jax.random.gumbel(key, (m,))
    _, idx = jax.lax.top_k(logits + gumbel, rank)
    p = jax.nn.one_hot(idx, m, dtype=jnp.float32).T  # (m, rank)
    return p


def make_projector(
    kind: ProjectorKind,
    g: jax.Array,
    rank: int,
    key: jax.Array,
    *,
    subspace_iters: int = 2,
) -> jax.Array:
    """Dispatch; all return (m, rank) with orthonormal columns."""
    if kind == "svd":
        return svd_projector(g, rank)
    if kind == "subspace":
        return subspace_projector(g, rank, key, iters=subspace_iters)
    if kind == "rsvd":
        return rsvd_projector(g, rank, key)
    if kind == "random":
        return random_projector(g.shape, rank, key)
    if kind == "grass":
        return grass_projector(g, rank, key)
    raise ValueError(f"unknown projector kind: {kind!r}")


@functools.partial(jax.jit, static_argnames=("rank", "kind", "subspace_iters"))
def jit_make_projector(
    kind: ProjectorKind, g: jax.Array, rank: int, key: jax.Array, subspace_iters: int = 2
) -> jax.Array:
    return make_projector(kind, g, rank, key, subspace_iters=subspace_iters)
