"""Shared machinery for blockwise low-rank optimizers (GaLore / GUM / GoLore).

A *family* is one pytree leaf of shape ``(*lead, m, n)`` whose leading dims
are stacked blocks (scan-stacked layers ``(L, m, n)``, stacked MoE experts
``(L, E, m, n)``).  All per-block linear algebra is expressed with
leading-ellipsis einsums and batched QR/SVD — NEVER a reshape that merges a
leading (possibly expert-sharded) dim into the block count, because GSPMD
cannot repartition such reshapes without a full rematerialization (observed
as "[SPMD] Involuntary full rematerialization" on MoE cells).

The projector ``P`` acts on the shorter matrix side per GaLore:
  left  (m <= n): state = Pᵀ G in (*lead, r, n);  back-projection  P @ S
  right (m >  n): state = G P in (*lead, m, r);   back-projection  S @ Pᵀ

The per-step hot loop (momentum update / projection) is dispatched through
:func:`lowrank_momentum_update` / :func:`project_dispatched`, whose
``kernel_impl`` knob ("auto" | "jnp" | "pallas" | "interpret") selects the
fused Pallas TPU kernels or the jnp reference (see repro.kernels.dispatch).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .rank_policy import resolve_rank


class FamilyShape(NamedTuple):
    lead: tuple[int, ...]  # leading block dims
    L: int                 # total block count = prod(lead)
    m: int
    n: int
    side: str              # "left" | "right"
    rank: int


def family_shape(p: jax.Array, rank) -> FamilyShape:
    """``rank`` is an int or a per-shape assignment (``rank_policy.RankMap``,
    duck-typed via ``rank_for(m, n)``) — the rank-policy engine threads one
    map through every call site that used to take a single static int."""
    if p.ndim < 2:
        raise ValueError(f"low-rank families need >=2 dims, got {p.shape}")
    m, n = int(p.shape[-2]), int(p.shape[-1])
    lead = tuple(int(d) for d in p.shape[:-2])
    L = 1
    for d in lead:
        L *= d
    side = "left" if m <= n else "right"
    rank = min(resolve_rank(rank, m, n), m, n)
    return FamilyShape(lead=lead, L=L, m=m, n=n, side=side, rank=rank)


def proj_dim(fs: FamilyShape) -> int:
    """Dim P projects: m for left, n for right."""
    return fs.m if fs.side == "left" else fs.n


def proj_shape(fs: FamilyShape) -> tuple[int, ...]:
    return fs.lead + (proj_dim(fs), fs.rank)


def lowrank_state_shape(fs: FamilyShape) -> tuple[int, ...]:
    """(*lead, r, n) for left, (*lead, m, r) for right."""
    if fs.side == "left":
        return fs.lead + (fs.rank, fs.n)
    return fs.lead + (fs.m, fs.rank)


def stack_shardable(L: int, n_shards: int) -> bool:
    """Whether an ``(L, ...)`` family stack partitions evenly over
    ``n_shards`` data shards.  This single predicate is applied by BOTH the
    runtime (the sharded projector refresh in ``combinators``) and the
    closed-form collective-schedule model (``repro.analysis.collectives``) —
    keeping them one rule is what makes the audited boundary-gather count
    always match what actually traces.  Non-divisible families stay
    replicated (no gather) rather than padding the stack."""
    return n_shards >= 1 and L % n_shards == 0


def stacked_grad_bytes(fs: FamilyShape) -> int:
    """fp32 bytes of one family's stacked gradient ``(L, m, n)`` — the
    operand of the boundary ``all_gather`` in the sharded fused step (the
    refresh gathers the gradient, never the moments)."""
    return fs.L * fs.m * fs.n * 4


def project(p: jax.Array, g: jax.Array, side: str) -> jax.Array:
    """Low-rank projection. p: (*lead, s, r), g: (*lead, m, n)."""
    if side == "left":
        return jnp.einsum("...mr,...mn->...rn", p, g)
    return jnp.einsum("...mn,...nr->...mr", g, p)


def back_project(p: jax.Array, s: jax.Array, side: str) -> jax.Array:
    """Back-projection of low-rank states to (*lead, m, n)."""
    if side == "left":
        return jnp.einsum("...mr,...rn->...mn", p, s)
    return jnp.einsum("...mr,...nr->...mn", s, p)


def reconstruct(p: jax.Array, g: jax.Array, side: str) -> jax.Array:
    """P Pᵀ G (left) or G P Pᵀ (right): the biased low-rank gradient."""
    return back_project(p, project(p, g, side), side)


def lowrank_momentum_update(
    p: jax.Array,
    g: jax.Array,
    r_state: jax.Array,
    beta: float,
    coeff: float,
    side: str,
    kernel_impl: str = "jnp",
) -> jax.Array:
    """The per-step hot loop ``R' = beta·R + coeff·⟨P, G⟩`` with kernel
    dispatch: ``kernel_impl`` routes to the fused Pallas kernel (TPU, or the
    interpreter off-TPU for "pallas"/"interpret") or the jnp einsum path
    ("jnp"; also what "auto" resolves to off-TPU).  All impls agree within
    fp32 roundoff; the jnp path is bit-identical to the pre-dispatch code."""
    from repro.kernels import dispatch  # lazy: kernels imports this module's peers

    return dispatch.lowrank_update(
        p, g, r_state, beta, coeff, side=side, impl=kernel_impl
    )


def project_dispatched(
    p: jax.Array, g: jax.Array, side: str, kernel_impl: str = "jnp"
) -> jax.Array:
    """``project`` routed through the projection kernel when requested —
    used by the Adam-based low-rank optimizers that need the projected
    gradient itself (for second moments / residuals)."""
    from repro.kernels import dispatch

    return dispatch.project(p, g, side=side, impl=kernel_impl)


def block_index(idx: jax.Array, fs: FamilyShape):
    """Flat block ids (gamma,) -> tuple of per-lead-dim index arrays usable
    for advanced-indexing gather/scatter on the UNreshaped leaf."""
    if len(fs.lead) == 1:
        return (idx,)
    return jnp.unravel_index(idx, fs.lead)


def gather_blocks(x: jax.Array, idx: jax.Array, fs: FamilyShape) -> jax.Array:
    """(*lead, a, b) -> (gamma, a, b) without reshaping the source."""
    if not fs.lead:  # single-block family: gamma is necessarily 1
        return x[None]
    return x[block_index(idx, fs)]


def scatter_blocks(x: jax.Array, idx: jax.Array, vals: jax.Array, fs: FamilyShape) -> jax.Array:
    if not fs.lead:
        return vals[0]
    return x.at[block_index(idx, fs)].set(vals)


def compute_projectors(
    kind: str,
    g: jax.Array,
    rank: int,
    key: jax.Array,
    side: str,
    subspace_iters: int = 2,
) -> jax.Array:
    """Batched per-block projectors; returns (*lead, s, rank), orthonormal
    columns per block (Property I).  Uses batched QR/SVD — no reshapes."""
    if side == "right":
        g = jnp.swapaxes(g, -1, -2)
    g32 = g.astype(jnp.float32)
    lead = g.shape[:-2]
    m, n = g.shape[-2], g.shape[-1]

    if kind == "svd":
        u, _, _ = jnp.linalg.svd(g32, full_matrices=False)
        return u[..., :, :rank]
    if kind in ("subspace", "rsvd"):
        # "rsvd" is the randomized range finder (Halko et al.): the
        # zero-power-iteration member of the subspace family, so refresh
        # costs one sketch GEMM + one thin QR instead of a full per-block
        # float32 SVD (see projectors.rsvd_projector).
        iters = 0 if kind == "rsvd" else subspace_iters
        omega = jax.random.normal(key, lead + (n, rank), jnp.float32)
        y = g32 @ omega
        for _ in range(iters):
            y, _ = jnp.linalg.qr(y)
            y = g32 @ (jnp.swapaxes(g32, -1, -2) @ y)
        q, _ = jnp.linalg.qr(y)
        return q
    if kind == "random":
        z = jax.random.normal(key, lead + (m, rank), jnp.float32)
        q, _ = jnp.linalg.qr(z)
        return q
    if kind == "grass":
        row_norms = jnp.linalg.norm(g32, axis=-1)  # (*lead, m)
        logits = jnp.log(row_norms + 1e-30)
        gumbel = jax.random.gumbel(key, logits.shape)
        _, idx = jax.lax.top_k(logits + gumbel, rank)  # (*lead, rank)
        p = jax.nn.one_hot(idx, m, dtype=jnp.float32)  # (*lead, rank, m)
        return jnp.swapaxes(p, -1, -2)
    raise ValueError(f"unknown projector kind: {kind!r}")


def default_lowrank_filter(path: str, p) -> bool:
    """Which leaves get low-rank treatment: hidden matrices, like GaLore's
    target-module convention (attention + MLP kernels).  Embeddings / head /
    norms / biases / routers / conv taps / per-layer vector stacks fall
    through to the base/fallback optimizer."""
    if p.ndim < 2:
        return False
    if min(int(p.shape[-1]), int(p.shape[-2])) < 8:
        return False  # per-layer vectors stacked into 2-D, conv taps, gates
    lowered = path.lower()
    return not any(
        k in lowered
        for k in ("embed", "lm_head", "norm", "scale", "bias",
                  "conv_w", "skip_d", "a_log", "router")
    )
