"""repro.core — the paper's contribution: unbiased gradient low-rank projection.

The package is organised around *composable gradient transforms*
(:mod:`repro.core.combinators`, optax-style): the paper's central claim —
layerwise sampling debiases ANY low-rank projection mechanism — is the API
itself, not a family of monolithic optimizer files.

Combinator API (the building blocks):
  * chain(*transforms)            — sequential composition
  * scale_by_momentum / scale_by_adam / scale_by_muon — base directions
  * add_decayed_weights / scale_by_lr / scale_by_factor — tail transforms
  * lowrank(inner, ...)           — periodic-refresh low-rank projection
                                    wrapper (svd|subspace|random|grass),
                                    project/back-project through the Pallas
                                    kernel dispatch layer
  * layerwise_unbias(base, ...)   — the paper's sampling debiasing (gamma
                                    full-rank slots, paper/finetune
                                    compensation) as an independent wrapper
  * with_fira_residual(base, ...) — Fira's out-of-subspace residual
  * with_matrix_routing(m, f)     — hidden-matrix vs fallback label routing

Named optimizers (thin shims over the combinators, signatures unchanged):
  * gum / gum_matrices            — Algorithm 2:
                                    lowrank(layerwise_unbias(scale_by_muon))
  * unbiased_galore_adam          — NEW: layerwise_unbias(scale_by_adam) —
                                    an unbiased variant that is a one-line
                                    composition, not a file
  * unbiased_lowrank              — Algorithm 3 (general Bernoulli paradigm,
                                    reference semantics)
  * galore / galore_muon / golore — Algorithm 1 baselines: lowrank(base)
  * muon / adamw / sgdm / fira / lisa — paper baselines
  * projectors (svd | subspace | random | grass), newton_schulz
  * build_optimizer(OptimizerConfig)

Migration note (PR 2): optimizer *state* pytrees changed shape — a named
optimizer's state is now the tuple of its chain stages (e.g. gum:
``MultiState(inner={"gum": (LowRankState, (), ScaleByLrState), "adamw":
(ScaleByAdamState, (), ScaleByLrState)})``).  Checkpoints from the monolith
era do not restore into the new layout.  Trajectories are preserved
loss-for-loss against the deleted pre-redesign monoliths via the recorded
fixtures in tests/test_legacy_fixtures.py.
"""
from .adamw import adamw, sgdm
from .api import (
    OptimizerConfig,
    Transform,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    multi_transform,
    state_bytes,
    tree_paths,
)
from .combinators import (
    FullUpdate,
    LayerwiseUnbiasState,
    LowRankState,
    PendingBack,
    ProjGrad,
    add_decayed_weights,
    chain,
    chain_info,
    find_lowrank_states,
    layerwise_unbias,
    lowrank,
    materialize_pending,
    scale_by_adam,
    scale_by_factor,
    scale_by_lr,
    scale_by_momentum,
    scale_by_muon,
    with_fira_residual,
    with_matrix_routing,
)
from .factory import build_optimizer, resolve_rank_policy
from .family_plan import FamilyPlan, StackSeg, build_family_plan
from .fira import fira, fira_matrices
from .galore import galore, galore_matrices, golore
from .gum import gum, gum_accum_tools, gum_matrices, unbiased_galore_adam
from .lisa import lisa
from .lowrank_common import default_lowrank_filter
from .muon import muon, muon_matrices
from .newton_schulz import msign_exact, muon_scale, newton_schulz
from .projectors import (
    grass_projector,
    make_projector,
    random_projector,
    rsvd_projector,
    subspace_projector,
    svd_projector,
)
from .rank_policy import (
    RankMap,
    RankPolicy,
    RankPolicyController,
    gather_probes,
    migrate_opt_state,
    parse_rank_policy,
)
from . import rank_policy
from .schedules import constant, linear_warmup, warmup_cosine
from .unbiased import unbiased_lowrank

__all__ = [
    "FamilyPlan", "FullUpdate", "LayerwiseUnbiasState", "LowRankState",
    "OptimizerConfig", "PendingBack", "ProjGrad", "RankMap", "RankPolicy",
    "RankPolicyController", "StackSeg", "Transform",
    "adamw", "add_decayed_weights", "apply_updates", "build_family_plan",
    "build_optimizer", "chain", "chain_info", "clip_by_global_norm",
    "constant",
    "default_lowrank_filter", "find_lowrank_states", "fira", "fira_matrices",
    "galore", "galore_matrices", "gather_probes", "global_norm", "golore",
    "grass_projector",
    "gum", "gum_accum_tools", "gum_matrices", "layerwise_unbias",
    "linear_warmup", "lisa", "lowrank", "make_projector",
    "materialize_pending", "migrate_opt_state", "msign_exact",
    "multi_transform", "muon",
    "muon_matrices", "muon_scale", "newton_schulz", "parse_rank_policy",
    "random_projector", "rank_policy", "resolve_rank_policy",
    "rsvd_projector", "scale_by_adam", "scale_by_factor", "scale_by_lr",
    "scale_by_momentum", "scale_by_muon", "sgdm", "state_bytes",
    "subspace_projector", "svd_projector", "tree_paths",
    "unbiased_galore_adam", "unbiased_lowrank", "warmup_cosine",
    "with_fira_residual", "with_matrix_routing",
]
