"""repro.core — the paper's contribution: unbiased gradient low-rank projection.

Public API:
  * gum / gum_matrices            — Algorithm 2 (GaLore Unbiased with Muon)
  * unbiased_lowrank              — Algorithm 3 (general Bernoulli paradigm)
  * galore / galore_muon / golore — Algorithm 1 baselines
  * muon / adamw / sgdm / fira / lisa — paper baselines
  * projectors (svd | subspace | random | grass), newton_schulz
  * build_optimizer(OptimizerConfig)
"""
from .adamw import adamw, sgdm
from .api import (
    OptimizerConfig,
    Transform,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    multi_transform,
    state_bytes,
    tree_paths,
)
from .factory import build_optimizer
from .fira import fira
from .galore import galore, galore_matrices, golore
from .gum import gum, gum_matrices
from .lisa import lisa
from .lowrank_common import default_lowrank_filter
from .muon import muon, muon_matrices
from .newton_schulz import msign_exact, muon_scale, newton_schulz
from .projectors import (
    grass_projector,
    make_projector,
    random_projector,
    subspace_projector,
    svd_projector,
)
from .schedules import constant, linear_warmup, warmup_cosine
from .unbiased import unbiased_lowrank

__all__ = [
    "OptimizerConfig", "Transform", "adamw", "apply_updates", "build_optimizer",
    "clip_by_global_norm", "constant", "default_lowrank_filter", "fira", "galore",
    "galore_matrices", "global_norm", "golore", "grass_projector", "gum",
    "gum_matrices", "linear_warmup", "lisa", "make_projector", "msign_exact",
    "multi_transform", "muon", "muon_matrices", "muon_scale", "newton_schulz",
    "random_projector", "sgdm", "state_bytes", "subspace_projector",
    "svd_projector", "tree_paths", "unbiased_lowrank", "warmup_cosine",
]
