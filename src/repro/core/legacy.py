"""FROZEN pre-combinator reference implementations (PR 2).

Verbatim copies of the monolithic optimizers that `repro.core` shipped
before the combinator redesign (gum.py / galore.py / fira.py / muon.py /
adamw.py as of PR 1), kept ONLY as the ground truth for

  * tests/test_combinators.py — the loss-for-loss equivalence suite proving
    the combinator-built optimizers reproduce the legacy trajectories, and
  * benchmarks/optimizer_api.py — the chained-vs-monolithic overhead table.

Never import this module from production code; it will be deleted once the
combinator API has soaked for a few PRs.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .api import PyTree, Schedule, Transform, multi_transform, schedule_value, tree_paths
from .lowrank_common import (
    back_project,
    compute_projectors,
    default_lowrank_filter,
    family_shape,
    gather_blocks,
    lowrank_momentum_update,
    lowrank_state_shape,
    project,
    proj_shape,
    project_dispatched,
    scatter_blocks,
)
from .newton_schulz import muon_scale, newton_schulz


class AdamWState(NamedTuple):
    count: jax.Array
    mu: PyTree
    nu: PyTree


def adamw(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Transform:
    def init(params: PyTree) -> AdamWState:
        zeros = lambda t: jax.tree_util.tree_map(
            lambda p: None if p is None else jnp.zeros_like(p, dtype=jnp.float32),
            t,
            is_leaf=lambda x: x is None,
        )
        return AdamWState(count=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))

    def update(grads: PyTree, state: AdamWState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(g, mu, nu, p):
            if g is None:
                return None, None, None
            g32 = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * jnp.square(g32)
            mhat = mu / bc1
            nhat = nu / bc2
            u = -step_lr * (mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u, mu, nu

        flat = jax.tree_util.tree_map(
            upd, grads, state.mu, state.nu, params, is_leaf=lambda x: x is None
        )
        # tree_map returned tuples at leaves; transpose into three trees.
        is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
        updates = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=is_triple)
        mu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_triple)
        nu = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=is_triple)
        return updates, AdamWState(count=count, mu=mu, nu=nu)

    return Transform(init, update)


def sgdm(lr: Schedule, beta: float = 0.9, weight_decay: float = 0.0) -> Transform:
    """SGD with (EMA) momentum — Property-II compliant base optimizer."""

    class SGDMState(NamedTuple):
        count: jax.Array
        mu: PyTree

    def init(params: PyTree) -> SGDMState:
        mu = jax.tree_util.tree_map(
            lambda p: None if p is None else jnp.zeros_like(p, dtype=jnp.float32),
            params,
            is_leaf=lambda x: x is None,
        )
        return SGDMState(count=jnp.zeros((), jnp.int32), mu=mu)

    def update(grads: PyTree, state: SGDMState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)

        def upd(g, mu, p):
            if g is None:
                return None, None
            mu = beta * mu + g.astype(jnp.float32)
            u = -step_lr * (mu + weight_decay * p.astype(jnp.float32))
            return u, mu

        flat = jax.tree_util.tree_map(upd, grads, state.mu, params, is_leaf=lambda x: x is None)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
        updates = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=is_pair)
        mu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_pair)
        return updates, SGDMState(count=count, mu=mu)

    return Transform(init, update)


class MuonState(NamedTuple):
    count: jax.Array
    mu: PyTree


def muon_matrices(
    lr: Schedule,
    beta: float = 0.95,
    weight_decay: float = 0.0,
    ns_steps: int = 5,
    nesterov: bool = True,
    use_muon_scale: bool = True,
    kernel_impl: str = "auto",
) -> Transform:
    """Muon over matrix leaves only (callers route 1-D leaves elsewhere)."""

    def init(params: PyTree) -> MuonState:
        mu = jax.tree_util.tree_map(
            lambda p: None if p is None else jnp.zeros_like(p, dtype=jnp.float32),
            params,
            is_leaf=lambda x: x is None,
        )
        return MuonState(count=jnp.zeros((), jnp.int32), mu=mu)

    def update(grads: PyTree, state: MuonState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)

        def upd(g, mu, p):
            if g is None:
                return None, None
            g32 = g.astype(jnp.float32)
            mu = beta * mu + g32
            mom = beta * mu + g32 if nesterov else mu
            o = newton_schulz(mom, steps=ns_steps, impl=kernel_impl)
            scale = muon_scale(p.shape) if use_muon_scale else 1.0
            u = -step_lr * (
                scale * o + weight_decay * p.astype(jnp.float32)
            )
            return u, mu

        flat = jax.tree_util.tree_map(upd, grads, state.mu, params, is_leaf=lambda x: x is None)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
        updates = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=is_pair)
        mu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_pair)
        return updates, MuonState(count=count, mu=mu)

    return Transform(init, update)


def default_matrix_filter(path: str, p: jax.Array) -> bool:
    """Hidden-layer matrices: >=2 trailing dims and not an embedding/head/norm."""
    if p.ndim < 2:
        return False
    lowered = path.lower()
    return not any(k in lowered for k in ("embed", "lm_head", "norm", "scale", "bias"))


def muon(
    lr: Schedule,
    beta: float = 0.95,
    weight_decay: float = 0.0,
    ns_steps: int = 5,
    adam_lr: Optional[Schedule] = None,
    matrix_filter: Callable[[str, jax.Array], bool] = default_matrix_filter,
    use_muon_scale: bool = True,
    kernel_impl: str = "auto",
) -> Transform:
    """Full Muon optimizer: Muon on hidden matrices, AdamW on the rest."""
    inner = {
        "muon": muon_matrices(lr, beta=beta, weight_decay=weight_decay,
                              ns_steps=ns_steps, use_muon_scale=use_muon_scale,
                              kernel_impl=kernel_impl),
        "adamw": adamw(adam_lr if adam_lr is not None else lr, weight_decay=weight_decay),
    }

    def label_fn(params: PyTree) -> PyTree:
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: "muon" if matrix_filter(path, p) else "adamw", paths, params
        )

    return multi_transform(inner, label_fn)


class GaLoreFamilyState(NamedTuple):
    p: jax.Array        # (L, s, r) projector
    m1: jax.Array       # (L, r, n)/(L, m, r) first moment (or momentum)
    m2: jax.Array | None  # second moment (adam only)


class GaLoreState(NamedTuple):
    count: jax.Array
    families: PyTree  # leaf -> GaLoreFamilyState


def galore_matrices(
    lr: Schedule,
    rank: int = 128,
    period: int = 200,
    projector: str = "svd",
    base: str = "adam",
    beta: float = 0.95,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    scale: float = 0.25,
    ns_steps: int = 5,
    weight_decay: float = 0.0,
    reset_on_update: bool = False,
    seed: int = 0,
    subspace_iters: int = 2,
    kernel_impl: str = "auto",
) -> Transform:
    """GaLore over matrix leaves only (route others via :func:`galore`)."""
    if base not in ("adam", "muon", "sgdm"):
        raise ValueError(f"unsupported base: {base}")
    use_m2 = base == "adam"

    def init_family(p_leaf: jax.Array) -> GaLoreFamilyState:
        fs = family_shape(p_leaf, rank)
        p0 = jnp.zeros(proj_shape(fs), jnp.float32)
        st = jnp.zeros(lowrank_state_shape(fs), jnp.float32)
        return GaLoreFamilyState(p=p0, m1=st, m2=st if use_m2 else None)

    def init(params: PyTree) -> GaLoreState:
        fams = jax.tree_util.tree_map(
            lambda p: None if p is None else init_family(p),
            params,
            is_leaf=lambda x: x is None,
        )
        return GaLoreState(count=jnp.zeros((), jnp.int32), families=fams)

    def update_family(
        g_leaf: jax.Array,
        st: GaLoreFamilyState,
        p_leaf: jax.Array,
        count: jax.Array,
        step_lr: jax.Array,
        key: jax.Array,
    ) -> tuple[jax.Array, GaLoreFamilyState]:
        fs = family_shape(p_leaf, rank)
        g = g_leaf.astype(jnp.float32)  # (*lead, m, n)

        refresh = (count - 1) % period == 0

        def do_refresh(_):
            p_new = compute_projectors(projector, g, fs.rank, key, fs.side, subspace_iters)
            if reset_on_update:
                z = jnp.zeros_like(st.m1)
                return p_new, z, (z if use_m2 else st.m2)
            return p_new, st.m1, st.m2

        def keep(_):
            return st.p, st.m1, st.m2

        p_proj, m1, m2 = jax.lax.cond(refresh, do_refresh, keep, None)

        if base == "adam":
            # Adam needs the projected gradient itself (second moment), so the
            # kernel fuses only the projection GEMM (beta=0 path).
            r_g = project_dispatched(p_proj, g, fs.side, kernel_impl)
            c = count.astype(jnp.float32)
            m1 = b1 * m1 + (1 - b1) * r_g
            m2 = b2 * m2 + (1 - b2) * jnp.square(r_g)
            mhat = m1 / (1.0 - b1 ** c)
            vhat = m2 / (1.0 - b2 ** c)
            s = mhat / (jnp.sqrt(vhat) + eps)
            upd_lr = scale * s
        elif base == "muon":
            m1 = lowrank_momentum_update(p_proj, g, m1, beta, 1.0, fs.side,
                                         kernel_impl)
            upd_lr = newton_schulz(m1, steps=ns_steps, impl=kernel_impl)
        else:  # sgdm
            m1 = lowrank_momentum_update(p_proj, g, m1, beta, 1.0, fs.side,
                                         kernel_impl)
            upd_lr = m1

        full = back_project(p_proj, upd_lr, fs.side)
        u = -step_lr * (full + weight_decay * p_leaf.astype(jnp.float32))
        return u, GaLoreFamilyState(p=p_proj, m1=m1, m2=m2)

    def update(grads: PyTree, state: GaLoreState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)

        leaves, treedef = jax.tree_util.tree_flatten(
            params, is_leaf=lambda x: x is None
        )
        g_leaves = treedef.flatten_up_to(grads)
        s_leaves = treedef.flatten_up_to(state.families)

        upds, new_states = [], []
        for i, (g, fst, p) in enumerate(zip(g_leaves, s_leaves, leaves)):
            if g is None or p is None:
                upds.append(None)
                new_states.append(None)
                continue
            key = jax.random.fold_in(base_key, i)
            u, ns = update_family(g, fst, p, count, step_lr, key)
            upds.append(u)
            new_states.append(ns)

        updates = jax.tree_util.tree_unflatten(treedef, upds)
        families = jax.tree_util.tree_unflatten(treedef, new_states)
        return updates, GaLoreState(count=count, families=families)

    return Transform(init, update)


def galore(
    lr: Schedule,
    rank: int = 128,
    period: int = 200,
    projector: str = "svd",
    base: str = "adam",
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    **kw,
) -> Transform:
    """Full GaLore: low-rank on hidden matrices, AdamW elsewhere."""
    inner = {
        "galore": galore_matrices(
            lr, rank=rank, period=period, projector=projector, base=base, **kw
        ),
        "adamw": adamw(lr, weight_decay=kw.get("weight_decay", 0.0)),
    }

    def label_fn(params: PyTree) -> PyTree:
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: "galore" if lowrank_filter(path, p) else "adamw",
            paths,
            params,
        )

    return multi_transform(inner, label_fn)


def golore(lr: Schedule, rank: int = 128, period: int = 200, base: str = "sgdm", **kw) -> Transform:
    """GoLore (He et al., 2024): GaLore with a gradient-independent random
    orthonormal projector — convergent but subspace-blind."""
    return galore(lr, rank=rank, period=period, projector="random", base=base, **kw)


class FiraFamilyState(NamedTuple):
    p: jax.Array
    m1: jax.Array
    m2: jax.Array
    prev_resid_norm: jax.Array  # (L,) norm-growth limiter memory


class FiraState(NamedTuple):
    count: jax.Array
    families: PyTree


def fira_matrices(
    lr: Schedule,
    rank: int = 128,
    period: int = 200,
    projector: str = "svd",
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    scale: float = 0.25,
    limiter: float = 1.01,
    seed: int = 0,
    kernel_impl: str = "auto",
) -> Transform:
    def init(params: PyTree) -> FiraState:
        def init_family(p_leaf):
            if p_leaf is None:
                return None
            fs = family_shape(p_leaf, rank)
            st = jnp.zeros(lowrank_state_shape(fs), jnp.float32)
            return FiraFamilyState(
                p=jnp.zeros(proj_shape(fs), jnp.float32),
                m1=st,
                m2=st,
                prev_resid_norm=jnp.zeros(fs.lead, jnp.float32),
            )

        fams = jax.tree_util.tree_map(
            init_family, params, is_leaf=lambda x: x is None
        )
        return FiraState(count=jnp.zeros((), jnp.int32), families=fams)

    def update_family(g_leaf, st, p_leaf, count, step_lr, key):
        fs = family_shape(p_leaf, rank)
        g = g_leaf.astype(jnp.float32)  # (*lead, m, n)
        refresh = (count - 1) % period == 0

        p_proj = jax.lax.cond(
            refresh,
            lambda _: compute_projectors(projector, g, fs.rank, key, fs.side),
            lambda _: st.p,
            None,
        )

        r_g = project_dispatched(p_proj, g, fs.side, kernel_impl)
        c = count.astype(jnp.float32)
        m1 = b1 * st.m1 + (1 - b1) * r_g
        m2 = b2 * st.m2 + (1 - b2) * jnp.square(r_g)
        s = (m1 / (1 - b1**c)) / (jnp.sqrt(m2 / (1 - b2**c)) + eps)

        # Residual outside the subspace, scaled by ||s|| / ||r_g|| per block.
        resid = g - back_project(p_proj, r_g, fs.side)
        s_norm = jnp.linalg.norm(s, axis=(-2, -1))
        rg_norm = jnp.linalg.norm(r_g, axis=(-2, -1))
        phi = s_norm / (rg_norm + eps)
        scaled_resid = phi[..., None, None] * resid

        # Norm-growth limiter: cap per-block residual norm at limiter x prev.
        rnorm = jnp.linalg.norm(scaled_resid, axis=(-2, -1))
        cap = jnp.where(st.prev_resid_norm > 0, limiter * st.prev_resid_norm, rnorm)
        shrink = jnp.minimum(1.0, cap / (rnorm + eps))
        scaled_resid = scaled_resid * shrink[..., None, None]
        new_rnorm = rnorm * shrink

        u = -step_lr * scale * (back_project(p_proj, s, fs.side) + scaled_resid)
        return u, FiraFamilyState(
            p=p_proj, m1=m1, m2=m2, prev_resid_norm=new_rnorm
        )

    def update(grads: PyTree, state: FiraState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        g_leaves = treedef.flatten_up_to(grads)
        s_leaves = treedef.flatten_up_to(state.families)
        upds, news = [], []
        for i, (g, fst, p) in enumerate(zip(g_leaves, s_leaves, leaves)):
            if g is None or p is None:
                upds.append(None)
                news.append(None)
                continue
            u, ns = update_family(g, fst, p, count, step_lr, jax.random.fold_in(base_key, i))
            upds.append(u)
            news.append(ns)
        return (
            jax.tree_util.tree_unflatten(treedef, upds),
            FiraState(count=count, families=jax.tree_util.tree_unflatten(treedef, news)),
        )

    return Transform(init, update)


def fira(
    lr: Schedule,
    rank: int = 128,
    period: int = 200,
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    **kw,
) -> Transform:
    inner = {
        "fira": fira_matrices(lr, rank=rank, period=period, **kw),
        "adamw": adamw(lr),
    }

    def label_fn(params: PyTree) -> PyTree:
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: "fira" if lowrank_filter(path, p) else "adamw", paths, params
        )

    return multi_transform(inner, label_fn)


class GUMFamilyState(NamedTuple):
    p: jax.Array               # (L, s, r)
    r_low: jax.Array           # (L, r, n) | (L, m, r)
    r_full: Optional[jax.Array]  # (gamma, m, n) or None when gamma == 0
    idx: Optional[jax.Array]     # (gamma,) int32 or None


class GUMState(NamedTuple):
    count: jax.Array
    families: PyTree


def gum_matrices(
    lr: Schedule,
    rank: int = 128,
    gamma: int = 2,
    period: int = 200,
    projector: str = "svd",
    base: str = "muon",
    beta: float = 0.95,
    ns_steps: int = 5,
    weight_decay: float = 0.0,
    compensation: str = "paper",
    seed: int = 0,
    subspace_iters: int = 2,
    external_refresh: bool = False,
    kernel_impl: str = "auto",
    use_muon_scale: bool = False,
) -> Transform:
    """GUM over matrix leaves (route 1-D/embedding leaves via :func:`gum`).

    ``external_refresh=True`` skips the in-update period refresh — used by
    the low-rank gradient-accumulation path, where :func:`gum_accum_tools`
    refreshes against a raw microbatch gradient before projection.

    ``kernel_impl`` selects the hot-loop implementation (see module
    docstring); ``use_muon_scale`` applies Muon's RMS-matching shape factor."""
    if base not in ("muon", "sgdm"):
        raise ValueError("GUM requires a Property-II base optimizer: muon | sgdm")
    if compensation not in ("paper", "finetune"):
        raise ValueError(f"unknown compensation: {compensation}")
    use_ns = base == "muon"

    def fam_gamma(L: int) -> int:
        return min(gamma, L)

    def init_family(p_leaf: jax.Array) -> GUMFamilyState:
        fs = family_shape(p_leaf, rank)
        g_f = fam_gamma(fs.L)
        p0 = jnp.zeros(proj_shape(fs), jnp.float32)
        r_low = jnp.zeros(lowrank_state_shape(fs), jnp.float32)
        if g_f == 0:
            return GUMFamilyState(p=p0, r_low=r_low, r_full=None, idx=None)
        r_full = jnp.zeros((g_f, fs.m, fs.n), jnp.float32)
        idx = jnp.arange(g_f, dtype=jnp.int32)
        return GUMFamilyState(p=p0, r_low=r_low, r_full=r_full, idx=idx)

    def init(params: PyTree) -> GUMState:
        fams = jax.tree_util.tree_map(
            lambda p: None if p is None else init_family(p),
            params,
            is_leaf=lambda x: x is None,
        )
        return GUMState(count=jnp.zeros((), jnp.int32), families=fams)

    def update_family(
        g_leaf: jax.Array,
        st: GUMFamilyState,
        p_leaf: jax.Array,
        count: jax.Array,
        step_lr: jax.Array,
        key: jax.Array,
    ) -> tuple[jax.Array, GUMFamilyState]:
        fs = family_shape(p_leaf, rank)
        g_f = fam_gamma(fs.L)
        q = g_f / fs.L
        g = g_leaf.astype(jnp.float32)  # (*lead, m, n) — never reshaped

        refresh = (count - 1) % period == 0
        key_proj, key_idx = jax.random.split(key)

        # --- period boundary: new projector, resample blocks, restart momentum
        def do_refresh(_):
            p_new = compute_projectors(
                projector, g, fs.rank, key_proj, fs.side, subspace_iters
            )
            out = (p_new, jnp.zeros_like(st.r_low))
            if g_f > 0:
                idx_new = jax.random.choice(
                    key_idx, fs.L, (g_f,), replace=False
                ).astype(jnp.int32)
                out += (jnp.zeros_like(st.r_full), idx_new)
            return out

        def keep(_):
            out = (st.p, st.r_low)
            if g_f > 0:
                out += (st.r_full, st.idx)
            return out

        if external_refresh:
            refreshed = keep(None)
        else:
            refreshed = jax.lax.cond(refresh, do_refresh, keep, None)
        if g_f > 0:
            p_proj, r_low, r_full, idx = refreshed
        else:
            p_proj, r_low = refreshed
            r_full, idx = None, None

        c_low = 1.0 if compensation == "finetune" else 1.0 / max(1.0 - q, 1e-12)
        c_comp = (1.0 - q) if compensation == "finetune" else 1.0

        # --- low-rank branch (computed for all blocks; sampled blocks' output
        # is overwritten by the scatter below and their r_low restarts at the
        # next period boundary, so advancing it is trajectory-neutral).
        if q < 1.0:
            r_low = lowrank_momentum_update(
                p_proj, g, r_low, beta, c_low, fs.side, kernel_impl
            )
            s_low = (
                newton_schulz(r_low, steps=ns_steps, impl=kernel_impl)
                if use_ns else r_low
            )
            u = back_project(p_proj, s_low, fs.side)
        else:
            u = jnp.zeros_like(g)

        # --- compensated full-rank branch on the gamma sampled blocks.
        if g_f > 0:
            c_full = 1.0 / q
            g_s = gather_blocks(g, idx, fs)       # (gamma, m, n)
            p_s = gather_blocks(p_proj, idx, fs)  # (gamma, s, r)
            pptg = back_project(p_s, project(p_s, g_s, fs.side), fs.side)
            resid = g_s - c_comp * pptg
            r_full = beta * r_full + c_full * resid
            s_full = (
                newton_schulz(r_full, steps=ns_steps, impl=kernel_impl)
                if use_ns else r_full
            )
            u = scatter_blocks(u, idx, s_full, fs)

        if use_muon_scale:
            u = muon_scale((fs.m, fs.n)) * u
        u = -step_lr * (u + weight_decay * p_leaf.astype(jnp.float32))
        return u, GUMFamilyState(p=p_proj, r_low=r_low, r_full=r_full, idx=idx)

    def update(grads: PyTree, state: GUMState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count)

        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        g_leaves = treedef.flatten_up_to(grads)
        s_leaves = treedef.flatten_up_to(state.families)

        upds, new_states = [], []
        for i, (g, fst, p) in enumerate(zip(g_leaves, s_leaves, leaves)):
            if g is None or p is None:
                upds.append(None)
                new_states.append(None)
                continue
            key = jax.random.fold_in(base_key, i)
            u, ns = update_family(g, fst, p, count, step_lr, key)
            upds.append(u)
            new_states.append(ns)

        updates = jax.tree_util.tree_unflatten(treedef, upds)
        families = jax.tree_util.tree_unflatten(treedef, new_states)
        return updates, GUMState(count=count, families=families)

    return Transform(init, update)




def gum(
    lr: Schedule,
    rank: int = 128,
    gamma: int = 2,
    period: int = 200,
    projector: str = "svd",
    lowrank_filter: Callable[[str, jax.Array], bool] = default_lowrank_filter,
    **kw,
) -> Transform:
    """Full GUM: unbiased low-rank Muon on hidden matrices, AdamW elsewhere
    (embeddings / head / norms / biases), mirroring the paper's setup."""
    inner = {
        "gum": gum_matrices(
            lr, rank=rank, gamma=gamma, period=period, projector=projector, **kw
        ),
        "adamw": adamw(lr, weight_decay=kw.get("weight_decay", 0.0)),
    }

    def label_fn(params: PyTree) -> PyTree:
        paths = tree_paths(params)
        return jax.tree_util.tree_map(
            lambda path, p: "gum" if lowrank_filter(path, p) else "adamw",
            paths,
            params,
        )

    return multi_transform(inner, label_fn)
