"""Algorithm 3 — the general unbiased low-rank paradigm, exact Bernoulli form.

This is the *reference semantics* implementation: every block independently
draws xi ~ Bernoulli(q) each period and keeps full (m, n) momentum buffers
(memory-naive, shapes static).  It exists for

  * the synthetic experiments (Fig. 1 counterexample) where blocks are single
    matrices and q is a true Bernoulli probability, and
  * the theory tests (Lemma 1/2): a single step is checkable against the base
    optimizer driven by the unbiased estimator G_hat.

The production, memory-efficient fixed-count instantiation is
:mod:`repro.core.gum`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .api import PyTree, Schedule, Transform, schedule_value
from .lowrank_common import (
    back_project,
    compute_projectors,
    family_shape,
    project,
    proj_shape,
)
from .newton_schulz import newton_schulz


class UnbiasedFamilyState(NamedTuple):
    p: jax.Array     # (L, s, r)
    mom: jax.Array   # (L, m, n) full-shape momentum (reference semantics)
    xi: jax.Array    # (L,) bool — full-rank this period?


class UnbiasedState(NamedTuple):
    count: jax.Array
    families: PyTree


def unbiased_lowrank(
    lr: Schedule,
    rank: int,
    q: float,
    period: int = 1,
    projector: str = "svd",
    base: str = "muon",
    beta: float = 0.95,
    ns_steps: int = 5,
    compensation: str = "paper",
    seed: int = 0,
) -> Transform:
    if base not in ("muon", "sgdm"):
        raise ValueError("Property II requires base in {muon, sgdm}")
    if not (0.0 < q < 1.0):
        raise ValueError("Bernoulli unbiased form needs 0 < q < 1")
    use_ns = base == "muon"
    c_low = 1.0 if compensation == "finetune" else 1.0 / (1.0 - q)
    c_comp = (1.0 - q) if compensation == "finetune" else 1.0
    c_full = 1.0 / q

    def init(params: PyTree) -> UnbiasedState:
        def init_family(p_leaf):
            fs = family_shape(p_leaf, rank)
            return UnbiasedFamilyState(
                p=jnp.zeros(proj_shape(fs), jnp.float32),
                mom=jnp.zeros(fs.lead + (fs.m, fs.n), jnp.float32),
                xi=jnp.zeros(fs.lead, bool),
            )

        fams = jax.tree_util.tree_map(init_family, params)
        return UnbiasedState(count=jnp.zeros((), jnp.int32), families=fams)

    def update_family(g_leaf, st, p_leaf, count, step_lr, key):
        fs = family_shape(p_leaf, rank)
        g = g_leaf.astype(jnp.float32)  # (*lead, m, n)
        refresh = (count - 1) % period == 0
        key_p, key_xi = jax.random.split(key)

        def do_refresh(_):
            p_new = compute_projectors(projector, g, fs.rank, key_p, fs.side)
            xi_new = jax.random.bernoulli(key_xi, q, fs.lead)
            return p_new, xi_new, jnp.zeros_like(st.mom)

        p_proj, xi, mom = jax.lax.cond(
            refresh, do_refresh, lambda _: (st.p, st.xi, st.mom), None
        )

        # Unbiased gradient estimate G_hat (Lemma 2's equivalent form).
        pptg = back_project(p_proj, project(p_proj, g, fs.side), fs.side)
        g_full = c_full * (g - c_comp * pptg)
        g_low = c_low * pptg
        g_hat = jnp.where(xi[..., None, None], g_full, g_low)

        mom = beta * mom + g_hat
        if use_ns:
            # Property II: NS(P Pᵀ M) = P NS(Pᵀ M); computing NS on the
            # full-shape momentum gives identical results for the low-rank
            # blocks (their momentum lies in span(P)).
            upd = newton_schulz(mom, steps=ns_steps)
        else:
            upd = mom
        u = -step_lr * upd
        return u, UnbiasedFamilyState(p=p_proj, mom=mom, xi=xi)

    def update(grads: PyTree, state: UnbiasedState, params: PyTree):
        count = state.count + 1
        step_lr = schedule_value(lr, count)
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), (count - 1) // period)

        leaves, treedef = jax.tree_util.tree_flatten(params)
        g_leaves = treedef.flatten_up_to(grads)
        s_leaves = treedef.flatten_up_to(state.families)
        upds, news = [], []
        for i, (g, fst, p) in enumerate(zip(g_leaves, s_leaves, leaves)):
            key = jax.random.fold_in(base_key, i)
            u, ns = update_family(g, fst, p, count, step_lr, key)
            upds.append(u)
            news.append(ns)
        return (
            jax.tree_util.tree_unflatten(treedef, upds),
            UnbiasedState(count=count, families=jax.tree_util.tree_unflatten(treedef, news)),
        )

    return Transform(init, update)
