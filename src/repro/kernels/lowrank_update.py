"""Fused GUM/GaLore momentum update kernel:  R' = beta·R + coeff·(Pᵀ G).

This is the per-step hot loop of every low-rank optimizer in the paper
(Algorithm 1 line 5-6 / Algorithm 2 eq. (1)).  Fusing the projection GEMM
with the momentum AXPY avoids materializing Pᵀ G in HBM: the (r, n) output
tile accumulates partial products over m (grid-minor reduction) and folds in
beta·R exactly once at the first reduction step.

Layout: P (m, r), G (m, n), R (r, n); r ≤ 512 so a whole (r, block_n) output
tile plus (block_m, r) / (block_m, block_n) input tiles fit VMEM.

The kernel runs on a (L, nblocks, mblocks) grid so a whole stacked family
(L, m, n) is one ``pallas_call`` — NOT ``jax.vmap``, whose batching rule
prepends a grid axis and would renumber the ``pl.program_id`` axes the
reduction relies on.  2-D callers are lifted to L=1.  Ragged (non
tile-divisible) shapes are handled by the padding wrappers in
:mod:`repro.kernels.dispatch`; this file keeps the bare divisibility
contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lowrank_update_kernel(
    p_ref, g_ref, r_ref, out_ref, acc, *, beta: float, coeff: float, mblocks: int
):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        acc[...] = beta * r_ref[0].astype(jnp.float32)

    p = p_ref[0].astype(jnp.float32)  # (bm, r)
    g = g_ref[0].astype(jnp.float32)  # (bm, bn)
    acc[...] += coeff * (p.T @ g)

    @pl.when(mi == mblocks - 1)
    def _done():
        out_ref[0] = acc[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("beta", "coeff", "block_m", "block_n", "interpret")
)
def lowrank_update_batched(
    p: jax.Array,
    g: jax.Array,
    r_state: jax.Array,
    beta: float,
    coeff: float,
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Batched fused update: p (L, m, r), g (L, m, n), r_state (L, r, n)."""
    L, m, r = p.shape
    _, _, n = g.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0
    mblocks = m // block_m
    return pl.pallas_call(
        functools.partial(
            _lowrank_update_kernel, beta=beta, coeff=coeff, mblocks=mblocks
        ),
        grid=(L, n // block_n, mblocks),  # m innermost: sequential reduction
        in_specs=[
            pl.BlockSpec((1, block_m, r), lambda l, ni, mi: (l, mi, 0)),
            pl.BlockSpec((1, block_m, block_n), lambda l, ni, mi: (l, mi, ni)),
            pl.BlockSpec((1, r, block_n), lambda l, ni, mi: (l, 0, ni)),
        ],
        out_specs=pl.BlockSpec((1, r, block_n), lambda l, ni, mi: (l, 0, ni)),
        out_shape=jax.ShapeDtypeStruct((L, r, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((r, block_n), jnp.float32)],
        interpret=interpret,
    )(p, g, r_state)


def lowrank_update(
    p: jax.Array,
    g: jax.Array,
    r_state: jax.Array,
    beta: float,
    coeff: float,
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Single-matrix form: p (m, r), g (m, n), r_state (r, n) -> (r, n)."""
    out = lowrank_update_batched(
        p[None], g[None], r_state[None], beta, coeff,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return out[0]


def _back_project_kernel(p_ref, s_ref, out_ref):
    # Whole contraction dim r (<= 512) is resident, so each (bm, bn) output
    # tile is one MXU matmul — no reduction loop, no scratch accumulator.
    p = p_ref[0].astype(jnp.float32)  # (bm, r)
    s = s_ref[0].astype(jnp.float32)  # (r, bn)
    out_ref[0] = (p @ s).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def back_project_batched(
    p: jax.Array,
    s: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Batched back-projection GEMM ``P @ S``: p (L, m, r), s (L, r, n) ->
    (L, m, n) — the second half of every low-rank optimizer step
    (``W <- W - lr * P NS(R)``), fused so NS(R) never round-trips HBM
    between the orthogonalization and the back-projection."""
    L, m, r = p.shape
    _, _, n = s.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0
    return pl.pallas_call(
        _back_project_kernel,
        grid=(L, m // block_m, n // block_n),
        in_specs=[
            pl.BlockSpec((1, block_m, r), lambda l, mi, ni: (l, mi, 0)),
            pl.BlockSpec((1, r, block_n), lambda l, mi, ni: (l, 0, ni)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n), lambda l, mi, ni: (l, mi, ni)),
        out_shape=jax.ShapeDtypeStruct((L, m, n), jnp.float32),
        interpret=interpret,
    )(p, s)


def _project_kernel(p_ref, g_ref, out_ref, acc, *, coeff: float, mblocks: int):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    p = p_ref[0].astype(jnp.float32)  # (bm, r)
    g = g_ref[0].astype(jnp.float32)  # (bm, bn)
    acc[...] += coeff * (p.T @ g)

    @pl.when(mi == mblocks - 1)
    def _done():
        out_ref[0] = acc[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("coeff", "block_m", "block_n", "interpret")
)
def project_batched(
    p: jax.Array,
    g: jax.Array,
    coeff: float,
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Projection-only form (the beta == 0 momentum update without the dead
    R operand): p (L, m, r), g (L, m, n) -> coeff·PᵀG (L, r, n)."""
    L, m, r = p.shape
    _, _, n = g.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0
    mblocks = m // block_m
    return pl.pallas_call(
        functools.partial(_project_kernel, coeff=coeff, mblocks=mblocks),
        grid=(L, n // block_n, mblocks),
        in_specs=[
            pl.BlockSpec((1, block_m, r), lambda l, ni, mi: (l, mi, 0)),
            pl.BlockSpec((1, block_m, block_n), lambda l, ni, mi: (l, mi, ni)),
        ],
        out_specs=pl.BlockSpec((1, r, block_n), lambda l, ni, mi: (l, 0, ni)),
        out_shape=jax.ShapeDtypeStruct((L, r, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((r, block_n), jnp.float32)],
        interpret=interpret,
    )(p, g)
