"""Pallas TPU kernels (hot spots) + jnp oracles.

Layout per task spec: <name>.py holds the pl.pallas_call + BlockSpec kernel,
ops.py the jit'd wrappers (impl dispatch), ref.py the pure-jnp oracles.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
