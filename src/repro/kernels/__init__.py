"""Pallas TPU kernels (hot spots) + jnp oracles + dispatch.

Layout per task spec: <name>.py holds the pl.pallas_call + BlockSpec kernel
(fused_step.py: the scale-and-back-project epilogue GEMM), ops.py the jit'd
wrappers (legacy impl dispatch), ref.py the pure-jnp oracles, dispatch.py
the backend-aware dispatch subsystem the optimizers use (auto backend
detection, shape-legality fallback, ragged-shape padding, family batching),
launch_count.py the trace-time launch counter benchmarks/tests use to prove
launch-count-optimality of the family-stacked engine.

``KERNEL_REGISTRY`` maps op name -> :class:`repro.kernels.dispatch.KernelEntry`
(dispatch entry point, jnp oracle, legality predicate); ``get_kernel`` looks
one up by name.
"""
from . import dispatch, ops, ref

__all__ = ["dispatch", "ops", "ref", "KERNEL_REGISTRY", "get_kernel"]

KERNEL_REGISTRY = dispatch.REGISTRY
get_kernel = dispatch.get_kernel
