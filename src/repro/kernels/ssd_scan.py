"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD insight maps the linear recurrence onto matmuls: split the sequence
into chunks; within a chunk the output is a masked quadratic form
((C Bᵀ) ⊙ L) (dt ⊙ X) — pure MXU work — while the O(S) dependence is carried
between chunks as a tiny (N, P) state held in VMEM scratch.  The grid is
(batch, heads, chunks) with chunks innermost/sequential, so the state never
round-trips HBM during the scan (the TPU-friendly replacement for the CUDA
warp-level scan in the Mamba-2 reference kernels).

Log-decay cumulative sums G are precomputed in XLA (cheap elementwise work,
and Mosaic's cumsum support is version-dependent); the kernel does the three
matmuls.  The D·x skip connection is applied by the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, g_ref, b_ref, c_ref, y_ref, sfin_ref, state, *, nch, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, :, 0, :].astype(jnp.float32)   # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)    # (c,)
    G = g_ref[0, :, 0].astype(jnp.float32)      # (c,) inclusive cum log-decay
    b = b_ref[0, :, :].astype(jnp.float32)      # (c, N)
    c = c_ref[0, :, :].astype(jnp.float32)      # (c, N)

    diff = G[:, None] - G[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask BEFORE exp (upper-tri diff > 0 overflows)
    L = jnp.exp(jnp.where(rows >= cols, diff, -jnp.inf))

    cb = c @ b.T                                # (c, c) MXU
    y = (cb * L * dt[None, :]) @ x              # intra-chunk, MXU
    y += (c * jnp.exp(G)[:, None]) @ state[...]  # inter-chunk, MXU

    g_last = G[chunk - 1]
    w = dt * jnp.exp(g_last - G)                # (c,)
    state[...] = jnp.exp(g_last) * state[...] + (b * w[:, None]).T @ x

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nch - 1)
    def _done():
        sfin_ref[0, 0] = state[...].astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) post-softplus
    a: jax.Array,   # (H,) negative
    b: jax.Array,   # (B, S, N)
    c: jax.Array,   # (B, S, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P) without the D·x skip, final_state (B,H,N,P))."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0
    nch = S // chunk

    # Per-chunk inclusive cumulative log-decay (reset at chunk boundaries).
    g_steps = a[None, None, :] * dt.astype(jnp.float32)        # (B, S, H)
    G = jnp.cumsum(g_steps.reshape(B, nch, chunk, H), axis=2).reshape(B, S, H)

    kernel = functools.partial(_ssd_kernel, nch=nch, chunk=chunk)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(B, H, nch),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, G, b, c)
    return y, sfin
