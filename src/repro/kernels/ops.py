"""jit'd public wrappers around the Pallas kernels with impl dispatch.

``impl`` semantics everywhere:
  "xla"       — the pure-jnp oracle path (default; used on CPU and for the
                multi-pod dry-run, which lowers for the CPU backend).
  "pallas"    — the TPU kernel (real hardware).
  "interpret" — the Pallas kernel executed by the interpreter (CPU tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.newton_schulz import newton_schulz as ns_xla

from . import dispatch, ref
from .flash_attention import flash_attention as _flash
from .ssd_scan import ssd_scan as _ssd_scan


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    impl: str = "xla", block_q: int = 128, block_kv: int = 128,
) -> jax.Array:
    if impl == "xla":
        return ref.attention_ref(q, k, v, causal=causal)
    if impl == "xla_chunked":
        return ref.attention_chunked_ref(q, k, v, causal=causal, block_kv=512)
    return _flash(
        q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=(impl == "interpret"),
    )


def decode_attention(q, k, v, pos, *, impl: str = "xla") -> jax.Array:
    # Decode is memory-bound gather+reduce; the XLA path is already optimal
    # on TPU for a single query token (no flash tiling needed).
    del impl
    return ref.decode_attention_ref(q, k, v, pos)


def newton_schulz(x: jax.Array, *, steps: int = 5, impl: str = "xla") -> jax.Array:
    """Batched (…, m, n) Newton–Schulz with impl dispatch."""
    if impl == "xla":
        return ns_xla(x, steps=steps)
    return dispatch.newton_schulz(x, steps=steps, impl=impl)


def lowrank_update(
    p: jax.Array, g: jax.Array, r_state: jax.Array, beta: float, coeff: float,
    *, impl: str = "xla",
) -> jax.Array:
    if impl == "xla":
        return ref.lowrank_update_ref(p, g, r_state, beta, coeff)
    return dispatch.lowrank_update(p, g, r_state, beta, coeff, impl=impl)


def ssd(
    x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
    d: jax.Array, *, chunk: int = 64, impl: str = "xla",
) -> tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD over a full sequence; returns (y, final_state)."""
    if impl == "xla":
        return ref.ssd_chunked_ref(x, dt, a, b, c, d, chunk)
    y, sfin = _ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=(impl == "interpret"))
    y = y + d[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), sfin


def ssd_decode_step(state, x, dt, a, b, c, d):
    return ref.ssd_decode_ref(state, x, dt, a, b, c, d)
