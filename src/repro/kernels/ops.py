"""jit'd public wrappers around the Pallas kernels with impl dispatch.

``impl`` semantics everywhere:
  "xla"       — the pure-jnp oracle path (default; used on CPU and for the
                multi-pod dry-run, which lowers for the CPU backend).
  "pallas"    — the TPU kernel (real hardware).
  "interpret" — the Pallas kernel executed by the interpreter (CPU tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.newton_schulz import newton_schulz as ns_xla

from . import ref
from .flash_attention import flash_attention as _flash
from .lowrank_update import lowrank_update as _lowrank_update
from .newton_schulz import newton_schulz_pallas
from .ssd_scan import ssd_scan as _ssd_scan


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    impl: str = "xla", block_q: int = 128, block_kv: int = 128,
) -> jax.Array:
    if impl == "xla":
        return ref.attention_ref(q, k, v, causal=causal)
    if impl == "xla_chunked":
        return ref.attention_chunked_ref(q, k, v, causal=causal, block_kv=512)
    return _flash(
        q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=(impl == "interpret"),
    )


def decode_attention(q, k, v, pos, *, impl: str = "xla") -> jax.Array:
    # Decode is memory-bound gather+reduce; the XLA path is already optimal
    # on TPU for a single query token (no flash tiling needed).
    del impl
    return ref.decode_attention_ref(q, k, v, pos)


def newton_schulz(x: jax.Array, *, steps: int = 5, impl: str = "xla") -> jax.Array:
    """Batched (…, m, n) Newton–Schulz with impl dispatch."""
    if impl == "xla":
        return ns_xla(x, steps=steps)
    interpret = impl == "interpret"

    def one(m):
        transposed = m.shape[0] > m.shape[1]
        m2 = m.T if transposed else m
        out = newton_schulz_pallas(m2, steps=steps, interpret=interpret)
        return out.T if transposed else out

    if x.ndim == 2:
        return one(x).astype(x.dtype)
    flat = x.reshape((-1,) + x.shape[-2:])
    out = jax.lax.map(one, flat)
    return out.reshape(x.shape).astype(x.dtype)


def lowrank_update(
    p: jax.Array, g: jax.Array, r_state: jax.Array, beta: float, coeff: float,
    *, impl: str = "xla",
) -> jax.Array:
    if impl == "xla":
        return ref.lowrank_update_ref(p, g, r_state, beta, coeff)
    return _lowrank_update(
        p, g, r_state, beta, coeff, interpret=(impl == "interpret")
    )


def ssd(
    x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
    d: jax.Array, *, chunk: int = 64, impl: str = "xla",
) -> tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD over a full sequence; returns (y, final_state)."""
    if impl == "xla":
        return ref.ssd_chunked_ref(x, dt, a, b, c, d, chunk)
    y, sfin = _ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=(impl == "interpret"))
    y = y + d[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), sfin


def ssd_decode_step(state, x, dt, a, b, c, d):
    return ref.ssd_decode_ref(state, x, dt, a, b, c, d)
