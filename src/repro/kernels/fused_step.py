"""Fused back-projection epilogue kernel:  out = scale·(P @ S) + decay·W.

The last stage of every low-rank optimizer step back-projects the
projected-space update and then runs elementwise chain-tail epilogues over
the full ``(m, n)`` result — ``-lr·u`` (scale_by_lr), ``+ wd·W``
(add_decayed_weights), GaLore's alpha (scale_by_factor).  As separate
launches each of those is an extra full-shape HBM round-trip after the GEMM.
This kernel keeps the ``(bm, bn)`` GEMM tile in VMEM and applies the whole
affine epilogue before the single store, so the chained path's write-back is
one launch per family stack:

    update = scale · (P @ S) + decay · W

``scale`` / ``decay`` are *traced* scalars (the learning rate comes from a
schedule), so they ride in SMEM as a ``(1, 2)`` operand rather than being
baked into the kernel as static constants.

Like the other low-rank kernels, the batch axis is a native grid dimension
(one ``pallas_call`` per stacked family, never ``jax.vmap``), and this file
keeps the bare tile-divisibility contract — ragged shapes are padded by the
wrapper in :mod:`repro.kernels.dispatch` (zero-padding is exact: padded P
rows / S columns contribute zeros, and padded W entries are zero, so the
sliced-back result is untouched).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _epilogue_kernel(sd_ref, p_ref, s_ref, out_ref):
    scale = sd_ref[0, 0]
    p = p_ref[0].astype(jnp.float32)  # (bm, r)
    s = s_ref[0].astype(jnp.float32)  # (r, bn)
    out_ref[0] = (scale * (p @ s)).astype(out_ref.dtype)


def _epilogue_w_kernel(sd_ref, p_ref, s_ref, w_ref, out_ref):
    scale, decay = sd_ref[0, 0], sd_ref[0, 1]
    p = p_ref[0].astype(jnp.float32)  # (bm, r)
    s = s_ref[0].astype(jnp.float32)  # (r, bn)
    w = w_ref[0].astype(jnp.float32)  # (bm, bn)
    out_ref[0] = (scale * (p @ s) + decay * w).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def back_project_epilogue_batched(
    p: jax.Array,
    s: jax.Array,
    w: jax.Array | None,
    scale_decay: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Batched fused write-back: p (L, m, r), s (L, r, n), w (L, m, n) or
    None, scale_decay (1, 2) fp32 -> scale·(P@S) + decay·W, (L, m, n).

    The whole contraction dim r (<= 512) is resident per tile, so each
    (bm, bn) output tile is one MXU matmul plus a VPU affine — no reduction
    loop, no scratch, one HBM store."""
    L, m, r = p.shape
    _, _, n = s.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0
    grid = (L, m // block_m, n // block_n)
    sd_spec = pl.BlockSpec((1, 2), lambda l, mi, ni: (0, 0),
                           memory_space=pltpu.SMEM)
    p_spec = pl.BlockSpec((1, block_m, r), lambda l, mi, ni: (l, mi, 0))
    s_spec = pl.BlockSpec((1, r, block_n), lambda l, mi, ni: (l, 0, ni))
    o_spec = pl.BlockSpec((1, block_m, block_n), lambda l, mi, ni: (l, mi, ni))
    out_shape = jax.ShapeDtypeStruct((L, m, n), jnp.float32)
    if w is None:
        return pl.pallas_call(
            _epilogue_kernel,
            grid=grid,
            in_specs=[sd_spec, p_spec, s_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(scale_decay, p, s)
    w_spec = pl.BlockSpec((1, block_m, block_n), lambda l, mi, ni: (l, mi, ni))
    return pl.pallas_call(
        _epilogue_w_kernel,
        grid=grid,
        in_specs=[sd_spec, p_spec, s_spec, w_spec],
        out_specs=o_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(scale_decay, p, s, w)


def back_project_epilogue(
    p: jax.Array,
    s: jax.Array,
    w: jax.Array | None,
    scale,
    decay,
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Single-matrix form: p (m, r), s (r, n), w (m, n) or None."""
    sd = jnp.stack([jnp.asarray(scale, jnp.float32),
                    jnp.asarray(decay, jnp.float32)]).reshape(1, 2)
    out = back_project_epilogue_batched(
        p[None], s[None], None if w is None else w[None], sd,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return out[0]
