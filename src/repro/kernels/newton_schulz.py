"""Pallas TPU kernels for the Muon Newton–Schulz iteration.

One quintic NS step is  X' = a·X + (b·G + c·G²) @ X  with the Gram matrix
G = X Xᵀ.  In GUM's low-rank branch X = R has shape (r, n) with r ≤ 512, so
G is at most (512, 512) — it fits VMEM whole.  We therefore split the step
into two MXU-friendly kernels plus an O(r³) polynomial evaluated inline:

  1. :func:`gram`          — G = X Xᵀ, reduction tiled over n (grid-minor,
                             accumulating into a VMEM scratch).
  2. :func:`poly_matmul_axpy` — Y = a·X + A2 @ X with A2 = b·G + c·G², tiled
                             over n; A2 is broadcast (block-constant) so it is
                             loaded to VMEM once per n tile.

The (r, r) polynomial A2 = b·G + c·G@G stays in jnp — it's ~2r³ FLOPs,
negligible next to the 2·r²·n Gram/apply work, and XLA fuses it fine.

Both kernels run on a (L, nblocks) grid so a stacked family (L, m, n) is a
single ``pallas_call`` (``jax.vmap`` would renumber the ``pl.program_id``
axis the Gram reduction keys on).  2-D inputs are lifted to L=1.  Ragged n
is handled by the padding wrappers in :mod:`repro.kernels.dispatch`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.newton_schulz import NS_COEFFS


def _gram_kernel(x_ref, g_ref, acc, *, nblocks):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[0].astype(jnp.float32)  # (m, bn)
    acc[...] += x @ x.T

    @pl.when(ki == nblocks - 1)
    def _done():
        g_ref[0] = acc[...].astype(g_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram(x: jax.Array, *, block_n: int = 512, interpret: bool = False) -> jax.Array:
    """G = X Xᵀ for X (m, n) or (L, m, n); the m side must fit VMEM (m ≤ ~1024)."""
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    L, m, n = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0, "pad n to a block multiple (see kernels.dispatch)"
    nblocks = n // block_n
    out = pl.pallas_call(
        functools.partial(_gram_kernel, nblocks=nblocks),
        grid=(L, nblocks),
        in_specs=[pl.BlockSpec((1, m, block_n), lambda l, k: (l, 0, k))],
        out_specs=pl.BlockSpec((1, m, m), lambda l, k: (l, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, m, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, m), jnp.float32)],
        interpret=interpret,
    )(x)
    return out[0] if squeeze else out


def _poly_apply_kernel(a2_ref, x_ref, y_ref, *, a: float):
    x = x_ref[0].astype(jnp.float32)
    a2 = a2_ref[0].astype(jnp.float32)
    y_ref[0] = (a * x + a2 @ x).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("a", "block_n", "interpret"))
def poly_matmul_axpy(
    a2: jax.Array, x: jax.Array, a: float, *, block_n: int = 512, interpret: bool = False
) -> jax.Array:
    """Y = a·X + A2 @ X for A2 (..., m, m), X (..., m, n), tiled over n."""
    squeeze = x.ndim == 2
    if squeeze:
        a2, x = a2[None], x[None]
    L, m, n = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    out = pl.pallas_call(
        functools.partial(_poly_apply_kernel, a=a),
        grid=(L, n // block_n),
        in_specs=[
            pl.BlockSpec((1, m, m), lambda l, k: (l, 0, 0)),
            pl.BlockSpec((1, m, block_n), lambda l, k: (l, 0, k)),
        ],
        out_specs=pl.BlockSpec((1, m, block_n), lambda l, k: (l, 0, k)),
        out_shape=jax.ShapeDtypeStruct((L, m, n), jnp.float32),
        interpret=interpret,
    )(a2, x)
    return out[0] if squeeze else out


def ns_iteration(
    x: jax.Array, *, block_n: int = 512, interpret: bool = False
) -> jax.Array:
    """One fused NS step via the two kernels (fp32 in/out, 2-D or batched)."""
    a, b, c = NS_COEFFS
    g = gram(x, block_n=block_n, interpret=interpret)
    a2 = b * g + c * (g @ g)  # (..., m, m) — tiny, stays in XLA
    return poly_matmul_axpy(a2, x, a, block_n=block_n, interpret=interpret)


def newton_schulz_pallas(
    x: jax.Array,
    *,
    steps: int = 5,
    eps: float = 1e-7,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Pallas Newton–Schulz on (m, n) or a stacked (L, m, n) family with
    m <= n (transposition and ragged-shape padding are handled by the
    dispatch wrapper :func:`repro.kernels.dispatch.newton_schulz`)."""
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    norm = jnp.linalg.norm(x, axis=(-2, -1), keepdims=True)
    x = x / (norm + eps)
    for _ in range(steps):
        x = ns_iteration(x, block_n=block_n, interpret=interpret)
    return x.astype(orig_dtype)
