"""Pallas TPU kernels for the Muon Newton–Schulz iteration.

One quintic NS step is  X' = a·X + (b·G + c·G²) @ X  with the Gram matrix
G = X Xᵀ.  In GUM's low-rank branch X = R has shape (r, n) with r ≤ 512, so
G is at most (512, 512) — it fits VMEM whole.  We therefore split the step
into two MXU-friendly kernels plus an O(r³) polynomial evaluated inline:

  1. :func:`gram`          — G = X Xᵀ, reduction tiled over n (grid-minor,
                             accumulating into a VMEM scratch).
  2. :func:`poly_matmul_axpy` — Y = a·X + A2 @ X with A2 = b·G + c·G², tiled
                             over n; A2 is broadcast (block-constant) so it is
                             loaded to VMEM once per n tile.

The (r, r) polynomial A2 = b·G + c·G@G stays in jnp — it's ~2r³ FLOPs,
negligible next to the 2·r²·n Gram/apply work, and XLA fuses it fine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.newton_schulz import NS_COEFFS


def _gram_kernel(x_ref, g_ref, acc, *, nblocks):
    ki = pl.program_id(0)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...].astype(jnp.float32)  # (m, bn)
    acc[...] += x @ x.T

    @pl.when(ki == nblocks - 1)
    def _done():
        g_ref[...] = acc[...].astype(g_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram(x: jax.Array, *, block_n: int = 512, interpret: bool = False) -> jax.Array:
    """G = X Xᵀ for X (m, n); the m side must fit VMEM (m ≤ ~1024)."""
    m, n = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0, "pad n to a block multiple"
    nblocks = n // block_n
    return pl.pallas_call(
        functools.partial(_gram_kernel, nblocks=nblocks),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((m, block_n), lambda k: (0, k))],
        out_specs=pl.BlockSpec((m, m), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, m), jnp.float32)],
        interpret=interpret,
    )(x)


def _poly_apply_kernel(a2_ref, x_ref, y_ref, *, a: float):
    x = x_ref[...].astype(jnp.float32)
    a2 = a2_ref[...].astype(jnp.float32)
    y_ref[...] = (a * x + a2 @ x).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("a", "block_n", "interpret"))
def poly_matmul_axpy(
    a2: jax.Array, x: jax.Array, a: float, *, block_n: int = 512, interpret: bool = False
) -> jax.Array:
    """Y = a·X + A2 @ X for A2 (m, m), X (m, n), tiled over n."""
    m, n = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    return pl.pallas_call(
        functools.partial(_poly_apply_kernel, a=a),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((m, m), lambda k: (0, 0)),
            pl.BlockSpec((m, block_n), lambda k: (0, k)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a2, x)


def ns_iteration(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """One fused NS step via the two kernels (fp32 in/out)."""
    a, b, c = NS_COEFFS
    g = gram(x, interpret=interpret)
    a2 = b * g + c * (g @ g)  # (m, m) — tiny, stays in XLA
    return poly_matmul_axpy(a2, x, a, interpret=interpret)


def newton_schulz_pallas(
    x: jax.Array, *, steps: int = 5, eps: float = 1e-7, interpret: bool = False
) -> jax.Array:
    """Drop-in replacement for core.newton_schulz on a single (m, n) matrix
    with m <= n (transpose handled by the wrapper in ops.py)."""
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    norm = jnp.linalg.norm(x)
    x = x / (norm + eps)
    for _ in range(steps):
        x = ns_iteration(x, interpret=interpret)
    return x.astype(orig_dtype)
