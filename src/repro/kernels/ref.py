"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

Shapes convention:
  attention:      q (B, S, H, D), k/v (B, T, KV, D), GQA via H % KV == 0
  newton-schulz:  x (m, n)
  lowrank update: p (m, r), g (m, n), r_state (r, n)
  ssd (Mamba-2):  x (B, S, H, P), dt (B, S, H), a (H,), b/c (B, S, N)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ attention


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    kv_len: jax.Array | None = None,
) -> jax.Array:
    """Softmax attention with GQA; fp32 softmax; optional causal/kv-length mask."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, S, KV, G, D)
    # fp32 ACCUMULATION via preferred_element_type — no materialized fp32
    # copies of K/V (matters enormously for decode over a 32k+ cache).
    logits = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    )
    logits *= scale
    T = k.shape[1]
    mask = jnp.ones((S, T), bool)
    if causal:
        # queries are the last S positions of the T-long kv sequence
        offset = T - S
        mask &= jnp.arange(T)[None, :] <= (jnp.arange(S)[:, None] + offset)
    if kv_len is not None:
        mask &= jnp.arange(T)[None, :] < kv_len
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum(
        "bkgst,btkd->bskgd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, S, H, D).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array
) -> jax.Array:
    """Single-step decode: q (B, 1, H, D) over a (B, Smax, KV, D) cache with
    valid length pos+1 (positions 0..pos)."""
    return attention_ref(q, k, v, causal=False, kv_len=pos + 1)


def attention_chunked_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_kv: int = 512,
) -> jax.Array:
    """Flash-algorithm attention in pure XLA: lax.scan over KV blocks with a
    running (max, denom, accumulator) — the lowering-compatible analogue of
    the Pallas kernel.  Peak score memory drops from O(S·T) to O(S·block_kv)
    per head; numerically identical to :func:`attention_ref` (fp32 softmax).
    """
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    block_kv = min(block_kv, T)
    assert T % block_kv == 0, "pad kv to a block multiple"
    nblk = T // block_kv

    qg = q.reshape(B, S, KV, G, D)
    kb = jnp.moveaxis(k.reshape(B, nblk, block_kv, KV, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, block_kv, KV, D), 1, 0)
    rows = jnp.arange(S) + (T - S)  # causal row offset for short q

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, blk_i = inp
        s = jnp.einsum(
            "bskgd,btkd->bkgst", qg, kblk, preferred_element_type=jnp.float32
        ) * scale                                           # (B,KV,G,S,bkv)
        if causal:
            cols = blk_i * block_kv + jnp.arange(block_kv)
            mask = cols[None, :] <= rows[:, None]           # (S, bkv)
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> nan
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isneginf(s), 0.0, p)
        alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l = alpha * l + jnp.sum(p, axis=-1)
        upd = jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(v.dtype), vblk,
            preferred_element_type=jnp.float32,
        )
        acc = alpha[..., None] * acc + upd
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nblk))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,KV,G,S,D)
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, D)
    return out.astype(q.dtype)


# ------------------------------------------------------------ newton-schulz


def ns_iteration_ref(x: jax.Array, a: float, b: float, c: float) -> jax.Array:
    """One quintic NS iteration: a X + (b XXᵀ + c (XXᵀ)²) X, fp32."""
    x = x.astype(jnp.float32)
    xxt = x @ x.T
    return a * x + (b * xxt + c * (xxt @ xxt)) @ x


def gram_ref(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.float32)
    return x @ x.T


def poly_matmul_axpy_ref(a2: jax.Array, x: jax.Array, a: float) -> jax.Array:
    """a X + A2 @ X (the second half of an NS iteration)."""
    return a * x.astype(jnp.float32) + a2.astype(jnp.float32) @ x.astype(jnp.float32)


# ------------------------------------------------------------ low-rank update


def lowrank_update_ref(
    p: jax.Array, g: jax.Array, r_state: jax.Array, beta: float, coeff: float
) -> jax.Array:
    """Fused GUM/GaLore momentum update: R' = beta R + coeff · Pᵀ G."""
    return beta * r_state.astype(jnp.float32) + coeff * (
        p.astype(jnp.float32).T @ g.astype(jnp.float32)
    )


def back_project_ref(p: jax.Array, s: jax.Array) -> jax.Array:
    """Back-projection GEMM: P (m, r) @ S (r, n) -> (m, n)."""
    return p.astype(jnp.float32) @ s.astype(jnp.float32)


def back_project_epilogue_ref(
    p: jax.Array, s: jax.Array, w: jax.Array | None, scale, decay
) -> jax.Array:
    """Fused write-back: scale·(P @ S) + decay·W (W optional)."""
    out = scale * (p.astype(jnp.float32) @ s.astype(jnp.float32))
    if w is not None:
        out = out + decay * w.astype(jnp.float32)
    return out


# ------------------------------------------------------------ Mamba-2 SSD


def ssd_ref(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)   (post-softplus)
    a: jax.Array,   # (H,)        negative (A = -exp(a_log))
    b: jax.Array,   # (B, S, N)
    c: jax.Array,   # (B, S, N)
    d: jax.Array,   # (H,)        skip
) -> tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence (the slow exact oracle).

    state S_t = exp(a·dt_t) S_{t-1} + dt_t · b_t ⊗ x_t        (N, P) per head
    y_t     = c_tᵀ S_t + d · x_t
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(a[None, :] * dtt)  # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhnp", bt, dtt, xt)
        state = decay[..., None, None] * state + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c.astype(jnp.float32), 1, 0),
    )
    state, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1) + d[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), state


def ssd_chunked_ref(x, dt, a, b, c, d, chunk: int) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD (state-space duality form) — the algorithm the Pallas
    kernel implements; mathematically equal to :func:`ssd_ref`.

    Per chunk (length c): with per-step log-decay g_i = a·dt_i and cumulative
    G_i = sum_{j<=i} g_j,
      intra:  Y = ((C Bᵀ) ⊙ L) (dt ⊙ X),  L_ij = exp(G_i - G_j) for i>=j
      inter:  Y += (C ⊙ exp(G)) S_prev
      state:  S = exp(G_c) S_prev + (B ⊙ dt ⊙ exp(G_c - G))ᵀ X
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # zero-pad: dt=0 makes padded steps exact identity updates
        # (decay exp(0)=1, zero state increment), so the final state and the
        # unpadded outputs are untouched.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nch = S_pad // chunk

    x32 = x.astype(jnp.float32).reshape(B, nch, chunk, H, P)
    dt32 = dt.astype(jnp.float32).reshape(B, nch, chunk, H)
    b32 = b.astype(jnp.float32).reshape(B, nch, chunk, N)
    c32 = c.astype(jnp.float32).reshape(B, nch, chunk, N)

    g = a[None, None, None, :] * dt32                    # (B, nch, c, H)
    G = jnp.cumsum(g, axis=2)                            # inclusive cumsum

    def chunk_step(state, inp):
        xc, dtc, bc, cc, gc, Gc = inp
        # L (lower-tri decay): exp(G_i - G_j) for i >= j else 0
        diff = Gc[:, :, None, :] - Gc[:, None, :, :]      # (B, c, c, H)
        ii = jnp.arange(chunk)
        tri = (ii[:, None] >= ii[None, :])[None, :, :, None]
        # mask BEFORE exp: upper-tri diff > 0 would overflow and poison grads
        L = jnp.exp(jnp.where(tri, diff, -jnp.inf))
        cb = jnp.einsum("bin,bjn->bij", cc, bc)           # (B, c, c)
        y = jnp.einsum("bij,bijh,bjh,bjhp->bihp", cb, L, dtc, xc)
        # inter-chunk from carried state
        y += jnp.einsum("bin,bih,bhnp->bihp", cc, jnp.exp(Gc), state)
        # new carry
        Gl = Gc[:, -1:, :]                                # (B, 1, H)
        w = dtc * jnp.exp(Gl - Gc)                        # (B, c, H)
        state = jnp.exp(Gl[:, 0, :, None, None]) * state + jnp.einsum(
            "bjn,bjh,bjhp->bhnp", bc, w, xc
        )
        return state, y

    state0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x32, dt32, b32, c32, g, G))
    state, ys = jax.lax.scan(chunk_step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S_pad, H, P)[:, :S]
    y = y + d[None, None, :, None] * x.astype(jnp.float32)[:, :S]
    return y.astype(x.dtype), state


def ssd_decode_ref(state, x, dt, a, b, c, d):
    """One decode step. state (B,H,N,P); x (B,H,P); dt (B,H); b/c (B,N)."""
    decay = jnp.exp(a[None, :] * dt)
    state = decay[..., None, None] * state + jnp.einsum("bn,bh,bhp->bhnp", b, dt, x)
    y = jnp.einsum("bn,bhnp->bhp", c, state) + d[None, :, None] * x
    return y, state
