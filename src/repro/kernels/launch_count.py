"""Trace-time kernel-launch counting for the dispatch layer.

Every dispatched optimizer op (``lowrank_update``, ``project``,
``back_project``, ``back_project_epilogue``, ``newton_schulz``) records one
count per *call* while a :func:`count_launches` context is active.  Because
the dispatchers run at trace time under ``jit``, counting the Python-level
calls counts exactly the kernel launches (``pallas_call``s, or their jnp
fallback ops) the compiled step will contain — which is how
``benchmarks/fused_step.py`` proves the family-stacked engine launches per
shape family, not per leaf.

Usage::

    with count_launches() as counts:
        jax.eval_shape(lambda: opt.update(grads, state, params))
    # counts == {"lowrank_update": 3, "newton_schulz": 3, ...}

:func:`assert_launches` upgrades the counter to a trace-time *assertion*:
the static-analysis layer (``repro.analysis``) computes the closed-form
expected counts from the optimizer's chain composition and
:class:`~repro.core.family_plan.FamilyPlan`, and a mismatch raises
:class:`LaunchCountMismatch` before a single real step runs.

Deliberately dependency-free itself (no jax import); :mod:`repro.core`
callers lazy-import it inside function bodies because the kernels package's
module-load imports run the other way (kernels.newton_schulz pulls
NS_COEFFS from core.newton_schulz).
"""
from __future__ import annotations

import contextlib
from typing import Iterator

# Every op name the dispatch layer may record — the closed vocabulary the
# closed-form launch model (repro.analysis.launch_model) and the assertion
# below validate against.
DISPATCH_OPS = (
    "lowrank_update",
    "project",
    "back_project",
    "back_project_epilogue",
    "newton_schulz",
)

# Collective primitives the sharded-step auditor
# (repro.analysis.collectives) records alongside the dispatch ops when it
# walks a shard_map'ped jaxpr — one count per collective *equation*, so a
# tree-level psum over N gradient leaves counts once, mirroring the single
# wire operation it becomes.
COLLECTIVE_OPS = (
    "psum",
    "all_gather",
    "reduce_scatter",
    "all_to_all",
    "ppermute",
)

_KNOWN_OPS = DISPATCH_OPS + COLLECTIVE_OPS

_ACTIVE: list[dict[str, int]] = []


def record(op: str) -> None:
    """Count one launch of ``op`` in every active counter (no-op otherwise)."""
    for counts in _ACTIVE:
        counts[op] = counts.get(op, 0) + 1


@contextlib.contextmanager
def count_launches() -> Iterator[dict[str, int]]:
    counts: dict[str, int] = {}
    _ACTIVE.append(counts)
    try:
        yield counts
    finally:
        _ACTIVE.remove(counts)


class LaunchCountMismatch(AssertionError):
    """Traced launch counts diverged from the closed-form expectation."""

    def __init__(self, expected: dict[str, int], actual: dict[str, int]):
        self.expected = dict(expected)
        self.actual = dict(actual)
        diff = []
        for op in sorted(set(expected) | set(actual)):
            e, a = expected.get(op, 0), actual.get(op, 0)
            if e != a:
                diff.append(f"{op}: expected {e}, traced {a}")
        super().__init__(
            "kernel-launch count mismatch — " + "; ".join(diff)
            + f" (expected {format_counts(expected)},"
            + f" traced {format_counts(actual)})"
        )


def format_counts(counts: dict[str, int]) -> str:
    """Stable one-line rendering: ``total [op=n, ...]`` in op order."""
    total = sum(counts.values())
    parts = [f"{op}={counts[op]}" for op in _KNOWN_OPS if counts.get(op)]
    parts += [f"{op}={n}" for op, n in sorted(counts.items())
              if op not in _KNOWN_OPS]
    return f"{total} [{', '.join(parts)}]"


@contextlib.contextmanager
def assert_launches(expected: dict[str, int]) -> Iterator[dict[str, int]]:
    """Count launches over the body and raise :class:`LaunchCountMismatch`
    unless they equal ``expected`` exactly (ops absent from ``expected``
    must not appear at all).  Run the body under ``jax.eval_shape`` /
    ``jax.make_jaxpr`` for a pure trace-time check — no math executes::

        with assert_launches({"project": 3, "back_project": 3}):
            jax.eval_shape(lambda: opt.update(grads, state, params))
    """
    for op in expected:
        if op not in _KNOWN_OPS:
            raise ValueError(f"unknown op in expectation: {op!r} "
                             f"(known: {_KNOWN_OPS})")
    with count_launches() as counts:
        yield counts
    clean = {op: n for op, n in expected.items() if n}
    if counts != clean:
        raise LaunchCountMismatch(clean, counts)
