"""Trace-time kernel-launch counting for the dispatch layer.

Every dispatched optimizer op (``lowrank_update``, ``project``,
``back_project``, ``back_project_epilogue``, ``newton_schulz``) records one
count per *call* while a :func:`count_launches` context is active.  Because
the dispatchers run at trace time under ``jit``, counting the Python-level
calls counts exactly the kernel launches (``pallas_call``s, or their jnp
fallback ops) the compiled step will contain — which is how
``benchmarks/fused_step.py`` proves the family-stacked engine launches per
shape family, not per leaf.

Usage::

    with count_launches() as counts:
        jax.eval_shape(lambda: opt.update(grads, state, params))
    # counts == {"lowrank_update": 3, "newton_schulz": 3, ...}

Deliberately dependency-free itself (no jax import); :mod:`repro.core`
callers lazy-import it inside function bodies because the kernels package's
module-load imports run the other way (kernels.newton_schulz pulls
NS_COEFFS from core.newton_schulz).
"""
from __future__ import annotations

import contextlib
from typing import Iterator

_ACTIVE: list[dict[str, int]] = []


def record(op: str) -> None:
    """Count one launch of ``op`` in every active counter (no-op otherwise)."""
    for counts in _ACTIVE:
        counts[op] = counts.get(op, 0) + 1


@contextlib.contextmanager
def count_launches() -> Iterator[dict[str, int]]:
    counts: dict[str, int] = {}
    _ACTIVE.append(counts)
    try:
        yield counts
    finally:
        _ACTIVE.remove(counts)
