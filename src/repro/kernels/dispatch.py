"""Kernel dispatch: route optimizer hot loops to Pallas or pure-jnp.

Every low-rank optimizer step has two hot loops — the projected momentum
update ``R' = beta·R + coeff·PᵀG`` and the Muon Newton–Schulz iteration.
The fused Pallas TPU kernels for both live in
:mod:`repro.kernels.lowrank_update` / :mod:`repro.kernels.newton_schulz`;
this module is the single entry point that decides, per call, which
implementation actually runs:

  impl="auto"      — Pallas on TPU, the jnp reference elsewhere (default).
  impl="jnp"/"xla" — the pure-jnp reference path, everywhere.
  impl="pallas"    — the Pallas kernel; off-TPU it degrades to the Pallas
                     interpreter so the kernel code is still exercised
                     (this is what CI parity tests rely on).
  impl="interpret" — the Pallas interpreter explicitly.

On top of backend selection the dispatchers add what the raw kernels
deliberately do not have:

  * shape-legality checks — shapes whose VMEM working set cannot fit
    (rank > MAX_LOWRANK_RANK, NS Gram side > MAX_NS_DIM) silently fall
    back to the jnp reference instead of failing to compile;
  * padding-aware wrappers — ragged (non tile-divisible) ``(m, n)`` are
    zero-padded to legal tiles and the result sliced back, which is exact
    for both ops (zero rows/columns contribute nothing to PᵀG or X Xᵀ and
    stay zero through the NS iteration);
  * family batching — ``(*lead, m, n)`` stacked families are flattened to
    one leading axis and run through the kernels' native batch grid, so a
    whole family is a single ``pallas_call``.  (The kernels carry their own
    batch grid axis rather than relying on ``jax.vmap``, whose batching
    rule would renumber the ``pl.program_id`` axes inside the kernels.)

``KernelEntry``/``REGISTRY`` (re-exported as ``repro.kernels.KERNEL_REGISTRY``)
name each dispatched op with its reference oracle and legality predicate, so
benchmarks and tests can enumerate the dispatch surface.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import launch_count, ref
from .fused_step import back_project_epilogue_batched
from .lowrank_update import (
    back_project_batched,
    lowrank_update_batched,
    project_batched,
)
from .newton_schulz import newton_schulz_pallas

VALID_IMPLS = ("auto", "jnp", "xla", "pallas", "interpret")

# VMEM working-set bounds (fp32): the lowrank kernel keeps an (r, block_n)
# accumulator plus (block_m, r) / (block_m, block_n) tiles resident; the NS
# kernels keep the whole (m, m) Gram matrix resident.
MAX_LOWRANK_RANK = 512
MAX_NS_DIM = 1024

_LANE = 128   # TPU lane width: last-dim tiling granule
_SUBLANE = 8  # fp32 sublane granule


def _rank_granule(pad_rank_to: int) -> int:
    """Opt-in lane-aligned rank padding: ``pad_rank_to=128`` rounds the rank
    axis up to a full MXU lane multiple (e.g. r=96 -> 128) so the (bm, r) /
    (r, bn) tiles hit peak systolic-array utilization; 0 keeps the minimal
    fp32 sublane granule.  Zero-padding the rank axis is exact for every
    dispatched op: padded P columns are zero, so PᵀG gains zero rows (sliced
    off), R gains zero rows (beta·0 stays 0), and P @ S is untouched."""
    if pad_rank_to < 0:
        raise ValueError(f"pad_rank_to must be >= 0, got {pad_rank_to}")
    return max(_SUBLANE, _round_up(pad_rank_to, _SUBLANE)) if pad_rank_to else _SUBLANE


def backend() -> str:
    """The default JAX backend ("tpu" | "gpu" | "cpu")."""
    return jax.default_backend()


def resolve_impl(impl: str) -> str:
    """Normalize an impl request to one of {"jnp", "pallas", "interpret"}.

    "auto" picks Pallas on TPU and jnp elsewhere; an explicit "pallas" off
    TPU degrades to the interpreter so the kernel code still runs.
    """
    if impl not in VALID_IMPLS:
        raise ValueError(f"impl must be one of {VALID_IMPLS}, got {impl!r}")
    if impl in ("jnp", "xla"):
        return "jnp"
    if impl == "auto":
        return "pallas" if backend() == "tpu" else "jnp"
    if impl == "pallas" and backend() != "tpu":
        return "interpret"
    return impl


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _pad_and_block(dim: int, target: int, granule: int) -> tuple[int, int]:
    """(padded dim, block) for tiling one axis.  Prefers a granule-multiple
    block in [target/4, target] that divides the granule-padded dim exactly
    (zero extra padding); when none exists (e.g. 8·prime dims, whose only
    divisor-block would be a tiny MXU-starving granule), pads up to a full
    target multiple instead — bounded extra padding, full-size blocks."""
    target = max(granule, _round_up(target, granule))
    dim_pad = _round_up(dim, granule)
    if dim_pad <= target:
        return dim_pad, dim_pad  # single block
    floor = max(granule, target // 4)
    for b in range(target, floor - 1, -granule):
        if dim_pad % b == 0:
            return dim_pad, b
    return _round_up(dim_pad, target), target


def _pad_axis(x: jax.Array, axis: int, new_dim: int) -> jax.Array:
    pad = new_dim - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _flatten_lead(x: jax.Array) -> jax.Array:
    """(*lead, a, b) -> (L, a, b).  Pallas calls are per-device (they run
    under shard_map / fully replicated optimizer math), so this reshape is
    invisible to GSPMD — the no-lead-reshape rule in lowrank_common applies
    to the partitioned jnp path, not here."""
    return x.reshape((-1,) + x.shape[-2:])


# --------------------------------------------------------------------------
# Fused low-rank momentum update:  R' = beta·R + coeff·<P, G>
# --------------------------------------------------------------------------


def lowrank_update_supported(p: jax.Array, g: jax.Array, side: str) -> bool:
    """Legality of the fused kernel for this family shape."""
    if side not in ("left", "right"):
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    return int(p.shape[-1]) <= MAX_LOWRANK_RANK


def _project_jnp(p: jax.Array, g: jax.Array, side: str) -> jax.Array:
    """The fp32 jnp oracle for PᵀG / G P shared by every fallback path —
    delegates to lowrank_common.project (safe non-lazy import: lowrank_common
    only imports this module inside function bodies)."""
    from repro.core.lowrank_common import project

    return project(p.astype(jnp.float32), g.astype(jnp.float32), side)


def _lowrank_kernel_form(p, g, r_state, side, pad_rank_to: int = 0):
    """Normalize (p, g[, r_state]) to the kernel's left-side batched layout:
    flatten leads, transpose the right side ((G P)ᵀ = Pᵀ Gᵀ), zero-pad to
    tile-legal shapes.  Zero rows/cols are exact: they add nothing to PᵀG,
    and padded R rows/cols are zero so beta·R stays zero there.  Returns the
    prepared operands plus everything needed to undo the normalization."""
    lead = g.shape[:-2]
    if side == "right":
        g = jnp.swapaxes(g, -1, -2)
        if r_state is not None:
            r_state = jnp.swapaxes(r_state, -1, -2)
    pk, gk = _flatten_lead(p), _flatten_lead(g)
    m, r = int(pk.shape[-2]), int(pk.shape[-1])
    n = int(gk.shape[-1])
    m_pad, bm = _pad_and_block(m, 256, _SUBLANE)
    n_pad, bn = _pad_and_block(n, 512, _LANE)
    r_pad = _round_up(r, _rank_granule(pad_rank_to))
    pk = _pad_axis(_pad_axis(pk, -2, m_pad), -1, r_pad)
    gk = _pad_axis(_pad_axis(gk, -2, m_pad), -1, n_pad)
    rk = None
    if r_state is not None:
        rk = _pad_axis(_pad_axis(_flatten_lead(r_state), -2, r_pad), -1, n_pad)
    return pk, gk, rk, (lead, r, n, bm, bn)


def _lowrank_unkernel_form(out, lead, r, n, side):
    out = out[..., :r, :n].reshape(lead + (r, n))
    return jnp.swapaxes(out, -1, -2) if side == "right" else out


def lowrank_update(
    p: jax.Array,
    g: jax.Array,
    r_state: jax.Array,
    beta: float,
    coeff: float,
    *,
    side: str = "left",
    impl: str = "auto",
    pad_rank_to: int = 0,
) -> jax.Array:
    """Dispatched momentum update over a family ``g (*lead, m, n)``.

    left  side: p (*lead, m, r), r_state (*lead, r, n) -> beta·R + coeff·PᵀG
    right side: p (*lead, n, r), r_state (*lead, m, r) -> beta·R + coeff·G P

    Returns fp32, identical (within fp32 roundoff) across impls.
    ``pad_rank_to`` opts into lane-aligned rank padding (see _rank_granule).
    """
    impl = resolve_impl(impl)
    if impl != "jnp" and not lowrank_update_supported(p, g, side):
        impl = "jnp"
    launch_count.record("lowrank_update")
    if impl == "jnp":
        return beta * r_state.astype(jnp.float32) + coeff * _project_jnp(p, g, side)

    pk, gk, rk, (lead, r, n, bm, bn) = _lowrank_kernel_form(
        p, g, r_state, side, pad_rank_to
    )
    out = lowrank_update_batched(
        pk, gk, rk, beta, coeff, block_m=bm, block_n=bn,
        interpret=(impl == "interpret"),
    )
    return _lowrank_unkernel_form(out, lead, r, n, side)


def project(p: jax.Array, g: jax.Array, *, side: str = "left",
            impl: str = "auto", pad_rank_to: int = 0) -> jax.Array:
    """Plain low-rank projection PᵀG / G P through the projection kernel —
    the dispatched counterpart of ``lowrank_common.project`` (used by the
    Adam-based optimizers, which consume the projected gradient itself)."""
    impl = resolve_impl(impl)
    if impl != "jnp" and not lowrank_update_supported(p, g, side):
        impl = "jnp"
    launch_count.record("project")
    if impl == "jnp":
        return _project_jnp(p, g, side)

    pk, gk, _, (lead, r, n, bm, bn) = _lowrank_kernel_form(
        p, g, None, side, pad_rank_to
    )
    out = project_batched(
        pk, gk, 1.0, block_m=bm, block_n=bn, interpret=(impl == "interpret")
    )
    return _lowrank_unkernel_form(out, lead, r, n, side)


# --------------------------------------------------------------------------
# Back-projection GEMM:  P @ S  /  S @ Pᵀ
# --------------------------------------------------------------------------


def back_project_supported(p: jax.Array, s: jax.Array, side: str) -> bool:
    """The back-projection kernel keeps the whole rank axis resident."""
    if side not in ("left", "right"):
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    return int(p.shape[-1]) <= MAX_LOWRANK_RANK


def _back_project_jnp(p: jax.Array, s: jax.Array, side: str) -> jax.Array:
    from repro.core.lowrank_common import back_project as bp

    return bp(p.astype(jnp.float32), s.astype(jnp.float32), side)


def _back_project_kernel_form(p, s, w, side, pad_rank_to: int):
    """Shared Pallas prologue for both back-projection entry points:
    left-side normalization ((S @ Pᵀ)ᵀ = P @ Sᵀ; W rides along), lead
    flattening, tile padding.  Returns the prepared operands plus everything
    needed to undo the normalization."""
    lead = s.shape[:-2]
    if side == "right":
        s = jnp.swapaxes(s, -1, -2)
        if w is not None:
            w = jnp.swapaxes(w, -1, -2)
    pk, sk = _flatten_lead(p), _flatten_lead(s)
    m, r = int(pk.shape[-2]), int(pk.shape[-1])
    n = int(sk.shape[-1])
    m_pad, bm = _pad_and_block(m, 256, _SUBLANE)
    n_pad, bn = _pad_and_block(n, 512, _LANE)
    r_pad = _round_up(r, _rank_granule(pad_rank_to))
    pk = _pad_axis(_pad_axis(pk, -2, m_pad), -1, r_pad)
    sk = _pad_axis(_pad_axis(sk, -2, r_pad), -1, n_pad)
    wk = None
    if w is not None:
        wk = _pad_axis(_pad_axis(_flatten_lead(w), -2, m_pad), -1, n_pad)
    return pk, sk, wk, (lead, m, n, bm, bn)


def _back_project_unkernel_form(out, lead, m, n, side):
    out = out[..., :m, :n].reshape(lead + (m, n))
    return jnp.swapaxes(out, -1, -2) if side == "right" else out


def back_project(p: jax.Array, s: jax.Array, *, side: str = "left",
                 impl: str = "auto", pad_rank_to: int = 0) -> jax.Array:
    """Dispatched back-projection of a projected-space array ``s`` to full
    ``(*lead, m, n)`` shape — the fused counterpart of
    ``lowrank_common.back_project`` used on every optimizer step's write-back
    path (``W <- W - lr * P NS(R)``).

    left  side: p (*lead, m, r), s (*lead, r, n) -> P @ S
    right side: p (*lead, n, r), s (*lead, m, r) -> S @ Pᵀ
    """
    impl = resolve_impl(impl)
    if impl != "jnp" and not back_project_supported(p, s, side):
        impl = "jnp"
    launch_count.record("back_project")
    if impl == "jnp":
        return _back_project_jnp(p, s, side)

    pk, sk, _, (lead, m, n, bm, bn) = _back_project_kernel_form(
        p, s, None, side, pad_rank_to
    )
    out = back_project_batched(
        pk, sk, block_m=bm, block_n=bn, interpret=(impl == "interpret")
    )
    return _back_project_unkernel_form(out, lead, m, n, side)


def back_project_epilogue(
    p: jax.Array,
    s: jax.Array,
    *,
    w: jax.Array | None = None,
    scale=1.0,
    decay=0.0,
    side: str = "left",
    impl: str = "auto",
    pad_rank_to: int = 0,
) -> jax.Array:
    """Fused write-back of a projected-space update: ``scale·back_project(p,
    s) + decay·W`` in one launch, with the GEMM tile staying in VMEM through
    the affine epilogue (see :mod:`repro.kernels.fused_step`).  This is the
    materialization path of the chained API's deferred epilogue
    (``combinators.PendingBack``): scale carries -lr (and GaLore's alpha),
    decay carries -lr·wd, ``w`` the (possibly family-stacked) params.

    ``scale`` / ``decay`` may be traced scalars (schedule-driven lr).
    left  side: p (*lead, m, r), s (*lead, r, n), w (*lead, m, n)
    right side: p (*lead, n, r), s (*lead, m, r), w (*lead, m, n)
    """
    impl = resolve_impl(impl)
    if impl != "jnp" and not back_project_supported(p, s, side):
        impl = "jnp"
    launch_count.record("back_project_epilogue")
    if impl == "jnp":
        out = scale * _back_project_jnp(p, s, side)
        if w is not None:
            out = out + decay * w.astype(jnp.float32)
        return out

    pk, sk, wk, (lead, m, n, bm, bn) = _back_project_kernel_form(
        p, s, w, side, pad_rank_to
    )
    sd = jnp.stack([jnp.asarray(scale, jnp.float32),
                    jnp.asarray(decay, jnp.float32)]).reshape(1, 2)
    out = back_project_epilogue_batched(
        pk, sk, wk, sd, block_m=bm, block_n=bn,
        interpret=(impl == "interpret"),
    )
    return _back_project_unkernel_form(out, lead, m, n, side)


# --------------------------------------------------------------------------
# Newton–Schulz orthogonalization
# --------------------------------------------------------------------------


def newton_schulz_supported(x: jax.Array) -> bool:
    """The NS kernels hold the (s, s) Gram matrix (s = short side) in VMEM."""
    return min(int(x.shape[-2]), int(x.shape[-1])) <= MAX_NS_DIM


def newton_schulz(
    x: jax.Array, *, steps: int = 5, eps: float = 1e-7, impl: str = "auto",
    block_n: int = 512,
) -> jax.Array:
    """Dispatched Newton–Schulz over (..., m, n), matching
    :func:`repro.core.newton_schulz.newton_schulz` semantics."""
    from repro.core.newton_schulz import newton_schulz as ns_jnp

    impl = resolve_impl(impl)
    if impl != "jnp" and not newton_schulz_supported(x):
        impl = "jnp"
    if impl == "jnp":
        # ns_jnp records the launch itself (jnp body), so don't double count.
        return ns_jnp(x, steps=steps, eps=eps)
    launch_count.record("newton_schulz")

    interpret = impl == "interpret"
    orig_dtype = x.dtype
    lead = x.shape[:-2]

    transposed = x.shape[-2] > x.shape[-1]
    if transposed:
        x = jnp.swapaxes(x, -1, -2)
    m, n = int(x.shape[-2]), int(x.shape[-1])
    # Zero padding is exact for NS: padded rows/cols of X are zero, stay zero
    # through every iteration (Gram gains zero blocks; a·X + A2·X preserves
    # them), and the Frobenius norm used for the initial scaling is unchanged.
    m_pad = _round_up(m, _SUBLANE)
    n_pad, bn = _pad_and_block(n, block_n, _LANE)
    xk = _flatten_lead(_pad_axis(_pad_axis(x, -2, m_pad), -1, n_pad))

    out = newton_schulz_pallas(
        xk, steps=steps, eps=eps, block_n=bn, interpret=interpret
    )[..., :m, :n]
    out = out.reshape(lead + (m, n))
    if transposed:
        out = jnp.swapaxes(out, -1, -2)
    return out.astype(orig_dtype)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One dispatched op: its entry point, jnp oracle, and legality check."""

    name: str
    fn: Callable        # dispatching wrapper; accepts impl=
    reference: Callable  # pure-jnp oracle (repro.kernels.ref)
    supported: Callable  # shape-legality predicate for the Pallas path


REGISTRY: dict[str, KernelEntry] = {}


def register(entry: KernelEntry) -> KernelEntry:
    if entry.name not in launch_count.DISPATCH_OPS:
        raise ValueError(
            f"kernel name {entry.name!r} is not in launch_count.DISPATCH_OPS "
            f"{launch_count.DISPATCH_OPS} — the closed-form launch model "
            "(repro.analysis.launch_model) requires the vocabulary to be "
            "closed; extend DISPATCH_OPS first"
        )
    REGISTRY[entry.name] = entry
    return entry


def get_kernel(name: str) -> KernelEntry:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {sorted(REGISTRY)}"
        ) from None


register(KernelEntry(
    name="lowrank_update",
    fn=lowrank_update,
    reference=ref.lowrank_update_ref,
    supported=lowrank_update_supported,
))
register(KernelEntry(
    name="project",
    fn=project,
    reference=lambda p, g, *, side="left": _project_jnp(p, g, side),
    supported=lowrank_update_supported,
))
register(KernelEntry(
    name="back_project",
    fn=back_project,
    reference=ref.back_project_ref,
    supported=back_project_supported,
))
register(KernelEntry(
    name="back_project_epilogue",
    fn=back_project_epilogue,
    reference=ref.back_project_epilogue_ref,
    supported=back_project_supported,
))
def _newton_schulz_ref(x, *, steps=5, eps=1e-7):
    from repro.core.newton_schulz import newton_schulz as ns_jnp

    return ns_jnp(x, steps=steps, eps=eps)


register(KernelEntry(
    name="newton_schulz",
    fn=newton_schulz,
    reference=_newton_schulz_ref,
    supported=newton_schulz_supported,
))
