"""Flash attention (causal, GQA) as a Pallas TPU kernel.

TPU-native design (FlashAttention's insight re-tiled for VMEM/MXU, not a CUDA
port): the grid is (batch, q_heads, q_blocks, kv_blocks) with the kv axis
innermost and sequential ("arbitrary"); running max / denominator / output
accumulator live in VMEM scratch that persists across kv-grid steps, so HBM
traffic is one pass over K/V per q block and one write of O.  Block shapes
should be multiples of (8, 128) on real TPU; interpret mode (tests) accepts
any shape.

GQA is expressed in the BlockSpec index maps: the kv block for query head h
is head ``h // (H // KV)`` — no materialized K/V repetition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _compiler_params():
    try:
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        )
    except Exception:  # older/newer API drift — semantics are an optimization
        return None


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, block_q: int, block_kv: int, seq_q: int, seq_kv: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + (seq_kv - seq_q)  # causal row offset for short q
    k_start = ki * block_kv

    if causal:
        # Skip kv blocks that are fully masked for this q block.
        run = k_start <= q_start + block_q - 1
    else:
        run = True

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bkv, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = (q @ k.T) * scale                      # (bq, bkv)

        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + p @ v
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q (B, S, H, D); k/v (B, T, KV, D); returns (B, S, H, D)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    group = H // KV
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    assert S % block_q == 0 and T % block_kv == 0, "pad seq to block multiples"

    grid = (B, H, S // block_q, T // block_kv)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, block_q=block_q, block_kv=block_kv,
        seq_q=S, seq_kv=T,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, qi, ki: (b, ki, h // group, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, qi, ki: (b, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
        compiler_params=_compiler_params(),
    )(q, k, v)
