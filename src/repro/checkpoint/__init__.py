from .manager import CheckpointCorruptionError, CheckpointManager

__all__ = ["CheckpointCorruptionError", "CheckpointManager"]
