"""Sharded checkpointing with atomic commit and elastic restore.

Layout (one directory per step):

    <dir>/step_000123.tmp/          # written first
        manifest.json               # pytree structure + per-leaf meta
        arr_<leaf_id>.shard<k>.npy  # per-host shard files
    <dir>/step_000123/              # atomic rename on success commit

Fault-tolerance properties:
  * atomic rename — a crash mid-write never corrupts the latest checkpoint
    (readers only ever see committed directories)
  * keep-last-N garbage collection
  * ``latest_step`` skips uncommitted/partial directories
  * **elastic restore**: arrays are saved as logical (global-shape) content
    per host shard along axis 0 of the host's addressable data; on load they
    are re-assembled to the logical array and re-sharded onto whatever mesh
    the restoring job uses — scale-up/down across restarts "just works".

On a multi-host fleet each host writes only its addressable shards; in this
single-process environment that degenerates to one shard per leaf, but the
code paths (manifest, assembly, resharding) are the real ones and are
exercised by tests/test_checkpoint.py including mesh-shape changes.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_STEP_RE = re.compile(r"^step_(\d{9})$")


def _leaf_paths(tree: PyTree) -> list[str]:
    from repro.core.api import tree_paths

    flat, _ = jax.tree_util.tree_flatten(tree_paths(tree))
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- paths

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: PyTree, *, extra: Optional[dict] = None) -> str:
        """Write a committed checkpoint for ``step``; returns its path."""
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        leaves, treedef = jax.tree_util.tree_flatten(tree)
        paths = _leaf_paths(tree)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "extra": extra or {},
            "leaves": [],
        }
        host = jax.process_index()
        for i, (leaf, path) in enumerate(zip(leaves, paths)):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"arr_{i:05d}.shard{host}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {
                    "id": i,
                    "path": path,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "shards": [fname],
                }
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # clean stale tmp dirs (crashed writers)
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # ------------------------------------------------------------- load

    def read_extra(self, step: int) -> dict:
        """The ``extra`` dict of a committed checkpoint WITHOUT restoring any
        arrays — resume flows that must rebuild the restore template from
        saved metadata first (e.g. the rank-policy controller state, which
        determines the optimizer-state shapes) read this before ``restore``."""
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f)["extra"]

    @staticmethod
    def _layout_mismatch_check(saved_paths, target_paths):
        """Raise a named error for the one structural mismatch users actually
        hit: an optimizer state saved with the other ``fuse_families``
        setting.  Per-leaf lowrank states keep projectors under params-shaped
        paths (``.../projs/<param path>``); the family-stacked engine keeps a
        flat family list (``.../projs/<family index>``) — so the projs
        subtrees differ textually whenever the layouts differ."""
        sp = [p for p in saved_paths if "/projs/" in p]
        tp = [p for p in target_paths if "/projs/" in p]
        if (sp or tp) and sp != tp:
            raise ValueError(
                "optimizer-state layout mismatch: the checkpoint stores "
                f"{len(sp)} projector leaves ({sp[:2]}...), the restore "
                f"target expects {len(tp)} ({tp[:2]}...).  This is what a "
                "fused-vs-per-leaf state difference looks like — the "
                "`fuse_families` flag (OptimizerConfig.fuse_families / "
                "--fuse-families) of the restoring run must match the run "
                "that wrote the checkpoint."
            )

    def restore(
        self,
        step: int,
        like: PyTree,
        *,
        shardings: Optional[PyTree] = None,
    ) -> tuple[PyTree, dict]:
        """Restore into the structure of ``like``.  ``shardings`` (optional
        pytree of NamedSharding) re-shards every leaf onto the *current* mesh
        — this is the elastic-scaling path: the saved mesh shape is
        irrelevant because content is stored logically."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        # Layout check runs even at equal leaf counts: a fused-vs-per-leaf
        # flip can coincidentally preserve both counts AND shapes (e.g. every
        # family has one member), which would otherwise restore projectors
        # into the wrong slots silently.
        self._layout_mismatch_check(
            [m["path"] for m in manifest["leaves"]], _leaf_paths(like)
        )
        if len(manifest["leaves"]) != len(leaves_like):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"restore target has {len(leaves_like)}"
            )
        shard_leaves = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves_like)
        )

        out = []
        for meta, ref, sh in zip(manifest["leaves"], leaves_like, shard_leaves):
            parts = [
                np.load(os.path.join(d, fn), allow_pickle=False)
                for fn in meta["shards"]
            ]
            arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
            if list(arr.shape) != list(ref.shape):
                hint = ""
                if "/projs/" in meta["path"] or "/inner/" in meta["path"]:
                    hint = (
                        "  (a rank-axis mismatch on low-rank optimizer state "
                        "usually means the checkpoint was written at a "
                        "different rank / rank-policy state — restore with "
                        "the saved RankMap, e.g. via the rank_policy extras "
                        "the Trainer stores, or migrate_opt_state)"
                    )
                raise ValueError(
                    f"{meta['path']}: saved shape {arr.shape} != target "
                    f"{ref.shape}{hint}"
                )
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr, dtype=ref.dtype))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]

    def restore_latest(self, like: PyTree, shardings: Optional[PyTree] = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like, shardings=shardings)
        return step, tree, extra
