"""Sharded checkpointing with atomic commit, integrity checksums and
elastic restore.

Layout (one directory per step):

    <dir>/step_000123.tmp/          # written first
        manifest.json               # pytree structure + per-leaf meta + CRC32
        arr_<leaf_id>.shard<k>.npy  # per-host shard files
    <dir>/step_000123/              # atomic rename on success commit

Fault-tolerance properties:
  * atomic rename — a crash mid-write never corrupts the latest checkpoint
    (readers only ever see committed directories)
  * **per-leaf CRC32 checksums** in the manifest, recomputed and verified on
    every restore (``verify=False`` opts out); a truncated shard or a single
    flipped bit raises :class:`CheckpointCorruptionError` instead of
    restoring garbage
  * ``latest_verified_step`` / ``restore_latest_verified`` walk committed
    steps newest-first and skip corrupt ones — the automatic fallback the
    resilience subsystem's ``restore`` rung relies on
  * keep-last-N garbage collection that **never deletes the newest verified
    checkpoint**: a corrupt/partial latest save does not count against the
    only restorable step
  * ``latest_step`` skips uncommitted/partial directories
  * **elastic restore**: arrays are saved as logical (global-shape) content
    per host shard along axis 0 of the host's addressable data; on load they
    are re-assembled to the logical array and re-sharded onto whatever mesh
    the restoring job uses — scale-up/down across restarts "just works".

``save(..., observer=...)`` calls ``observer(leaf_index, total)`` after each
leaf is written — the hook :mod:`repro.resilience.inject` uses to kill the
process mid-save in preemption tests (and a progress callback elsewhere).

On a multi-host fleet each host writes only its addressable shards; in this
single-process environment that degenerates to one shard per leaf, but the
code paths (manifest, assembly, resharding, verification) are the real ones.
Checkpoints written before checksums existed restore fine (leaves without a
recorded CRC are trusted as before).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
import zlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_STEP_RE = re.compile(r"^step_(\d{9})$")


class CheckpointCorruptionError(ValueError):
    """A committed checkpoint failed integrity verification (truncated
    shard, checksum mismatch, unreadable manifest)."""


def _leaf_paths(tree: PyTree) -> list[str]:
    from repro.core.api import tree_paths

    flat, _ = jax.tree_util.tree_flatten(tree_paths(tree))
    return flat


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, checksums: bool = True,
                 telemetry=None):
        self.dir = directory
        self.keep = keep
        self.checksums = checksums   # False skips CRC computation on save
        # Optional repro.telemetry.Telemetry bus: save / GC / corrupt-skip
        # become structured "checkpoint" events instead of bare prints.
        self.telemetry = telemetry
        os.makedirs(directory, exist_ok=True)

    def _event(self, detail: str, *, step=None, severity="info", **data):
        if self.telemetry is not None:
            self.telemetry.event("checkpoint", detail, step=step,
                                 severity=severity, **data)
        elif severity not in ("info", "debug"):
            # pre-bus behavior: only problems printed
            print(detail, flush=True)

    # ------------------------------------------------------------- paths

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: PyTree, *, extra: Optional[dict] = None,
             observer: Optional[Callable[[int, int], None]] = None) -> str:
        """Write a committed checkpoint for ``step``; returns its path.

        ``observer(leaf_index, total)`` fires after each leaf's shard hits
        disk — fault-injection kill hooks and progress reporting."""
        t0 = time.time()
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        leaves, treedef = jax.tree_util.tree_flatten(tree)
        paths = _leaf_paths(tree)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "extra": extra or {},
            "leaves": [],
        }
        host = jax.process_index()
        for i, (leaf, path) in enumerate(zip(leaves, paths)):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"arr_{i:05d}.shard{host}.npy"
            np.save(os.path.join(tmp, fname), arr)
            meta = {
                "id": i,
                "path": path,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "shards": [fname],
            }
            if self.checksums:
                meta["crc32"] = [_crc(arr)]
            manifest["leaves"].append(meta)
            if observer is not None:
                observer(i, len(leaves))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit
        self._event(
            f"checkpoint: saved step {step} ({len(leaves)} leaves, "
            f"{(time.time() - t0) * 1e3:.0f} ms)", step=step,
            severity="debug", action="save", leaves=len(leaves))
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        doomed = steps[: -self.keep] if self.keep > 0 else []
        if doomed:
            # Never evict the newest VERIFIED checkpoint: if the latest
            # save(s) are corrupt/partial they must not count toward
            # ``keep`` — deleting the only restorable step would make the
            # run unrecoverable.  The newest step usually verifies on the
            # first try (we just wrote it), so this is one CRC pass over
            # the latest checkpoint per save.
            protect = None
            for s in reversed(steps):
                if self.verify_step(s):
                    protect = s
                    break
            doomed = [s for s in doomed if s != protect]
        for s in doomed:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
            self._event(f"checkpoint: gc step {s}", severity="debug",
                        action="gc", gc_step=s)
        # clean stale tmp dirs (crashed writers)
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # ------------------------------------------------------------- verify

    def _manifest(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f)

    def verify_step(self, step: int) -> bool:
        """Full integrity check of a committed checkpoint: every shard file
        loads and matches its recorded CRC32 (legacy leaves without a CRC
        just need to load with the recorded shape)."""
        try:
            self._verify(step)
            return True
        except (CheckpointCorruptionError, OSError):
            return False

    def _verify(self, step: int) -> None:
        d = self._step_dir(step)
        try:
            manifest = self._manifest(step)
        except (OSError, ValueError) as e:
            raise CheckpointCorruptionError(
                f"step {step}: unreadable manifest ({e})") from e
        for meta in manifest["leaves"]:
            crcs = meta.get("crc32")
            for k, fn in enumerate(meta["shards"]):
                self._load_shard(d, meta, k, fn,
                                 crcs[k] if crcs else None, step)

    @staticmethod
    def _load_shard(d: str, meta: dict, k: int, fn: str,
                    crc: Optional[int], step: int) -> np.ndarray:
        try:
            arr = np.load(os.path.join(d, fn), allow_pickle=False)
        except Exception as e:   # truncated/garbled .npy raises ValueError
            raise CheckpointCorruptionError(
                f"step {step}: shard {fn} of {meta['path']} unreadable "
                f"({type(e).__name__}: {e})") from e
        if crc is not None and _crc(arr) != crc:
            raise CheckpointCorruptionError(
                f"step {step}: checksum mismatch on {meta['path']} "
                f"(shard {fn}) — the file is corrupt (bit flip / partial "
                f"write); restore falls back to the previous verified step")
        return arr

    def latest_verified_step(self) -> Optional[int]:
        """Newest committed step that passes full verification (the restore
        anchor for the resilience ladder's last rung)."""
        for s in reversed(self.all_steps()):
            if self.verify_step(s):
                return s
        return None

    # ------------------------------------------------------------- load

    def read_extra(self, step: int) -> dict:
        """The ``extra`` dict of a committed checkpoint WITHOUT restoring any
        arrays — resume flows that must rebuild the restore template from
        saved metadata first (e.g. the rank-policy controller state, which
        determines the optimizer-state shapes) read this before ``restore``."""
        return self._manifest(step)["extra"]

    @staticmethod
    def _layout_mismatch_check(saved_paths, target_paths):
        """Raise a named error for the one structural mismatch users actually
        hit: an optimizer state saved with the other ``fuse_families``
        setting.  Per-leaf lowrank states keep projectors under params-shaped
        paths (``.../projs/<param path>``); the family-stacked engine keeps a
        flat family list (``.../projs/<family index>``) — so the projs
        subtrees differ textually whenever the layouts differ."""
        sp = [p for p in saved_paths if "/projs/" in p]
        tp = [p for p in target_paths if "/projs/" in p]
        if (sp or tp) and sp != tp:
            raise ValueError(
                "optimizer-state layout mismatch: the checkpoint stores "
                f"{len(sp)} projector leaves ({sp[:2]}...), the restore "
                f"target expects {len(tp)} ({tp[:2]}...).  This is what a "
                "fused-vs-per-leaf state difference looks like — the "
                "`fuse_families` flag (OptimizerConfig.fuse_families / "
                "--fuse-families) of the restoring run must match the run "
                "that wrote the checkpoint."
            )

    def restore(
        self,
        step: int,
        like: PyTree,
        *,
        shardings: Optional[PyTree] = None,
        verify: bool = True,
    ) -> tuple[PyTree, dict]:
        """Restore into the structure of ``like``.  ``shardings`` (optional
        pytree of NamedSharding) re-shards every leaf onto the *current* mesh
        — this is the elastic-scaling path: the saved mesh shape is
        irrelevant because content is stored logically.

        ``verify=True`` (default) checks every shard against its manifest
        CRC32 while loading and raises :class:`CheckpointCorruptionError`
        on any mismatch — corrupted state never reaches the model."""
        d = self._step_dir(step)
        try:
            manifest = self._manifest(step)
        except (OSError, ValueError) as e:
            raise CheckpointCorruptionError(
                f"step {step}: unreadable manifest ({e})") from e

        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        # Layout check runs even at equal leaf counts: a fused-vs-per-leaf
        # flip can coincidentally preserve both counts AND shapes (e.g. every
        # family has one member), which would otherwise restore projectors
        # into the wrong slots silently.
        self._layout_mismatch_check(
            [m["path"] for m in manifest["leaves"]], _leaf_paths(like)
        )
        if len(manifest["leaves"]) != len(leaves_like):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"restore target has {len(leaves_like)}"
            )
        shard_leaves = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves_like)
        )

        out = []
        for meta, ref, sh in zip(manifest["leaves"], leaves_like, shard_leaves):
            crcs = meta.get("crc32") if verify else None
            parts = [
                self._load_shard(d, meta, k, fn,
                                 crcs[k] if crcs else None, step)
                for k, fn in enumerate(meta["shards"])
            ]
            arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
            if list(arr.shape) != list(ref.shape):
                hint = ""
                if "/projs/" in meta["path"] or "/inner/" in meta["path"]:
                    hint = (
                        "  (a rank-axis mismatch on low-rank optimizer state "
                        "usually means the checkpoint was written at a "
                        "different rank / rank-policy state — restore with "
                        "the saved RankMap, e.g. via the rank_policy extras "
                        "the Trainer stores, or migrate_opt_state)"
                    )
                raise ValueError(
                    f"{meta['path']}: saved shape {arr.shape} != target "
                    f"{ref.shape}{hint}"
                )
            if sh is not None:
                # Cast BEFORE placing: device_put of a raw numpy array keeps
                # its dtype, and a saved-fp32 / target-bf16 mismatch would
                # otherwise survive restore only on the sharded path.
                out.append(jax.device_put(
                    np.asarray(arr, dtype=np.dtype(ref.dtype)), sh))
            else:
                out.append(jnp.asarray(arr, dtype=ref.dtype))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]

    def restore_latest(self, like: PyTree, shardings: Optional[PyTree] = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like, shardings=shardings)
        return step, tree, extra

    def restore_latest_verified(self, like: PyTree,
                                shardings: Optional[PyTree] = None):
        """Restore the newest checkpoint that passes verification, walking
        past corrupt ones (each skip is reported on stdout).  Returns
        ``(step, tree, extra)`` or None when nothing restorable exists."""
        for step in reversed(self.all_steps()):
            try:
                tree, extra = self.restore(step, like, shardings=shardings,
                                           verify=True)
                return step, tree, extra
            except CheckpointCorruptionError as e:
                self._event(f"checkpoint: skipping corrupt step {step} ({e})",
                            severity="warn", action="corrupt_skip",
                            corrupt_step=step)
        return None
