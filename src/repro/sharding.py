"""Logical sharding rules shared by models and the launcher.

Models annotate activations with *logical* axis names; the launcher resolves
them against whichever mesh is active.  Logical axes:

  "fsdp"  -> ("pod", "data") on the multi-pod mesh, ("data",) on single-pod
  "tp"    -> ("model",)
  "ep"    -> ("model",)   (expert parallelism reuses the model axis)
  None    -> replicated

Param rules (DESIGN.md §5) are path-based so any pytree layout works.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Activate a mesh for logical-axis resolution (and pjit contexts)."""
    prev = _mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def resolve_axis(logical: Optional[str], mesh: Mesh) -> Any:
    if logical is None:
        return None
    names = mesh.axis_names
    if logical == "fsdp":
        axes = tuple(a for a in ("pod", "data") if a in names)
        return axes if len(axes) > 1 else (axes[0] if axes else None)
    if logical in ("tp", "ep"):
        return "model" if "model" in names else None
    if logical in names:
        return logical
    return None


def resolve_spec(logical_spec: Sequence[Optional[str]], mesh: Optional[Mesh] = None) -> P:
    mesh = mesh or _mesh()
    if mesh is None:
        return P()
    return P(*(resolve_axis(ax, mesh) for ax in logical_spec))


def logical_axis_size(logical: str) -> int:
    """Size of a logical axis on the active mesh (1 if no mesh)."""
    mesh = _mesh()
    if mesh is None:
        return 1
    return _axis_size(resolve_axis(logical, mesh), mesh)


def _axis_size(ax: Any, mesh: Mesh) -> int:
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def validate_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop axes whose dim isn't divisible by the shard count (e.g. batch=1
    in long_500k, vocab=504 on a 16-way model axis)."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        out.append(ax if ax is not None and dim % _axis_size(ax, mesh) == 0 else None)
    return P(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate an activation with a logical sharding; no-op without a mesh."""
    mesh = _mesh()
    if mesh is None:
        return x
    spec = validate_spec(x.shape, resolve_spec(logical_axes, mesh), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules: ordered (regex on path, logical spec) pairs.
# Specs are per-dimension logical names, right-aligned is NOT assumed — they
# must match the rank (leading stacked-layer dims get None automatically).
# ---------------------------------------------------------------------------

PARAM_RULES: list[tuple[str, tuple[Optional[str], ...]]] = [
    # embeddings / lm head: vocab tensor-parallel, d_model fsdp
    (r"embed", ("tp", "fsdp")),
    (r"lm_head", ("fsdp", "tp")),
    # MoE experts (E, d_in, d_out): expert-parallel over model axis, fsdp rows
    (r"experts?.*(w_in|w_gate)", ("ep", "fsdp", None)),
    (r"experts?.*w_out", ("ep", None, "fsdp")),
    (r"router", ("fsdp", None)),
    # attention projections
    (r"(wq|wk|wv|wqkv|q_proj|k_proj|v_proj|in_proj)", ("fsdp", "tp")),
    (r"(wo|o_proj|out_proj)", ("tp", "fsdp")),
    # mlp
    (r"(w_in|w_gate|w_up|gate_proj|up_proj)", ("fsdp", "tp")),
    (r"(w_out|w_down|down_proj)", ("tp", "fsdp")),
    # mamba projections
    (r"(ssm_in)", ("fsdp", "tp")),
    (r"(ssm_out)", ("tp", "fsdp")),
    (r"conv_w", (None, "fsdp")),
    (r"pos_embed", ("fsdp", None)),
    (r"frame_proj", ("fsdp", "tp")),
    # everything 1-D (norms, biases, dt, A) replicated
]


def spec_for_param(path: str, p: Any) -> tuple[Optional[str], ...]:
    ndim = p.ndim if hasattr(p, "ndim") else len(p.shape)
    if ndim <= 1:
        return (None,) * ndim
    for pat, spec in PARAM_RULES:
        if re.search(pat, path):
            pad = ndim - len(spec)
            if pad < 0:
                # rule is for the trailing dims; keep the trailing ones
                return spec[-ndim:]
            return (None,) * pad + tuple(spec)
    # default: fsdp on the penultimate dim
    return (None,) * (ndim - 2) + ("fsdp", None)


def param_specs(params: Any) -> Any:
    """Pytree of logical specs matching ``params``."""
    from repro.core.api import tree_paths  # local import to avoid cycles

    paths = tree_paths(params)
    return jax.tree_util.tree_map(
        lambda path, p: spec_for_param(path, p), paths, params
    )


def named_sharding_tree(params: Any, mesh: Mesh) -> Any:
    from repro.core.api import tree_paths  # local import to avoid cycles

    paths = tree_paths(params)
    return jax.tree_util.tree_map(
        lambda path, p: NamedSharding(
            mesh,
            validate_spec(p.shape, resolve_spec(spec_for_param(path, p), mesh), mesh),
        ),
        paths,
        params,
    )


def per_shard_bytes(tree: Any, mesh: Mesh) -> int:
    """Static bytes ONE device holds for ``tree`` sharded under the param
    rules on ``mesh`` — nbytes divided by the shard count of every resolved
    (and divisibility-surviving) spec axis.  Works on ShapeDtypeStructs;
    this is the per-SHARD number the analysis buffer pass (RA605) checks
    runtime shardings against, not the per-replica total."""
    from repro.core.api import tree_paths  # local import to avoid cycles

    paths = tree_paths(tree)
    total = 0
    for path, x in zip(jax.tree_util.tree_leaves(paths),
                       jax.tree_util.tree_leaves(tree)):
        if not hasattr(x, "shape"):
            continue
        nelem = 1
        for d in x.shape:
            nelem *= int(d)
        nbytes = nelem * jax.numpy.dtype(x.dtype).itemsize
        spec = validate_spec(x.shape,
                             resolve_spec(spec_for_param(path, x), mesh),
                             mesh)
        shards = 1
        for ax in spec:
            shards *= _axis_size(ax, mesh)
        total += nbytes // max(shards, 1)
    return total


def _family_stack_leaf_ids(opt_state: Any) -> set:
    """ids of the leaves living inside FUSED (family-list layout)
    ``LowRankState`` nodes — the stacked projectors, projected moments and
    probes the ZeRO sharding partitions.  Per-leaf lowrank states (projs is a
    params-shaped tree, not a list) are excluded: their leading dims are
    block dims of one parameter, not a member stack."""
    from repro.core.combinators import find_lowrank_states  # lazy (cycles)

    ids: set = set()
    for st in find_lowrank_states(opt_state):
        if not isinstance(st.projs, list):
            continue
        for leaf in jax.tree_util.tree_leaves(st):
            ids.add(id(leaf))
    return ids


def _family_shardable(x: Any, n_shards: int) -> bool:
    from repro.core.lowrank_common import stack_shardable

    return (hasattr(x, "ndim") and x.ndim >= 2
            and stack_shardable(int(x.shape[0]), n_shards))


def family_state_sharding(opt_state: Any, mesh: Mesh,
                          axis: str = "data") -> Any:
    """ZeRO-style sharding tree for a ``fuse_families=True`` optimizer state:
    every family-stacked low-rank leaf (projectors, projected moments,
    whatever the inner transform allocated per family) partitions on mesh
    ``axis`` along its leading stack dim — members of a family land on
    different shards — and everything else stays replicated, exactly like the
    pure-DP shard_map step.  Families whose stack doesn't divide the axis
    fall back to replicated (mirroring the runtime refresh fallback in
    ``combinators``)."""
    n = _axis_size(axis, mesh)
    fam_ids = _family_stack_leaf_ids(opt_state)

    def leaf_sharding(x):
        if not hasattr(x, "shape"):
            return None
        if id(x) in fam_ids and _family_shardable(x, n) and n > 1:
            return NamedSharding(mesh, P(axis))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(leaf_sharding, opt_state)


def family_state_bytes(opt_state: Any, n_shards: int) -> tuple[int, int]:
    """``(total, per_shard)`` bytes of the family-stacked low-rank state
    under ``n_shards``-way ZeRO sharding — the closed-form the sharded-step
    benchmark and the static memory accountant report (works on
    ShapeDtypeStructs).  Non-divisible families are charged replicated."""
    fam_ids = _family_stack_leaf_ids(opt_state)
    total = per_shard = 0
    for x in jax.tree_util.tree_leaves(opt_state):
        if id(x) not in fam_ids or not hasattr(x, "shape"):
            continue
        nelem = 1
        for d in x.shape:
            nelem *= int(d)
        nbytes = nelem * jax.numpy.dtype(x.dtype).itemsize
        total += nbytes
        if _family_shardable(x, n_shards):
            per_shard += nbytes // max(n_shards, 1)
        else:
            per_shard += nbytes
    return total, per_shard


def opt_state_sharding(opt_state: Any, mesh: Mesh, *,
                       family_axis: Optional[str] = None) -> Any:
    """Sharding for optimizer states.  State leaves live under the param path
    they belong to (e.g. families/blocks/attn/wq/r_low), so the param rules
    apply directly; full-shape moments inherit the param's exact spec, and
    low-rank states keep whichever trailing axes still divide.

    With ``family_axis`` (the ZeRO-sharded fused step), family-stacked
    low-rank leaves instead partition on that axis along their leading stack
    dim — see :func:`family_state_sharding` for the rule."""
    from repro.core.api import tree_paths

    paths = tree_paths(opt_state)
    fam_ids = _family_stack_leaf_ids(opt_state) if family_axis else set()
    fam_n = _axis_size(family_axis, mesh) if family_axis else 1

    def leaf_sharding(path, x):
        if family_axis and id(x) in fam_ids and fam_n > 1 \
                and _family_shardable(x, fam_n):
            return NamedSharding(mesh, P(family_axis))
        if not hasattr(x, "ndim") or x.ndim <= 1:
            return NamedSharding(mesh, P())
        spec = resolve_spec(spec_for_param(path, x), mesh)
        return NamedSharding(mesh, validate_spec(x.shape, spec, mesh))

    return jax.tree_util.tree_map(leaf_sharding, paths, opt_state)
