"""qwen1.5-4b [dense] — MHA (kv=heads), QKV bias [hf:Qwen/Qwen1.5].

40L d_model=2560 20H (kv=20, head_dim=128) d_ff=6912 vocab=151936.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    act="swiglu",
    qkv_bias=True,
    rope="rope",
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=256,
    vocab=128, dtype="float32", remat=False,
)
