"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8, head_dim=192) d_ff=73728 vocab=256000.
Pure full attention -> long_500k is skipped (DESIGN.md §6).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab=256000,
    act="relu2",
    rope="rope",
    norm="layernorm",
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=256,
    vocab=128, dtype="float32", remat=False,
)
