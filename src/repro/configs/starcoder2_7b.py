"""starcoder2-7b [dense] — GQA, RoPE, GELU MLP with biases [arXiv:2402.19173].

32L d_model=4608 36H (GQA kv=4, head_dim=128) d_ff=18432 vocab=49152.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab=49152,
    act="gelu",
    qkv_bias=True,
    mlp_bias=True,
    rope="rope",
    norm="layernorm",
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=256,
    vocab=128, dtype="float32", remat=False,
)
