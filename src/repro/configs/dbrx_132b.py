"""dbrx-132b [moe] — 16 experts top-4 fine-grained MoE every layer
[hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8, head_dim=128) expert d_ff=10752 vocab=100352.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    act="swiglu",
    rope="rope",
    n_experts=16,
    top_k=4,
    moe_dff=10752,
    moe_every=1,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=128, n_experts=4, top_k=2, moe_dff=128, dtype="float32", remat=False,
)
