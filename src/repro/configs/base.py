"""Model / shape / run configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str = "dense"          # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 0            # 0 -> = n_heads (MHA)
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab: int = 32000
    act: str = "swiglu"            # swiglu | geglu | gelu | relu2
    qkv_bias: bool = False
    mlp_bias: bool = False
    rope: str = "rope"             # rope | rope2d | none
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0     # chatglm rope-2d applies rotary to half dims
    causal: bool = True
    encoder_only: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    n_shared_experts: int = 0
    moe_every: int = 1             # MoE replaces dense MLP every Nth layer
    capacity_factor: float = 1.25
    moe_groups: int = 0          # dispatch groups (0 = one per data shard)
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 64
    # --- hybrid (Zamba-2) ---
    shared_attn_every: int = 0     # apply the shared attention block every Nth layer
    # --- VLM ---
    cross_attn_every: int = 0      # a cross-attn layer every Nth layer
    n_image_tokens: int = 0
    # --- audio/vision frontend stubs ---
    frontend: str = "none"         # none | frames (precomputed embeddings input)
    # --- numerics / implementation ---
    param_dtype: str = "float32"
    dtype: str = "bfloat16"        # activation compute dtype for large runs
    attn_impl: str = "xla"         # xla | xla_chunked | pallas
    seq_shard_attn: str = "auto"   # auto | on | off — sequence-parallel q
                                   # fallback when heads don't divide the TP axis
    seq_parallel_norms: bool = False  # Megatron-style sequence parallelism for
                                      # the residual stream (norms/adds sharded
                                      # over the model axis between blocks)
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots | full
    scan_layers: bool = True
    logit_chunk: int = 0           # 0 = unchunked cross-entropy
    max_seq: int = 8192            # learned-pos-embedding table size (audio stub)

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k (per assignment: SSM/hybrid only)?"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    microbatch: int = 0            # 0 = no gradient accumulation


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """End-to-end run settings consumed by the Trainer / launcher."""

    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    seed: int = 0
    grad_clip: float = 1.0
    lowrank_grad_accum: bool = False   # beyond-paper: accumulate PᵀG
    resume: bool = True
