"""llama4-maverick-400b-a17b [moe] — 128-expert top-1 MoE with a shared
expert, MoE every other layer [hf:meta-llama/Llama-4].

48L d_model=5120 40H (GQA kv=8, head_dim=128), routed expert d_ff=8192,
vocab=202048.  moe_every=2 + shared expert reproduces the published totals:
24 MoE layers x 128 x 3 x 5120 x 8192 = 386B routed + dense/attn = ~400B
total, ~17B active (DESIGN.md §6).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,          # dense layers' MLP width
    vocab=202048,
    act="swiglu",
    rope="rope",
    n_experts=128,
    top_k=1,
    moe_dff=8192,
    n_shared_experts=1,
    moe_every=2,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=256,
    vocab=128, n_experts=4, moe_dff=64, dtype="float32", remat=False,
)
