"""hubert-xlarge [audio] — encoder-only transformer backbone
[arXiv:2106.07447].

48L d_model=1280 16H MHA (head_dim=80) d_ff=5120 vocab=504 (unit targets).
The wav2vec2 conv frontend is a STUB per the assignment: input_specs()
provides precomputed frame embeddings (B, S, d_model).  No decode step
(encoder-only) -> decode shapes are skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    act="gelu",
    rope="none",
    norm="layernorm",
    causal=False,
    encoder_only=True,
    frontend="frames",
    max_seq=32768,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=256,
    vocab=64, max_seq=64, dtype="float32", remat=False,
)
