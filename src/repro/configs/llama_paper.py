"""The paper's own pre-training architectures (Table 4): LLaMA 60M/130M/350M,
standard GaLore-paper configs (Zhao et al., 2024 Table 12), context 1024.
"""
from .base import ModelConfig

_COMMON = dict(
    family="dense", act="swiglu", rope="rope", vocab=32000,
    tie_embeddings=True, dtype="float32", max_seq=1024,
)

LLAMA_60M = ModelConfig(
    name="llama-60m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=1376, **_COMMON,
)
LLAMA_130M = ModelConfig(
    name="llama-130m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=2048, **_COMMON,
)
LLAMA_350M = ModelConfig(
    name="llama-350m", n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2736, **_COMMON,
)

CONFIG = LLAMA_130M
SMOKE = LLAMA_60M.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=256, remat=False)
