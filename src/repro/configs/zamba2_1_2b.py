"""zamba2-1.2b [hybrid] — Mamba-2 backbone + shared attention block applied
every 6th layer (one set of attn+MLP weights reused) [arXiv:2411.15242].

38L d_model=2048, ssm_state=64; shared block: 32H MHA (head_dim=64) d_ff=8192,
vocab=32000.  Sub-quadratic -> long_500k RUNS for this arch.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    act="swiglu",
    rope="rope",
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=64,
    shared_attn_every=6,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab=128, ssm_state=16, ssm_headdim=16, ssm_chunk=16, shared_attn_every=2,
    dtype="float32", remat=False,
)
