"""llama-3.2-vision-11b [vlm] — text backbone with gated cross-attention
image layers every 5th layer [hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=128256.
The vision encoder is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, n_image_tokens, d_model).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    act="swiglu",
    rope="rope",
    rope_theta=500000.0,
    cross_attn_every=5,
    n_image_tokens=1601,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=256,
    vocab=128, cross_attn_every=2, n_image_tokens=16, dtype="float32", remat=False,
)
