"""--arch registry: id -> (full config, smoke config)."""
from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeConfig

ARCHS: dict[str, str] = {
    # assigned pool (10)
    "nemotron-4-340b": "nemotron_4_340b",
    "starcoder2-7b": "starcoder2_7b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen1.5-4b": "qwen1_5_4b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mamba2-370m": "mamba2_370m",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "dbrx-132b": "dbrx_132b",
    "hubert-xlarge": "hubert_xlarge",
    # paper's own pre-training archs
    "llama-60m": "llama_paper",
    "llama-130m": "llama_paper",
    "llama-350m": "llama_paper",
}

_PAPER = {"llama-60m": "LLAMA_60M", "llama-130m": "LLAMA_130M", "llama-350m": "LLAMA_350M"}

ASSIGNED = [a for a in ARCHS if not a.startswith("llama-") or "vision" in a or "maverick" in a]


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    if arch in _PAPER:
        return getattr(mod, _PAPER[arch])
    return mod.CONFIG


def get_smoke(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.SMOKE


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch x shape) runnable?  Returns (supported, reason_if_not)."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k requires sub-quadratic attention (SSM/hybrid only)"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch x shape) cells (including skipped ones)."""
    out = []
    for arch in ARCHS:
        if arch in _PAPER:
            continue
        for shape in SHAPES:
            out.append((arch, shape))
    return out
