"""mamba2-370m [ssm] — attention-free SSD (state-space duality)
[arXiv:2405.21060].

48L d_model=1024 (expand 2 -> d_inner 2048, 32 heads of 64), ssm_state=128,
vocab=50280.  Sub-quadratic -> long_500k RUNS for this arch.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=128,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, vocab=128, ssm_state=16, ssm_headdim=16,
    ssm_chunk=16, dtype="float32", remat=False,
)
