from .base import SHAPES, ModelConfig, RunConfig, ShapeConfig
from .registry import ARCHS, all_cells, cell_supported, get_config, get_shape, get_smoke

__all__ = [
    "ARCHS", "SHAPES", "ModelConfig", "RunConfig", "ShapeConfig",
    "all_cells", "cell_supported", "get_config", "get_shape", "get_smoke",
]
