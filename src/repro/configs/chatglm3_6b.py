"""chatglm3-6b [dense] — 2-D RoPE (rotary on half the head dims), GQA kv=2,
QKV bias [arXiv:2406.12793].

28L d_model=4096 32H (kv=2, head_dim=128) d_ff=13696 vocab=65024.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=65024,
    act="swiglu",
    qkv_bias=True,
    rope="rope2d",
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=256,
    vocab=128, dtype="float32", remat=False,
)
