"""Mamba-2 (SSD) block: in-proj -> causal depthwise conv -> SSD -> gated norm
-> out-proj.  Sequence mixing runs through the SSD kernel (chunked scan)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.sharding import shard

from .layers import trunc_normal


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_headdim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * cfg.ssm_ngroups * N
    return d_inner, H, N, conv_dim


def init_mamba_block(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, H, N, conv_dim = dims(cfg)
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    in_dim = 2 * d_inner + 2 * cfg.ssm_ngroups * N + H
    p = {
        "ssm_in": trunc_normal(ks[0], (d, in_dim), std),
        "conv_w": trunc_normal(ks[1], (cfg.ssm_conv, conv_dim), 0.1),
        "conv_bias": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "skip_d": jnp.ones((H,), jnp.float32),
        "gnorm_scale": jnp.ones((d_inner,), jnp.float32),
        "ssm_out": trunc_normal(ks[2], (d_inner, d), 1.0 / math.sqrt(d_inner)),
    }
    return p


def _split_in(h, cfg: ModelConfig):
    d_inner, H, N, _ = dims(cfg)
    gN = cfg.ssm_ngroups * N
    z, xbc, dt = jnp.split(h, [d_inner, 2 * d_inner + 2 * gN], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with taps (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return jax.nn.silu(out + b[None, None, :].astype(out.dtype))


def apply_mamba_block(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence forward: x (B, S, D) -> (B, S, D)."""
    B, S, _ = x.shape
    d_inner, H, N, conv_dim = dims(cfg)
    P = cfg.ssm_headdim

    h = x @ p["ssm_in"].astype(x.dtype)
    z, xbc, dt = _split_in(h, cfg)
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype), p["conv_bias"])
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + cfg.ssm_ngroups * N], axis=-1)

    xs = shard(xs.reshape(B, S, H, P), "fsdp", None, "tp", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])

    # ngroups == 1: B/C shared across heads
    y, _ = ops.ssd(
        xs, dt, a, bmat.astype(jnp.float32), cmat.astype(jnp.float32),
        p["skip_d"], chunk=cfg.ssm_chunk,
        impl="xla" if cfg.attn_impl == "xla" else cfg.attn_impl,
    )
    y = y.reshape(B, S, d_inner).astype(x.dtype)

    # gated RMSNorm (Mamba-2's norm-before-out-proj)
    y = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6) * p["gnorm_scale"]).astype(x.dtype)
    return y @ p["ssm_out"].astype(x.dtype)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, H, N, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, N, cfg.ssm_headdim), jnp.float32),
    }


def decode_mamba_block(p, x: jax.Array, cache: dict, cfg: ModelConfig):
    """One-token decode: x (B, 1, D); returns (out (B, 1, D), new cache)."""
    B = x.shape[0]
    d_inner, H, N, conv_dim = dims(cfg)
    P = cfg.ssm_headdim

    h = x[:, 0, :] @ p["ssm_in"].astype(x.dtype)   # (B, in_dim)
    z, xbc, dt = _split_in(h, cfg)

    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B, W, C)
    conv = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), p["conv_w"])
    xbc = jax.nn.silu(conv + p["conv_bias"]).astype(x.dtype)
    new_conv = window[:, 1:, :]

    xs, bvec, cvec = jnp.split(xbc, [d_inner, d_inner + cfg.ssm_ngroups * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    a = -jnp.exp(p["a_log"])
    y, new_ssm = ops.ssd_decode_step(
        cache["ssm"], xs.reshape(B, H, P).astype(jnp.float32), dt, a,
        bvec.astype(jnp.float32), cvec.astype(jnp.float32), p["skip_d"],
    )
    y = y.reshape(B, d_inner).astype(x.dtype)

    y = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6) * p["gnorm_scale"]).astype(x.dtype)
    out = (y @ p["ssm_out"].astype(x.dtype))[:, None, :]
    return out, {"conv": new_conv, "ssm": new_ssm}
