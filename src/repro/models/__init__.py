"""Model zoo: composable transformer / SSM / hybrid / MoE definitions."""
from .transformer import (
    Model,
    build_model,
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
)

__all__ = [
    "Model", "build_model", "decode_step", "forward", "init_cache",
    "init_params", "lm_loss",
]
