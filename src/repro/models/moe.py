"""Mixture-of-Experts FFN with capacity-based token-choice dispatch.

GShard/MaxText-style: top-k routing, per-expert capacity C, token gather ->
stacked expert GEMMs -> weighted scatter-add.  Everything is dense einsum /
top_k / gather, so GSPMD shards it cleanly: experts over the "ep" (model)
axis, tokens over "fsdp" — the token exchange lowers to all-to-all-like
collectives in the partitioned HLO.  Over-capacity tokens are dropped
(standard), and the router returns the Switch/GShard load-balancing aux loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import shard

from .layers import mlp_act, trunc_normal


def init_moe(key, cfg: ModelConfig):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_dff or cfg.d_ff
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    gated = cfg.act in ("swiglu", "geglu")
    p = {
        "router": trunc_normal(ks[0], (d, E), std),
        "experts_w_in": trunc_normal(ks[1], (E, d, f), std),
        "experts_w_out": trunc_normal(ks[2], (E, f, d), 1.0 / math.sqrt(f)),
    }
    if gated:
        p["experts_w_gate"] = trunc_normal(ks[3], (E, d, f), std)
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_w_in"] = trunc_normal(ks[4], (d, fs), std)
        if gated:
            p["shared_w_gate"] = trunc_normal(jax.random.fold_in(ks[4], 1), (d, fs), std)
        p["shared_w_out"] = trunc_normal(
            jax.random.fold_in(ks[4], 2), (fs, d), 1.0 / math.sqrt(fs)
        )
    return p


def apply_moe(p, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Dispatch is GROUPED (GShard-style): tokens are split into ``n_groups``
    (aligned with the data shards) and each group routes its own tokens under
    a per-group capacity.  The gather then moves (G, E, C_g, D) between the
    group (fsdp) and expert (ep/model) shardings — an all-to-all-shaped
    exchange — instead of replicating the full token tensor to every expert
    rank (the collective-term bottleneck in the baseline llama4 dry-run).
    ``cfg.moe_groups == 0`` keeps a single global group (measured BETTER on
    this partitioner: grouping inflated the backward scatter all-reduce —
    see EXPERIMENTS.md §Perf j1, a refuted hypothesis); set it to the data-
    shard count to get the GShard-style all-to-all exchange.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = max(cfg.moe_groups, 1)
    while T % G or G < 1:
        G -= 1
    Tg = T // G
    xt = x.reshape(G, Tg, D)
    xt = shard(xt, "fsdp", None, None)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                              # (G, Tg, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # Switch-style load-balance aux: E * sum_e fraction_e * prob_e
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)               # (G, Tg, k, E)
    frac = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))             # (E,)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    combine = jnp.sum(topw[..., None] * onehot, axis=2)               # (G, Tg, E)
    cap = max(1, min(Tg, int(cfg.capacity_factor * Tg * k / E)))
    score = jnp.where(combine > 0, combine, -1.0)
    g_score, g_idx = jax.lax.top_k(jnp.swapaxes(score, 1, 2), cap)    # (G, E, C)
    g_w = jnp.where(g_score > 0, g_score, 0.0)                        # drop invalid

    if G == 1:
        # flat gather/scatter (measured cheaper than the batched
        # take_along_axis form under GSPMD — §Perf j7 bisect)
        xt2 = xt.reshape(T, D)
        xg = jnp.take(xt2, g_idx[0].reshape(-1), axis=0).reshape(1, E, cap, D)
    else:
        xg = jnp.take_along_axis(xt[:, None], g_idx[..., None], axis=2)
    xg = shard(xg, "fsdp", "ep", None, None)
    h = jnp.einsum("gecd,edf->gecf", xg, p["experts_w_in"].astype(xg.dtype))
    g = (
        jnp.einsum("gecd,edf->gecf", xg, p["experts_w_gate"].astype(xg.dtype))
        if "experts_w_gate" in p
        else None
    )
    h = mlp_act(h, g, cfg.act)
    y = jnp.einsum("gecf,efd->gecd", h, p["experts_w_out"].astype(xg.dtype))
    y = y * g_w[..., None].astype(y.dtype)
    y = shard(y, "fsdp", "ep", None, None)

    if G == 1:
        out = jnp.zeros((T, D), y.dtype).at[g_idx[0].reshape(-1)].add(
            y.reshape(E * cap, D)
        )
        out = shard(out, "fsdp", None)
    else:
        out = jnp.zeros((G, Tg, D), y.dtype)
        out = out.at[jnp.arange(G)[:, None, None], g_idx, :].add(y)
        out = shard(out, "fsdp", None, None)
        out = out.reshape(T, D)
    xt = xt.reshape(T, D)

    if "shared_w_in" in p:
        hs = xt @ p["shared_w_in"].astype(xt.dtype)
        gs = xt @ p["shared_w_gate"].astype(xt.dtype) if "shared_w_gate" in p else None
        out = out + mlp_act(hs, gs, cfg.act) @ p["shared_w_out"].astype(xt.dtype)

    return out.reshape(B, S, D), aux
