"""Model assembly for every architecture family.

All families share one params layout convention:

  params = {
    "embed":   {embed, lm_head?} | {frame_proj, pos_embed} (audio stub)
    "blocks":  pytree whose leaves are stacked over layers (scan axis 0)
    "shared":  (hybrid) the Zamba-style shared attention+MLP block
    "final_norm": {...}
  }

Layer stacks run under ``lax.scan`` with per-layer ``jax.checkpoint`` (remat),
so the HLO stays compact for 96-layer configs and activation memory is one
layer's residual stream per step.
"""
from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import shard

from . import attention as attn
from . import mamba2, moe
from .layers import (
    apply_mlp,
    apply_norm,
    embed_tokens,
    init_embed,
    init_mlp,
    init_norm,
    trunc_normal,
    unembed,
)

PyTree = Any


def _remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # save nothing


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# =====================================================================
# per-family block init / apply
# =====================================================================


def init_dense_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": attn.init_attention(k1, cfg),
        "ln2": init_norm(cfg, cfg.d_model),
        "mlp": init_mlp(k2, cfg, cfg.d_model, cfg.d_ff),
    }


def _residual_spec(cfg: ModelConfig):
    """Residual-stream sharding.  ``seq_parallel_norms`` (Megatron-style SP)
    was tried and REFUTED on this partitioner — GSPMD inserts mass
    all-gathers instead of the RS/AG pair (§Perf n3); it stays available as
    a knob but constraints are applied ONLY at the block boundary: extra
    pre/mid-block constraints measurably pessimize the partitioner's own
    layout choices (§Perf v2 regression note)."""
    return ("fsdp", "tp", None) if cfg.seq_parallel_norms else ("fsdp", None, None)


def apply_dense_block(bp, x, cfg: ModelConfig, positions, causal):
    h, kv = attn.self_attention(bp["attn"], apply_norm(bp["ln1"], x, cfg), cfg, positions, causal)
    x = x + h
    x = x + apply_mlp(bp["mlp"], apply_norm(bp["ln2"], x, cfg), cfg)
    return shard(x, *_residual_spec(cfg)), kv


def init_moe_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": attn.init_attention(k1, cfg),
        "ln2": init_norm(cfg, cfg.d_model),
        "moe": moe.init_moe(k2, cfg),
    }


def apply_moe_block(bp, x, cfg: ModelConfig, positions, causal):
    h, kv = attn.self_attention(bp["attn"], apply_norm(bp["ln1"], x, cfg), cfg, positions, causal)
    x = x + h
    m, aux = moe.apply_moe(bp["moe"], apply_norm(bp["ln2"], x, cfg), cfg)
    x = x + m
    return shard(x, "fsdp", None, None), kv, aux


def init_cross_block(key, cfg: ModelConfig):
    """Gated cross-attention + gated MLP (Llama-3.2-Vision style)."""
    k1, k2 = jax.random.split(key)
    return {
        "ln": init_norm(cfg, cfg.d_model),
        "xattn": attn.init_attention(k1, cfg, cross=True),
        "gate_attn": jnp.zeros((), jnp.float32),
        "ln_mlp": init_norm(cfg, cfg.d_model),
        "mlp": init_mlp(k2, cfg, cfg.d_model, cfg.d_ff),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def apply_cross_block(bp, x, cfg: ModelConfig, xk, xv):
    h = attn.cross_attention(bp["xattn"], apply_norm(bp["ln"], x, cfg), cfg, xk, xv)
    x = x + jnp.tanh(bp["gate_attn"]).astype(x.dtype) * h
    m = apply_mlp(bp["mlp"], apply_norm(bp["ln_mlp"], x, cfg), cfg)
    x = x + jnp.tanh(bp["gate_mlp"]).astype(x.dtype) * m
    return shard(x, "fsdp", None, None)


def init_mamba_layer(key, cfg: ModelConfig):
    return {"ln1": init_norm(cfg, cfg.d_model), "mamba": mamba2.init_mamba_block(key, cfg)}


def apply_mamba_layer(bp, x, cfg: ModelConfig):
    x = x + mamba2.apply_mamba_block(bp["mamba"], apply_norm(bp["ln1"], x, cfg), cfg)
    return shard(x, "fsdp", None, None)


# =====================================================================
# init
# =====================================================================


def init_params(key, cfg: ModelConfig) -> PyTree:
    ke, kb, ks = jax.random.split(key, 3)
    params: dict = {"final_norm": init_norm(cfg, cfg.d_model)}

    if cfg.frontend == "frames":
        params["embed"] = {
            "frame_proj": trunc_normal(ke, (cfg.d_model, cfg.d_model), 1.0 / math.sqrt(cfg.d_model)),
            "pos_embed": trunc_normal(jax.random.fold_in(ke, 1), (cfg.max_seq, cfg.d_model), 0.02),
            "lm_head": trunc_normal(jax.random.fold_in(ke, 2), (cfg.d_model, cfg.vocab), 0.02),
        }
    else:
        params["embed"] = init_embed(ke, cfg)

    L = cfg.n_layers
    fam = cfg.family

    if fam in ("dense", "audio"):
        keys = jax.random.split(kb, L)
        params["blocks"] = jax.vmap(lambda k: init_dense_block(k, cfg))(keys)
    elif fam == "moe":
        if cfg.moe_every == 1:
            keys = jax.random.split(kb, L)
            params["blocks"] = jax.vmap(lambda k: init_moe_block(k, cfg))(keys)
        else:
            assert L % cfg.moe_every == 0
            G = L // cfg.moe_every
            per = cfg.moe_every - 1
            kd, km = jax.random.split(kb)
            dense_keys = jax.random.split(kd, G * per).reshape(G, per, 2)
            params["blocks"] = {
                "dense": jax.vmap(jax.vmap(lambda k: init_dense_block(k, cfg)))(dense_keys),
                "moe": jax.vmap(lambda k: init_moe_block(k, cfg))(jax.random.split(km, G)),
            }
    elif fam == "vlm":
        assert cfg.cross_attn_every > 0 and L % cfg.cross_attn_every == 0
        G = L // cfg.cross_attn_every
        per = cfg.cross_attn_every
        kd, kx = jax.random.split(kb)
        self_keys = jax.random.split(kd, G * per).reshape(G, per, 2)
        params["blocks"] = {
            "self": jax.vmap(jax.vmap(lambda k: init_dense_block(k, cfg)))(self_keys),
            "cross": jax.vmap(lambda k: init_cross_block(k, cfg))(jax.random.split(kx, G)),
        }
    elif fam == "ssm":
        keys = jax.random.split(kb, L)
        params["blocks"] = jax.vmap(lambda k: init_mamba_layer(k, cfg))(keys)
    elif fam == "hybrid":
        keys = jax.random.split(kb, L)
        params["blocks"] = jax.vmap(lambda k: init_mamba_layer(k, cfg))(keys)
        params["shared"] = init_dense_block(ks, cfg)
    else:
        raise ValueError(f"unknown family {fam}")

    if cfg.param_dtype != "float32":
        # bf16 parameter storage (mixed precision): matrices are cast down —
        # FSDP all-gathers and gradient reductions run at half the bytes;
        # optimizer states stay fp32 internally.  Norms/biases stay fp32.
        pd = jnp.dtype(cfg.param_dtype)

        def cast(x):
            return x.astype(pd) if x.ndim >= 2 else x

        params = jax.tree_util.tree_map(cast, params)
    return params


# =====================================================================
# forward (full sequence)
# =====================================================================


def _embed_input(params, cfg: ModelConfig, tokens=None, frames=None):
    dtype = _dtype(cfg)
    if cfg.frontend == "frames":
        x = frames.astype(dtype) @ params["embed"]["frame_proj"].astype(dtype)
        S = x.shape[1]
        x = x + params["embed"]["pos_embed"][:S].astype(dtype)[None]
    else:
        x = embed_tokens(params["embed"], tokens, cfg, dtype)
    return shard(x, "fsdp", None, None)


def forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    *,
    frames: Optional[jax.Array] = None,
    images: Optional[jax.Array] = None,
    return_cache: bool = False,
    return_hidden: bool = False,
):
    """Returns (logits, aux_loss, cache|None).

    ``tokens`` (B, S) int32 for LM families; ``frames`` (B, S, D) for the
    audio stub; ``images`` (B, T_img, D) precomputed patch embeddings (vlm).
    """
    x = _embed_input(params, cfg, tokens=tokens, frames=frames)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    causal = cfg.causal and not cfg.encoder_only
    fam = cfg.family

    aux0 = jnp.zeros((), jnp.float32)

    if fam in ("dense", "audio"):

        def body(carry, bp):
            y, kv = apply_dense_block(bp, carry, cfg, positions, causal)
            return y, (kv if return_cache else None)

        x, kvs = jax.lax.scan(_remat(body, cfg), x, params["blocks"])
        aux, cache = aux0, _stack_cache(kvs, cfg, S) if return_cache else None

    elif fam == "moe" and cfg.moe_every == 1:

        def body(carry, bp):
            y, aux = carry
            y, kv, a = apply_moe_block(bp, y, cfg, positions, causal)
            return (y, aux + a), (kv if return_cache else None)

        (x, aux), kvs = jax.lax.scan(_remat(body, cfg), (x, aux0), params["blocks"])
        cache = _stack_cache(kvs, cfg, S) if return_cache else None

    elif fam == "moe":

        def body(carry, bps):
            y, aux = carry
            dense_bps, moe_bp = bps["dense"], bps["moe"]

            def inner(c, bp):
                o, kv = apply_dense_block(bp, c, cfg, positions, causal)
                return o, (kv if return_cache else None)

            y, kv_d = jax.lax.scan(inner, y, dense_bps)
            y, kv_m, a = apply_moe_block(moe_bp, y, cfg, positions, causal)
            kvs = (kv_d, kv_m) if return_cache else None
            return (y, aux + a), kvs

        (x, aux), kvs = jax.lax.scan(_remat(body, cfg), (x, aux0), params["blocks"])
        cache = _stack_moe_group_cache(kvs, cfg, S) if return_cache else None

    elif fam == "vlm":
        img_x = shard(images.astype(x.dtype), "fsdp", None, None)

        def body(carry, bps):
            y = carry

            def inner(c, bp):
                o, kv = apply_dense_block(bp, c, cfg, positions, causal)
                return o, (kv if return_cache else None)

            y, kv_s = jax.lax.scan(inner, y, bps["self"])
            xk, xv = attn.encode_cross_kv(bps["cross"]["xattn"], img_x, cfg)
            y = apply_cross_block(bps["cross"], y, cfg, xk, xv)
            out = (kv_s, (xk, xv)) if return_cache else None
            return y, out

        x, kvs = jax.lax.scan(_remat(body, cfg), x, params["blocks"])
        aux = aux0
        cache = _stack_vlm_cache(kvs, cfg, S) if return_cache else None

    elif fam == "ssm":

        def body(carry, bp):
            return apply_mamba_layer(bp, carry, cfg), None

        x, _ = jax.lax.scan(_remat(body, cfg), x, params["blocks"])
        aux, cache = aux0, None  # decode cache is built by prefill_cache()

    elif fam == "hybrid":
        shared_bp = params["shared"]
        every = cfg.shared_attn_every

        def body(carry, xs):
            bp, idx = xs
            y = apply_mamba_layer(bp, carry, cfg)

            def with_attn(y):
                o, _ = apply_dense_block(shared_bp, y, cfg, positions, causal)
                return o

            y = jax.lax.cond(idx % every == 0, with_attn, lambda y: y, y)
            return y, None

        idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        x, _ = jax.lax.scan(_remat(body, cfg), x, (params["blocks"], idxs))
        aux, cache = aux0, None

    else:
        raise ValueError(fam)

    x = apply_norm(params["final_norm"], x, cfg)
    if return_hidden:
        return x, aux, cache
    if cfg.frontend == "frames":
        logits = x @ params["embed"]["lm_head"].astype(x.dtype)
    else:
        logits = unembed(params["embed"], x, cfg)
    logits = shard(logits, "fsdp", None, "tp")
    return logits, aux, cache


def _stack_cache(kvs, cfg, S):
    if kvs is None:
        return None
    k, v = kvs
    return {"k": k, "v": v}


def _stack_moe_group_cache(kvs, cfg, S):
    if kvs is None:
        return None
    (kd, vd), (km, vm) = kvs[0], kvs[1]
    return {"dense": {"k": kd, "v": vd}, "moe": {"k": km, "v": vm}}


def _stack_vlm_cache(kvs, cfg, S):
    if kvs is None:
        return None
    (ks, vs), (xk, xv) = kvs
    return {"self": {"k": ks, "v": vs}, "xk": xk, "xv": xv}


# =====================================================================
# loss
# =====================================================================


def lm_loss(logits: jax.Array, targets: jax.Array, aux: jax.Array, *, shift: bool = True):
    """Mean next-token cross-entropy (+0.01·aux for MoE load balance)."""
    if shift:
        logits = logits[:, :-1]
        targets = targets[:, 1:]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - gold)
    return ce + 0.01 * aux


def chunked_lm_loss(
    params: PyTree, cfg: ModelConfig, hidden: jax.Array, targets: jax.Array,
    aux: jax.Array, *, shift: bool = True,
):
    """Sequence-chunked cross-entropy: logits are materialized one seq chunk
    at a time (scan), never as the full (B, S, V) tensor — the memory-term
    optimization for large-vocab cells (cfg.logit_chunk)."""
    if shift:
        hidden = hidden[:, :-1]
        targets = targets[:, 1:]
    B, S, D = hidden.shape
    chunk = cfg.logit_chunk
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    nch = (S + pad) // chunk
    hs = jnp.moveaxis(hidden.reshape(B, nch, chunk, D), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, nch, chunk), 1, 0)
    valid = jnp.moveaxis(
        (jnp.arange(S + pad) < S).reshape(1, nch, chunk), 1, 0
    )

    if cfg.tie_embeddings:
        w = params["embed"]["embed"].T
    else:
        w = params["embed"]["lm_head"]

    def body(acc, inp):
        h, t, m = inp
        logits = (h @ w.astype(h.dtype)).astype(jnp.float32)  # (B, chunk, V)
        logits = shard(logits, "fsdp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - gold) * m), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hs, ts, valid))
    ce = total / (B * S)
    return ce + 0.01 * aux


# =====================================================================
# decode (single token with cache)
# =====================================================================


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> PyTree:
    dtype = dtype or _dtype(cfg)
    KV, hd, L = cfg.kv_heads, cfg.hd, cfg.n_layers
    fam = cfg.family

    def kv(n):
        return {
            "k": jnp.zeros((n, batch, max_seq, KV, hd), dtype),
            "v": jnp.zeros((n, batch, max_seq, KV, hd), dtype),
        }

    if fam in ("dense", "audio"):
        return kv(L)
    if fam == "moe" and cfg.moe_every == 1:
        return kv(L)
    if fam == "moe":
        G = L // cfg.moe_every
        per = cfg.moe_every - 1
        dense = {
            "k": jnp.zeros((G, per, batch, max_seq, KV, hd), dtype),
            "v": jnp.zeros((G, per, batch, max_seq, KV, hd), dtype),
        }
        return {"dense": dense, "moe": kv(G)}
    if fam == "vlm":
        G = L // cfg.cross_attn_every
        per = cfg.cross_attn_every
        T_img = cfg.n_image_tokens
        return {
            "self": {
                "k": jnp.zeros((G, per, batch, max_seq, KV, hd), dtype),
                "v": jnp.zeros((G, per, batch, max_seq, KV, hd), dtype),
            },
            "xk": jnp.zeros((G, batch, T_img, KV, hd), dtype),
            "xv": jnp.zeros((G, batch, T_img, KV, hd), dtype),
        }
    if fam == "ssm":
        caches = [mamba2.init_mamba_cache(cfg, batch, dtype) for _ in range(L)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)
    if fam == "hybrid":
        caches = [mamba2.init_mamba_cache(cfg, batch, dtype) for _ in range(L)]
        mcache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)
        n_apps = (L + cfg.shared_attn_every - 1) // cfg.shared_attn_every
        return {
            "mamba": mcache,
            "attn": {
                "k": jnp.zeros((n_apps, batch, max_seq, KV, hd), dtype),
                "v": jnp.zeros((n_apps, batch, max_seq, KV, hd), dtype),
            },
        }
    raise ValueError(fam)


def _decode_dense_block(bp, x, cfg, kc, vc, pos):
    h = apply_norm(bp["ln1"], x, cfg)
    h, kc, vc = attn.decode_self_attention(bp["attn"], h, cfg, kc, vc, pos)
    x = x + h
    x = x + apply_mlp(bp["mlp"], apply_norm(bp["ln2"], x, cfg), cfg)
    return x, kc, vc


def _decode_moe_block(bp, x, cfg, kc, vc, pos):
    h = apply_norm(bp["ln1"], x, cfg)
    h, kc, vc = attn.decode_self_attention(bp["attn"], h, cfg, kc, vc, pos)
    x = x + h
    m, _ = moe.apply_moe(bp["moe"], apply_norm(bp["ln2"], x, cfg), cfg)
    x = x + m
    return x, kc, vc


def decode_step(params: PyTree, cfg: ModelConfig, cache: PyTree, tokens: jax.Array, pos: jax.Array):
    """One decode step: tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    x = _embed_input(params, cfg, tokens=tokens)
    fam = cfg.family

    if fam in ("dense", "audio") or (fam == "moe" and cfg.moe_every == 1):
        dec = _decode_moe_block if fam == "moe" else _decode_dense_block

        def body(carry, xs):
            bp, kc, vc = xs
            y, kc, vc = dec(bp, carry, cfg, kc, vc, pos)
            return y, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}

    elif fam == "moe":

        def body(carry, xs):
            bps, kcd, vcd, kcm, vcm = xs

            def inner(c, ys):
                bp, kc, vc = ys
                y, kc, vc = _decode_dense_block(bp, c, cfg, kc, vc, pos)
                return y, (kc, vc)

            y, (kcd, vcd) = jax.lax.scan(inner, carry, (bps["dense"], kcd, vcd))
            y, kcm, vcm = _decode_moe_block(bps["moe"], y, cfg, kcm, vcm, pos)
            return y, (kcd, vcd, kcm, vcm)

        x, (kcd, vcd, kcm, vcm) = jax.lax.scan(
            body, x,
            (params["blocks"], cache["dense"]["k"], cache["dense"]["v"],
             cache["moe"]["k"], cache["moe"]["v"]),
        )
        new_cache = {"dense": {"k": kcd, "v": vcd}, "moe": {"k": kcm, "v": vcm}}

    elif fam == "vlm":

        def body(carry, xs):
            bps, kcs, vcs, xk, xv = xs

            def inner(c, ys):
                bp, kc, vc = ys
                y, kc, vc = _decode_dense_block(bp, c, cfg, kc, vc, pos)
                return y, (kc, vc)

            y, (kcs, vcs) = jax.lax.scan(inner, carry, (bps["self"], kcs, vcs))
            y = apply_cross_block(bps["cross"], y, cfg, xk, xv)
            return y, (kcs, vcs)

        x, (kcs, vcs) = jax.lax.scan(
            body, x,
            (params["blocks"], cache["self"]["k"], cache["self"]["v"],
             cache["xk"], cache["xv"]),
        )
        new_cache = {"self": {"k": kcs, "v": vcs}, "xk": cache["xk"], "xv": cache["xv"]}

    elif fam == "ssm":

        def body(carry, xs):
            bp, mc = xs
            h = apply_norm(bp["ln1"], carry, cfg)
            o, mc = mamba2.decode_mamba_block(bp["mamba"], h, mc, cfg)
            return carry + o, mc

        x, mcache = jax.lax.scan(body, x, (params["blocks"], cache))
        new_cache = mcache

    elif fam == "hybrid":
        shared_bp = params["shared"]
        every = cfg.shared_attn_every

        def body(carry, xs):
            bp, mc, idx, slot = xs
            y = carry
            h = apply_norm(bp["ln1"], y, cfg)
            o, mc = mamba2.decode_mamba_block(bp["mamba"], h, mc, cfg)
            y = y + o

            kc = jax.lax.dynamic_index_in_dim(cache["attn"]["k"], slot, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(cache["attn"]["v"], slot, 0, keepdims=False)

            def with_attn(args):
                y, kc, vc = args
                return _decode_dense_block(shared_bp, y, cfg, kc, vc, pos)

            y, kc, vc = jax.lax.cond(
                idx % every == 0, with_attn, lambda a: a, (y, kc, vc)
            )
            return y, (mc, kc, vc, slot)

        L = cfg.n_layers
        idxs = jnp.arange(L, dtype=jnp.int32)
        slots = idxs // every
        x, (mcache, kslices, vslices, outslots) = jax.lax.scan(
            body, x, (params["blocks"], cache["mamba"], idxs, slots)
        )
        # Write back per-application attn cache slices.  Slot s is only
        # modified at layer i = s*every (static indices), other layers pass
        # their slice through unchanged, so gather those rows statically.
        rows = jnp.asarray([i for i in range(L) if i % every == 0], jnp.int32)
        tgt = rows // every
        kattn = cache["attn"]["k"].at[tgt].set(jnp.take(kslices, rows, axis=0))
        vattn = cache["attn"]["v"].at[tgt].set(jnp.take(vslices, rows, axis=0))
        new_cache = {"mamba": mcache, "attn": {"k": kattn, "v": vattn}}

    else:
        raise ValueError(fam)

    x = apply_norm(params["final_norm"], x, cfg)
    if cfg.frontend == "frames":
        logits = x @ params["embed"]["lm_head"].astype(x.dtype)
    else:
        logits = unembed(params["embed"], x, cfg)
    return logits, new_cache


class Model(NamedTuple):
    cfg: ModelConfig
    init: Any
    forward: Any
    decode_step: Any
    init_cache: Any
    loss: Any


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: init_params(key, cfg),
        forward=lambda params, tokens=None, **kw: forward(params, cfg, tokens, **kw),
        decode_step=lambda params, cache, tokens, pos: decode_step(params, cfg, cache, tokens, pos),
        init_cache=lambda batch, max_seq, dtype=None: init_cache(cfg, batch, max_seq, dtype),
        loss=lm_loss,
    )
