"""Primitive layers: norms, rotary embeddings, MLP variants, initializers.

Pure-functional: ``init_*`` builds param dicts, apply functions take them.
All matmul params are 2-D (so the low-rank optimizers treat each as a block);
stacked-layer leading dims are added by the scan machinery in transformer.py.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def trunc_normal(key, shape, std, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


# --------------------------------------------------------------------- norms


def init_norm(cfg: ModelConfig, d: int):
    p = {"norm_scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["norm_bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["norm_scale"] + p["norm_bias"]
    else:
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        out = x32 * jax.lax.rsqrt(ms + eps) * p["norm_scale"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------- rope


def rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for ``dim`` rotary dims at integer ``positions``."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Rotary embedding on (..., S, H, hd). ``rope_fraction < 1`` rotates only
    the leading fraction of head dims (ChatGLM's 2-D RoPE applies rotary to
    half the dims and leaves the rest as-is)."""
    if cfg.rope == "none":
        return x
    hd = x.shape[-1]
    rot = int(hd * (0.5 if cfg.rope == "rope2d" else cfg.rope_fraction))
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    cos, sin = rope_angles(positions, rot, cfg.rope_theta)  # (..., S, rot/2)
    cos = cos[..., :, None, :]  # broadcast over heads
    sin = sin[..., :, None, :]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- mlp


def init_mlp(key, cfg: ModelConfig, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    std = 1.0 / math.sqrt(d)
    gated = cfg.act in ("swiglu", "geglu")
    p = {"w_in": trunc_normal(k1, (d, d_ff), std)}
    if gated:
        p["w_gate"] = trunc_normal(k3, (d, d_ff), std)
    p["w_out"] = trunc_normal(k2, (d_ff, d), 1.0 / math.sqrt(d_ff))
    if cfg.mlp_bias:
        p["bias_in"] = jnp.zeros((d_ff,), jnp.float32)
        p["bias_out"] = jnp.zeros((d,), jnp.float32)
    return p


def mlp_act(h: jax.Array, g: Optional[jax.Array], act: str) -> jax.Array:
    if act == "swiglu":
        return jax.nn.silu(g) * h
    if act == "geglu":
        return jax.nn.gelu(g) * h
    if act == "gelu":
        return jax.nn.gelu(h)
    if act == "relu2":  # squared ReLU (Primer / Nemotron-4)
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(f"unknown act {act}")


def apply_mlp(p, x, cfg: ModelConfig):
    h = x @ p["w_in"].astype(x.dtype)
    if "bias_in" in p:
        h = h + p["bias_in"].astype(x.dtype)
    g = x @ p["w_gate"].astype(x.dtype) if "w_gate" in p else None
    h = mlp_act(h, g, cfg.act)
    out = h @ p["w_out"].astype(x.dtype)
    if "bias_out" in p:
        out = out + p["bias_out"].astype(x.dtype)
    return out


# --------------------------------------------------------------------- embed


def init_embed(key, cfg: ModelConfig):
    p = {"embed": trunc_normal(key, (cfg.vocab, cfg.d_model), 0.02)}
    if not cfg.tie_embeddings:
        p["lm_head"] = trunc_normal(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab), 0.02
        )
    return p


def embed_tokens(p, tokens: jax.Array, cfg: ModelConfig, dtype) -> jax.Array:
    return p["embed"].astype(dtype)[tokens]


def unembed(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ p["embed"].astype(x.dtype).T
    return x @ p["lm_head"].astype(x.dtype)
