"""GQA self-attention, cross-attention, and the decode cache path."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.sharding import logical_axis_size, shard

from .layers import apply_rope, trunc_normal


def _shard_qkv(x: jax.Array, n_heads: int, mode: str = "auto") -> jax.Array:
    """Tensor-parallel heads when they divide the model axis; otherwise fall
    back to SEQUENCE parallelism (e.g. qwen1.5's 20 heads on a 16-way axis —
    without this the attention activations replicate across the model axis,
    a 16x memory/compute redundancy observed in the baseline dry-run).
    ``mode="off"`` leaves the layout to GSPMD's propagation (measured better
    on MoE archs whose profile is expert-dominated — EXPERIMENTS.md §Perf)."""
    tp = logical_axis_size("tp")
    if tp > 1 and n_heads % tp == 0 and mode != "on":
        return shard(x, "fsdp", None, "tp", None)
    if mode == "off":
        return shard(x, "fsdp", None, None, None)
    return shard(x, "fsdp", "tp", None, None)


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p = {
        "wq": trunc_normal(ks[0], (d, H * hd), std),
        "wk": trunc_normal(ks[1], (d, KV * hd), std),
        "wv": trunc_normal(ks[2], (d, KV * hd), std),
        "wo": trunc_normal(ks[3], (H * hd, d), 1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bias_q"] = jnp.zeros((H * hd,), jnp.float32)
        p["bias_k"] = jnp.zeros((KV * hd,), jnp.float32)
        p["bias_v"] = jnp.zeros((KV * hd,), jnp.float32)
    return p


def _qkv(p, x, cfg: ModelConfig, *, y: Optional[jax.Array] = None):
    """Project q from x and k/v from y (cross) or x (self)."""
    H, KV, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    src = x if y is None else y
    q = x @ p["wq"].astype(x.dtype)
    k = src @ p["wk"].astype(x.dtype)
    v = src @ p["wv"].astype(x.dtype)
    if "bias_q" in p:
        q = q + p["bias_q"].astype(x.dtype)
        k = k + p["bias_k"].astype(x.dtype)
        v = v + p["bias_v"].astype(x.dtype)
    q = q.reshape(x.shape[:-1] + (H, hd))
    k = k.reshape(src.shape[:-1] + (KV, hd))
    v = v.reshape(src.shape[:-1] + (KV, hd))
    return q, k, v


def self_attention(
    p, x, cfg: ModelConfig, positions: jax.Array, causal: bool
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence attention; returns output and the fresh (k, v) cache."""
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    q = _shard_qkv(q, cfg.n_heads, cfg.seq_shard_attn)
    kv_tp = "tp" if cfg.kv_heads % max(logical_axis_size("tp"), 1) == 0 else None
    k = shard(k, "fsdp", None, kv_tp, None)
    v = shard(v, "fsdp", None, kv_tp, None)
    o = ops.attention(q, k, v, causal=causal, impl=cfg.attn_impl)
    o = o.reshape(x.shape[:-1] + (cfg.n_heads * cfg.hd,))
    return o @ p["wo"].astype(x.dtype), (k, v)


def decode_self_attention(
    p, x, cfg: ModelConfig, kcache, vcache, pos: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode: x (B, 1, D); caches (B, Smax, KV, hd); pos scalar."""
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, pos[None, None], cfg)
    k = apply_rope(k, pos[None, None], cfg)
    kcache = jax.lax.dynamic_update_slice_in_dim(kcache, k.astype(kcache.dtype), pos, axis=1)
    vcache = jax.lax.dynamic_update_slice_in_dim(vcache, v.astype(vcache.dtype), pos, axis=1)
    o = ops.decode_attention(q, kcache, vcache, pos)
    o = o.reshape(x.shape[:-1] + (cfg.n_heads * cfg.hd,))
    return o @ p["wo"].astype(x.dtype), kcache, vcache


def cross_attention(p, x, cfg: ModelConfig, xk, xv) -> jax.Array:
    """Cross-attend x (B, S, D) over precomputed image/frame K/V
    (B, T_img, KV, hd) — no RoPE on cross-attention (Llama-3.2-V style)."""
    H, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(x.shape[:-1] + (H, hd))
    o = ops.attention(q, xk.astype(x.dtype), xv.astype(x.dtype), causal=False, impl=cfg.attn_impl)
    o = o.reshape(x.shape[:-1] + (H * hd,))
    return o @ p["wo"].astype(x.dtype)


def encode_cross_kv(p, img: jax.Array, cfg: ModelConfig):
    """K/V projections of the (precomputed) image embeddings."""
    KV, hd = cfg.kv_heads, cfg.hd
    k = (img @ p["wk"].astype(img.dtype)).reshape(img.shape[:-1] + (KV, hd))
    v = (img @ p["wv"].astype(img.dtype)).reshape(img.shape[:-1] + (KV, hd))
    return k, v
