"""Static-analysis subsystem (PR 6): lint codes, launch model, jaxpr passes.

Three layers of guarantees:

  1. the full audit pass matrix — every factory optimizer across
     fuse_families x fused_epilogue — is clean, with the closed-form launch
     model agreeing with the dispatch layer's trace-time counts (9/step for
     fused GUM on the 3-family reference tree);
  2. every lint code has a failing case: a deliberately malformed chain /
     program is caught with the right code and an actionable message;
  3. the integration points work: ``build_optimizer(audit=True)`` raises at
     build time, ``assert_launches`` raises at trace time, the memory
     accountant agrees with the committed benchmark numbers.
"""
import itertools
import json

import jax
import jax.numpy as jnp
import pytest

import repro.core as core
from repro.analysis import (
    ChainLintError,
    audit_optimizer,
    audit_summary,
    dtype_flow_findings,
    expected_launches,
    lint_chain,
    memory_crosscheck,
    recompile_findings,
    run_matrix,
    trace_update,
)
from repro.analysis.audit import default_params, launch_findings
from repro.core import OptimizerConfig, Transform, build_optimizer
from repro.core import combinators as C
from repro.kernels import launch_count

PARAMS = default_params()


def codes(findings):
    return {f.code for f in findings}


def _msg(findings, code):
    return next(f.message for f in findings if f.code == code)


# ------------------------------------------------------------ pass matrix


def test_audit_matrix_all_clean():
    """Acceptance: every factory optimizer x fuse_families x fused_epilogue
    audits clean — chain lint, launch model vs traced dispatch counts,
    dtype flow, signature stability across the rank ladder."""
    reports = run_matrix(PARAMS)
    dirty = {k: [f.format() for f in r.errors]
             for k, r in reports.items() if not r.ok}
    assert not dirty, dirty
    # 6 lowrank optimizers x 4 fuse combos + 4 full-rank baselines
    assert len(reports) == 28


@pytest.mark.parametrize("opt,epi,want", [
    ("gum", False, {"project": 3, "newton_schulz": 3, "back_project": 3}),
    ("gum", True, {"project": 3, "newton_schulz": 3, "back_project": 3}),
    ("galore_muon", True, {"lowrank_update": 3, "newton_schulz": 3,
                           "back_project_epilogue": 3}),
    ("golore", True, {"lowrank_update": 3, "newton_schulz": 3,
                      "back_project_epilogue": 3}),  # default base=muon
], ids=["gum", "gum_epilogue", "galore_muon_epilogue", "golore_epilogue"])
def test_static_launches_match_traced_on_family_tree(opt, epi, want):
    """The closed-form expectation equals the dispatch layer's trace-time
    count on the 3-family reference tree — one launch set per family (GUM:
    9/step; the unbias emits FullUpdates so its epilogue stays unfused)."""
    cfg = OptimizerConfig(name=opt, rank=8, period=5, gamma=1,
                          kernel_impl="jnp", fuse_families=True,
                          fused_epilogue=epi)
    t = build_optimizer(cfg)
    expected, model_findings = expected_launches(t, PARAMS)
    assert not model_findings
    assert expected == want
    state = jax.eval_shape(t.init, PARAMS)
    with launch_count.assert_launches(expected):
        jax.make_jaxpr(lambda g, s, p: t.update(g, s, p))(
            PARAMS, state, PARAMS)


def test_assert_launches_raises_on_mismatch():
    cfg = OptimizerConfig(name="galore", rank=8, period=5,
                          kernel_impl="jnp", fuse_families=True)
    t = build_optimizer(cfg)
    state = jax.eval_shape(t.init, PARAMS)
    with pytest.raises(launch_count.LaunchCountMismatch, match="project"):
        with launch_count.assert_launches({"project": 999,
                                           "back_project": 3}):
            jax.make_jaxpr(lambda g, s, p: t.update(g, s, p))(
                PARAMS, state, PARAMS)
    with pytest.raises(ValueError, match="unknown op"):
        with launch_count.assert_launches({"warp_drive": 1}):
            pass


# ------------------------------------------------- chain linter (RC1xx)


def test_rc101_nested_lowrank():
    t = C.chain(
        C.lowrank(C.lowrank(C.scale_by_momentum(0.9), rank=4, period=2),
                  rank=8, period=2),
        C.scale_by_lr(1e-2),
    )
    fs = lint_chain(t)
    assert "RC101" in codes(fs)
    assert "nested" in _msg(fs, "RC101")


def test_rc102_unbias_outside_lowrank():
    t = C.chain(C.layerwise_unbias(C.scale_by_momentum(0.9), gamma=1),
                C.scale_by_lr(1e-2))
    fs = lint_chain(t)
    assert "RC102" in codes(fs)
    assert "lowrank" in _msg(fs, "RC102")


def test_rc103_scale_by_lr_not_terminal():
    t = C.chain(C.scale_by_lr(1e-2), C.scale_by_momentum(0.9))
    fs = lint_chain(t)
    assert "RC103" in codes(fs)
    assert any(f.code == "RC103" and f.severity == "error" for f in fs)
    # ... and inside lowrank() is also an error
    t2 = C.chain(
        C.lowrank(C.chain(C.scale_by_momentum(0.9), C.scale_by_lr(1e-2)),
                  rank=4, period=2),
        C.scale_by_lr(1e-2),
    )
    assert "RC103" in codes(lint_chain(t2))
    # missing entirely (with a lowrank stage) is only a warning
    t3 = C.chain(C.lowrank(C.scale_by_momentum(0.9), rank=4, period=2))
    fs3 = lint_chain(t3)
    assert any(f.code == "RC103" and f.severity == "warning" for f in fs3)
    assert not any(f.severity == "error" for f in fs3)


def test_rc104_non_monotone_ladder():
    t = C.chain(C.lowrank(C.scale_by_momentum(0.9), rank=16, period=2),
                C.scale_by_lr(1e-2))
    fs = lint_chain(t, ladder=(16, 8, 16))
    assert "RC104" in codes(fs)
    assert "strictly increasing" in _msg(fs, "RC104")


def test_rc105_initial_rank_off_ladder():
    t = C.chain(C.lowrank(C.scale_by_momentum(0.9), rank=5, period=2),
                C.scale_by_lr(1e-2))
    fs = lint_chain(t, ladder=(8, 16))
    assert "RC105" in codes(fs)
    assert "[5]" in _msg(fs, "RC105")
    # on-ladder initial rank is clean
    t2 = C.chain(C.lowrank(C.scale_by_momentum(0.9), rank=8, period=2),
                 C.scale_by_lr(1e-2))
    assert "RC105" not in codes(lint_chain(t2, ladder=(8, 16)))


def test_rc106_unaligned_pad_rank():
    t = C.chain(
        C.lowrank(C.scale_by_momentum(0.9), rank=4, period=2,
                  pad_rank_to=96),
        C.scale_by_lr(1e-2),
    )
    fs = lint_chain(t)
    assert "RC106" in codes(fs)
    assert "128" in _msg(fs, "RC106")  # the fix-it suggests the lane width


def test_build_optimizer_audit_raises():
    """audit=True turns lint errors into a build-time ChainLintError."""
    cfg = OptimizerConfig(name="gum", rank=5, period=5, gamma=1,
                          kernel_impl="jnp", rank_ladder=(8, 16))
    with pytest.raises(ChainLintError, match="RC105"):
        build_optimizer(cfg, audit=True)
    # the same config without the off-ladder rank builds fine
    build_optimizer(OptimizerConfig(name="gum", rank=8, period=5, gamma=1,
                                    kernel_impl="jnp", rank_ladder=(8, 16)),
                    audit=True)


# ------------------------------------------- dtype-flow auditor (RA2xx)


def _elementwise_transform(fn):
    return Transform(
        lambda p: (),
        lambda g, s, p: (jax.tree_util.tree_map(fn, g), s),
    )


def test_ra201_f64_leak():
    t = _elementwise_transform(lambda x: x.astype(jnp.float64))
    with jax.experimental.enable_x64():
        params = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        jaxpr, _ = trace_update(t, params)
        fs = dtype_flow_findings(jaxpr)
    assert "RA201" in codes(fs)
    assert "f64" in _msg(fs, "RA201")


def test_ra202_bf16_roundtrip():
    t = _elementwise_transform(
        lambda x: x.astype(jnp.bfloat16).astype(jnp.float32) * 2.0)
    jaxpr, _ = trace_update(t, PARAMS)
    fs = dtype_flow_findings(jaxpr)
    assert "RA202" in codes(fs)
    # the allowlist knob suppresses it
    assert "RA202" not in codes(
        dtype_flow_findings(jaxpr, allow_bf16_roundtrip=True))


def test_dtype_flow_clean_on_factory_step():
    t = build_optimizer(OptimizerConfig(name="gum", rank=8, period=5,
                                        gamma=1, kernel_impl="jnp"))
    jaxpr, _ = trace_update(t, PARAMS)
    assert not dtype_flow_findings(jaxpr)


# ---------------------------------------- launch/fusion auditor (RA3xx)


def test_ra301_launch_divergence():
    fs = launch_findings({"project": 3, "back_project": 3},
                         {"project": 8, "back_project": 3},
                         fused_epilogue=False, where="x")
    assert codes(fs) == {"RA301"}
    assert "expected 3, traced 8" in _msg(fs, "RA301")


def test_ra302_stray_back_projection():
    fs = launch_findings(
        {"lowrank_update": 3, "back_project_epilogue": 3},
        {"lowrank_update": 3, "back_project": 3},
        fused_epilogue=True, where="x")
    assert codes(fs) == {"RA302"}
    assert "back_project" in _msg(fs, "RA302")


def test_ra303_unmodelable_stage():
    opaque = Transform(lambda p: (), lambda g, s, p: (g, s))
    t = C.chain(C.lowrank(opaque, rank=4, period=2), C.scale_by_lr(1e-2))
    _, fs = expected_launches(t, PARAMS)
    assert "RA303" in codes(fs)


# --------------------------------- recompilation-hazard detector (RA4xx)


def test_ra401_unstable_signature():
    counter = itertools.count(1)
    t = _elementwise_transform(lambda x: x * float(next(counter)))
    fs, _ = recompile_findings(lambda r: t, PARAMS, [4])
    assert "RA401" in codes(fs)


def test_ra402_weak_scalar_capture():
    weak = jnp.asarray(0.5)  # weak-typed 0-d closure capture
    t = _elementwise_transform(lambda x: x * weak)
    fs, _ = recompile_findings(lambda r: t, PARAMS, [4])
    assert "RA402" in codes(fs)
    assert all(f.severity == "warning" for f in fs if f.code == "RA402")


def test_signature_stable_per_rank_for_factory():
    cfg = OptimizerConfig(name="galore", rank=8, period=5,
                          kernel_impl="jnp", rank_ladder=(4, 8))
    from repro.core.rank_policy import RankMap

    fs, hashes = recompile_findings(
        lambda r: build_optimizer(cfg, rank_map=RankMap(r)), PARAMS, (4, 8))
    assert not [f for f in fs if f.severity == "error"]
    # ranks recompile (different shapes) but each rank's trace is stable
    assert len(set(hashes.values())) == 2


# ----------------------------------- static memory accountant (RA5xx)


def test_memory_crosscheck_matches_committed_bench():
    """The eval_shape accountant reproduces the committed runtime
    proj_bytes_final for every rank-policy cell exactly."""
    assert memory_crosscheck() == []


def test_ra501_on_doctored_bench(tmp_path):
    real = json.loads(
        open("results/BENCH_rank_policy.json").read())
    real["results"]["fixed16"]["proj_bytes_final"] += 1
    doctored = tmp_path / "BENCH_rank_policy.json"
    doctored.write_text(json.dumps(real))
    fs = memory_crosscheck(doctored)
    assert "RA501" in codes(fs)
    assert any(f.code == "RA501" and "fixed16" in f.where for f in fs)
    assert "303137" in _msg(fs, "RA501")


# --------------------------------------------------------- integration


def test_audit_summary_one_liner():
    t = build_optimizer(OptimizerConfig(name="gum", rank=8, period=5,
                                        gamma=1, kernel_impl="jnp",
                                        fuse_families=True))
    line = audit_summary(t, PARAMS, name="gum")
    assert "launches/step=9" in line
    assert "proj_state=" in line and "sig=" in line
    assert "\n" not in line


def test_audit_report_roundtrip():
    cfg = OptimizerConfig(name="golore", rank=8, period=5,
                          kernel_impl="jnp", fuse_families=True,
                          fused_epilogue=True, rank_ladder=(4, 8))
    rep = audit_optimizer(cfg, PARAMS, ladder=(4, 8))
    assert rep.ok, [f.format() for f in rep.errors]
    d = rep.to_json()
    assert d["ok"] and d["summary"]["launches_per_step"] == 9
    assert "back_project_epilogue" in d["summary"]["launch_counts"]


def test_lowrank_plan_stats_geometry():
    from repro.analysis import lowrank_plan_stats
    t = build_optimizer(OptimizerConfig(name="gum", rank=8, period=5,
                                        gamma=1, kernel_impl="jnp",
                                        fuse_families=True))
    stats = lowrank_plan_stats(t, PARAMS, name="gum")
    assert len(stats) == 1
    (s,) = stats
    assert s["fused"] and s["n_families"] == 3 and s["n_stacked"] == 8
    assert sorted(s["families"]) == ["128x64r8x2", "64x128r8x2", "64x64r8x4"]


def test_launch_model_counts_both_unbias_branches_when_q_lt_1():
    """Leaves with lead blocks (q = gamma/L < 1) trace BOTH layerwise_unbias
    branches — the compensated sample AND the plain low-rank path — and the
    closed-form model must count both (caught live on llama-60m-smoke)."""
    lead_params = {
        # L = 3 blocks per leaf, gamma = 1 -> q = 1/3 < 1
        "blocks/wq": jax.ShapeDtypeStruct((3, 64, 64), jnp.float32),
        "blocks/wo": jax.ShapeDtypeStruct((3, 64, 64), jnp.float32),
        "norm/scale": jax.ShapeDtypeStruct((64,), jnp.float32),
    }
    cfg = OptimizerConfig(name="gum", rank=8, period=5, gamma=1,
                          kernel_impl="jnp")
    t = build_optimizer(cfg)
    expected, findings = expected_launches(t, lead_params, name="gum")
    assert findings == []
    # per leaf: unbias sample (project, newton_schulz, back_project) + plain
    # muon low branch (lowrank_update, newton_schulz, back_project)
    assert expected == {"project": 2, "lowrank_update": 2,
                       "newton_schulz": 4, "back_project": 4}
    state = jax.eval_shape(t.init, lead_params)
    with launch_count.assert_launches(expected):
        jax.make_jaxpr(lambda g, s, w: t.update(g, s, w))(
            lead_params, state, lead_params)


# ------------------------------------------- sharded audit (RA6xx, PR 7)
# The clean path is covered at mesh 1/2/8 via the AbstractMesh trace (no
# devices needed); every RA6xx code then gets a doctored failing case.


from repro.analysis import (  # noqa: E402  (section-local imports)
    ArgInfo,
    CollectiveRecord,
    audit_sharded,
    collective_schedule_findings,
    donation_findings,
    expected_collective_schedule,
    parse_main_args,
    per_shard_memory,
    replication_findings,
    trace_sharded_step,
    wire_bytes_model,
)


def _rec(**kw):
    base = dict(primitive="psum", axes=("data",), dtypes=("bfloat16",),
                shapes=((64, 64),), n_operands=1, payload_bytes=8192,
                under_cond=False, pinned=True, path=("shard_map",))
    base.update(kw)
    return CollectiveRecord(**base)


def _sharded_expected(n_leaves=1, payload=8192):
    return {
        "grad_psum": {"count": 1, "dtype": "bfloat16",
                      "operands": n_leaves, "payload_bytes": payload,
                      "axis": "data", "phase": "steady"},
        "loss_psum": {"count": 1, "dtype": "float32", "operands": 1,
                      "payload_bytes": 4, "axis": "data",
                      "phase": "steady"},
        "boundary_gather": {"count": 0, "families": 0, "payload_bytes": 0,
                            "phase": "boundary"},
        "n_shards": 2,
    }


_LOSS = dict(dtypes=("float32",), shapes=((),), payload_bytes=4,
             pinned=False)


@pytest.mark.parametrize("n_shards", [1, 2, 8])
def test_sharded_audit_clean_static_matches_traced(n_shards):
    """Acceptance: the traced shard_map step matches the closed-form
    schedule on 1/2/8-way meshes — one reduce_dtype gradient psum over
    every param leaf plus one scalar f32 loss psum, nothing else.
    AbstractMesh trace: runs with however many devices the host has."""
    cfg = OptimizerConfig(name="gum", rank=8, period=5, gamma=1,
                          kernel_impl="jnp")
    rep = audit_sharded(cfg, mesh_axes=(("data", n_shards),), lower=False)
    assert rep.ok, [f.format() for f in rep.errors]
    exp = rep.summary["expected_schedule"]
    assert exp["grad_psum"]["count"] == 1
    assert exp["grad_psum"]["dtype"] == "bfloat16"
    wire = rep.summary["wire"]
    if n_shards == 1:
        assert wire["steady_bytes_per_step"] == 0
    else:
        # ring psum: 2(N-1)/N bytes on the wire per payload byte
        payload = (exp["grad_psum"]["payload_bytes"]
                   + exp["loss_psum"]["payload_bytes"])
        want = int(exp["grad_psum"]["payload_bytes"]
                   * 2 * (n_shards - 1) / n_shards) + int(
                       exp["loss_psum"]["payload_bytes"]
                       * 2 * (n_shards - 1) / n_shards)
        assert wire["steady_bytes_per_step"] == want, (wire, payload)


def test_trace_sharded_step_schedule_shape():
    """The raw trace on an 8-way AbstractMesh: exactly two steady psums —
    the multi-operand bf16 gradient reduction (barrier-pinned) and the
    scalar f32 loss pmean."""
    from repro.analysis.audit import arch_model

    model = arch_model("llama-60m-smoke")
    t = build_optimizer(OptimizerConfig(name="adamw", lr=1e-3))
    _, records, counts, (params, _, _) = trace_sharded_step(
        model, t, n_shards=8)
    psums = [r for r in records if r.primitive == "psum"]
    assert len(psums) == 2
    grad = next(r for r in psums if not r.scalar_only)
    loss = next(r for r in psums if r.scalar_only)
    n_leaves = len(jax.tree_util.tree_leaves(params))
    assert grad.n_operands == n_leaves
    assert grad.dtypes == ("bfloat16",) and grad.pinned
    assert loss.dtypes == ("float32",)
    assert counts["psum"] == 2


def test_ra601_wide_dtype_on_wire():
    recs = [_rec(dtypes=("float32",), payload_bytes=16384), _rec(**_LOSS)]
    fs = collective_schedule_findings(recs, _sharded_expected())
    assert "RA601" in codes(fs)
    assert "float32" in _msg(fs, "RA601")


def test_ra601_unpinned_narrow_reduction():
    """bf16 psum without the optimization_barrier pin: XLA may re-promote
    it — the structural def-use check fires even though the jaxpr dtype
    still says bf16."""
    recs = [_rec(pinned=False), _rec(**_LOSS)]
    fs = collective_schedule_findings(recs, _sharded_expected())
    assert "RA601" in codes(fs)
    assert "barrier" in _msg(fs, "RA601")


def test_ra602_unconditional_boundary_collective():
    recs = [_rec(), _rec(**_LOSS),
            _rec(primitive="all_gather", shapes=((8, 16),),
                 payload_bytes=512, pinned=False)]
    fs = collective_schedule_findings(recs, _sharded_expected())
    assert "RA602" in codes(fs)


def test_ra603_full_gradient_gather_in_steady_state():
    params = {"w": jax.ShapeDtypeStruct((64, 64), jnp.float32)}
    recs = [_rec(), _rec(**_LOSS),
            _rec(primitive="all_gather", shapes=((64, 64),),
                 payload_bytes=16384, pinned=False)]
    fs = collective_schedule_findings(recs, _sharded_expected(),
                                      params=params)
    assert "RA603" in codes(fs)
    assert "RA602" not in codes(fs)


def test_ra606_schedule_divergence():
    # two gradient psums where the model says one (per-leaf reduction crept
    # back in)
    recs = [_rec(), _rec(), _rec(**_LOSS)]
    fs = collective_schedule_findings(recs, _sharded_expected())
    assert "RA606" in codes(fs)
    # missing loss pmean
    fs = collective_schedule_findings([_rec()], _sharded_expected())
    assert "RA606" in codes(fs)


_ALIASED = ('%arg{i}: tensor<{t}> {{tf.aliasing_output = {i} : i32, '
            'mhlo.sharding = "{{replicated}}"}}')
_PLAIN = '%arg{i}: tensor<{t}>'
_SHARDED = ('%arg{i}: tensor<{t}> '
            '{{mhlo.sharding = "{{devices=[2,1]<=[2]}}"}}')


def _module(arg_chunks):
    return ("module @jit_step {\n  func.func public @main("
            + ", ".join(arg_chunks) + ") -> (tensor<4x4xf32>) {}\n}")


def test_parse_main_args_and_donation_clean():
    txt = _module([
        _ALIASED.format(i=0, t="4x4xf32"),
        _ALIASED.format(i=1, t="4x4xf32"),
        _SHARDED.format(i=2, t="8x16xi32"),
    ])
    args = parse_main_args(txt)
    assert [a.aliased for a in args] == [True, True, False]
    assert args[0].nbytes == 64 and args[2].dtype == "i32"
    assert not args[2].replicated
    assert donation_findings(args, n_params=1, n_opt=1) == []
    assert replication_findings(args, n_params=1, n_opt=1, n_shards=2) == []


def test_ra604_lost_donation():
    txt = _module([
        _ALIASED.format(i=0, t="4x4xf32"),
        _PLAIN.format(i=1, t="4x4xf32"),      # opt-state leaf, not aliased
        _SHARDED.format(i=2, t="8x16xi32"),
    ])
    fs = donation_findings(parse_main_args(txt), n_params=1, n_opt=1)
    assert codes(fs) == {"RA604"}
    assert "opt_state" in _msg(fs, "RA604")


def test_ra605_replicated_batch():
    txt = _module([
        _ALIASED.format(i=0, t="4x4xf32"),
        _ALIASED.format(i=1, t="4x4xf32"),
        _PLAIN.format(i=2, t="8x16xi32"),     # batch with no sharding attr
    ])
    fs = replication_findings(parse_main_args(txt), n_params=1, n_opt=1,
                              n_shards=2)
    assert codes(fs) == {"RA605"}
    # mesh of 1: replication is the only option, not a finding
    assert replication_findings(parse_main_args(txt), n_params=1, n_opt=1,
                                n_shards=1) == []


def test_wire_bytes_ring_coefficients():
    recs = [_rec(payload_bytes=1000),
            _rec(primitive="all_gather", payload_bytes=1000, pinned=False,
                 under_cond=True)]
    m = wire_bytes_model(recs, 8)
    assert m["steady_bytes_per_step"] == int(1000 * 2 * 7 / 8)
    assert m["boundary_bytes"] == int(1000 * 7 / 8)
    assert wire_bytes_model(recs, 1)["steady_bytes_per_step"] == 0


def test_per_shard_memory_model():
    params = {"w": jax.ShapeDtypeStruct((64, 64), jnp.float32)}
    opt = {"mu": jax.ShapeDtypeStruct((64, 64), jnp.float32)}
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    m = per_shard_memory(params, opt, batch, n_shards=8)
    assert m["params_bytes"] == 64 * 64 * 4
    assert m["grad_bytes_fp32"] == 64 * 64 * 4
    assert m["grad_wire_bytes"] == 64 * 64 * 2     # bf16 wire copy
    assert m["batch_bytes_per_shard"] == 8 * 16 * 4 // 8
    assert m["peak_bytes_per_shard"] == sum(
        m[k] for k in ("params_bytes", "opt_state_bytes", "grad_bytes_fp32",
                       "grad_wire_bytes", "batch_bytes_per_shard"))


def test_expected_schedule_counts_families():
    t = build_optimizer(OptimizerConfig(name="gum", rank=8, period=5,
                                        gamma=1, kernel_impl="jnp",
                                        fuse_families=True))
    exp = expected_collective_schedule(t, PARAMS, n_shards=4)
    assert exp["grad_psum"]["operands"] == len(
        jax.tree_util.tree_leaves(PARAMS))
    assert exp["boundary_gather"]["count"] == 0
    assert exp["boundary_gather"]["families"] == 3


def test_per_shard_bytes_divides_by_mesh():
    """sharding.per_shard_bytes charges per-shard, not per-replica: a 2-D
    fsdp-sharded matrix divides by the data-axis size, a 1-D norm vector
    (replicated by rule) does not."""
    import numpy as np
    from jax.sharding import Mesh

    from repro.sharding import per_shard_bytes

    devs = np.asarray(jax.devices()[:1]).reshape(1)
    mesh = Mesh(devs, ("data",))
    tree = {"layers/0/attn/wq": jax.ShapeDtypeStruct((64, 64), jnp.float32),
            "norm/scale": jax.ShapeDtypeStruct((64,), jnp.float32)}
    # 1-way mesh: nothing divides
    assert per_shard_bytes(tree, mesh) == 64 * 64 * 4 + 64 * 4

    class FakeMesh:
        axis_names = ("data",)
        shape = {"data": 4}

    assert per_shard_bytes(tree, FakeMesh()) == 64 * 64 * 4 // 4 + 64 * 4
