"""Static-analysis subsystem (PR 6): lint codes, launch model, jaxpr passes.

Three layers of guarantees:

  1. the full audit pass matrix — every factory optimizer across
     fuse_families x fused_epilogue — is clean, with the closed-form launch
     model agreeing with the dispatch layer's trace-time counts (9/step for
     fused GUM on the 3-family reference tree);
  2. every lint code has a failing case: a deliberately malformed chain /
     program is caught with the right code and an actionable message;
  3. the integration points work: ``build_optimizer(audit=True)`` raises at
     build time, ``assert_launches`` raises at trace time, the memory
     accountant agrees with the committed benchmark numbers.
"""
import itertools
import json

import jax
import jax.numpy as jnp
import pytest

import repro.core as core
from repro.analysis import (
    ChainLintError,
    audit_optimizer,
    audit_summary,
    dtype_flow_findings,
    expected_launches,
    lint_chain,
    memory_crosscheck,
    recompile_findings,
    run_matrix,
    trace_update,
)
from repro.analysis.audit import default_params, launch_findings
from repro.core import OptimizerConfig, Transform, build_optimizer
from repro.core import combinators as C
from repro.kernels import launch_count

PARAMS = default_params()


def codes(findings):
    return {f.code for f in findings}


def _msg(findings, code):
    return next(f.message for f in findings if f.code == code)


# ------------------------------------------------------------ pass matrix


def test_audit_matrix_all_clean():
    """Acceptance: every factory optimizer x fuse_families x fused_epilogue
    audits clean — chain lint, launch model vs traced dispatch counts,
    dtype flow, signature stability across the rank ladder."""
    reports = run_matrix(PARAMS)
    dirty = {k: [f.format() for f in r.errors]
             for k, r in reports.items() if not r.ok}
    assert not dirty, dirty
    # 6 lowrank optimizers x 4 fuse combos + 4 full-rank baselines
    assert len(reports) == 28


@pytest.mark.parametrize("opt,epi,want", [
    ("gum", False, {"project": 3, "newton_schulz": 3, "back_project": 3}),
    ("gum", True, {"project": 3, "newton_schulz": 3, "back_project": 3}),
    ("galore_muon", True, {"lowrank_update": 3, "newton_schulz": 3,
                           "back_project_epilogue": 3}),
    ("golore", True, {"lowrank_update": 3, "newton_schulz": 3,
                      "back_project_epilogue": 3}),  # default base=muon
], ids=["gum", "gum_epilogue", "galore_muon_epilogue", "golore_epilogue"])
def test_static_launches_match_traced_on_family_tree(opt, epi, want):
    """The closed-form expectation equals the dispatch layer's trace-time
    count on the 3-family reference tree — one launch set per family (GUM:
    9/step; the unbias emits FullUpdates so its epilogue stays unfused)."""
    cfg = OptimizerConfig(name=opt, rank=8, period=5, gamma=1,
                          kernel_impl="jnp", fuse_families=True,
                          fused_epilogue=epi)
    t = build_optimizer(cfg)
    expected, model_findings = expected_launches(t, PARAMS)
    assert not model_findings
    assert expected == want
    state = jax.eval_shape(t.init, PARAMS)
    with launch_count.assert_launches(expected):
        jax.make_jaxpr(lambda g, s, p: t.update(g, s, p))(
            PARAMS, state, PARAMS)


def test_assert_launches_raises_on_mismatch():
    cfg = OptimizerConfig(name="galore", rank=8, period=5,
                          kernel_impl="jnp", fuse_families=True)
    t = build_optimizer(cfg)
    state = jax.eval_shape(t.init, PARAMS)
    with pytest.raises(launch_count.LaunchCountMismatch, match="project"):
        with launch_count.assert_launches({"project": 999,
                                           "back_project": 3}):
            jax.make_jaxpr(lambda g, s, p: t.update(g, s, p))(
                PARAMS, state, PARAMS)
    with pytest.raises(ValueError, match="unknown dispatch op"):
        with launch_count.assert_launches({"warp_drive": 1}):
            pass


# ------------------------------------------------- chain linter (RC1xx)


def test_rc101_nested_lowrank():
    t = C.chain(
        C.lowrank(C.lowrank(C.scale_by_momentum(0.9), rank=4, period=2),
                  rank=8, period=2),
        C.scale_by_lr(1e-2),
    )
    fs = lint_chain(t)
    assert "RC101" in codes(fs)
    assert "nested" in _msg(fs, "RC101")


def test_rc102_unbias_outside_lowrank():
    t = C.chain(C.layerwise_unbias(C.scale_by_momentum(0.9), gamma=1),
                C.scale_by_lr(1e-2))
    fs = lint_chain(t)
    assert "RC102" in codes(fs)
    assert "lowrank" in _msg(fs, "RC102")


def test_rc103_scale_by_lr_not_terminal():
    t = C.chain(C.scale_by_lr(1e-2), C.scale_by_momentum(0.9))
    fs = lint_chain(t)
    assert "RC103" in codes(fs)
    assert any(f.code == "RC103" and f.severity == "error" for f in fs)
    # ... and inside lowrank() is also an error
    t2 = C.chain(
        C.lowrank(C.chain(C.scale_by_momentum(0.9), C.scale_by_lr(1e-2)),
                  rank=4, period=2),
        C.scale_by_lr(1e-2),
    )
    assert "RC103" in codes(lint_chain(t2))
    # missing entirely (with a lowrank stage) is only a warning
    t3 = C.chain(C.lowrank(C.scale_by_momentum(0.9), rank=4, period=2))
    fs3 = lint_chain(t3)
    assert any(f.code == "RC103" and f.severity == "warning" for f in fs3)
    assert not any(f.severity == "error" for f in fs3)


def test_rc104_non_monotone_ladder():
    t = C.chain(C.lowrank(C.scale_by_momentum(0.9), rank=16, period=2),
                C.scale_by_lr(1e-2))
    fs = lint_chain(t, ladder=(16, 8, 16))
    assert "RC104" in codes(fs)
    assert "strictly increasing" in _msg(fs, "RC104")


def test_rc105_initial_rank_off_ladder():
    t = C.chain(C.lowrank(C.scale_by_momentum(0.9), rank=5, period=2),
                C.scale_by_lr(1e-2))
    fs = lint_chain(t, ladder=(8, 16))
    assert "RC105" in codes(fs)
    assert "[5]" in _msg(fs, "RC105")
    # on-ladder initial rank is clean
    t2 = C.chain(C.lowrank(C.scale_by_momentum(0.9), rank=8, period=2),
                 C.scale_by_lr(1e-2))
    assert "RC105" not in codes(lint_chain(t2, ladder=(8, 16)))


def test_rc106_unaligned_pad_rank():
    t = C.chain(
        C.lowrank(C.scale_by_momentum(0.9), rank=4, period=2,
                  pad_rank_to=96),
        C.scale_by_lr(1e-2),
    )
    fs = lint_chain(t)
    assert "RC106" in codes(fs)
    assert "128" in _msg(fs, "RC106")  # the fix-it suggests the lane width


def test_build_optimizer_audit_raises():
    """audit=True turns lint errors into a build-time ChainLintError."""
    cfg = OptimizerConfig(name="gum", rank=5, period=5, gamma=1,
                          kernel_impl="jnp", rank_ladder=(8, 16))
    with pytest.raises(ChainLintError, match="RC105"):
        build_optimizer(cfg, audit=True)
    # the same config without the off-ladder rank builds fine
    build_optimizer(OptimizerConfig(name="gum", rank=8, period=5, gamma=1,
                                    kernel_impl="jnp", rank_ladder=(8, 16)),
                    audit=True)


# ------------------------------------------- dtype-flow auditor (RA2xx)


def _elementwise_transform(fn):
    return Transform(
        lambda p: (),
        lambda g, s, p: (jax.tree_util.tree_map(fn, g), s),
    )


def test_ra201_f64_leak():
    t = _elementwise_transform(lambda x: x.astype(jnp.float64))
    with jax.experimental.enable_x64():
        params = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        jaxpr, _ = trace_update(t, params)
        fs = dtype_flow_findings(jaxpr)
    assert "RA201" in codes(fs)
    assert "f64" in _msg(fs, "RA201")


def test_ra202_bf16_roundtrip():
    t = _elementwise_transform(
        lambda x: x.astype(jnp.bfloat16).astype(jnp.float32) * 2.0)
    jaxpr, _ = trace_update(t, PARAMS)
    fs = dtype_flow_findings(jaxpr)
    assert "RA202" in codes(fs)
    # the allowlist knob suppresses it
    assert "RA202" not in codes(
        dtype_flow_findings(jaxpr, allow_bf16_roundtrip=True))


def test_dtype_flow_clean_on_factory_step():
    t = build_optimizer(OptimizerConfig(name="gum", rank=8, period=5,
                                        gamma=1, kernel_impl="jnp"))
    jaxpr, _ = trace_update(t, PARAMS)
    assert not dtype_flow_findings(jaxpr)


# ---------------------------------------- launch/fusion auditor (RA3xx)


def test_ra301_launch_divergence():
    fs = launch_findings({"project": 3, "back_project": 3},
                         {"project": 8, "back_project": 3},
                         fused_epilogue=False, where="x")
    assert codes(fs) == {"RA301"}
    assert "expected 3, traced 8" in _msg(fs, "RA301")


def test_ra302_stray_back_projection():
    fs = launch_findings(
        {"lowrank_update": 3, "back_project_epilogue": 3},
        {"lowrank_update": 3, "back_project": 3},
        fused_epilogue=True, where="x")
    assert codes(fs) == {"RA302"}
    assert "back_project" in _msg(fs, "RA302")


def test_ra303_unmodelable_stage():
    opaque = Transform(lambda p: (), lambda g, s, p: (g, s))
    t = C.chain(C.lowrank(opaque, rank=4, period=2), C.scale_by_lr(1e-2))
    _, fs = expected_launches(t, PARAMS)
    assert "RA303" in codes(fs)


# --------------------------------- recompilation-hazard detector (RA4xx)


def test_ra401_unstable_signature():
    counter = itertools.count(1)
    t = _elementwise_transform(lambda x: x * float(next(counter)))
    fs, _ = recompile_findings(lambda r: t, PARAMS, [4])
    assert "RA401" in codes(fs)


def test_ra402_weak_scalar_capture():
    weak = jnp.asarray(0.5)  # weak-typed 0-d closure capture
    t = _elementwise_transform(lambda x: x * weak)
    fs, _ = recompile_findings(lambda r: t, PARAMS, [4])
    assert "RA402" in codes(fs)
    assert all(f.severity == "warning" for f in fs if f.code == "RA402")


def test_signature_stable_per_rank_for_factory():
    cfg = OptimizerConfig(name="galore", rank=8, period=5,
                          kernel_impl="jnp", rank_ladder=(4, 8))
    from repro.core.rank_policy import RankMap

    fs, hashes = recompile_findings(
        lambda r: build_optimizer(cfg, rank_map=RankMap(r)), PARAMS, (4, 8))
    assert not [f for f in fs if f.severity == "error"]
    # ranks recompile (different shapes) but each rank's trace is stable
    assert len(set(hashes.values())) == 2


# ----------------------------------- static memory accountant (RA5xx)


def test_memory_crosscheck_matches_committed_bench():
    """The eval_shape accountant reproduces the committed runtime
    proj_bytes_final for every rank-policy cell exactly."""
    assert memory_crosscheck() == []


def test_ra501_on_doctored_bench(tmp_path):
    real = json.loads(
        open("results/BENCH_rank_policy.json").read())
    real["results"]["fixed16"]["proj_bytes_final"] += 1
    doctored = tmp_path / "BENCH_rank_policy.json"
    doctored.write_text(json.dumps(real))
    fs = memory_crosscheck(doctored)
    assert "RA501" in codes(fs)
    assert any(f.code == "RA501" and "fixed16" in f.where for f in fs)
    assert "303137" in _msg(fs, "RA501")


# --------------------------------------------------------- integration


def test_audit_summary_one_liner():
    t = build_optimizer(OptimizerConfig(name="gum", rank=8, period=5,
                                        gamma=1, kernel_impl="jnp",
                                        fuse_families=True))
    line = audit_summary(t, PARAMS, name="gum")
    assert "launches/step=9" in line
    assert "proj_state=" in line and "sig=" in line
    assert "\n" not in line


def test_audit_report_roundtrip():
    cfg = OptimizerConfig(name="golore", rank=8, period=5,
                          kernel_impl="jnp", fuse_families=True,
                          fused_epilogue=True, rank_ladder=(4, 8))
    rep = audit_optimizer(cfg, PARAMS, ladder=(4, 8))
    assert rep.ok, [f.format() for f in rep.errors]
    d = rep.to_json()
    assert d["ok"] and d["summary"]["launches_per_step"] == 9
    assert "back_project_epilogue" in d["summary"]["launch_counts"]


def test_lowrank_plan_stats_geometry():
    from repro.analysis import lowrank_plan_stats
    t = build_optimizer(OptimizerConfig(name="gum", rank=8, period=5,
                                        gamma=1, kernel_impl="jnp",
                                        fuse_families=True))
    stats = lowrank_plan_stats(t, PARAMS, name="gum")
    assert len(stats) == 1
    (s,) = stats
    assert s["fused"] and s["n_families"] == 3 and s["n_stacked"] == 8
    assert sorted(s["families"]) == ["128x64r8x2", "64x128r8x2", "64x64r8x4"]


def test_launch_model_counts_both_unbias_branches_when_q_lt_1():
    """Leaves with lead blocks (q = gamma/L < 1) trace BOTH layerwise_unbias
    branches — the compensated sample AND the plain low-rank path — and the
    closed-form model must count both (caught live on llama-60m-smoke)."""
    lead_params = {
        # L = 3 blocks per leaf, gamma = 1 -> q = 1/3 < 1
        "blocks/wq": jax.ShapeDtypeStruct((3, 64, 64), jnp.float32),
        "blocks/wo": jax.ShapeDtypeStruct((3, 64, 64), jnp.float32),
        "norm/scale": jax.ShapeDtypeStruct((64,), jnp.float32),
    }
    cfg = OptimizerConfig(name="gum", rank=8, period=5, gamma=1,
                          kernel_impl="jnp")
    t = build_optimizer(cfg)
    expected, findings = expected_launches(t, lead_params, name="gum")
    assert findings == []
    # per leaf: unbias sample (project, newton_schulz, back_project) + plain
    # muon low branch (lowrank_update, newton_schulz, back_project)
    assert expected == {"project": 2, "lowrank_update": 2,
                       "newton_schulz": 4, "back_project": 4}
    state = jax.eval_shape(t.init, lead_params)
    with launch_count.assert_launches(expected):
        jax.make_jaxpr(lambda g, s, w: t.update(g, s, w))(
            lead_params, state, lead_params)
