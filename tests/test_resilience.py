"""Resilience subsystem: fault plan/gate units, health detectors, the
recovery ladder, checkpoint integrity, and the deterministic fault matrix
(ISSUE 8 acceptance: every fault class recovers via its documented rung,
reproducibly, with final loss within budget of the fault-free run)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.resilience import (
    FaultEvent,
    FaultGate,
    FaultPlan,
    HealthMonitor,
    RecoveryController,
    ResilienceConfig,
    SnapshotRing,
    bitflip_checkpoint,
    force_refresh,
    poison_projectors,
    truncate_checkpoint,
)
from repro.resilience.health import HealthReport

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------- fault plan


def test_fault_plan_parse_and_roundtrip():
    plan = FaultPlan.parse(
        "grad_nan@5;grad_spike@9*1e3;refresh_zero@13;kill_save@20#3", seed=7)
    assert [(e.step, e.kind) for e in plan.events] == [
        (5, "grad_nan"), (9, "grad_spike"), (13, "refresh_zero"),
        (20, "kill_save")]
    assert plan.events[1].scale == 1e3
    assert plan.events[3].arg == 3
    clone = FaultPlan.from_json(plan.to_json())
    assert [(e.step, e.kind) for e in clone.events] == \
        [(e.step, e.kind) for e in plan.events]
    assert clone.seed == 7
    with pytest.raises(ValueError):
        FaultPlan.parse("grad_nan")  # no @step
    with pytest.raises(ValueError):
        FaultEvent(step=1, kind="nonsense")


def test_fault_plan_fires_once_and_logs():
    plan = FaultPlan.parse("grad_nan@5;refresh_zero@5")
    ev = plan.grad_event(5)
    assert ev is not None and ev.kind == "grad_nan"
    # consumed: a rollback replaying step 5 does not re-trigger
    assert plan.grad_event(5) is None
    assert [e.kind for e in plan.state_events(5)] == ["refresh_zero"]
    assert plan.state_events(5) == []
    assert plan.log == [(5, "grad_nan"), (5, "refresh_zero")]
    # no gate needed for state-only remains of the plan
    assert FaultPlan.parse("refresh_zero@3").gate() is None
    assert FaultPlan.parse("grad_inf@3").gate() is not None


def test_fault_gate_mode0_is_identity():
    """The disarmed gate must be elementwise-identical to the stock step —
    resilience-on training with no armed fault is the stock trajectory."""
    from repro.configs import get_smoke
    from repro.core import OptimizerConfig, build_optimizer
    from repro.launch.steps import make_train_step
    from repro.models import build_model

    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = build_optimizer(OptimizerConfig(name="adamw", lr=1e-3))
    st = opt.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab)}

    plain = jax.jit(make_train_step(model, opt, grad_clip=1.0))
    gated = jax.jit(make_train_step(model, opt, grad_clip=1.0,
                                    fault_gate=FaultGate()))
    p1, _, m1 = plain(params, st, batch)
    p2, _, m2 = gated(params, st, batch, FaultGate.disarmed())
    assert float(m1["loss"]) == float(m2["loss"])
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # armed: NaN mode kills the grads -> guard skips the update
    _, _, m3 = gated(params, st, batch,
                     FaultGate.armed(FaultEvent(0, "grad_nan")))
    assert not bool(m3["update_applied"])
    # spike mode scales the raw grad norm by ~scale
    _, _, m4 = gated(params, st, batch,
                     FaultGate.armed(FaultEvent(0, "grad_spike", scale=1e4)))
    assert bool(m4["update_applied"])


# ------------------------------------------------------------ state surgery


def _matrix_opt_state(name="galore", rank=4):
    from repro.core import OptimizerConfig, build_optimizer

    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 16)),
              "b": jnp.zeros((16,))}
    opt = build_optimizer(OptimizerConfig(name=name, lr=1e-2, rank=rank,
                                          period=5))
    st = opt.init(params)
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    _, st = opt.update(g, st, params)  # first update materializes projectors
    return opt, params, st


def test_poison_projectors_zero_and_illcond():
    from repro.core import find_lowrank_states

    _, _, st = _matrix_opt_state()
    z = poison_projectors(st, "refresh_zero")
    for lr in find_lowrank_states(z):
        for p in jax.tree_util.tree_leaves(lr.projs):
            assert float(jnp.abs(p).max()) == 0.0
    ill = poison_projectors(st, "refresh_illcond")
    for lr in find_lowrank_states(ill):
        for p in jax.tree_util.tree_leaves(lr.projs):
            cols = np.asarray(p).reshape(-1, p.shape[-1])
            for j in range(1, cols.shape[1]):
                np.testing.assert_array_equal(cols[:, 0], cols[:, j])
    with pytest.raises(ValueError):
        poison_projectors(st, "grad_nan")


def test_force_refresh_advances_to_period_boundary():
    from repro.core import find_lowrank_states

    opt, params, st = _matrix_opt_state(rank=4)
    g = {"w": jnp.ones((32, 16)), "b": jnp.ones((16,))}
    _, st = opt.update(g, st, params)  # count now 2
    count = int(jax.device_get(find_lowrank_states(st)[0].count))
    assert count == 2
    bumped = force_refresh(st, period=5)
    assert int(jax.device_get(find_lowrank_states(bumped)[0].count)) == 5
    # already on a boundary: no-op
    again = force_refresh(bumped, period=5)
    assert int(jax.device_get(find_lowrank_states(again)[0].count)) == 5
    # the very next update refreshes: a zeroed projector gets rebuilt
    poisoned = poison_projectors(bumped, "refresh_zero")
    _, healed = opt.update(g, poisoned, params)
    after = jax.tree_util.tree_leaves(find_lowrank_states(healed)[0].projs)
    assert float(jnp.abs(after[0]).max()) > 0.0, \
        "forced refresh did not rebuild the zeroed projector"


def test_snapshot_ring_roundtrip_and_eviction():
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 8))}
    state = {"m": jnp.ones((8, 8)) * 0.5}
    ring = SnapshotRing(k=2)
    for s in (4, 8, 12):
        ring.add(s, params, state, extra={"rank_policy": {"x": s}})
    assert ring.steps == [8, 12]  # oldest evicted
    snap = ring.pop_latest()
    assert snap.step == 12 and ring.steps == [8]
    p2, s2 = ring.restore(snap)
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))
    np.testing.assert_array_equal(np.asarray(s2["m"]), np.asarray(state["m"]))
    assert snap.extra == {"rank_policy": {"x": 12}}
    # host copies: mutating the live tree does not touch the snapshot
    assert isinstance(snap.params["w"], np.ndarray)


# ------------------------------------------------------------------- health


def _cfg(**kw):
    base = dict(spike_min_samples=4, spike_z=4.0, spike_min_delta=0.5,
                collapse_min_samples=3, blowup_k=3)
    base.update(kw)
    return ResilienceConfig(**base)


def test_health_loss_spike_detector():
    mon = HealthMonitor(_cfg())
    for i in range(8):
        r = mon.observe(i, loss=1.0 + 0.01 * (i % 3), applied=True,
                        grad_norm=1.0)
        assert r.status == "ok"
    r = mon.observe(8, loss=50.0, applied=True, grad_norm=1.0)
    assert [e.kind for e in r.critical] == ["loss_spike"]
    # the spike was not folded into the window: an identical second spike
    # is still detected against the clean statistics
    r2 = mon.observe(9, loss=50.0, applied=True, grad_norm=1.0)
    assert [e.kind for e in r2.critical] == ["loss_spike"]


def test_health_grad_spike_detector():
    mon = HealthMonitor(_cfg())
    for i in range(8):
        assert mon.observe(i, loss=1.0, applied=True,
                           grad_norm=2.0 + 0.1 * (i % 2)).status == "ok"
    r = mon.observe(8, loss=1.0, applied=True, grad_norm=1e6)
    assert "grad_spike" in [e.kind for e in r.critical]


def test_health_blowup_detector():
    mon = HealthMonitor(_cfg(spike_z=100.0))  # mute the spike detector
    loss = 1.0
    kinds = []
    for i in range(8):
        loss *= 1.4
        kinds += [e.kind for e in
                  mon.observe(i, loss=loss, applied=True, grad_norm=1.0)
                  .critical]
    assert "blowup" in kinds


def test_health_dead_subspace_detector():
    mon = HealthMonitor(_cfg())
    for i in range(6):
        assert mon.observe(i, loss=1.0, applied=True, grad_norm=1.0,
                           update_norm=0.1).status == "ok"
    r = mon.observe(6, loss=1.0, applied=True, grad_norm=1.0,
                    update_norm=1e-6)
    assert [e.kind for e in r.critical] == ["dead_subspace"]
    # zero grads (real stall, not a dead projector): no event
    mon2 = HealthMonitor(_cfg())
    for i in range(6):
        mon2.observe(i, loss=1.0, applied=True, grad_norm=1.0,
                     update_norm=0.1)
    assert mon2.observe(6, loss=1.0, applied=True, grad_norm=0.0,
                        update_norm=1e-6).status == "ok"


def test_health_nonfinite_energy_and_reset():
    from repro.train import StepTimeMonitor

    mon = HealthMonitor(_cfg(energy_min=0.2),
                        step_monitor=StepTimeMonitor(min_samples=3))
    r = mon.observe(0, loss=float("nan"), applied=False, grad_norm=1.0)
    assert [e.kind for e in r.critical] == ["nonfinite"]
    # starved probe energy: warn only
    probes = {(32, 16): {"sv2": np.array([0.01, 0.01]), "g2": 1.0}}
    r2 = mon.observe(1, loss=1.0, applied=True, grad_norm=1.0, probes=probes)
    assert r2.status == "warn"
    assert [e.kind for e in r2.events] == ["subspace_energy"]
    mon.observe(2, loss=1.0, applied=True, grad_norm=1.0)
    assert len(mon._losses) > 0
    mon.reset()
    assert len(mon._losses) == 0
    assert mon.counts["nonfinite"] == 1  # lifetime counters survive reset


# ----------------------------------------------------------------- recovery


def _report(step, kind):
    from repro.resilience.health import CRITICAL, HealthEvent

    ev = HealthEvent(step, kind, CRITICAL)
    return HealthReport(step=step, status="critical", events=[ev],
                        loss=1.0, grad_norm=1.0)


def _ok(step):
    return HealthReport(step=step, status="ok", events=[], loss=1.0,
                        grad_norm=1.0)


def test_recovery_base_rungs():
    rc = RecoveryController(ResilienceConfig())
    assert rc.decide(_ok(0)).kind == "none"
    assert rc.decide(_report(1, "nonfinite")).kind == "skip"
    rc2 = RecoveryController(ResilienceConfig())
    assert rc2.decide(_report(1, "dead_subspace")).kind == "refresh"
    rc3 = RecoveryController(ResilienceConfig())
    assert rc3.decide(_report(1, "loss_spike")).kind == "rollback"
    rc4 = RecoveryController(ResilienceConfig())
    assert rc4.decide(_report(1, "grad_spike")).kind == "rollback"


def test_recovery_skip_streak_escalates():
    rc = RecoveryController(ResilienceConfig(max_skips=2))
    assert rc.decide(_report(1, "nonfinite")).kind == "skip"
    assert rc.decide(_report(2, "nonfinite")).kind == "skip"
    a = rc.decide(_report(3, "nonfinite"))
    assert a.kind == "rollback"
    # a healthy report resets the streak
    rc.record(a, target=0)
    rc.decide(_ok(4))
    # outside the escalation window the ladder re-enters at the base rung
    far = 4 + rc.cfg.escalation_window + 1
    assert rc.decide(_report(far, "nonfinite")).kind == "skip"


def test_recovery_escalation_within_window():
    rc = RecoveryController(ResilienceConfig(escalation_window=8))
    a1 = rc.decide(_report(10, "loss_spike"))
    assert a1.kind == "rollback"
    rc.record(a1, target=8)
    # recurrence right after the rollback: climb to restore
    a2 = rc.decide(_report(12, "loss_spike"))
    assert a2.kind == "restore"
    rc.record(a2, target=4)
    # and the trace carries the executed actions with targets
    assert [(t["action"], t["target"]) for t in rc.trace] == [
        ("rollback", 8), ("restore", 4)]


def test_resilience_config_parse():
    cfg = ResilienceConfig.parse("ring=3,snapshot_every=5,spike_z=4.5")
    assert cfg.ring == 3 and cfg.snapshot_every == 5 and cfg.spike_z == 4.5
    assert ResilienceConfig.parse(None).ring == ResilienceConfig().ring
    assert ResilienceConfig.parse("").max_skips == 3
    same = ResilienceConfig(ring=9)
    assert ResilienceConfig.parse(same) is same
    with pytest.raises(ValueError):
        ResilienceConfig.parse("no_such_knob=1")


# ------------------------------------------------------- checkpoint hardening


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 16)),
            "b": {"c": jnp.arange(32, dtype=jnp.float32)}}


def test_checkpoint_checksum_detects_bitflip(tmp_path):
    from repro.checkpoint import CheckpointCorruptionError, CheckpointManager

    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=5)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    assert mgr.verify_step(2)
    bitflip_checkpoint(d, 2, rng=np.random.default_rng(0))
    assert not mgr.verify_step(2)
    with pytest.raises(CheckpointCorruptionError):
        mgr.restore(2, _tree())
    # verified fallback walks past the corrupt step
    assert mgr.latest_verified_step() == 1
    got = mgr.restore_latest_verified(_tree())
    assert got is not None and got[0] == 1
    np.testing.assert_array_equal(np.asarray(got[1]["a"]),
                                  np.asarray(_tree(1)["a"]))


def test_checkpoint_truncation_detected(tmp_path):
    from repro.checkpoint import CheckpointCorruptionError, CheckpointManager

    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=5)
    mgr.save(3, _tree(3))
    truncate_checkpoint(d, 3, rng=np.random.default_rng(1), keep_frac=0.4)
    assert not mgr.verify_step(3)
    with pytest.raises(CheckpointCorruptionError):
        mgr.restore(3, _tree())
    # verify=False restores-at-own-risk is only for readable files; a
    # truncated .npy cannot even load, so it still raises
    with pytest.raises(Exception):
        mgr.restore(3, _tree(), verify=False)


def test_gc_never_deletes_newest_verified(tmp_path):
    """Regression (ISSUE 8 satellite): with every newer checkpoint corrupt,
    keep-last-N GC must protect the newest VERIFIED step — deleting it
    would leave the run unrecoverable."""
    from repro.checkpoint import CheckpointManager

    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=0)  # no gc while we set the stage
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    for s in (2, 3, 4):
        bitflip_checkpoint(d, s, rng=np.random.default_rng(s))
    mgr.keep = 2
    mgr._gc()
    # steps (1,2) were doomed, but 1 is the newest verified -> protected
    assert 1 in mgr.all_steps()
    assert mgr.latest_verified_step() == 1
    got = mgr.restore_latest_verified(_tree())
    assert got is not None and got[0] == 1
    # step 2 (doomed, corrupt) was actually collected
    assert 2 not in mgr.all_steps()


def test_save_observer_and_abort_atomicity(tmp_path):
    """An exception mid-save (the kill hook's tame cousin) must leave the
    previous committed checkpoint untouched and no partial commit."""
    from repro.checkpoint import CheckpointManager

    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=3)
    mgr.save(1, _tree(1))
    calls = []

    def bomb(i, total):
        calls.append((i, total))
        if i >= 1:
            raise RuntimeError("simulated preemption")

    with pytest.raises(RuntimeError):
        mgr.save(2, _tree(2), observer=bomb)
    assert len(calls) == 2
    assert mgr.all_steps() == [1]          # step 2 never committed
    assert mgr.latest_verified_step() == 1
    mgr.save(3, _tree(3))                  # stale tmp dir cleaned up
    assert not any(n.endswith(".tmp") for n in os.listdir(d))


def test_checkpoint_extra_rides_and_legacy_no_crc(tmp_path):
    import json

    from repro.checkpoint import CheckpointManager

    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=3)
    mgr.save(1, _tree(1), extra={"rank_policy": {"map": "x"}})
    assert mgr.read_extra(1) == {"rank_policy": {"map": "x"}}
    # strip the CRCs -> legacy checkpoint: still verifies and restores
    mpath = os.path.join(mgr._step_dir(1), "manifest.json")
    with open(mpath) as f:
        man = json.load(f)
    for meta in man["leaves"]:
        meta.pop("crc32", None)
    with open(mpath, "w") as f:
        json.dump(man, f)
    assert mgr.verify_step(1)
    tree, extra = mgr.restore(1, _tree())
    assert extra["rank_policy"]["map"] == "x"


# ------------------------------------------------------- fault matrix (e2e)


def _trainer(tmpdir, steps, *, opt="gum", resilience="", inject=None,
             ckpt_every=10, period=10, rank=4, seed=0, resume=True):
    from repro.configs import RunConfig, get_smoke
    from repro.core import OptimizerConfig
    from repro.data import DataConfig
    from repro.models import build_model
    from repro.train import Trainer

    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    return Trainer(
        model,
        OptimizerConfig(name=opt, lr=1e-3, rank=rank, gamma=1, period=period),
        RunConfig(steps=steps, ckpt_dir=tmpdir, ckpt_every=ckpt_every,
                  log_every=0, resume=resume, seed=seed),
        DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=seed),
        resilience=resilience, inject=inject,
    )


def test_fault_matrix_gradient_faults_recover_and_reproduce(tmp_path):
    """grad_nan -> skip (rung 0), grad_spike -> rollback (rung 2), same
    plan+seed reproduces the identical recovery trace, and the final loss
    stays within the declared budget of the fault-free run."""
    steps, budget = 26, 0.5
    clean = _trainer(str(tmp_path / "clean"), steps, resilience="").train()
    assert clean.recovery_counts == {"skip": 0, "refresh": 0,
                                    "rollback": 0, "restore": 0}

    plan = "grad_nan@6;grad_spike@17*1e9"
    runs = []
    for tag in ("a", "b"):
        r = _trainer(str(tmp_path / tag), steps,
                     resilience="snapshot_every=4",
                     inject=plan).train()
        runs.append(r)
    r = runs[0]
    assert r.fault_log == [(6, "grad_nan"), (17, "grad_spike")]
    assert r.recovery_counts["skip"] >= 1
    assert r.recovery_counts["rollback"] >= 1
    kinds = [(t["step"], t["event"], t["action"]) for t in r.recovery_trace]
    assert (6, "nonfinite", "skip") in kinds
    assert any(ev == "grad_spike" and act == "rollback"
               for _, ev, act in kinds)
    # declared loss budget vs the fault-free run
    assert abs(r.losses[-1] - clean.losses[-1]) < budget, \
        (r.losses[-1], clean.losses[-1])
    # determinism: identical plan + seed -> identical trace, faults, losses
    assert runs[0].recovery_trace == runs[1].recovery_trace
    assert runs[0].fault_log == runs[1].fault_log
    np.testing.assert_allclose(runs[0].losses, runs[1].losses, rtol=1e-6)


def test_fault_matrix_poisoned_refresh_recovers_by_forced_refresh(tmp_path):
    """refresh_zero on a galore-family optimizer (whose whole update lives
    in the subspace) -> dead_subspace -> forced off-cycle refresh (rung 1)."""
    r = _trainer(str(tmp_path), 24, opt="galore", resilience="",
                 inject="refresh_zero@14").train()
    assert r.fault_log == [(14, "refresh_zero")]
    assert r.recovery_counts["refresh"] >= 1
    assert any(t["event"] == "dead_subspace" and t["action"] == "refresh"
               for t in r.recovery_trace)
    # training kept going and kept improving after the recovery
    assert r.final_step == 24
    assert r.losses[-1] < r.losses[0]


@pytest.mark.parametrize("fault", ["ckpt_bitflip", "ckpt_truncate"])
def test_fault_matrix_corrupt_checkpoint_resume_falls_back(tmp_path, fault):
    """A corrupted durable checkpoint (bit flip / truncation of the newest
    save) is caught by the manifest checksums on restart; resume falls back
    to the previous verified step (rung 3's fallback path)."""
    d = str(tmp_path)
    r1 = _trainer(d, 20, resilience="", inject=f"{fault}@20").train()
    assert r1.fault_log == [(20, fault)]
    r2 = _trainer(d, 24, resilience="").train()
    assert r2.resumed_from == 10, r2.resumed_from
    assert r2.final_step == 24


def test_restore_rung_uses_durable_checkpoint_when_ring_empty(tmp_path):
    """With no snapshots available (snapshot_every=0) a rollback-rung event
    falls through to restoring the last verified durable checkpoint."""
    r = _trainer(str(tmp_path), 24, resilience="snapshot_every=0",
                 inject="grad_spike@17*1e9").train()
    assert r.recovery_counts["restore"] >= 1
    assert any(t["action"] == "restore" and t["target"] == 10
               for t in r.recovery_trace)
    assert r.final_step == 24


# --------------------------------------------------- mid-save kill (slow)


@pytest.mark.slow
def test_kill_midsave_resumes_bitexact_with_rank_policy(tmp_path):
    """kill -9 mid-save (via the fault plan's save observer): the partial
    save must be invisible, and resume from the last verified checkpoint —
    including the rank-policy controller extras — must be bit-exact vs an
    uninterrupted run (counter-based stream + deterministic optimizer)."""
    code = """
import sys
import jax
from repro.configs import RunConfig, get_smoke
from repro.core import OptimizerConfig
from repro.data import DataConfig
from repro.models import build_model
from repro.train import Trainer

ckpt_dir, steps, inject = sys.argv[1], int(sys.argv[2]), sys.argv[3] or None
cfg = get_smoke("llama-60m")
model = build_model(cfg)
t = Trainer(
    model,
    OptimizerConfig(name="gum", lr=1e-3, rank=4, gamma=1, period=3,
                    rank_policy="stepwise:0=4,6=2", rank_ladder=(2, 4)),
    RunConfig(steps=steps, ckpt_dir=ckpt_dir, ckpt_every=4, log_every=0,
              seed=0),
    DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2, seed=0),
    resilience="", inject=inject,
)
r = t.train()
print("RESUMED_FROM", r.resumed_from)
print("TRAIN_DONE", r.final_step)
"""
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}

    def run(ckpt_dir, steps, inject=""):
        return subprocess.run(
            [sys.executable, "-c", code, ckpt_dir, str(steps), inject],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=600)

    d_kill, d_ref = str(tmp_path / "kill"), str(tmp_path / "ref")
    # killed run: SIGKILL after 2 leaves of the step-12 save (the stepwise
    # rank change 4->2 lands at count 6, well before the kill)
    r1 = run(d_kill, 16, "kill_save@12#2")
    assert r1.returncode == -9, (r1.returncode, r1.stdout, r1.stderr)
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(d_kill)
    assert mgr.latest_step() == 8          # 12 never committed
    assert mgr.latest_verified_step() == 8
    # the aborted write left only an uncommitted tmp dir behind
    assert any(n.endswith(".tmp") for n in os.listdir(d_kill))

    # resume to completion; reference run straight through
    r2 = run(d_kill, 16)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "RESUMED_FROM 8" in r2.stdout, r2.stdout
    assert not any(n.endswith(".tmp") for n in os.listdir(d_kill))
    r3 = run(d_ref, 16)
    assert r3.returncode == 0, r3.stdout + r3.stderr

    # bit-exact final state, including the rank-policy extras
    ka = CheckpointManager(d_kill)
    kb = CheckpointManager(d_ref)
    ea, eb = ka.read_extra(16), kb.read_extra(16)
    assert ea["rank_policy"]["map"] == eb["rank_policy"]["map"]
    assert ea["rank_policy"]["map"]["default"] == 2  # the change survived

    # rebuild the restore template at the saved rank state, then compare
    from repro.configs import RunConfig, get_smoke
    from repro.core import OptimizerConfig
    from repro.data import DataConfig
    from repro.models import build_model
    from repro.train import Trainer

    cfg = get_smoke("llama-60m")
    like_t = Trainer(
        build_model(cfg),
        OptimizerConfig(name="gum", lr=1e-3, rank=4, gamma=1, period=3,
                        rank_policy="stepwise:0=4,6=2", rank_ladder=(2, 4)),
        RunConfig(steps=16, ckpt_dir=str(tmp_path / "like"), ckpt_every=4,
                  log_every=0, seed=0),
        DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2, seed=0),
    )
    like_t.rank_ctrl.load_state_dict(ea["rank_policy"])
    like_t._set_optimizer(like_t.rank_ctrl.transform())
    like = like_t.init_state()
    (pa, sa), _ = ka.restore(16, like)
    (pb, sb), _ = kb.restore(16, like)
    for x, y in zip(jax.tree_util.tree_leaves((pa, sa)),
                    jax.tree_util.tree_leaves((pb, sb))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
