"""CI guard for the benchmark harness: ``benchmarks/run.py --smoke`` must
execute EVERY suite end-to-end (1-2 steps, no timing claims, no result-JSON
writes).  Before this test existed the harness itself had bit-rotted — the
suite imports were broken under the documented invocation and nothing
noticed.  Runs the harness once as a subprocess, exactly as a user would."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_run_py_smoke_executes_all_suites(tmp_path):
    # (subprocess timeout=520 is the watchdog; pytest-timeout isn't vendored)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    res = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"), "--smoke"],
        cwd=tmp_path,  # NOT the repo root: smoke must not depend on cwd
        env=env, capture_output=True, text=True, timeout=520,
    )
    assert res.returncode == 0, res.stderr[-4000:]
    out = res.stdout
    assert "# smoke run complete" in out
    # every registered suite announced itself (run.py prints to stderr)
    for suite in ("synthetic_counterexample", "memory_table", "pretrain_proxy",
                  "bias_residual", "stable_rank", "roofline_report",
                  "optimizer_api", "fused_step", "rank_policy",
                  "audit_matrix", "resilience", "sharded_step", "telemetry"):
        assert f"# --- {suite} ---" in res.stderr, suite
    # the fused-step suite produced its rows, including launch counts
    assert "fusedstep_gum_stacked" in out
    assert "launches=" in out
    # the resilience suite measured the monitor and checksum costs
    assert "resilience_step_monitor_on" in out
    assert "resilience_save_crc" in out
    # the audit-matrix suite audited its smoke cells clean
    assert "audit_gum," in out and ",clean" in out
    # ...including the sharded collective-schedule cell (AbstractMesh trace,
    # so it runs identically with however many devices the runner has)
    assert "audit_sharded_gum_mesh8," in out
    assert "steady_wire_bytes=" in out
    # the ZeRO sharded-step suite reported its per-device state row
    assert "sharded_step_state_mesh8," in out
    assert "opt_bytes_per_shard=" in out
    # the telemetry suite measured the full-path overhead and bus throughput
    assert "telemetry_step_on" in out
    assert "telemetry_bus_jsonl" in out
    # registered suites all have their result JSONs committed, and every
    # suite is declared in exactly one of RESULT_JSON / NO_RESULT_JSON
    assert "WARNING: suite" not in res.stderr
    # no result JSONs written in smoke mode (cwd is a scratch dir anyway)
    assert "# wrote" not in out


def test_committed_telemetry_result_is_within_budget():
    """The committed BENCH_telemetry.json must show the telemetry path
    holding its acceptance budget: full-path step-time overhead <= 2%."""
    import json

    with open(os.path.join(REPO, "results", "BENCH_telemetry.json")) as f:
        rec = json.load(f)
    ovh = rec["overhead"]
    assert ovh["budget_pct"] == 2.0
    assert ovh["overhead_pct"] <= ovh["budget_pct"], ovh
    # throughput sanity: the JSONL sink must sustain well over the handful
    # of records per step a real run emits
    assert rec["throughput"]["jsonl_records_per_s"] > 1000
