"""The kernel dispatch subsystem: impl resolution, registry, padding-aware
ragged-shape parity (Pallas interpret vs jnp reference), optimizer-level
parity with kernel_impl="pallas", and the use_muon_scale wiring.

Everything runs the Pallas kernels through the interpreter (CPU), so the
kernel code itself is exercised on every backend."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply_updates
from repro.core.galore import galore_matrices
from repro.core.gum import gum_matrices
from repro.core.muon import muon_matrices
from repro.core.newton_schulz import muon_scale, newton_schulz
from repro.kernels import KERNEL_REGISTRY, dispatch, get_kernel, ref

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- resolution


def test_resolve_impl():
    # CPU/GPU CI: auto -> jnp, pallas degrades to interpret.
    on_tpu = dispatch.backend() == "tpu"
    assert dispatch.resolve_impl("auto") == ("pallas" if on_tpu else "jnp")
    assert dispatch.resolve_impl("pallas") == ("pallas" if on_tpu else "interpret")
    assert dispatch.resolve_impl("xla") == "jnp"
    assert dispatch.resolve_impl("jnp") == "jnp"
    assert dispatch.resolve_impl("interpret") == "interpret"
    with pytest.raises(ValueError):
        dispatch.resolve_impl("cuda")


def test_registry():
    assert set(KERNEL_REGISTRY) >= {"lowrank_update", "newton_schulz",
                                    "back_project"}
    entry = get_kernel("lowrank_update")
    assert entry.fn is dispatch.lowrank_update
    assert get_kernel("back_project").fn is dispatch.back_project
    with pytest.raises(KeyError):
        get_kernel("nope")


def test_shape_legality_fallback():
    # rank beyond the VMEM bound must fall back to jnp, not fail to compile
    m, n, r = 8, 16, dispatch.MAX_LOWRANK_RANK + 1
    p = jnp.zeros((m, r))
    g = jnp.zeros((m, n))
    assert not dispatch.lowrank_update_supported(p, g, "left")
    out = dispatch.lowrank_update(p, g, jnp.zeros((r, n)), 0.9, 1.0,
                                  impl="interpret")
    assert out.shape == (r, n)
    big = jnp.zeros((dispatch.MAX_NS_DIM + 8, dispatch.MAX_NS_DIM + 8))
    assert not dispatch.newton_schulz_supported(big)


# ------------------------------------------------------------- ragged parity


@pytest.mark.parametrize("m,n,r", [
    (1000, 768, 96),   # the GaLore/GUM production operating point, ragged
    (100, 76, 12),     # nothing divides the default blocks
    (24, 128, 8),      # only n tile-aligned
])
def test_lowrank_update_ragged_left(m, n, r):
    ks = jax.random.split(KEY, 3)
    p = jax.random.normal(ks[0], (m, r))
    g = jax.random.normal(ks[1], (m, n))
    rst = jax.random.normal(ks[2], (r, n))
    out = dispatch.lowrank_update(p, g, rst, 0.95, 4.0 / 3, impl="interpret")
    want = ref.lowrank_update_ref(p, g, rst, 0.95, 4.0 / 3)
    np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)


def test_lowrank_update_ragged_right_batched():
    """Right-side projection (m > n) over a stacked (L, m, n) family."""
    L, m, n, r = 3, 76, 40, 12
    ks = jax.random.split(KEY, 3)
    p = jax.random.normal(ks[0], (L, n, r))
    g = jax.random.normal(ks[1], (L, m, n))
    rst = jax.random.normal(ks[2], (L, m, r))
    out = dispatch.lowrank_update(p, g, rst, 0.9, 2.0, side="right",
                                  impl="interpret")
    want = 0.9 * rst + 2.0 * jnp.einsum("lmn,lnr->lmr", g, p)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


def test_lowrank_update_multi_lead():
    """(L, E, m, n) MoE-style families flatten through the batch grid."""
    lead, m, n, r = (2, 3), 20, 36, 4
    ks = jax.random.split(KEY, 3)
    p = jax.random.normal(ks[0], lead + (m, r))
    g = jax.random.normal(ks[1], lead + (m, n))
    rst = jax.random.normal(ks[2], lead + (r, n))
    out = dispatch.lowrank_update(p, g, rst, 0.5, 1.0, impl="interpret")
    want = 0.5 * rst + jnp.einsum("...mr,...mn->...rn", p, g)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


def test_project_dispatch_matches_einsum():
    m, n, r = 100, 76, 12
    p = jax.random.normal(KEY, (m, r))
    g = jax.random.normal(jax.random.fold_in(KEY, 1), (m, n))
    out = dispatch.project(p, g, side="left", impl="interpret")
    np.testing.assert_allclose(out, p.T @ g, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("m,n,r", [
    (1000, 768, 96),   # the production operating point, ragged
    (100, 76, 12),
    (24, 128, 8),
])
def test_back_project_ragged_left(m, n, r):
    """The fused back-projection GEMM P @ S through the padding wrappers."""
    p = jax.random.normal(KEY, (m, r))
    s = jax.random.normal(jax.random.fold_in(KEY, 1), (r, n))
    out = dispatch.back_project(p, s, side="left", impl="interpret")
    np.testing.assert_allclose(out, ref.back_project_ref(p, s),
                               atol=2e-4, rtol=2e-4)


def test_back_project_right_batched():
    """Right side S @ Pᵀ over a stacked family, plus shape-legality fallback."""
    L, m, n, r = 3, 76, 40, 12
    p = jax.random.normal(KEY, (L, n, r))
    s = jax.random.normal(jax.random.fold_in(KEY, 1), (L, m, r))
    out = dispatch.back_project(p, s, side="right", impl="interpret")
    want = jnp.einsum("lmr,lnr->lmn", s, p)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)
    # rank beyond the VMEM bound falls back to jnp instead of failing
    r_big = dispatch.MAX_LOWRANK_RANK + 1
    pb = jnp.zeros((8, r_big))
    sb = jnp.zeros((r_big, 16))
    assert not dispatch.back_project_supported(pb, sb, "left")
    assert dispatch.back_project(pb, sb, impl="interpret").shape == (8, 16)


@pytest.mark.parametrize("side", ["left", "right"])
def test_pad_rank_to_parity_ragged_rank(side):
    """Opt-in lane-aligned rank padding (r=96 -> 128) is exact across all
    three dispatched ops."""
    m, n, r = 200, 160, 96
    ks = jax.random.split(KEY, 3)
    if side == "left":
        p = jax.random.normal(ks[0], (m, r))
        rst = jax.random.normal(ks[2], (r, n))
        s = rst
    else:
        p = jax.random.normal(ks[0], (n, r))
        rst = jax.random.normal(ks[2], (m, r))
        s = rst
    g = jax.random.normal(ks[1], (m, n))
    for pad in (0, 128):
        out = dispatch.lowrank_update(p, g, rst, 0.9, 1.5, side=side,
                                      impl="interpret", pad_rank_to=pad)
        want = dispatch.lowrank_update(p, g, rst, 0.9, 1.5, side=side, impl="jnp")
        np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)
        outp = dispatch.project(p, g, side=side, impl="interpret", pad_rank_to=pad)
        np.testing.assert_allclose(
            outp, dispatch.project(p, g, side=side, impl="jnp"),
            atol=2e-4, rtol=2e-4)
        outb = dispatch.back_project(p, s, side=side, impl="interpret",
                                     pad_rank_to=pad)
        np.testing.assert_allclose(
            outb, dispatch.back_project(p, s, side=side, impl="jnp"),
            atol=2e-4, rtol=2e-4)


def test_pad_rank_to_optimizer_parity():
    """An optimizer built with pad_rank_to=128 at a ragged rank matches the
    unpadded kernel path (and the jnp path) trajectory."""
    from repro.core.galore import galore_matrices

    params = {"w": jax.random.normal(KEY, (2, 24, 40)) * 0.1}
    mk = lambda **kw: galore_matrices(1e-2, rank=6, period=3, base="muon",
                                      seed=2, **kw)
    p_ref = _run_traj(mk(kernel_impl="jnp"), params)
    p_pad = _run_traj(mk(kernel_impl="pallas", pad_rank_to=128), params)
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_pad)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", [
    (96, 1000),    # GUM's low-rank NS operand (r, n), ragged n
    (1000, 96),    # transposed path
    (33, 100),
    (3, 40, 28),   # stacked family, m > n
])
def test_newton_schulz_ragged_parity(shape):
    x = jax.random.normal(KEY, shape)
    out = dispatch.newton_schulz(x, impl="interpret")
    want = newton_schulz(x)  # jnp reference
    np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)


def test_core_newton_schulz_impl_arg():
    """core.newton_schulz's documented impl= dispatch reaches the kernels."""
    x = jax.random.normal(KEY, (16, 40))
    np.testing.assert_allclose(
        newton_schulz(x, impl="interpret"), newton_schulz(x, impl="jnp"),
        atol=1e-4, rtol=1e-4,
    )
    # "auto" resolves to the backend default and must always work
    np.testing.assert_allclose(
        newton_schulz(x, impl="auto"), newton_schulz(x, impl="jnp"),
        atol=1e-4, rtol=1e-4,
    )


# ------------------------------------------------------------- optimizer parity


def _quad_loss(p):
    return 0.5 * sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(p))


def _run_traj(opt, params, steps=5):
    st = opt.init(params)
    p = params
    for _ in range(steps):
        g = jax.grad(_quad_loss)(p)
        u, st = opt.update(g, st, p)
        p = apply_updates(p, u)
    return p


PARAMS = {
    "left": jax.random.normal(KEY, (3, 24, 40)) * 0.1,            # m <= n
    "right": jax.random.normal(jax.random.fold_in(KEY, 1), (3, 40, 24)) * 0.1,
}


def test_gum_kernel_impl_pallas_matches_jnp():
    """Acceptance: gum_matrices(kernel_impl="pallas") (interpret on CPU)
    matches the jnp path within fp32 tolerance, across a projector refresh."""
    mk = lambda impl: gum_matrices(1e-2, rank=6, gamma=1, period=3,
                                   projector="svd", seed=5, kernel_impl=impl)
    p_jnp = _run_traj(mk("jnp"), PARAMS)
    p_pal = _run_traj(mk("pallas"), PARAMS)
    for a, b in zip(jax.tree_util.tree_leaves(p_jnp),
                    jax.tree_util.tree_leaves(p_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("base", ["muon", "sgdm", "adam"])
def test_galore_kernel_impl_pallas_matches_jnp(base):
    mk = lambda impl: galore_matrices(1e-2, rank=6, period=3, projector="svd",
                                      base=base, seed=2, kernel_impl=impl)
    p_jnp = _run_traj(mk("jnp"), PARAMS)
    p_pal = _run_traj(mk("pallas"), PARAMS)
    for a, b in zip(jax.tree_util.tree_leaves(p_jnp),
                    jax.tree_util.tree_leaves(p_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


def test_muon_kernel_impl_pallas_matches_jnp():
    mk = lambda impl: muon_matrices(1e-2, kernel_impl=impl)
    p_jnp = _run_traj(mk("jnp"), PARAMS, steps=3)
    p_pal = _run_traj(mk("pallas"), PARAMS, steps=3)
    for a, b in zip(jax.tree_util.tree_leaves(p_jnp),
                    jax.tree_util.tree_leaves(p_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------- muon_scale


def test_muon_scale_value():
    assert muon_scale((40, 28)) == pytest.approx(math.sqrt(40 / 28))
    assert muon_scale((28, 40)) == 1.0  # wide matrices are not scaled


def test_muon_use_muon_scale_flag():
    """Flag on (default) scales tall-matrix updates by sqrt(m/n); off is the
    raw orthogonalized update.  Both settings must descend."""
    g = jax.tree_util.tree_map(jnp.ones_like, PARAMS)
    on = muon_matrices(1.0, use_muon_scale=True)
    off = muon_matrices(1.0, use_muon_scale=False)
    u_on, _ = on.update(g, on.init(PARAMS), PARAMS)
    u_off, _ = off.update(g, off.init(PARAMS), PARAMS)
    # left family is wide (24x40): scale == 1, identical either way
    np.testing.assert_allclose(u_on["left"], u_off["left"], rtol=1e-6)
    # right family is tall (40x24): exactly sqrt(40/24) between the flags
    np.testing.assert_allclose(
        np.asarray(u_on["right"]),
        np.asarray(u_off["right"]) * math.sqrt(40 / 24), rtol=1e-5,
    )


def test_gum_use_muon_scale_flag():
    """GUM default (False) preserves the seed trajectory; True scales the
    whole family update by the per-family muon_scale factor."""
    mk = lambda flag: gum_matrices(1e-2, rank=4, gamma=0, period=3, seed=3,
                                   use_muon_scale=flag)
    params = {"w": PARAMS["right"]}
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    off = mk(False)
    on = mk(True)
    u_off, _ = off.update(g, off.init(params), params)
    u_on, _ = on.update(g, on.init(params), params)
    np.testing.assert_allclose(
        np.asarray(u_on["w"]),
        np.asarray(u_off["w"]) * muon_scale((40, 24)), rtol=1e-5,
    )
    # both settings still descend on the quadratic
    for flag in (False, True):
        p = _run_traj(mk(flag), params, steps=10)
        assert float(_quad_loss(p)) < float(_quad_loss(params))
