"""Telemetry subsystem (ISSUE 10): bus/sink units, JSONL schema round-trip
and golden file, in-jit instrumentation correctness (bit-exactness, probe
payloads, launch cross-check), trainer event-stream determinism across
seeded faulted reruns, and the report/diff CLI."""
import io
import json
import os
import shutil
import subprocess
import sys

import pytest

from repro.telemetry import (
    SCHEMA_VERSION,
    JsonlSink,
    MemorySink,
    StdoutSink,
    Telemetry,
    TelemetryConfig,
)
from repro.telemetry.bus import read_jsonl

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "data",
                      "telemetry_golden.jsonl")


class FakeClock:
    """Deterministic bus clock: 100.0, 100.5, 101.0, ..."""

    def __init__(self, t0=100.0, dt=0.5):
        self.t = t0 - dt
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


def _emit_fixture(tele: Telemetry) -> None:
    """One fixed record sequence — shared by the round-trip and golden
    tests so the golden file is regenerable from this function alone."""
    tele.metric(1, "loss", 4.25)
    tele.metric(1, "energy", 0.75, family="8x16")
    tele.event("fault", "fault-injection: grad_nan", step=3, severity="warn",
               kind="grad_nan")
    tele.event("audit", "audit[gum]: launches/step=42")
    tele.record_span("step", 0.0321, step=1, kind="steady")
    tele.record_span("step", 0.0123, step=2, kind="refresh")
    tele.close(step=2)


# ------------------------------------------------------------------ bus units


def test_jsonl_schema_roundtrip(tmp_path):
    path = str(tmp_path / "events.jsonl")
    tele = Telemetry([JsonlSink(path)], run={"optimizer": "gum"},
                     clock=FakeClock())
    _emit_fixture(tele)
    recs = read_jsonl(path)

    assert [r["kind"] for r in recs] == [
        "header", "metric", "metric", "event", "event", "span", "span",
        "counters"]
    hdr = recs[0]
    assert hdr["schema"] == SCHEMA_VERSION
    assert hdr["run"] == {"optimizer": "gum"}
    assert recs[1] == {"kind": "metric", "t": 100.5, "step": 1,
                       "name": "loss", "value": 4.25}
    assert recs[2]["tags"] == {"family": "8x16"}
    assert recs[3]["severity"] == "warn"
    assert recs[3]["data"] == {"kind": "grad_nan"}
    assert recs[5]["dur_us"] == 32100.0
    # counters: cumulative event counts + span aggregates
    tail = recs[-1]
    assert tail["counts"] == {"event.audit": 1, "event.fault": 1}
    assert tail["spans"]["step"]["count"] == 2
    # close() is idempotent
    tele.close()
    assert len(read_jsonl(path)) == 8


def test_jsonl_reader_skips_garbage_and_rejects_newer_schema(tmp_path):
    path = str(tmp_path / "events.jsonl")
    tele = Telemetry([JsonlSink(path)], clock=FakeClock())
    tele.metric(0, "loss", 1.0)
    tele.close()
    with open(path, "a") as f:
        f.write('{"kind": "metric", "truncat')  # crashed writer
    recs = read_jsonl(path)
    assert [r["kind"] for r in recs] == ["header", "metric", "counters"]

    newer = str(tmp_path / "future.jsonl")
    with open(newer, "w") as f:
        f.write(json.dumps({"kind": "header",
                            "schema": SCHEMA_VERSION + 1}) + "\n")
    with pytest.raises(ValueError, match="newer"):
        read_jsonl(newer)


def test_golden_file_byte_exact(tmp_path):
    """The on-disk format is a contract: an injected deterministic clock
    must reproduce the committed golden log byte-for-byte.  Regenerate with
    this test's `regen` block if the schema version is ever bumped."""
    path = str(tmp_path / "events.jsonl")
    tele = Telemetry([JsonlSink(path)], run={"optimizer": "gum", "seed": 0},
                     clock=FakeClock())
    _emit_fixture(tele)
    with open(path) as f:
        produced = f.read()
    if not os.path.exists(GOLDEN):  # pragma: no cover - regen helper
        with open(GOLDEN, "w") as f:
            f.write(produced)
    with open(GOLDEN) as f:
        assert produced == f.read()


def test_stdout_sink_renders_only_events_at_print_format():
    buf = io.StringIO()
    tele = Telemetry([StdoutSink(stream=buf)], clock=FakeClock())
    tele.metric(1, "loss", 4.25)                      # not printed
    tele.record_span("step", 0.01, step=1)            # not printed
    tele.event("log", "loss 4.2500", step=10)
    tele.event("audit", "audit[gum]: summary")        # step-less
    tele.event("checkpoint", "checkpoint: saved step 5", step=5,
               severity="debug")                      # below console floor
    tele.close()
    assert buf.getvalue() == ("step     10 loss 4.2500\n"
                              "audit[gum]: summary\n")


def test_memory_sink_ring_and_no_sink_bus():
    ring = MemorySink(maxlen=2)
    tele = Telemetry([ring], clock=FakeClock())
    for i in range(5):
        tele.event("e", f"n{i}")
    assert [r["detail"] for r in ring.records] == ["n3", "n4"]
    # a bus with zero sinks is a no-op, not an error
    none = Telemetry([], clock=FakeClock())
    none.metric(0, "loss", 1.0)
    none.close()


def test_telemetry_config_parse():
    assert TelemetryConfig.parse(None) is None
    assert TelemetryConfig.parse(False) is None
    cfg = TelemetryConfig.parse(True)
    assert (cfg.every, cfg.stdout, cfg.memory) == (1, True, 0)
    assert TelemetryConfig.parse("") == TelemetryConfig()
    cfg = TelemetryConfig.parse("every=5,stdout=0,memory=16,events=/tmp/x")
    assert (cfg.every, cfg.stdout, cfg.memory, cfg.events) == (
        5, False, 16, "/tmp/x")
    assert TelemetryConfig.parse(cfg) is cfg
    with pytest.raises(ValueError, match="unknown telemetry knob"):
        TelemetryConfig.parse("cadence=5")


# ------------------------------------------------- in-jit instrumentation


def _tiny_setup(telemetry: bool):
    import jax

    from repro.core import OptimizerConfig, build_optimizer

    ocfg = OptimizerConfig(name="gum", lr=1e-3, rank=4, gamma=1, period=3,
                           telemetry=telemetry)
    opt = build_optimizer(ocfg)
    key = jax.random.PRNGKey(0)
    params = {
        "wq": jax.random.normal(key, (16, 8)) * 0.1,
        "wk": jax.random.normal(jax.random.PRNGKey(1), (16, 8)) * 0.1,
        "bias": jax.random.normal(jax.random.PRNGKey(2), (8,)) * 0.1,
    }
    return opt, params


def test_telemetry_knob_is_bit_exact_and_adds_probe_keys():
    """lowrank(telemetry=True) must not change a single update bit — the
    instrumentation is write-only state riding the probe slots."""
    import jax
    import numpy as np

    opt_off, params = _tiny_setup(False)
    opt_on, _ = _tiny_setup(True)
    s_off, s_on = opt_off.init(params), opt_on.init(params)
    for i in range(7):
        g = jax.tree_util.tree_map(
            lambda p, i=i: p * 0.1 + 0.01 * (i + 1), params)
        u_off, s_off = jax.jit(opt_off.update)(g, s_off, params)
        u_on, s_on = jax.jit(opt_on.update)(g, s_on, params)
        for a, b in zip(jax.tree_util.tree_leaves(u_off),
                        jax.tree_util.tree_leaves(u_on)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    from repro.telemetry import lowrank_family_metrics

    fams = lowrank_family_metrics(s_on)
    assert [f["family"] for f in fams] == ["16x8"]
    rec = fams[0]
    assert rec["rank"] == 4
    assert 0.0 <= rec["energy"] <= 1.0 + 1e-6
    # telemetry-only keys present (and absent without the knob)
    assert 0.0 <= rec["drift"] <= 1.0
    assert 0.0 <= rec["bias"] <= 1.0
    assert rec["bias_step"] >= 1  # the sampler visited at least one site
    off_fams = lowrank_family_metrics(s_off)
    assert off_fams == [] or "drift" not in off_fams[0]


def test_launch_crosscheck_matches_model():
    from repro.telemetry.instrument import launch_crosscheck

    for telemetry in (False, True):
        opt, params = _tiny_setup(telemetry)
        xc = launch_crosscheck(opt, params, name="gum")
        assert xc["ok"], xc
        assert xc["unmodeled"] == []
        assert xc["traced"] == xc["expected"]
    # telemetry forces the probe-spectrum project — the model must have
    # accounted for it, and the counts must actually differ
    assert launch_crosscheck(*_tiny_setup(True)[:2])["traced"] != \
        launch_crosscheck(*_tiny_setup(False)[:2])["traced"]


def test_gamma_slot_tracker_accumulates():
    import jax

    from repro.telemetry import GammaSlotTracker

    opt, params = _tiny_setup(True)
    state = opt.init(params)
    tracker = GammaSlotTracker()
    recs0 = tracker.observe(state)
    assert recs0, "gum's layerwise-unbias state should expose gamma slots"
    for i in range(4):
        g = jax.tree_util.tree_map(lambda p: p * 0.1, params)
        _, state = jax.jit(opt.update)(g, state, params)
    recs = tracker.observe(state)
    assert tracker.observations == 2
    assert all(r["visits_max"] >= 1 for r in recs)
    assert all(len(r["slots"]) >= 1 for r in recs)


# ------------------------------------------------------- trainer integration


def _trainer(tmp, *, telemetry="stdout=0", inject=None, resilience=None,
             steps=8):
    from repro.configs import RunConfig, get_smoke
    from repro.core import OptimizerConfig
    from repro.data import DataConfig
    from repro.models import build_model
    from repro.train import Trainer

    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    return Trainer(
        model,
        OptimizerConfig(name="gum", lr=1e-3, rank=4, gamma=1, period=4,
                        telemetry=telemetry is not None),
        RunConfig(steps=steps, ckpt_dir=str(tmp), ckpt_every=4, log_every=4,
                  resume=False),
        DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2),
        telemetry=telemetry,
        resilience=resilience,
        inject=inject,
    )


def _stream_signature(path):
    """Everything deterministic about a run log: record kinds, names, steps,
    details, severities and metric values — with wall-clock fields (t,
    dur_us, span aggregates, ms-valued details) masked out."""
    import re

    sig = []
    for rec in read_jsonl(path):
        rec = dict(rec)
        rec.pop("t", None)
        kind = rec["kind"]
        if kind == "span":
            rec.pop("dur_us", None)
        elif kind == "counters":
            rec["spans"] = sorted(rec.get("spans", {}))  # names only
        detail = rec.get("detail")
        if detail is not None:
            rec["detail"] = re.sub(r"\d+ ms", "_ ms", detail)
        sig.append(json.dumps(rec, sort_keys=True))
    return sig


def test_trainer_run_produces_coherent_events_jsonl(tmp_path):
    t = _trainer(tmp_path / "run", steps=8)
    result = t.train()
    assert result.events_path == str(tmp_path / "run" / "events.jsonl")
    recs = read_jsonl(result.events_path)

    kinds = [r["kind"] for r in recs]
    assert kinds[0] == "header" and kinds[-1] == "counters"
    hdr = recs[0]
    assert hdr["schema"] == SCHEMA_VERSION
    assert hdr["run"]["optimizer"] == "gum"

    by_name = {}
    for r in recs:
        by_name.setdefault(r.get("name"), []).append(r)
    # step metrics every step (every=1 default)
    assert len(by_name["loss"]) == 8 and len(by_name["grad_norm"]) == 8
    # the step span tags refresh vs steady
    span_kinds = {r["tags"]["kind"] for r in by_name["step"]}
    assert span_kinds == {"refresh", "steady"}
    # in-jit family metrics at refresh boundaries (steps 0 and 4), one
    # record per shape family per boundary
    for metric in ("rank", "energy", "drift", "bias"):
        fam_recs = by_name[metric]
        families = {r["tags"]["family"] for r in fam_recs}
        assert families, metric
        assert len(fam_recs) == 2 * len(families), metric
        assert {r["step"] for r in fam_recs} == {1, 5}, metric
    # gamma-slot sampling event rode the same boundaries
    assert len(by_name["gamma_slots"]) == 2
    assert by_name["gamma_slots"][0]["data"]["leaves"]
    # one audit summary + one launch cross-check, and it verified ok
    assert len(by_name["launch_crosscheck"]) == 1
    xc = by_name["launch_crosscheck"][0]
    assert xc["severity"] == "info", xc
    # checkpoint saves (step 4, 8) landed as events inside ckpt_save spans
    saves = [r for r in by_name["checkpoint"]
             if r["data"]["action"] == "save"]
    assert [r["step"] for r in saves] == [4, 8]
    assert len(by_name["ckpt_save"]) == 2
    # closing counters agree with the event records themselves
    counts = recs[-1]["counts"]
    n_events = sum(1 for r in recs if r["kind"] == "event")
    assert sum(v for k, v in counts.items() if k.startswith("event.")) \
        == n_events


def test_event_stream_deterministic_across_faulted_reruns(tmp_path):
    """Two runs of the same seeded faulted config must emit the same event
    stream (timing fields aside) — the PR 8 fault matrix made observable."""
    sigs = []
    for name in ("a", "b"):
        t = _trainer(tmp_path / name, inject="grad_nan@3;grad_spike@5*1e9",
                     resilience="", steps=8)
        res = t.train()
        assert res.fault_log, "fault plan should have fired"
        sigs.append(_stream_signature(res.events_path))
    assert sigs[0] == sigs[1]
    # and the faulted stream actually contains fault + health records
    assert any('"name": "fault"' in line for line in sigs[0])
    assert any('"name": "health"' in line for line in sigs[0])


def test_telemetry_does_not_change_loss_trajectory(tmp_path):
    """--telemetry must be a pure observer: loss trajectory bit-exact vs the
    same run with telemetry fully off (in-jit knob included)."""
    off = _trainer(tmp_path / "off", telemetry=None, steps=6).train()
    on = _trainer(tmp_path / "on", telemetry="stdout=0", steps=6).train()
    assert off.losses == on.losses  # exact float equality, not approx
    # and the JSONL's loss metrics are the same numbers
    logged = [r["value"] for r in read_jsonl(on.events_path)
              if r.get("name") == "loss"]
    assert logged == on.losses


def test_memory_sink_attaches_via_config(tmp_path):
    t = _trainer(tmp_path / "run", telemetry="stdout=0,memory=64", steps=4)
    t.train()
    assert t.memory_sink is not None
    kinds = {r["kind"] for r in t.memory_sink.records}
    assert {"metric", "span", "event"} <= kinds


# ------------------------------------------------------------- report CLI


def _report(args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, "-m", "repro.telemetry.report"] + args,
        env=env, capture_output=True, text=True, timeout=120)


def test_report_cli_summary_and_diff(tmp_path):
    run_dir = tmp_path / "run"
    _trainer(run_dir, steps=8).train()

    res = _report([str(run_dir)])
    assert res.returncode == 0, res.stderr
    out = res.stdout
    assert "# telemetry report" in out
    assert "## loss" in out and "## families" in out
    assert "## spans" in out and "## events" in out
    assert "optimizer=gum" in out

    # diff against an identical copy: loss delta must read as identical
    twin = tmp_path / "twin.jsonl"
    shutil.copy(run_dir / "events.jsonl", twin)
    res = _report([str(run_dir), "--diff", str(twin)])
    assert res.returncode == 0, res.stderr
    assert "(identical)" in res.stdout
    assert "<-- differs" not in res.stdout

    # error paths exit 2 with a message, not a traceback
    res = _report([str(tmp_path / "nope")])
    assert res.returncode == 2
    assert "error:" in res.stderr and "Traceback" not in res.stderr
