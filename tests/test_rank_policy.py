"""Rank-policy engine: schedules, spectral adaptation, live state migration.

The migration contract: a rank change at a refresh boundary is
indistinguishable — from the next refresh on — from having run at the new
rank all along.  With ``reset_on_refresh=True`` chains (the GUM family) that
means a ``stepwise`` rank drop mid-run produces BIT-IDENTICAL updates to a
fresh run started at the low rank, from the first post-drop refresh onward
(same step counts => same PRNG keys => same projectors / gamma samples; the
refresh recomputes the projector at the new rank and zeroes all momenta).
Covered on the per-leaf AND family-stacked paths, with ragged shapes and
``pad_rank_to=128``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import combinators as C
from repro.core import rank_policy as RP
from repro.core import OptimizerConfig, build_optimizer, find_lowrank_states

KEY = jax.random.PRNGKey(0)

PARAMS = {
    "blocks": jax.random.normal(jax.random.fold_in(KEY, 0), (3, 16, 24)) * 0.1,
    "single": jax.random.normal(jax.random.fold_in(KEY, 1), (16, 24)) * 0.1,
    "ragged": jax.random.normal(jax.random.fold_in(KEY, 2), (20, 9)) * 0.1,
}


def grads_at(step):
    """Deterministic per-step synthetic gradients (identical across runs)."""
    return jax.tree_util.tree_map(
        lambda p, i=step: p + 0.03 * jax.random.normal(
            jax.random.fold_in(KEY, 1000 + i), p.shape),
        PARAMS,
    )


# ----------------------------------------------------------- RankMap / specs


def test_rank_map_basics():
    m = RP.RankMap(64, {(16, 24): 8, (20, 9): 4})
    assert m.rank_for(16, 24) == 8
    assert m.rank_for(20, 9) == 4
    assert m.rank_for(100, 100) == 64
    # canonical form: overrides equal to the default vanish
    assert RP.RankMap(8, {(16, 24): 8}) == RP.RankMap(8)
    assert hash(RP.RankMap(8, {(16, 24): 8})) == hash(RP.RankMap(8))
    assert RP.RankMap.from_json(m.to_json()) == m


def test_parse_rank_policy_specs():
    assert RP.parse_rank_policy("fixed:64").ladder() == (64,)
    assert RP.parse_rank_policy("64").ladder() == (64,)
    sw = RP.parse_rank_policy("stepwise:0=128,500=64")
    assert sw.initial_map(0).default == 128
    _, m = sw.decide({}, 600, {}, RP.RankMap(128))
    assert m.default == 64
    fam = RP.parse_rank_policy("family:512x512=32,1024x256=64")
    assert fam.initial_map(128).rank_for(512, 512) == 32
    assert fam.initial_map(128).rank_for(7, 7) == 128
    sp = RP.parse_rank_policy("spectral:0.9", ladder=(4, 8, 16))
    assert sp.ladder() == (4, 8, 16) and sp.wants_probes
    with pytest.raises(ValueError):
        RP.parse_rank_policy("nope:1")


def test_stepwise_threshold_snapping():
    pol = RP.stepwise({0: 8, 10: 4, 20: 2})
    assert [pol._rank_at(s, 99) for s in (0, 9, 10, 19, 20, 99)] == \
        [8, 8, 4, 4, 2, 2]
    assert pol.ladder() == (2, 4, 8)
    # without a step-0 key the configured base rank applies until the first
    # threshold — it is NOT silently replaced by the first scheduled value
    pol = RP.stepwise({500: 64})
    assert pol.initial_map(128) == RP.RankMap(128)
    _, m = pol.decide({}, 400, {}, RP.RankMap(128))
    assert m == RP.RankMap(128)
    _, m = pol.decide({}, 500, {}, RP.RankMap(128))
    assert m == RP.RankMap(64)


# ----------------------------------------------------------- migration


def _chain(rank, period=4, ff=False, prt=0, gamma=1):
    return C.chain(
        C.lowrank(
            C.layerwise_unbias(C.scale_by_momentum(beta=0.9), gamma=gamma),
            rank=rank, period=period, reset_on_refresh=True,
            kernel_impl="jnp", pad_rank_to=prt, fuse_families=ff,
        ),
        C.scale_by_lr(0.1),
    )


def test_migrate_truncates_and_preserves():
    t_hi, t_lo = _chain(RP.RankMap(6)), _chain(RP.RankMap(3))
    st = t_hi.init(PARAMS)
    for step in range(3):
        _, st = t_hi.update(grads_at(step), st, PARAMS)
    mig = RP.migrate_opt_state(st, t_lo.init(PARAMS))
    lr_hi = find_lowrank_states(st)[0]
    lr_lo = find_lowrank_states(mig)[0]
    assert int(lr_lo.count) == int(lr_hi.count)
    for hi, lo in zip(jax.tree_util.tree_leaves(lr_hi.projs),
                      jax.tree_util.tree_leaves(lr_lo.projs)):
        np.testing.assert_array_equal(np.asarray(hi[..., :lo.shape[-1]]),
                                      np.asarray(lo))
    for hi, lo in zip(jax.tree_util.tree_leaves(lr_hi.inner.idx),
                      jax.tree_util.tree_leaves(lr_lo.inner.idx)):
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(lo))
    # growing back zero-pads the new columns
    grown = RP.migrate_opt_state(mig, t_hi.init(PARAMS))
    for lo, gr in zip(jax.tree_util.tree_leaves(lr_lo.projs),
                      jax.tree_util.tree_leaves(
                          find_lowrank_states(grown)[0].projs)):
        np.testing.assert_array_equal(np.asarray(gr[..., :lo.shape[-1]]),
                                      np.asarray(lo))
        assert not np.asarray(gr[..., lo.shape[-1]:]).any()


def test_migrate_rejects_structure_change():
    t = _chain(RP.RankMap(4))
    other = C.chain(C.lowrank(C.scale_by_momentum(0.9), rank=4),
                    C.scale_by_lr(0.1))
    with pytest.raises(ValueError, match="structure"):
        RP.migrate_opt_state(t.init(PARAMS), other.init(PARAMS))


@pytest.mark.parametrize("ff", [False, True], ids=["perleaf", "fused"])
@pytest.mark.parametrize("prt", [0, 128], ids=["nopad", "pad128"])
def test_stepwise_drop_matches_fresh_low_rank_run(ff, prt):
    """A stepwise 8->3 rank drop at step 8 (a refresh boundary of period 4)
    produces bit-identical updates to a fresh rank-3 run from the first
    post-drop refresh on — on per-leaf and fused paths, ragged shapes
    included, with and without lane-aligned rank padding."""
    period, drop, total = 4, 8, 16
    pol = RP.stepwise({0: 8, drop: 3})
    build = lambda m: _chain(m, period=period, ff=ff, prt=prt)
    ctrl = RP.RankPolicyController(pol, build, period=period, default_rank=8)

    opt = ctrl.transform()
    st = opt.init(PARAMS)
    mig_updates = []
    changed_at = None
    for step in range(total):
        st, changed = ctrl.maybe_update(st, PARAMS)
        if changed:
            opt = ctrl.transform()
            changed_at = step
        u, st = opt.update(grads_at(step), st, PARAMS)
        mig_updates.append(u)
    assert changed_at == drop
    assert ctrl.current_map == RP.RankMap(3)

    fresh = build(RP.RankMap(3))
    st_f = fresh.init(PARAMS)
    for step in range(total):
        u_f, st_f = fresh.update(grads_at(step), st_f, PARAMS)
        if step >= drop:
            for a, b in zip(jax.tree_util.tree_leaves(mig_updates[step]),
                            jax.tree_util.tree_leaves(u_f)):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"step {step} ff={ff} prt={prt}")


# ----------------------------------------------------------- spectral


def test_spectrum_probe_matches_svd():
    """probe sv2 == squared top-r singular values of G (svd projector)."""
    g = grads_at(0)
    pol = RP.spectral(target_energy=0.99, r_min=2, r_max=8, ladder=(2, 4, 8))
    t = C.chain(
        C.lowrank(C.scale_by_momentum(0.9), rank=8, period=4,
                  kernel_impl="jnp", rank_policy=pol),
        C.scale_by_lr(0.1),
    )
    st = t.init(PARAMS)
    _, st = t.update(g, st, PARAMS)  # count=1 -> refresh, probes captured
    probes = RP.gather_probes(st)
    sv = np.linalg.svd(np.asarray(g["single"]), compute_uv=False)
    got = probes[(16, 24)]["sv2"]
    # (16, 24) aggregates "single" + the 3 "blocks" members
    blocks = np.asarray(g["blocks"]).reshape(-1, 16, 24)
    want = np.sort(np.concatenate(
        [np.linalg.svd(b, compute_uv=False)[:8] ** 2 for b in blocks]
        + [sv[:8] ** 2]))[::-1]
    # aggregation sums per-leaf sorted spectra; compare total captured energy
    np.testing.assert_allclose(got.sum(), want.sum(), rtol=1e-4)
    g2 = probes[(16, 24)]["g2"]
    assert got.sum() <= g2 * (1 + 1e-5)


def test_spectral_decisions():
    pol = RP.spectral(target_energy=0.9, r_min=2, r_max=8, ladder=(2, 4, 8))
    cur = RP.RankMap(8)
    # concentrated spectrum: top-2 carry 99% of the energy -> shrink to 2
    probes = {(16, 24): {"sv2": np.array([50.0, 49.0, 0.5, 0.25] + [0.0] * 4),
                         "g2": 100.0, "rank": 8}}
    _, m = pol.decide(pol.init_state(), 4, probes, cur)
    assert m.rank_for(16, 24) == 2
    # flat spectrum far from target -> grow one ladder step above current
    probes = {(16, 24): {"sv2": np.ones(4) * 1.0, "g2": 100.0, "rank": 4}}
    _, m = pol.decide(pol.init_state(), 4, probes, RP.RankMap(4))
    assert m.rank_for(16, 24) == 8
    # never exceeds the family dims
    probes = {(20, 9): {"sv2": np.ones(8), "g2": 1e6, "rank": 8}}
    _, m = pol.decide(pol.init_state(), 4, probes, RP.RankMap(8))
    assert m.rank_for(20, 9) <= 9
    # probe_every rate-limits decisions
    pol2 = RP.spectral(target_energy=0.9, probe_every=100,
                       r_min=2, r_max=8, ladder=(2, 4, 8))
    ps = pol2.init_state()
    ps, m = pol2.decide(ps, 4, probes, RP.RankMap(8))
    assert m is not None
    ps, m = pol2.decide(ps, 8, probes, RP.RankMap(8))
    assert m is None  # within the probe_every window


@pytest.mark.parametrize("ff", [False, True], ids=["perleaf", "fused"])
def test_spectral_shrinks_on_lowrank_gradients(ff):
    """Rank-2 gradients drive the spectral policy down the ladder on both
    execution paths; the shrunken state is smaller and still trains."""
    u = jax.random.normal(jax.random.fold_in(KEY, 7), (16, 2))
    v = jax.random.normal(jax.random.fold_in(KEY, 8), (2, 24))
    glow = {"blocks": jnp.stack([u @ v] * 3), "single": u @ v,
            "ragged": jax.random.normal(jax.random.fold_in(KEY, 10), (20, 1))
            @ jax.random.normal(jax.random.fold_in(KEY, 11), (1, 9))}
    pol = RP.spectral(target_energy=0.95, r_min=2, r_max=8, ladder=(2, 4, 8))
    build = lambda m: C.chain(
        C.lowrank(C.layerwise_unbias(C.scale_by_momentum(0.9), gamma=1),
                  rank=m, period=2, reset_on_refresh=True, kernel_impl="jnp",
                  rank_policy=pol, fuse_families=ff),
        C.scale_by_lr(0.1))
    ctrl = RP.RankPolicyController(pol, build, period=2, default_rank=8)
    opt = ctrl.transform()
    st = opt.init(PARAMS)
    bytes_before = core.state_bytes(st)
    for step in range(6):
        st, changed = ctrl.maybe_update(st, PARAMS)
        if changed:
            opt = ctrl.transform()
        _, st = opt.update(glow, st, PARAMS)
    assert ctrl.current_map.rank_for(16, 24) == 2, ctrl.history
    assert core.state_bytes(st) < bytes_before


# ----------------------------------------------------------- checkpointing


def test_checkpoint_layout_mismatch_names_fuse_families(tmp_path):
    from repro.checkpoint import CheckpointManager

    cfg = dict(rank=4, gamma=1, period=3, kernel_impl="jnp")
    fused_state = core.gum(1e-2, fuse_families=True, **cfg).init(PARAMS)
    leaf_state = core.gum(1e-2, **cfg).init(PARAMS)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, fused_state)
    with pytest.raises(ValueError, match="fuse_families"):
        mgr.restore(1, leaf_state)


def test_checkpoint_rank_mismatch_hint(tmp_path):
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _chain(RP.RankMap(6)).init(PARAMS))
    with pytest.raises(ValueError, match="rank"):
        mgr.restore(1, _chain(RP.RankMap(3)).init(PARAMS))


def test_trainer_bitexact_resume_across_rank_change(tmp_path):
    """End-to-end acceptance: a stepwise drop at step 6 (period 3), trained
    through the real Trainer + CheckpointManager; stopping at step 8 (after
    the drop) and resuming to 10 reproduces the uninterrupted run's final
    params BIT-exactly — the controller state rides in checkpoint extras and
    rebuilds the restore template at the saved RankMap."""
    from repro.configs import RunConfig, get_smoke
    from repro.data import DataConfig
    from repro.models import build_model
    from repro.train import Trainer

    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(
        name="gum", lr=5e-3, rank=8, gamma=1, period=3,
        kernel_impl="jnp", rank_policy="stepwise:0=8,6=4",
    )
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)

    def run(ckpt_dir, steps, resume):
        run_cfg = RunConfig(steps=steps, ckpt_dir=str(ckpt_dir),
                            resume=resume, ckpt_every=0, log_every=0)
        tr = Trainer(model, opt_cfg, run_cfg, data_cfg)
        tr.train()
        return tr

    tr_a = run(tmp_path / "a", 10, resume=False)
    assert tr_a.rank_ctrl.current_map == RP.RankMap(4), tr_a.rank_ctrl.history

    run(tmp_path / "b", 8, resume=False)   # stops AFTER the rank change
    tr_b = run(tmp_path / "b", 10, resume=True)
    assert tr_b.rank_ctrl.current_map == RP.RankMap(4)

    (pa, sa), _ = tr_a.ckpt.restore(10, tr_a.init_state())
    (pb, sb), _ = tr_b.ckpt.restore(10, tr_b.init_state())
    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(sb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_spectral_grow_hysteresis_no_oscillation():
    """Regression: spectral's grow path oscillated (4 <-> 8).  A shrink to a
    rank that barely met the target produces *starved* probes at the new
    rank (the smaller sketch cannot measure the target energy), which grew
    the rank right back — and the next full-rank probe shrank it again,
    forever.  A starvation grow now floors the family at the grown rank for
    floor_ttl decisions, so replaying the oscillating probe sequence must
    converge instead of flip-flopping."""
    import json as _json

    pol = RP.spectral(target_energy=0.9, r_min=2, r_max=8, ladder=(2, 4, 8))
    ps = pol.init_state()
    cur = RP.RankMap(8)
    # probe the policy would see at rank 8: target met at k=4 -> shrink
    at8 = {"sv2": np.array([50.0, 30.0, 9.0, 5.0, 2.0, 1.5, 1.5, 1.0]),
           "g2": 100.0}
    # probe at rank 4: 4 singular values cannot reach the target -> starved
    at4 = {"sv2": np.array([40.0, 25.0, 10.0, 5.0]), "g2": 100.0}
    hist = []
    for i in range(8):
        r = cur.rank_for(16, 24)
        pr = dict(at8 if r == 8 else at4, rank=r)
        ps, m = pol.decide(ps, 4 * (i + 1), {(16, 24): pr}, cur)
        if m is not None:
            cur = m
        hist.append(cur.rank_for(16, 24))
    # first decision shrinks, second grows back; the floor then pins the
    # family — no further oscillation
    assert hist[0] == 4 and hist[1] == 8, hist
    assert all(r == 8 for r in hist[2:]), f"rank oscillated: {hist}"
    assert ps["floors"] == {"16x24": [8, 2 + pol.floor_ttl]}
    # hysteresis state must survive the checkpoint-extras JSON round-trip
    assert _json.loads(_json.dumps(ps)) == ps


def test_spectral_floor_expires():
    """The hysteresis floor has a TTL: once it expires, genuine rank decay
    can shrink the family again."""
    pol = RP.spectral(target_energy=0.9, r_min=2, r_max=8, ladder=(2, 4, 8),
                      floor_ttl=2)
    ps = {"last_decision_step": None, "decisions": 0,
          "floors": {"16x24": [8, 2]}}
    shrinky = {(16, 24): {"sv2": np.array([95.0] + [0.5] * 7),
                          "g2": 100.0, "rank": 8}}
    # decision 1: floor [8, 2] still active (2 > 1) -> held at 8
    ps, m = pol.decide(ps, 4, shrinky, RP.RankMap(8))
    assert m.rank_for(16, 24) == 8
    # decision 2: floor expired (2 > 2 is false) -> shrink wins
    ps, m = pol.decide(ps, 8, shrinky, RP.RankMap(8))
    assert m.rank_for(16, 24) == 2
    assert ps["floors"] == {}
