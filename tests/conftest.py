"""Shared pytest plumbing: in-process isolation for crash-prone test files.

``test_unbiasedness.py`` is skipped during whole-suite collection and runs
through ``test_unbiasedness_subprocess.py`` instead: executing its
jit-heavy parametrized cases *after* the rest of the suite in one
interpreter segfaults XLA's CPU ``backend_compile`` (rc 139 — the same
class of in-process-reuse crash as the persistent-compilation-cache hazard
recorded in ROADMAP.md).  In a fresh interpreter the file is green, so the
suite still covers every test in it — just behind a process boundary.

Naming the file explicitly (``pytest tests/test_unbiasedness.py``) bypasses
the isolation, which is exactly what the subprocess wrapper does.
"""
import os

# Files that must not share an interpreter with the rest of the suite.
ISOLATED = {"test_unbiasedness.py"}


def pytest_ignore_collect(collection_path, config):
    name = os.path.basename(str(collection_path))
    if name not in ISOLATED:
        return None
    # honor explicit selection: `pytest tests/test_unbiasedness.py ...`
    if any(name in str(a) for a in config.invocation_params.args):
        return None
    return True
