"""The combinator redesign (PR 2): Table-1 memory regression via
state_bytes, the new unbiased GaLore-Adam composition, and custom-chain
composition.  The pre-redesign-monolith equivalence guarantee lives in
tests/test_legacy_fixtures.py as recorded trajectories
(tests/data/legacy_trajectories.json) — the live monoliths
(core/legacy.py) were deleted in PR 7 after the soak the ROADMAP
scheduled."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import (
    OptimizerConfig,
    apply_updates,
    build_optimizer,
    chain,
    combinators,
    layerwise_unbias,
    lowrank,
    scale_by_adam,
    scale_by_lr,
    scale_by_muon,
    state_bytes,
    unbiased_galore_adam,
    with_matrix_routing,
)

KEY = jax.random.PRNGKey(0)

# A routing-exercising tree: stacked matrix families (left- and right-side
# projection) plus embedding / norm leaves that fall to the AdamW fallback.
PARAMS = {
    "blocks": {
        "wq": jax.random.normal(KEY, (3, 16, 24)) * 0.1,
        "w_out": jax.random.normal(jax.random.fold_in(KEY, 1), (3, 24, 16)) * 0.1,
    },
    "embed": jax.random.normal(jax.random.fold_in(KEY, 2), (64, 16)) * 0.1,
    "norm_scale": jnp.ones((16,)),
}


def quad_loss(p):
    return 0.5 * sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(p))


def run_traj(opt, params=PARAMS, steps=8):
    """(final params, per-step losses) on the shared quadratic."""
    st = opt.init(params)
    p = params
    losses = []
    for _ in range(steps):
        g = jax.grad(quad_loss)(p)
        u, st = opt.update(g, st, p)
        p = apply_updates(p, u)
        losses.append(float(quad_loss(p)))
    return p, losses, st


# ---------------------------------------------------------- interpret parity


@pytest.mark.parametrize("name,builder", [
    ("gum", lambda kw: core.gum(1e-2, rank=4, gamma=1, period=3, seed=5,
                                weight_decay=0.01, **kw)),
    ("galore_muon", lambda kw: core.galore(1e-2, rank=4, period=3,
                                           base="muon", weight_decay=0.01,
                                           **kw)),
])
def test_jnp_vs_interpret_parity(name, builder):
    """The kernel-using optimizers produce the same trajectory through the
    Pallas interpreter as on the jnp reference path (fp32 roundoff — the
    interpreter routes back-projection through the fused kernel)."""
    p_new, l_new, _ = run_traj(builder(dict(kernel_impl="interpret")), steps=5)
    p_old, l_old, _ = run_traj(builder(dict(kernel_impl="jnp")), steps=5)
    np.testing.assert_allclose(l_new, l_old, rtol=1e-4, err_msg=name)
    for a, b in zip(jax.tree_util.tree_leaves(p_new),
                    jax.tree_util.tree_leaves(p_old)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4, err_msg=name)


def test_factory_returns_combinator_chains():
    """build_optimizer resolves every name to combinator-composed transforms
    (a lowrank() stage is discoverable in each low-rank optimizer's state)."""
    for name in ("gum", "galore", "galore_muon", "golore", "fira",
                 "unbiased_galore_adam"):
        opt = build_optimizer(OptimizerConfig(name=name, lr=1e-2, rank=4,
                                              gamma=1, period=4))
        st = opt.init(PARAMS)
        assert len(core.find_lowrank_states(st)) == 1, name
    for name in ("adamw", "sgdm", "muon", "lisa"):
        opt = build_optimizer(OptimizerConfig(name=name, lr=1e-2))
        opt.init(PARAMS)  # constructs without error


# ------------------------------------------------- Table-1 memory regression


def test_state_bytes_matches_table1():
    """state_bytes of lowrank()+layerwise_unbias() matches Table 1's
    O((2-q)·mrL + q·Lmn) up to the known static-shape overhead (q·L·r·n, the
    always-allocated low-rank momentum of sampled blocks) plus O(1) counts
    and the (gamma,) int32 slot index."""
    L, m, r, gamma = 8, 32, 4, 2
    q = gamma / L
    params = {"w": jnp.zeros((L, m, m))}
    opt = chain(
        lowrank(layerwise_unbias(scale_by_muon(beta=0.95), gamma=gamma),
                rank=r, period=10, reset_on_refresh=True),
        scale_by_lr(1e-2),
    )
    st = opt.init(params)
    got = state_bytes(st)
    paper_floats = (2 - q) * L * m * r + q * L * m * m
    static_overhead = q * L * r * m          # low momentum of sampled blocks
    # idx int32 + the lowrank and lr-schedule counts (scale_by_muon is
    # count-free: its state is the momentum tree alone)
    bookkeeping = gamma * 4 + 2 * 4
    assert got == (paper_floats + static_overhead) * 4 + bookkeeping, got
    # GaLore at the same rank for comparison: 2·L·m·r floats + 2 counts
    gal = chain(lowrank(scale_by_muon(beta=0.95), rank=r, period=10),
                scale_by_lr(1e-2))
    assert state_bytes(gal.init(params)) == 2 * L * m * r * 4 + 2 * 4


# --------------------------------------------- the NEW composition: UGA


def test_unbiased_galore_adam_descends_and_samples():
    """Acceptance: unbiased GaLore-Adam ships as a pure composition —
    layerwise_unbias wrapping scale_by_adam — with full-rank sampled slots
    and descent on the quadratic."""
    opt = build_optimizer(OptimizerConfig(
        name="unbiased_galore_adam", lr=1e-1, rank=4, gamma=2, period=100,
        projector="svd", seed=3,
    ))
    L, m, n, r = 6, 10, 14, 4
    params = {"w": jnp.zeros((L, m, n))}
    st = opt.init(params)
    g = {"w": jax.random.normal(KEY, (L, m, n))}
    upd, st2 = opt.update(g, st, params)
    idx = np.asarray(core.find_lowrank_states(st2)[0].inner.idx["w"])
    assert idx.shape == (2,)
    for l in range(L):
        rank_u = np.linalg.matrix_rank(np.asarray(upd["w"][l]), tol=1e-5)
        if l in idx:
            assert rank_u > r, (l, rank_u)   # compensated full-rank Adam slot
        else:
            assert rank_u <= r, (l, rank_u)  # projected GaLore-Adam update
    # the full branch carries its own Adam moment slots: (gamma, m, n) x2
    full = core.find_lowrank_states(st2)[0].inner.full
    assert full.mu["w"].shape == (2, m, n) and full.nu["w"].shape == (2, m, n)
    # and it trains once the subspace/block sampling actually rotates
    # (short period; lr*alpha = 2.5e-2 effective Adam step)
    opt_fast = build_optimizer(OptimizerConfig(
        name="unbiased_galore_adam", lr=1e-1, rank=4, gamma=2, period=5,
        projector="svd", seed=3,
    ))
    p_end, losses, _ = run_traj(
        opt_fast, {"w": jax.random.normal(KEY, (L, m, n)) * 0.3}, steps=60
    )
    assert losses[-1] < 0.2 * losses[0], losses


def test_unbiased_galore_adam_gamma0_is_galore_adam():
    """With no sampled slots the composition degenerates to plain GaLore-Adam
    (same gradient path, same moments) — the q=0 sanity anchor."""
    uga = build_optimizer(OptimizerConfig(
        name="unbiased_galore_adam", lr=1e-2, rank=4, gamma=0, period=3, seed=5))
    # galore resets moments only with reset_on_update; UGA always resets at
    # the boundary, so compare against a reset_on_update GaLore-Adam chain.
    gal = with_matrix_routing(
        core.galore_matrices(1e-2, rank=4, period=3, reset_on_update=True, seed=5),
        core.adamw(1e-2),
        matrix_label="unbiased_galore_adam",
    )
    p_a, l_a, _ = run_traj(uga)
    p_b, l_b, _ = run_traj(gal)
    np.testing.assert_allclose(l_a, l_b, rtol=1e-6)


# ------------------------------------------------------- custom compositions


def test_custom_chain_with_clip_descends():
    """The combinators compose freely: clip -> lowrank(muon) -> lr."""
    opt = with_matrix_routing(
        chain(
            combinators.clip_by_global_norm(1.0),
            lowrank(scale_by_muon(beta=0.9), rank=4, period=5, seed=1),
            combinators.add_decayed_weights(0.001),
            scale_by_lr(3e-2),
        ),
        core.adamw(3e-2),
    )
    p_end, losses, _ = run_traj(opt, steps=20)
    assert losses[-1] < 0.6 * losses[0], losses


def test_with_matrix_routing_custom_filter():
    """with_matrix_routing generalizes the old per-optimizer label plumbing:
    a custom predicate routes leaves, labels name the state entries."""
    routed = with_matrix_routing(
        core.sgdm(1e-1),
        core.adamw(1e-2),
        matrix_filter=lambda path, p: "wq" in path,
        matrix_label="sgdm_side",
        fallback_label="adam_side",
    )
    st = routed.init(PARAMS)
    assert set(st.inner) == {"sgdm_side", "adam_side"}
    g = jax.tree_util.tree_map(jnp.ones_like, PARAMS)
    u, _ = routed.update(g, st, PARAMS)
    # sgdm side: -lr * mu = -0.1 exactly on first step; adam side differs
    np.testing.assert_allclose(np.asarray(u["blocks"]["wq"]), -0.1, rtol=1e-6)
    assert not np.allclose(np.asarray(u["embed"]), -0.1)


def test_layerwise_unbias_q1_skips_low_branch():
    """gamma >= L (q = 1, e.g. an unstacked 2-D matrix under the default
    gamma=2): every block is sampled full-rank, so the low branch carries no
    state and does no work — and the optimizer still descends."""
    params = {"w": jax.random.normal(KEY, (10, 14)) * 0.3}  # L = 1
    new = core.gum_matrices(1e-2, rank=4, gamma=2, period=3, seed=5)
    st = new.init(params)
    assert core.find_lowrank_states(st)[0].inner.low["w"] is None
    assert core.find_lowrank_states(st)[0].inner.full["w"].shape == (1, 10, 14)
    _, l_new, _ = run_traj(new, params)
    assert l_new[-1] < l_new[0], l_new


def test_chain_inside_lowrank_forwards_protocol():
    """A chain whose head speaks the lowrank protocol composes inside
    lowrank(): chain() forwards wants_sample_key/refresh_state and
    scale_by_factor scales through ProjGrad/FullUpdate leaves."""
    def mk(factor):
        stages = [layerwise_unbias(scale_by_muon(beta=0.9), gamma=1)]
        if factor is not None:
            stages.append(combinators.scale_by_factor(factor))
        inner = chain(*stages) if factor is not None else stages[0]
        return chain(
            lowrank(inner, rank=4, period=3, seed=5, reset_on_refresh=True),
            scale_by_lr(1e-2),
        )

    params = {"w": jax.random.normal(KEY, (3, 10, 12)) * 0.3}
    plain, halved = mk(None), mk(0.5)
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    u1, _ = plain.update(g, plain.init(params), params)
    u2, _ = halved.update(g, halved.init(params), params)
    np.testing.assert_allclose(np.asarray(u2["w"]), 0.5 * np.asarray(u1["w"]),
                               atol=1e-6, rtol=1e-5)
    # and the composed chain still trains across refreshes (RNG key plumbing
    # survived the chain wrapper)
    _, losses, _ = run_traj(halved, params, steps=10)
    assert losses[-1] < losses[0]


def test_layerwise_unbias_requires_lowrank():
    t = chain(layerwise_unbias(scale_by_adam()), scale_by_lr(1e-2))
    params = {"w": jnp.zeros((2, 8, 8))}
    with pytest.raises(TypeError, match="inside lowrank"):
        t.init(params)


def test_fira_residual_honors_reset_on_refresh_consistently():
    """reset_on_refresh=True through with_fira_residual: the in-update path
    (ProjGrad.reset) and the external-refresh path (generic float zeroing)
    must produce identical trajectories — the base consumes plain arrays, so
    the wrapper has to apply the reset itself."""
    from repro.core.combinators import with_fira_residual

    def mk(ext):
        return chain(
            lowrank(with_fira_residual(scale_by_adam(), eps=1e-8),
                    rank=3, period=2, seed=4, reset_on_refresh=True,
                    external_refresh=ext),
            scale_by_lr(1e-2),
        )

    internal, external = mk(False), mk(True)
    params = {"w": jax.random.normal(KEY, (2, 8, 12)) * 0.3}
    st_i, st_e = internal.init(params), external.init(params)
    # the refresh hook is config-determined, so an identically-configured
    # fresh lowrank stage drives the external chain's state
    lr_t = lowrank(with_fira_residual(scale_by_adam(), eps=1e-8),
                   rank=3, period=2, seed=4, reset_on_refresh=True,
                   external_refresh=True)
    p_i, p_e = params, params
    for _ in range(5):
        g_i = jax.grad(quad_loss)(p_i)
        u_i, st_i = internal.update(g_i, st_i, p_i)
        p_i = apply_updates(p_i, u_i)
        g_e = jax.grad(quad_loss)(p_e)
        new_lr = lr_t.update.refresh(g_e, st_e[0], p_e)
        st_e = (new_lr,) + tuple(st_e[1:])
        u_e, st_e = external.update(g_e, st_e, p_e)
        p_e = apply_updates(p_e, u_e)
    for a, b in zip(jax.tree_util.tree_leaves(p_i), jax.tree_util.tree_leaves(p_e)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_external_refresh_matches_in_update_refresh():
    """lowrank's external-refresh hook (the accumulation path) reproduces the
    in-update refresh exactly: same projector RNG, same slot resampling."""
    mk = lambda ext: core.gum_matrices(1e-2, rank=4, gamma=1, period=2, seed=9,
                                       external_refresh=ext)
    internal, external = mk(False), mk(True)
    ext_refresh = external.update.lowrank_transform.update.refresh
    params = {"w": jax.random.normal(KEY, (3, 10, 12)) * 0.3}
    st_i, st_e = internal.init(params), external.init(params)
    p_i, p_e = params, params
    for _ in range(5):
        g_i = jax.grad(quad_loss)(p_i)
        u_i, st_i = internal.update(g_i, st_i, p_i)
        p_i = apply_updates(p_i, u_i)
        g_e = jax.grad(quad_loss)(p_e)
        st_e = st_e[:1] + st_e[1:]  # no-op: states are plain tuples
        new_lr = ext_refresh(g_e, st_e[0], p_e)
        st_e = (new_lr,) + tuple(st_e[1:])
        u_e, st_e = external.update(g_e, st_e, p_e)
        p_e = apply_updates(p_e, u_e)
    for a, b in zip(jax.tree_util.tree_leaves(p_i), jax.tree_util.tree_leaves(p_e)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
