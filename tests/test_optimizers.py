"""Optimizer behaviour: descent, GUM==GaLore-Muon at q=0, Table-1 memory
accounting, schedules, NaN guard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OptimizerConfig,
    apply_updates,
    build_optimizer,
    constant,
    galore_matrices,
    gum_matrices,
    state_bytes,
    warmup_cosine,
)

KEY = jax.random.PRNGKey(0)

PARAMS = {
    "blocks": {
        "wq": jax.random.normal(KEY, (3, 16, 24)) * 0.1,
        "w_out": jax.random.normal(jax.random.fold_in(KEY, 1), (3, 24, 16)) * 0.1,
    },
    "embed": jax.random.normal(jax.random.fold_in(KEY, 2), (64, 16)) * 0.1,
    "norm_scale": jnp.ones((16,)),
}

ALL_OPTS = ["adamw", "sgdm", "muon", "galore", "galore_muon", "golore", "gum",
            "fira", "lisa"]


def quad_loss(p):
    return 0.5 * sum(jnp.sum(x**2) for x in jax.tree_util.tree_leaves(p))


@pytest.mark.parametrize("name", ALL_OPTS)
def test_descends_quadratic(name):
    cfg = OptimizerConfig(name=name, lr=3e-2, rank=4, gamma=1, period=4,
                          projector="svd")
    opt = build_optimizer(cfg)
    st = opt.init(PARAMS)

    @jax.jit
    def step(p, s):
        g = jax.grad(quad_loss)(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    p = PARAMS
    l0 = float(quad_loss(p))
    for _ in range(30):
        p, st = step(p, st)
    assert float(quad_loss(p)) < 0.7 * l0, name


def test_gum_gamma0_equals_galore_muon():
    """GUM with no sampled full-rank blocks IS GaLore-Muon (eq. (1), q=0)."""
    gum = gum_matrices(1e-2, rank=4, gamma=0, period=3, projector="svd",
                       base="muon", seed=7)
    gal = galore_matrices(1e-2, rank=4, period=3, projector="svd", base="muon",
                          reset_on_update=True, seed=7)
    params = {"w": jax.random.normal(KEY, (2, 12, 20)) * 0.5}
    sg, sl = gum.init(params), gal.init(params)
    p_g, p_l = params, params
    for i in range(7):
        g = jax.grad(quad_loss)(p_g)
        ug, sg = gum.update(g, sg, p_g)
        g2 = jax.grad(quad_loss)(p_l)
        ul, sl = gal.update(g2, sl, p_l)
        np.testing.assert_allclose(ug["w"], ul["w"], atol=1e-5, rtol=1e-5)
        p_g = apply_updates(p_g, ug)
        p_l = apply_updates(p_l, ul)


def test_gum_memory_matches_table1():
    """Table 1: paper GUM state = (2-q)·L·m·r + q·L·m·n floats.  Our
    static-shape formulation (jit-compatible) keeps the low-rank momentum for
    all L blocks, adding exactly q·L·r·n on top (≈2% at the paper's gamma=2,
    L=32+): total = 2·L·m·r + q·L·m·n.  State navigation: gum_matrices is
    chain(lowrank(layerwise_unbias(...)), ...) — the lowrank state sits at
    chain position 0 with the unbias state (low/full/idx) inside."""
    L, m, r, gamma = 8, 32, 4, 2
    q = gamma / L
    params = {"w": jnp.zeros((L, m, m))}
    opt = gum_matrices(1e-2, rank=r, gamma=gamma, period=10)
    st = opt.init(params)
    lrs = st[0]  # LowRankState
    floats = (lrs.projs["w"].size + lrs.inner.low["w"].size
              + lrs.inner.full["w"].size)
    paper = (2 - q) * L * m * r + q * L * m * m
    static_overhead = q * L * r * m
    assert floats == paper + static_overhead, (floats, paper, static_overhead)
    # the overhead is bounded by q·(r/m) relative to the paper's m² term
    assert static_overhead / paper < 0.10
    # GaLore for comparison: 2·L·m·r (projector + one projected moment)
    gal = galore_matrices(1e-2, rank=r, period=10, base="muon")
    sg = gal.init(params)
    assert sg[0].projs["w"].size + sg[0].inner["w"].size == 2 * L * m * r


def test_gum_equal_memory_tradeoff():
    """Paper: with r' < r, GUM at q = 2(r-r')/(m-r') matches GaLore memory."""
    m, r, rp = 64, 16, 8
    q = 2 * (r - rp) / (m - rp)
    gum_cost = (2 - q) * m * rp + q * m * m
    galore_cost = 2 * m * r
    np.testing.assert_allclose(gum_cost, galore_cost, rtol=1e-9)


def test_gum_full_slots_follow_sampled_layers():
    """Sampled layers get full-rank updates; others get rank<=r updates."""
    L, m, n, r, gamma = 6, 10, 14, 2, 2
    params = {"w": jnp.zeros((L, m, n))}
    opt = gum_matrices(1.0, rank=r, gamma=gamma, period=100, projector="svd",
                       base="sgdm", beta=0.0, seed=3)
    st = opt.init(params)
    g = {"w": jax.random.normal(KEY, (L, m, n))}
    upd, st2 = opt.update(g, st, params)
    idx = np.asarray(st2[0].inner.idx["w"])
    for l in range(L):
        u = np.asarray(upd["w"][l])
        rank_u = np.linalg.matrix_rank(u, tol=1e-5)
        if l in idx:
            assert rank_u > r, (l, rank_u)  # compensated full-rank residual
        else:
            assert rank_u <= r, (l, rank_u)


def test_schedules():
    sched = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=0.01)
    assert float(constant(0.3)(jnp.asarray(7))) == pytest.approx(0.3)


def test_state_bytes_counts_arrays():
    opt = build_optimizer(OptimizerConfig(name="adamw", lr=1e-3))
    st = opt.init({"w": jnp.zeros((8, 8))})
    # mu + nu (f32) + the adam count + the lr-schedule count
    assert state_bytes(st) == 8 * 8 * 4 * 2 + 4 + 4
