"""Family-stacked fused step engine (PR 3): fused-vs-per-leaf equivalence.

The contract: ``fuse_families=True`` executes the lowrank() pipeline as one
batched launch per shape family but is TRAJECTORY-IDENTICAL to the per-leaf
path — bit-exact on the jnp backend (per-member PRNG keys and
layerwise_unbias gamma-slot sampling are preserved exactly), within fp32
tolerance on the interpret-mode Pallas kernels.  Covers ragged shapes,
``pad_rank_to=128``, mixed families, ``external_refresh``, the rsvd
projector, the fused epilogue, and launch-count scaling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import (
    OptimizerConfig,
    apply_updates,
    build_family_plan,
    build_optimizer,
    combinators,
)
from repro.core.lowrank_common import compute_projectors
from repro.kernels import dispatch, launch_count

KEY = jax.random.PRNGKey(0)


def _rand(i, shape, scale=0.1):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape) * scale


# Mixed-family routing tree: a stacked 3-block family, two single leaves of
# the SAME shape (they stack with each other, not with the 3-block leaves —
# different lead), a right-side family, a ragged family, and fallback leaves.
PARAMS = {
    "blocks": {
        "wq": _rand(0, (3, 16, 24)),
        "wk": _rand(1, (3, 16, 24)),
        "w_out": _rand(2, (3, 24, 16)),
    },
    "single_a": _rand(3, (16, 24)),
    "single_b": _rand(4, (16, 24)),
    "ragged": _rand(5, (20, 9)),
    "embed": _rand(6, (64, 16)),
    "norm_scale": jnp.ones((16,)),
}


def quad_loss(p):
    return 0.5 * sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(p))


def run_traj(opt, params=PARAMS, steps=8):
    st = opt.init(params)
    p = params
    losses = []
    for _ in range(steps):
        g = jax.grad(quad_loss)(p)
        u, st = opt.update(g, st, p)
        p = apply_updates(p, u)
        losses.append(float(quad_loss(p)))
    return p, losses


def _builders(**kw):
    return [
        ("gum", lambda: core.gum(1e-2, rank=4, gamma=1, period=3, seed=5,
                                 weight_decay=0.01, **kw)),
        ("gum_gamma2", lambda: core.gum(1e-2, rank=4, gamma=2, period=3,
                                        seed=7, **kw)),
        ("galore_adam", lambda: core.galore(1e-2, rank=4, period=3, **kw)),
        ("galore_muon", lambda: core.galore(1e-2, rank=4, period=3,
                                            base="muon", weight_decay=0.01, **kw)),
        ("fira", lambda: core.fira(1e-2, rank=4, period=3, **kw)),
        ("unbiased_galore_adam",
         lambda: core.unbiased_galore_adam(1e-2, rank=4, gamma=1, period=3,
                                           seed=3, **kw)),
    ]


def _assert_trees(p_a, p_b, bitexact, name, atol=1e-6):
    for a, b in zip(jax.tree_util.tree_leaves(p_a),
                    jax.tree_util.tree_leaves(p_b)):
        if bitexact:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=atol, rtol=1e-5, err_msg=name)


# --------------------------------------------------------------- equivalence


@pytest.mark.parametrize("idx", range(6))
def test_fuse_families_bitexact_jnp(idx):
    """Acceptance: the stacked engine reproduces the per-leaf trajectories
    BIT-FOR-BIT on the jnp path (8 steps = two refresh periods, so projector
    refresh and gamma-slot resampling both happen under stacking)."""
    name, mk = _builders(kernel_impl="jnp")[idx]
    p_leaf, l_leaf = run_traj(mk())
    name_f, mk_f = _builders(kernel_impl="jnp", fuse_families=True)[idx]
    p_fuse, l_fuse = run_traj(mk_f())
    np.testing.assert_array_equal(l_leaf, l_fuse, err_msg=name)
    _assert_trees(p_leaf, p_fuse, bitexact=True, name=name)


@pytest.mark.parametrize("idx", [0, 3])
def test_fuse_families_interpret(idx):
    """Stacked vs per-leaf through the interpret-mode Pallas kernels
    (tolerance: the padded batch grids change reduction tiling)."""
    name, mk = _builders(kernel_impl="interpret")[idx]
    p_leaf, _ = run_traj(mk(), steps=4)
    _, mk_f = _builders(kernel_impl="interpret", fuse_families=True)[idx]
    p_fuse, _ = run_traj(mk_f(), steps=4)
    _assert_trees(p_leaf, p_fuse, bitexact=False, name=name)


@pytest.mark.parametrize("idx", [0, 3, 4])
def test_fused_epilogue_matches(idx):
    """fused_epilogue folds -lr/wd into the GEMM — same trajectory within
    fp32 tolerance (the epilogue redistributes the multiplications)."""
    name, mk = _builders(kernel_impl="jnp")[idx]
    p_leaf, _ = run_traj(mk())
    _, mk_f = _builders(kernel_impl="jnp", fuse_families=True,
                        fused_epilogue=True)[idx]
    p_fuse, _ = run_traj(mk_f())
    _assert_trees(p_leaf, p_fuse, bitexact=False, name=name)


def test_fused_epilogue_interpret_pad_rank():
    """Epilogue kernel through interpret mode with lane-aligned rank padding
    on ragged shapes — the dispatch padding contract covers the W operand."""
    mk = lambda **kw: core.galore(1e-2, rank=4, period=3, base="muon",
                                  weight_decay=0.01, kernel_impl="interpret",
                                  pad_rank_to=128, **kw)
    p_leaf, _ = run_traj(mk(), steps=4)
    p_fuse, _ = run_traj(mk(fuse_families=True, fused_epilogue=True), steps=4)
    _assert_trees(p_leaf, p_fuse, bitexact=False, name="epilogue_pad128",
                  atol=5e-6)


def test_fuse_families_jit_bitexact():
    """Same contract under jit (the production path)."""
    mk = lambda **kw: core.gum(1e-2, rank=4, gamma=1, period=3, seed=5, **kw)

    def run(opt, steps=7):
        st = opt.init(PARAMS)

        @jax.jit
        def step(p, s):
            g = jax.grad(quad_loss)(p)
            u, s = opt.update(g, s, p)
            return apply_updates(p, u), s

        p = PARAMS
        for _ in range(steps):
            p, st = step(p, st)
        return p

    _assert_trees(run(mk()), run(mk(fuse_families=True)), bitexact=True,
                  name="gum_jit")


def test_external_refresh_matches_under_stacking():
    """lowrank's external-refresh hook drives the stacked engine to the same
    trajectory as the in-update refresh — in all four mode combinations."""
    matrices = {k: PARAMS[k] for k in ("blocks", "single_a", "single_b", "ragged")}

    def run_mode(fused, external, steps=7):
        lt = combinators.lowrank(
            combinators.layerwise_unbias(combinators.scale_by_muon(beta=0.9),
                                         gamma=1),
            rank=4, period=3, seed=5, reset_on_refresh=True,
            external_refresh=external, fuse_families=fused,
        )
        t = combinators.chain(lt, combinators.scale_by_lr(1e-2))
        st = t.init(matrices)
        p = matrices
        for _ in range(steps):
            g = jax.grad(quad_loss)(p)
            if external:
                st = (lt.update.refresh(g, st[0], p),) + tuple(st[1:])
            u, st = t.update(g, st, p)
            p = apply_updates(p, u)
        return p

    base = run_mode(False, False)
    for fused, external in [(True, False), (True, True), (False, True)]:
        _assert_trees(base, run_mode(fused, external), bitexact=True,
                      name=f"fused={fused} external={external}")


def test_factory_threads_fusion_knobs():
    for name in ("gum", "galore", "galore_muon", "fira", "unbiased_galore_adam"):
        cfg = OptimizerConfig(name=name, rank=4, period=3,
                              fuse_families=True, fused_epilogue=True)
        opt = build_optimizer(cfg)
        p, losses = run_traj(opt, steps=4)
        assert losses[-1] < losses[0], name


# ------------------------------------------------------------- family plan


def test_family_plan_groups_by_signature():
    leaves = [PARAMS["blocks"]["wq"], PARAMS["blocks"]["wk"],
              PARAMS["blocks"]["w_out"], None, PARAMS["single_a"],
              PARAMS["single_b"], PARAMS["ragged"]]
    plan = build_family_plan(leaves, rank=4)
    sizes = sorted((fam.seg.members, fam.seg.member_L, fam.fs.L)
                   for fam in plan.families)
    # (3,16,24)x2 -> M=2,L_mem=3 ; (3,24,16) -> M=1,L_mem=3 ;
    # (16,24)x2 -> M=2,L_mem=1 ; (20,9) -> M=1,L_mem=1
    assert sizes == [(1, 1, 1), (1, 3, 3), (2, 1, 2), (2, 3, 6)]
    # member indices partition the non-None leaves
    members = sorted(i for fam in plan.families for i in fam.members)
    assert members == [0, 1, 2, 4, 5, 6]


def test_launch_count_scales_with_families_not_leaves():
    """The dispatch-launch count of a fused step depends on the number of
    shape families; adding more leaves to an existing family must not add
    launches (the per-leaf path adds ~3 per leaf)."""

    def launches(params, fused):
        opt = core.galore(1e-2, rank=4, period=3, base="muon",
                          fuse_families=fused)
        st = opt.init(params)
        g = jax.tree_util.tree_map(lambda p: 0.01 * jnp.ones_like(p), params)
        with launch_count.count_launches() as counts:
            opt.update(g, st, params)
        return sum(counts.values())

    two_leaves = {"a": _rand(0, (16, 24)), "b": _rand(1, (16, 24))}
    six_leaves = {f"l{i}": _rand(i, (16, 24)) for i in range(6)}
    assert launches(six_leaves, True) == launches(two_leaves, True)
    assert launches(six_leaves, False) > launches(six_leaves, True)


# ------------------------------------------------------------------- rsvd


def test_rsvd_projector_property_one():
    """rsvd returns orthonormal columns (Property I) at every shape."""
    for i, shape in enumerate([(16, 24), (64, 16), (20, 9)]):
        g = _rand(40 + i, shape, scale=1.0)
        p = core.rsvd_projector(g, 4, jax.random.fold_in(KEY, 50 + i))
        assert p.shape == (shape[0], 4)
        np.testing.assert_allclose(np.asarray(p.T @ p), np.eye(4),
                                   atol=1e-5)


def test_rsvd_batched_matches_single():
    """compute_projectors('rsvd') over a stacked family == per-block calls
    modulo the batched draw layout (same key => same sketch)."""
    g = _rand(60, (3, 16, 24), scale=1.0)
    key = jax.random.fold_in(KEY, 61)
    p = compute_projectors("rsvd", g, 4, key, "left")
    assert p.shape == (3, 16, 4)
    for l in range(3):
        blk = np.asarray(p[l])
        np.testing.assert_allclose(blk.T @ blk, np.eye(4), atol=1e-5)


def test_rsvd_captures_dominant_range():
    """On a low-rank-plus-noise gradient, rsvd's subspace captures (nearly)
    the same energy as the exact SVD projector."""
    u = jnp.linalg.qr(_rand(70, (32, 4), scale=1.0))[0]
    v = jnp.linalg.qr(_rand(71, (24, 4), scale=1.0))[0]
    g = u @ jnp.diag(jnp.array([10.0, 8.0, 6.0, 4.0])) @ v.T \
        + 0.01 * _rand(72, (32, 24), scale=1.0)
    p_svd = core.svd_projector(g, 4)
    p_rsvd = core.rsvd_projector(g, 4, jax.random.fold_in(KEY, 73))
    energy = lambda p: float(jnp.linalg.norm(p.T @ g))
    assert energy(p_rsvd) > 0.95 * energy(p_svd)


def test_rsvd_in_lowrank_fused_bitexact():
    """projector='rsvd' end-to-end, fused vs per-leaf, bit-exact."""
    mk = lambda **kw: core.gum(1e-2, rank=4, gamma=1, period=3, seed=5,
                               projector="rsvd", **kw)
    p_leaf, _ = run_traj(mk(), steps=6)
    p_fuse, _ = run_traj(mk(fuse_families=True), steps=6)
    _assert_trees(p_leaf, p_fuse, bitexact=True, name="rsvd")
    cfg = OptimizerConfig(name="gum", rank=4, period=3, projector="rsvd")
    _, losses = run_traj(build_optimizer(cfg), steps=4)
    assert losses[-1] < losses[0]


# -------------------------------------------------------- epilogue dispatch


def test_back_project_epilogue_registry_and_parity():
    entry = dispatch.get_kernel("back_project_epilogue")
    p = _rand(80, (20, 5), scale=1.0)     # ragged on purpose
    s = _rand(81, (5, 9), scale=1.0)
    w = _rand(82, (20, 9), scale=1.0)
    want = np.asarray(entry.reference(p, s, w, -0.5, 0.25))
    for impl in ("jnp", "interpret"):
        got = dispatch.back_project_epilogue(
            p, s, w=w, scale=jnp.float32(-0.5), decay=jnp.float32(0.25),
            side="left", impl=impl)
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-5,
                                   err_msg=impl)
    # right side + no-W form + batched lead
    p2 = _rand(83, (2, 9, 5), scale=1.0)
    s2 = _rand(84, (2, 20, 5), scale=1.0)
    want2 = np.asarray(2.0 * jnp.einsum("lmr,lnr->lmn", s2, p2))
    for impl in ("jnp", "interpret"):
        got2 = dispatch.back_project_epilogue(
            p2, s2, scale=2.0, side="right", impl=impl)
        np.testing.assert_allclose(np.asarray(got2), want2, atol=1e-5,
                                   err_msg=impl)


def test_pending_back_survives_chain_without_lr():
    """A chain that ends before scale_by_lr leaves PendingBack leaves;
    apply_updates materializes them (ungrouped fallback)."""
    matrices = {"a": PARAMS["single_a"], "b": PARAMS["single_b"]}
    t = combinators.chain(
        combinators.lowrank(combinators.scale_by_momentum(beta=0.9),
                            rank=4, period=3, fuse_families=True,
                            fused_epilogue=True),
        combinators.add_decayed_weights(0.01),
    )
    st = t.init(matrices)
    g = jax.tree_util.tree_map(lambda p: 0.01 * jnp.ones_like(p), matrices)
    u, st = t.update(g, st, matrices)
    assert any(isinstance(x, core.PendingBack)
               for x in jax.tree_util.tree_leaves(
                   u, is_leaf=lambda x: isinstance(x, core.PendingBack)))
    p2 = apply_updates(matrices, u)
    for a, b in zip(jax.tree_util.tree_leaves(matrices),
                    jax.tree_util.tree_leaves(p2)):
        assert a.shape == b.shape
        assert not bool(jnp.array_equal(a, b))


def test_gum_accum_tools_fused_layout():
    """gum_accum_tools speaks the family-plan state layout: under
    fuse_families the compact project/reconstruct hooks unstack each
    family's projector and shift its global idx back to member-local block
    ids, so (a) the projected-accumulation roundtrip stays update-equivalent
    and (b) the compact trees match the per-leaf layout's bit-for-bit (the
    fused refresh preserves per-member PRNG exactly)."""
    params = {k: PARAMS[k] for k in ("blocks", "single_a", "ragged",
                                     "norm_scale")}
    g = jax.tree_util.tree_map(lambda p: 0.7 * p + 0.01, params)

    compacts = []
    for fuse in (False, True):
        tools = core.gum_accum_tools(1e-2, rank=4, gamma=1, period=2,
                                     projector="svd", kernel_impl="jnp",
                                     fuse_families=fuse)
        st = tools.transform.init(params)
        st = tools.refresh(g, st, params)
        u1, _ = tools.transform.update(g, st, params)
        ghat = tools.reconstruct(tools.project(g, st, params), st, params)
        u2, _ = tools.transform.update(ghat, st, params)
        for a, b in zip(jax.tree_util.tree_leaves(u1),
                        jax.tree_util.tree_leaves(u2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, err_msg=f"fuse={fuse}")
        compacts.append(tools.project(g, st, params))
    for a, b in zip(jax.tree_util.tree_leaves(compacts[0]),
                    jax.tree_util.tree_leaves(compacts[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
