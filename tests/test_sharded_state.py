"""PR-9 acceptance: ZeRO-style sharded projected state (``shard_state``).

Three subprocess suites (host forced to N CPU devices each):

  * equivalence — the fused gum / galore_muon step with the family-stacked
    optimizer state sharded over the data axis produces the SAME trajectory
    as the replicated-state step on the same mesh, through a projector
    refresh boundary, on meshes 1 / 2 / 8.  The boundary all_gather hands
    ``_stacked_projectors`` the identical full gradient (and keys), so the
    sharded refresh is mathematically the replicated refresh.
  * resume — a mesh run with ``shard_state=True`` checkpoints host-gathered
    full arrays; resuming re-applies the re-derived shardings and the
    retrained segment (crossing a refresh boundary) is bit-exact against
    the uninterrupted run's checkpoint.
  * migration — a spectral rank-policy migration under ``shard_state``
    re-derives and re-applies the optimizer-state sharding (the controller's
    ``reshard`` hook); the sharded and replicated runs migrate identically
    and keep matching losses.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout: int = 600):
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO, timeout=timeout,
    )


EQUIV_SCRIPT = """
from repro.launch.devices import force_host_device_count
force_host_device_count(8)
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.core import OptimizerConfig, build_optimizer
from repro.launch.shardmap_fsdp import make_shardmap_train_step
from repro.models import build_model

cfg = get_smoke("llama-60m")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
batch = {"tokens": tokens}
copy = lambda t: jax.tree_util.tree_map(jnp.copy, t)

def run(opt_name, n, shard_state, steps=7):
    opt = build_optimizer(OptimizerConfig(
        name=opt_name, lr=1e-2, rank=4, gamma=1, period=3, projector="svd",
        fuse_families=True))
    mesh = jax.make_mesh((n,), ("data",), devices=jax.devices()[:n])
    _, jit_builder = make_shardmap_train_step(
        model, opt, mesh, grad_clip=1.0, shard_state=shard_state)
    p, s = copy(params), opt.init(copy(params))
    jitted = jit_builder(p, s)
    losses = []
    for _ in range(steps):  # period=3 -> crosses refresh boundaries
        p, s, m = jitted(p, s, batch)
        losses.append(float(m["loss"]))
    return jax.device_get(p), losses

for name in ("gum", "galore_muon"):
    for n in (1, 2, 8):
        sp, sl = run(name, n, True)
        rp, rl = run(name, n, False)
        # Same mesh, same gathered gradient, same keys: sharding the state
        # must not change the math.  bf16 enters only through the (shared)
        # wire psum, so the two trajectories track to fp32 round-off.
        np.testing.assert_allclose(sl, rl, rtol=1e-5, atol=1e-6,
                                   err_msg=f"{name} mesh={n} losses")
        for a, b in zip(jax.tree_util.tree_leaves(sp),
                        jax.tree_util.tree_leaves(rp)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-4, atol=1e-5, err_msg=f"{name} mesh={n} params")
        print(f"EQUIV {name} mesh={n} ok last_loss={sl[-1]:.4f}")
print("ZERO_EQUIV_OK")
"""


@pytest.mark.slow
def test_sharded_state_matches_replicated_trajectory():
    r = _run(EQUIV_SCRIPT)
    assert "ZERO_EQUIV_OK" in r.stdout, r.stdout[-3000:] + r.stderr[-4000:]


RESUME_SCRIPT = """
from repro.launch.devices import force_host_device_count
force_host_device_count(4)
import os, shutil
import numpy as np
import jax
from repro.configs import RunConfig, get_smoke
from repro.core import OptimizerConfig
from repro.data import DataConfig
from repro.models import build_model
from repro.train import Trainer

cfg = get_smoke("llama-60m")
model = build_model(cfg)
opt_cfg = OptimizerConfig(name="gum", lr=1e-2, rank=4, gamma=1, period=3,
                          projector="svd", fuse_families=True,
                          shard_state=True)
data_cfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8,
                      num_hosts=1, host_id=0)
mesh = jax.make_mesh((4,), ("data",))
CKPT = "/tmp/repro_ckpt_zero_resume"
shutil.rmtree(CKPT, ignore_errors=True)
run_cfg = RunConfig(steps=6, ckpt_dir=CKPT, resume=True, ckpt_every=3,
                    log_every=0)

r1 = Trainer(model, opt_cfg, run_cfg, data_cfg, mesh=mesh).train()
assert r1.resumed_from is None

# keep the uninterrupted step-6 checkpoint aside, delete it, and resume
# from step 3 — the retrained segment crosses the refresh boundary at
# step 3 (period=3), i.e. the restored SHARDED state feeds the boundary
# all_gather refresh immediately.
d6 = os.path.join(CKPT, "step_%09d" % 6)
ref = d6 + ".ref"
shutil.copytree(d6, ref)
shutil.rmtree(d6)

r2 = Trainer(model, opt_cfg, run_cfg, data_cfg, mesh=mesh).train()
assert r2.resumed_from == 3, r2.resumed_from

for fn in sorted(os.listdir(ref)):
    if not fn.endswith(".npy"):
        continue
    a = np.load(os.path.join(ref, fn))
    b = np.load(os.path.join(d6, fn))
    assert a.dtype == b.dtype and a.shape == b.shape, fn
    assert np.array_equal(a, b, equal_nan=True), f"leaf {fn} not bit-exact"
print("ZERO_RESUME_BITEXACT_OK")
"""


@pytest.mark.slow
def test_sharded_resume_is_bit_exact():
    r = _run(RESUME_SCRIPT)
    assert "ZERO_RESUME_BITEXACT_OK" in r.stdout, (
        r.stdout[-3000:] + r.stderr[-4000:])


MIGRATION_SCRIPT = """
from repro.launch.devices import force_host_device_count
force_host_device_count(2)
import shutil
import numpy as np
import jax
from repro.configs import RunConfig, get_smoke
from repro.core import OptimizerConfig
from repro.data import DataConfig
from repro.models import build_model
from repro.train import Trainer

cfg = get_smoke("llama-60m")
model = build_model(cfg)
data_cfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8,
                      num_hosts=1, host_id=0)
mesh = jax.make_mesh((2,), ("data",))

def run(shard_state, tag):
    ckpt = f"/tmp/repro_ckpt_zero_mig_{tag}"
    shutil.rmtree(ckpt, ignore_errors=True)
    opt_cfg = OptimizerConfig(
        name="gum", lr=1e-2, rank=8, gamma=1, period=3, projector="svd",
        fuse_families=True, shard_state=shard_state,
        rank_policy="spectral:0.3", rank_ladder=(2, 4, 8))
    run_cfg = RunConfig(steps=9, ckpt_dir=ckpt, resume=False, ckpt_every=0,
                        log_every=0)
    t = Trainer(model, opt_cfg, run_cfg, data_cfg, mesh=mesh)
    m0 = t.rank_ctrl.current_map
    res = t.train()
    return m0, t.rank_ctrl.current_map, res.losses

m0s, m1s, ls = run(True, "sharded")
m0r, m1r, lr_ = run(False, "replicated")
assert m1s != m0s, f"spectral policy never migrated: {m0s} -> {m1s}"
assert m1s == m1r, f"sharded migrated to {m1s}, replicated to {m1r}"
np.testing.assert_allclose(ls, lr_, rtol=1e-5, atol=1e-6)
print("ZERO_MIGRATION_OK", m0s, "->", m1s)
"""


@pytest.mark.slow
def test_spectral_migration_under_sharded_state():
    """A spectral rank migration under ``shard_state`` goes through the
    controller's ``reshard`` hook (re-derive + re-apply opt_state_sharding
    on the migrated state) and keeps tracking the replicated run."""
    r = _run(MIGRATION_SCRIPT)
    assert "ZERO_MIGRATION_OK" in r.stdout, (
        r.stdout[-3000:] + r.stderr[-4000:])
