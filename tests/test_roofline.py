"""HLO roofline analyzer: trip-count handling, dot flops, collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (
    analyze_hlo,
    model_flops,
    parse_hlo,
    roofline_from_text,
    shape_bytes,
    xla_cost_dict,
)


def test_shape_bytes():
    assert shape_bytes("bf16[8,4]{1,0}") == 64
    assert shape_bytes("f32[2,3] f32[10]") == 64
    assert shape_bytes("(f32[4], s32[2])") == 24
    assert shape_bytes("pred[]") == 1


def test_scan_trip_count_flops():
    def scanned(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jnp.ones((64, 128))
    w = jnp.ones((16, 128, 128))
    txt = jax.jit(scanned).lower(x, w).compile().as_text()
    rc = analyze_hlo(txt)
    analytic = 16 * 2 * 64 * 128 * 128
    assert abs(rc.flops - analytic) / analytic < 0.01, rc.flops


def test_unrolled_matches_xla_cost_analysis():
    def f(x, w):
        for i in range(4):
            x = jnp.tanh(x @ w[i])
        return x

    x = jnp.ones((32, 64))
    w = jnp.ones((4, 64, 64))
    comp = jax.jit(f).lower(x, w).compile()
    rc = analyze_hlo(comp.as_text())
    xla = xla_cost_dict(comp)["flops"]
    assert abs(rc.flops - xla) / xla < 0.05, (rc.flops, xla)


def test_roofline_report_bottleneck():
    rep = roofline_from_text("", model_flops_per_device=0)
    assert rep.flops == 0
    txt = """
ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  ROOT %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    rep = roofline_from_text(txt)
    assert rep.flops == 2 * 8 * 8 * 8
    assert rep.bottleneck == "memory"  # tiny dot is bandwidth-bound


def test_model_flops_dense_vs_moe():
    from repro.configs import get_config, get_shape

    dense = model_flops(get_config("qwen1.5-4b"), get_shape("train_4k"))
    # ~6 * 4B * 1M tokens ~ 2.4e16 within 2x
    assert 1e16 < dense < 6e16, dense
    moe_active = model_flops(get_config("llama4-maverick-400b-a17b"), get_shape("train_4k"))
    # active params (~17B) not total (400B): 6*17e9*1e6 ~ 1e17
    assert 4e16 < moe_active < 3e17, moe_active
