"""Theory tests: Lemma 1/2 (unbiasedness), Property I (orthonormal
projectors), Property II (projection/Newton-Schulz commutativity)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    apply_updates,
    make_projector,
    msign_exact,
    newton_schulz,
    sgdm,
    unbiased_lowrank,
)
from repro.core.lowrank_common import back_project, project

KEY = jax.random.PRNGKey(0)
PROJECTORS = ["svd", "subspace", "random", "grass"]


# ---------------------------------------------------------------- Property I


@pytest.mark.parametrize("kind", PROJECTORS)
@pytest.mark.parametrize("shape,rank", [((8, 12), 3), ((16, 6), 4), ((32, 32), 8)])
def test_property_i_orthonormal_columns(kind, shape, rank):
    g = jax.random.normal(KEY, shape)
    p = make_projector(kind, g, rank, jax.random.PRNGKey(1))
    np.testing.assert_allclose(p.T @ p, np.eye(rank), atol=1e-5)


def test_svd_projector_captures_top_subspace():
    # low-rank signal + tiny noise: svd and subspace projectors must capture it
    u = jnp.linalg.qr(jax.random.normal(KEY, (32, 4)))[0]
    v = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    g = u @ (jnp.diag(jnp.array([10.0, 8.0, 6.0, 4.0])) @ v[:4]) \
        + 1e-3 * jax.random.normal(jax.random.PRNGKey(2), (32, 64))
    for kind in ("svd", "subspace"):
        p = make_projector(kind, g, 4, jax.random.PRNGKey(3))
        # energy captured: ||PPᵀG|| / ||G|| ~ 1
        cap = jnp.linalg.norm(p @ (p.T @ g)) / jnp.linalg.norm(g)
        assert cap > 0.999, (kind, float(cap))


# ---------------------------------------------------------------- Property II


def test_property_ii_newton_schulz_commutes():
    p = jnp.linalg.qr(jax.random.normal(KEY, (24, 6)))[0]
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 16))
    left = newton_schulz(p @ x)
    right = p @ newton_schulz(x)
    np.testing.assert_allclose(left, right, atol=2e-4, rtol=2e-4)


def test_property_ii_rank_preserved():
    """NS is a matrix polynomial: zero singular values stay zero, so NS(P X)
    lies entirely in span(P) (unlike SVD-based UVᵀ, which is arbitrary on the
    null space — that's why Property II is stated for Newton–Schulz)."""
    p = jnp.linalg.qr(jax.random.normal(KEY, (20, 5)))[0]
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 12))
    out = newton_schulz(p @ x)
    # component orthogonal to span(P) must vanish
    resid = out - p @ (p.T @ out)
    assert float(jnp.linalg.norm(resid)) < 1e-4 * float(jnp.linalg.norm(out))


def test_newton_schulz_approximates_msign():
    x = jax.random.normal(KEY, (12, 20))
    ns = newton_schulz(x)
    ex = msign_exact(x)
    # quintic NS oscillates around 1 by design; direction must match closely
    assert jnp.linalg.norm(ns - ex) / jnp.linalg.norm(ex) < 0.2
    # singular values of the NS output near 1
    s = jnp.linalg.svd(ns.astype(jnp.float32), compute_uv=False)
    assert float(jnp.max(jnp.abs(s - 1.0))) < 0.35


# ---------------------------------------------------------------- Lemma 2


@pytest.mark.parametrize("q", [0.25, 0.5, 0.75])
@pytest.mark.parametrize("comp", ["paper", "finetune"])
def test_estimator_identity_exact(q, comp):
    """E[G_hat] = G is a deterministic two-branch identity given P."""
    g = jax.random.normal(KEY, (10, 14))
    p = make_projector("svd", g + jax.random.normal(jax.random.PRNGKey(1), g.shape), 4,
                       jax.random.PRNGKey(2))
    pptg = p @ (p.T @ g)
    if comp == "paper":
        full = (g - pptg) / q
        low = pptg / (1 - q)
    else:
        full = (g - (1 - q) * pptg) / q
        low = pptg
    expectation = q * full + (1 - q) * low
    np.testing.assert_allclose(expectation, g, atol=1e-5)


def test_lemma1_monte_carlo_unbiased():
    """Through the actual optimizer (sgdm base, beta=0, period=1, lr=1):
    the mean update over seeds approximates -G."""
    g_fixed = jax.random.normal(KEY, (6, 9))
    params = {"w": jnp.zeros((6, 9))}
    total = np.zeros((6, 9))
    n = 400
    for seed in range(n):
        opt = unbiased_lowrank(1.0, rank=2, q=0.5, period=1, projector="svd",
                               base="sgdm", beta=0.0, seed=seed)
        st = opt.init(params)
        upd, _ = opt.update({"w": g_fixed}, st, params)
        total += np.asarray(upd["w"])
    mean_update = total / n
    # -lr * G_hat averaged ~ -G; MC error ~ sigma/sqrt(n)
    err = np.linalg.norm(mean_update + np.asarray(g_fixed)) / np.linalg.norm(g_fixed)
    assert err < 0.15, err


def test_unbiased_optimizer_descends_quadratic():
    # Muon moves every singular direction at rate ~lr per step (msign has
    # unit singular values), so give it enough steps to cover ||w0||.
    opt = unbiased_lowrank(0.15, rank=2, q=0.5, period=5, base="muon")
    params = {"w": jax.random.normal(KEY, (8, 10))}
    st = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda p: 0.5 * jnp.sum(p["w"] ** 2))(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    l0 = float(jnp.sum(params["w"] ** 2))
    for _ in range(120):
        params, st = step(params, st)
    l1 = float(jnp.sum(params["w"] ** 2))
    assert l1 < 0.2 * l0


# ------------------------------------------------- project/back_project algebra


def test_projection_roundtrip_left_right():
    g = jax.random.normal(KEY, (1, 12, 8))  # right projection (m > n)
    p = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (1, 8, 3)))[0]
    r = project(p, g, "right")
    assert r.shape == (1, 12, 3)
    gg = back_project(p, r, "right")
    assert gg.shape == g.shape
    # idempotence of the projection operator
    r2 = project(p, gg, "right")
    np.testing.assert_allclose(r, r2, atol=1e-5)
