"""shard_map manual-FSDP step: bf16 wire reduction, fp32 accumulate —
matches the pjit step to bf16-rounding tolerance, and the HLO really carries
bf16 collectives (the §Perf finding GSPMD could not express)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = """
from repro.launch.devices import force_host_device_count
force_host_device_count(8)  # shared helper: preserves other XLA_FLAGS
import re
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.core import OptimizerConfig, build_optimizer
from repro.launch.shardmap_fsdp import make_shardmap_train_step
from repro.launch.steps import make_train_step
from repro.models import build_model

cfg = get_smoke("llama-60m")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = build_optimizer(OptimizerConfig(name="gum", lr=1e-2, rank=4, gamma=1, period=3, projector="svd"))
st = opt.init(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
batch = {"tokens": tokens}

mesh = jax.make_mesh((8,), ("data",))
step_fn, jit_builder = make_shardmap_train_step(model, opt, mesh, grad_clip=1.0)
jitted = jit_builder(params, st)

# 1) the emitted program carries bf16 wire collectives.  Assert at the
# StableHLO level: XLA:CPU legalizes bf16 all-reduce by upconverting (no
# native bf16 reduction on CPU); the TPU backend reduces bf16 natively.
txt = jitted.lower(params, st, batch).as_text()
bf16_colls = re.findall(r"all_reduce.*?tensor<[0-9x]*xbf16>", txt, re.S)
assert len(bf16_colls) > 0, "expected bf16 all_reduce in StableHLO"

# 2) matches the plain pjit step numerically (bf16 rounding tolerance).
# Use AdamW for the equivalence check — Newton-Schulz's msign direction
# amplifies bf16 grad rounding, AdamW is Lipschitz in the gradient.
# jitted steps donate inputs -> give each call its own copies.
copy = lambda t: jax.tree_util.tree_map(jnp.copy, t)
aopt = build_optimizer(OptimizerConfig(name="adamw", lr=1e-2))
ast = aopt.init(params)
_, a_jit_builder = make_shardmap_train_step(model, aopt, mesh, grad_clip=1.0)
a_jitted = a_jit_builder(params, ast)
p1, s1, m1 = a_jitted(copy(params), copy(ast), batch)
plain = jax.jit(make_train_step(model, aopt, grad_clip=1.0))
p2, s2, m2 = plain(copy(params), aopt.init(copy(params)), batch)
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-2)
# atol 2.5e-2 = 2*lr: a bf16-rounded near-zero grad can flip Adam's step-1
# sign (mhat/sqrt(vhat) ~ sign(g)), moving a weight by up to 2*lr.
for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               atol=2.5e-2, rtol=5e-2)

# 3) trains: loss decreases over steps
p, s = copy(params), opt.init(copy(params))
losses = []
for i in range(6):
    p, s, m = jitted(p, s, batch)
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
print("SHARDMAP_FSDP_OK", len(bf16_colls))
"""


@pytest.mark.slow
def test_shardmap_fsdp_bf16_reduction():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO, timeout=600,
    )
    assert "SHARDMAP_FSDP_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]
