"""Frozen-monolith equivalence baselines as committed fixtures.

``tests/test_combinators.py`` proves the combinator chains match
``repro.core.legacy`` by running both *live*.  That guard dies with
``legacy.py`` — and legacy is scheduled to be deleted once nothing imports
it.  This module freezes the monoliths' trajectories (per-step quadratic
losses + final param norm, jnp path, 8 steps on the shared routing tree)
into ``tests/data/legacy_trajectories.json`` and asserts:

  1. the combinator-built optimizers reproduce the *recorded* trajectories
     (the guard that survives legacy's deletion), and
  2. while legacy still exists, it matches its own recording (fixture
     staleness check).

Regenerate after a deliberate trajectory change::

    PYTHONPATH=src python tests/test_legacy_fixtures.py --regen
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import apply_updates, global_norm, legacy

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "data", "legacy_trajectories.json")
KEY = jax.random.PRNGKey(0)
STEPS = 8

PARAMS = {
    "blocks": {
        "wq": jax.random.normal(KEY, (3, 16, 24)) * 0.1,
        "w_out": jax.random.normal(jax.random.fold_in(KEY, 1), (3, 24, 16)) * 0.1,
    },
    "embed": jax.random.normal(jax.random.fold_in(KEY, 2), (64, 16)) * 0.1,
    "norm_scale": jnp.ones((16,)),
}


def builder_specs():
    """(name, core builder, legacy builder) — the PR-2 equivalence matrix,
    jnp path (the legacy monoliths' only fully shared impl)."""
    kw = dict(kernel_impl="jnp")
    return [
        ("gum",
         lambda: core.gum(1e-2, rank=4, gamma=1, period=3, seed=5,
                          weight_decay=0.01, **kw),
         lambda: legacy.gum(1e-2, rank=4, gamma=1, period=3, seed=5,
                            weight_decay=0.01, **kw)),
        ("gum_finetune_sgdm",
         lambda: core.gum(1e-2, rank=4, gamma=1, period=3, seed=7,
                          base="sgdm", compensation="finetune", **kw),
         lambda: legacy.gum(1e-2, rank=4, gamma=1, period=3, seed=7,
                            base="sgdm", compensation="finetune", **kw)),
        ("galore",
         lambda: core.galore(1e-2, rank=4, period=3, **kw),
         lambda: legacy.galore(1e-2, rank=4, period=3, **kw)),
        ("galore_muon",
         lambda: core.galore(1e-2, rank=4, period=3, base="muon",
                             weight_decay=0.01, **kw),
         lambda: legacy.galore(1e-2, rank=4, period=3, base="muon",
                               weight_decay=0.01, **kw)),
        ("golore",
         lambda: core.golore(1e-2, rank=4, period=3, seed=2, **kw),
         lambda: legacy.golore(1e-2, rank=4, period=3, seed=2, **kw)),
        ("fira",
         lambda: core.fira(1e-2, rank=4, period=3, **kw),
         lambda: legacy.fira(1e-2, rank=4, period=3, **kw)),
        ("muon",
         lambda: core.muon(1e-2, weight_decay=0.01, **kw),
         lambda: legacy.muon(1e-2, weight_decay=0.01, **kw)),
    ]


def quad_loss(p):
    return 0.5 * sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(p))


def run_traj(opt, steps=STEPS):
    st = opt.init(PARAMS)
    p = PARAMS
    losses = []
    for _ in range(steps):
        g = jax.grad(quad_loss)(p)
        u, st = opt.update(g, st, p)
        p = apply_updates(p, u)
        losses.append(float(quad_loss(p)))
    return losses, float(global_norm(p))


def _load():
    with open(FIXTURE) as f:
        return json.load(f)


NAMES = [name for name, _, _ in builder_specs()]


@pytest.mark.parametrize("idx", range(len(NAMES)), ids=NAMES)
def test_core_matches_recorded_legacy(idx):
    """Combinator chains reproduce the frozen monolith trajectories — the
    equivalence guard that outlives core/legacy.py itself."""
    name, build_core, _ = builder_specs()[idx]
    rec = _load()[name]
    losses, pnorm = run_traj(build_core())
    np.testing.assert_allclose(losses, rec["losses"], rtol=1e-5,
                               err_msg=name)
    np.testing.assert_allclose(pnorm, rec["final_param_norm"], rtol=1e-5,
                               err_msg=name)


@pytest.mark.parametrize("idx", range(len(NAMES)), ids=NAMES)
def test_legacy_matches_its_recording(idx):
    """While the monoliths still exist, they must agree with their own
    fixture — catches silent edits to legacy.py or a stale recording."""
    name, _, build_legacy = builder_specs()[idx]
    rec = _load()[name]
    losses, pnorm = run_traj(build_legacy())
    np.testing.assert_allclose(losses, rec["losses"], rtol=1e-5,
                               err_msg=name)
    np.testing.assert_allclose(pnorm, rec["final_param_norm"], rtol=1e-5,
                               err_msg=name)


def _regen():
    out = {}
    for name, _, build_legacy in builder_specs():
        losses, pnorm = run_traj(build_legacy())
        out[name] = {"losses": losses, "final_param_norm": pnorm,
                     "steps": STEPS, "impl": "jnp"}
        print(f"{name}: final loss {losses[-1]:.6f}")
    os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
    with open(FIXTURE, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {FIXTURE}")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
