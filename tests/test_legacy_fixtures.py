"""Frozen-monolith equivalence baselines as committed fixtures.

The pre-redesign monoliths (``repro.core.legacy``) were deleted in PR 7
after the soak the ROADMAP scheduled.  Their trajectories (per-step
quadratic losses + final param norm, jnp path, 8 steps on the shared
routing tree) live on in ``tests/data/legacy_trajectories.json``, recorded
while the monoliths were still importable.  This module asserts the
combinator-built optimizers reproduce those recorded trajectories — the
equivalence guard that outlives ``legacy.py`` itself.

The fixture is frozen history: regenerating it from the live builders
(``--regen``) re-baselines after a *deliberate* trajectory change and
forfeits the link back to the monoliths, so do it only with a reviewed
diff of the JSON::

    PYTHONPATH=src python tests/test_legacy_fixtures.py --regen
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import apply_updates, global_norm

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "data", "legacy_trajectories.json")
KEY = jax.random.PRNGKey(0)
STEPS = 8

PARAMS = {
    "blocks": {
        "wq": jax.random.normal(KEY, (3, 16, 24)) * 0.1,
        "w_out": jax.random.normal(jax.random.fold_in(KEY, 1), (3, 24, 16)) * 0.1,
    },
    "embed": jax.random.normal(jax.random.fold_in(KEY, 2), (64, 16)) * 0.1,
    "norm_scale": jnp.ones((16,)),
}


def builder_specs():
    """(name, core builder) — the PR-2 equivalence matrix, jnp path (the
    only impl the deleted monoliths fully shared)."""
    kw = dict(kernel_impl="jnp")
    return [
        ("gum",
         lambda: core.gum(1e-2, rank=4, gamma=1, period=3, seed=5,
                          weight_decay=0.01, **kw)),
        ("gum_finetune_sgdm",
         lambda: core.gum(1e-2, rank=4, gamma=1, period=3, seed=7,
                          base="sgdm", compensation="finetune", **kw)),
        ("galore",
         lambda: core.galore(1e-2, rank=4, period=3, **kw)),
        ("galore_muon",
         lambda: core.galore(1e-2, rank=4, period=3, base="muon",
                             weight_decay=0.01, **kw)),
        ("golore",
         lambda: core.golore(1e-2, rank=4, period=3, seed=2, **kw)),
        ("fira",
         lambda: core.fira(1e-2, rank=4, period=3, **kw)),
        ("muon",
         lambda: core.muon(1e-2, weight_decay=0.01, **kw)),
    ]


def quad_loss(p):
    return 0.5 * sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(p))


def run_traj(opt, steps=STEPS):
    st = opt.init(PARAMS)
    p = PARAMS
    losses = []
    for _ in range(steps):
        g = jax.grad(quad_loss)(p)
        u, st = opt.update(g, st, p)
        p = apply_updates(p, u)
        losses.append(float(quad_loss(p)))
    return losses, float(global_norm(p))


def _load():
    with open(FIXTURE) as f:
        return json.load(f)


NAMES = [name for name, _ in builder_specs()]


@pytest.mark.parametrize("idx", range(len(NAMES)), ids=NAMES)
def test_core_matches_recorded_legacy(idx):
    """Combinator chains reproduce the frozen monolith trajectories — the
    equivalence guard that outlives core/legacy.py itself."""
    name, build_core = builder_specs()[idx]
    rec = _load()[name]
    losses, pnorm = run_traj(build_core())
    np.testing.assert_allclose(losses, rec["losses"], rtol=1e-5,
                               err_msg=name)
    np.testing.assert_allclose(pnorm, rec["final_param_norm"], rtol=1e-5,
                               err_msg=name)


def _regen():
    out = {}
    for name, build_core in builder_specs():
        losses, pnorm = run_traj(build_core())
        out[name] = {"losses": losses, "final_param_norm": pnorm,
                     "steps": STEPS, "impl": "jnp"}
        print(f"{name}: final loss {losses[-1]:.6f}")
    os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
    with open(FIXTURE, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {FIXTURE}")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
