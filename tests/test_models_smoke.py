"""Per-architecture smoke tests: reduced configs of all 10 assigned archs
(+ paper LLaMA): one forward + one train step on CPU, asserting output
shapes and no NaNs; decode-vs-forward consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.core import OptimizerConfig, apply_updates, build_optimizer
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
ASSIGNED = [a for a in ARCHS if a not in ("llama-60m", "llama-130m", "llama-350m")]


def make_inputs(cfg, B=2, S=32):
    kw = {}
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.family == "vlm":
        kw["images"] = jax.random.normal(KEY, (B, cfg.n_image_tokens, cfg.d_model)) * 0.02
    if cfg.frontend == "frames":
        kw["frames"] = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.02
    return tokens, kw


# The giant-config cells dominate this module's runtime (pure jit compile);
# they stay in the `slow` sweep, out of the tier-1 loop.  starcoder2/chatglm3
# are dense decoders whose code paths the qwen1.5 / llama cells already
# cover, and zamba2's hybrid glue sits on the mamba2 + attention paths both
# still in tier-1; dbrx (MoE), vision-11b (VLM) and hubert (audio) keep
# their families in the default selection.
_SLOW_ARCHS = {"nemotron-4-340b", "llama4-maverick-400b-a17b",
               "starcoder2-7b", "chatglm3-6b", "zamba2-1.2b"}


@pytest.mark.parametrize(
    "arch",
    [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
     for a in ASSIGNED + ["llama-60m"]],
)
def test_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 32
    tokens, kw = make_inputs(cfg, B, S)

    logits, aux, _ = model.forward(
        params, None if cfg.frontend == "frames" else tokens, **kw
    )
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    # one GUM train step
    opt = build_optimizer(OptimizerConfig(name="gum", lr=1e-3, rank=4,
                                          gamma=1, period=3, projector="svd"))
    st = opt.init(params)

    def loss_fn(p):
        lg, a, _ = model.forward(p, None if cfg.frontend == "frames" else tokens, **kw)
        return model.loss(lg, tokens, a, shift=not cfg.encoder_only)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    upd, st = opt.update(grads, st, params)
    new_params = apply_updates(params, upd)
    for x in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(x)))


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if get_smoke(a).has_decode])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full-sequence forward logits —
    the strongest cache-correctness check, per family."""
    cfg = get_smoke(arch)
    if cfg.family == "moe":
        # capacity drops differ between a 16-token prefill and a 2-token
        # decode step (different populations compete); make capacity
        # generous so the test isolates cache correctness from drop policy.
        cfg = cfg.replace(capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 8
    tokens, kw = make_inputs(cfg, B, S)

    full_logits, _, _ = model.forward(params, tokens, **kw)

    cache = model.init_cache(batch=B, max_seq=S, dtype=jnp.float32)
    if cfg.family == "vlm":
        # populate the image-KV cache as prefill would
        from repro.models import attention as attn_mod
        from repro.models.transformer import init_cache  # noqa: F401
        img = kw["images"]
        G = cfg.n_layers // cfg.cross_attn_every
        xks, xvs = [], []
        for gidx in range(G):
            bp = jax.tree_util.tree_map(lambda x: x[gidx], params["blocks"]["cross"])
            k, v = attn_mod.encode_cross_kv(bp["xattn"], img, cfg)
            xks.append(k)
            xvs.append(v)
        cache["xk"] = jnp.stack(xks).astype(cache["xk"].dtype)
        cache["xv"] = jnp.stack(xvs).astype(cache["xv"].dtype)

    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, cache=c, tokens=t, pos=pos))
    dec_logits = []
    for i in range(S):
        lg, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
        dec_logits.append(lg[:, 0])
    dec_logits = jnp.stack(dec_logits, axis=1)

    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), atol=2e-2, rtol=2e-2
    )


def test_moe_capacity_dispatch_matches_dense_oracle():
    """Top-1 MoE with generous capacity == explicit per-token expert mlp."""
    from repro.models import moe as moe_mod

    cfg = get_smoke("llama4-maverick-400b-a17b").replace(
        n_experts=4, top_k=1, capacity_factor=4.0, n_shared_experts=0
    )
    p = moe_mod.init_moe(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.5
    out, aux = moe_mod.apply_moe(p, x, cfg)
    assert float(aux) >= 1.0 - 1e-5  # load-balance aux lower bound is 1

    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    eidx = jnp.argmax(probs, -1)
    from repro.models.layers import mlp_act
    want = []
    for t in range(xt.shape[0]):
        e = int(eidx[t])
        h = xt[t] @ p["experts_w_in"][e]
        g = xt[t] @ p["experts_w_gate"][e] if "experts_w_gate" in p else None
        h = mlp_act(h, g, cfg.act)
        w = jnp.max(probs[t])  # renormalized top-1 weight == max prob / itself
        want.append((h @ p["experts_w_out"][e]) * 1.0)
    want = jnp.stack(want).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


def test_param_count_analytic_close_to_actual():
    from repro.launch.roofline import count_params

    for arch in ["qwen1.5-4b", "dbrx-132b", "mamba2-370m"]:
        cfg = get_smoke(arch)
        model = build_model(cfg)
        params = model.init(KEY)
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        est = count_params(cfg)
        assert abs(est - actual) / actual < 0.15, (arch, est, actual)
