"""Correctness of the §Perf optimization paths: chunked attention and
sequence-chunked cross-entropy must be numerically identical to the plain
implementations (these get flipped on for the hillclimbed cells).
Deterministic parametrize grids (stdlib + pytest only; the seed's hypothesis
dependency is not in the CI image)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.kernels import ref
from repro.models import build_model
from repro.models.transformer import chunked_lm_loss, forward, lm_loss

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,s,t_mult,h,kv,causal", [
    (1, 32, 1, 2, 1, True),
    (1, 64, 2, 4, 2, True),
    (2, 32, 2, 2, 2, False),
    (2, 64, 1, 4, 1, False),
    (1, 32, 2, 4, 1, True),
    (2, 64, 2, 2, 1, True),
])
def test_chunked_attention_matches_oracle(b, s, t_mult, h, kv, causal):
    if kv > h:
        kv = h
    t = s * t_mult
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, 16))
    k = jax.random.normal(ks[1], (b, t, kv, 16))
    v = jax.random.normal(ks[2], (b, t, kv, 16))
    out = ref.attention_chunked_ref(q, k, v, causal=causal, block_kv=16)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_chunked_attention_grads_finite():
    q = jax.random.normal(KEY, (1, 32, 2, 8))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 32, 2, 8))

    def f(q, k):
        return jnp.sum(ref.attention_chunked_ref(q, k, k, causal=True, block_kv=8))

    gq, gk = jax.grad(f, argnums=(0, 1))(q, k)
    assert bool(jnp.all(jnp.isfinite(gq))) and bool(jnp.all(jnp.isfinite(gk)))
    # and matches the oracle's grads
    def f0(q, k):
        return jnp.sum(ref.attention_ref(q, k, k, causal=True))
    gq0, gk0 = jax.grad(f0, argnums=(0, 1))(q, k)
    np.testing.assert_allclose(gq, gq0, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(gk, gk0, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("tie", [True, False])
@pytest.mark.parametrize("chunk", [8, 16, 24])
def test_chunked_lm_loss_matches_plain(tie, chunk):
    cfg = get_smoke("llama-60m").replace(tie_embeddings=tie, logit_chunk=chunk)
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (2, 40), 0, cfg.vocab)

    logits, aux, _ = forward(params, cfg, tokens)
    want = lm_loss(logits, tokens, aux)
    hidden, aux2, _ = forward(params, cfg, tokens, return_hidden=True)
    got = chunked_lm_loss(params, cfg, hidden, tokens, aux2)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_xla_chunked_attention_via_model():
    cfg = get_smoke("qwen1.5-4b").replace(attn_impl="xla_chunked")
    cfg0 = get_smoke("qwen1.5-4b")
    model, model0 = build_model(cfg), build_model(cfg0)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (2, 64), 0, cfg.vocab)
    l1, _, _ = model.forward(params, tokens)
    l0, _, _ = model0.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0), atol=2e-4, rtol=2e-4)


def test_lowrank_accum_update_equivalence():
    """Beyond-paper low-rank gradient accumulation: feeding the optimizer the
    compact-projected-then-reconstructed gradient produces the SAME update as
    the raw gradient (Property I makes the roundtrip exact on both the
    low-rank branch and the sampled full blocks)."""
    from repro.core.gum import gum_accum_tools

    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (4, 32), 0, cfg.vocab)

    def gradf(p):
        def loss_fn(p):
            lg, aux, _ = forward(p, cfg, tokens)
            return lm_loss(lg, tokens, aux)
        return jax.grad(loss_fn)(p)

    tools = gum_accum_tools(1e-2, rank=4, gamma=1, period=2, projector="svd")
    st = tools.transform.init(params)
    g = gradf(params)
    st = tools.refresh(g, st, params)
    u1, _ = tools.transform.update(g, st, params)
    ghat = tools.reconstruct(tools.project(g, st, params), st, params)
    u2, _ = tools.transform.update(ghat, st, params)
    for a, b in zip(jax.tree_util.tree_leaves(u1), jax.tree_util.tree_leaves(u2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_lowrank_accum_trains():
    """End-to-end: the accumulating train step descends like the plain one."""
    from repro.core.gum import gum_accum_tools
    from repro.launch.steps import make_train_step

    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    params = model.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (8, 64), 0, cfg.vocab)}
    tools = gum_accum_tools(1e-2, rank=4, gamma=1, period=3, projector="svd")
    step = jax.jit(make_train_step(model, tools.transform, grad_clip=1.0,
                                   microbatches=4, lowrank_accum=tools))
    st = tools.transform.init(params)
    losses = []
    for _ in range(8):
        params, st, m = step(params, st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses
