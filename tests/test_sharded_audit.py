"""PR-7 acceptance: the sharded audit CLI catches exactly the regressions
it was built for.  One subprocess (host forced to 8 CPU devices) runs
``repro.analysis.audit.main`` three times in-process:

  1. clean      — exit 0, no findings, donation verified on the lowered jit
  2. barrier    — ``jax.lax.optimization_barrier`` patched to identity (the
                  "delete the barrier" regression): exit 1 with RA601
  3. donation   — ``jax.jit`` patched to drop ``donate_argnums``: exit 1
                  with RA604

plus the ``train.py --audit`` gate: the same doctored step must die before
step 0 with a non-zero exit.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = """
from repro.launch.devices import force_host_device_count
force_host_device_count(8)
import jax
from repro.analysis import audit as audit_mod

ARGS = ["--sharded", "--mesh", "data=8", "--optimizer", "gum"]

rc_clean = audit_mod.main(ARGS)
assert rc_clean == 0, f"clean sharded audit returned {rc_clean}"

# regression 1: drop the optimization_barrier pin around the bf16 psum —
# the auditor must flag the reduction as unpinned (RA601).
orig_barrier = jax.lax.optimization_barrier
jax.lax.optimization_barrier = lambda x: x
try:
    rc_barrier = audit_mod.main(ARGS)
finally:
    jax.lax.optimization_barrier = orig_barrier
assert rc_barrier == 1, f"barrier-stripped audit returned {rc_barrier}"

# regression 2: lose donate_argnums on the jit wrapper — the lowered module
# stops aliasing params/opt_state and the buffer pass must fire (RA604).
orig_jit = jax.jit
def jit_no_donate(*a, **kw):
    kw.pop("donate_argnums", None)
    return orig_jit(*a, **kw)
jax.jit = jit_no_donate
try:
    rc_donate = audit_mod.main(ARGS)
finally:
    jax.jit = orig_jit
assert rc_donate == 1, f"donation-stripped audit returned {rc_donate}"

print("SHARDED_AUDIT_ACCEPTANCE_OK")
"""


@pytest.mark.slow
def test_sharded_audit_catches_doctored_regressions(capfd):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO, timeout=600,
    )
    assert "SHARDED_AUDIT_ACCEPTANCE_OK" in r.stdout, (
        r.stdout[-3000:] + r.stderr[-3000:])
    # the doctored runs surfaced the right codes
    assert "RA601" in r.stdout
    assert "RA604" in r.stdout


ZERO_SCRIPT = """
from repro.launch.devices import force_host_device_count
force_host_device_count(8)
import jax
from repro.analysis import audit as audit_mod
from repro.core import combinators

ARGS = ["--sharded", "--mesh", "data=8", "--optimizer", "gum",
        "--shard-state"]

rc_clean = audit_mod.main(ARGS)
assert rc_clean == 0, f"clean ZeRO sharded audit returned {rc_clean}"

# doctored schedule: suppress the family-sharding context so the fused
# refresh silently falls back to the replicated path (no boundary
# all_gather in the trace) while the config still promises ZeRO sharding.
# The closed-form schedule expects one cond-gated gather per shardable
# family -> the mismatch must surface as RA606 and exit 1.
orig = combinators.active_family_sharding
combinators.active_family_sharding = lambda: None
try:
    rc_doctored = audit_mod.main(ARGS)
finally:
    combinators.active_family_sharding = orig
assert rc_doctored == 1, f"doctored-schedule audit returned {rc_doctored}"

print("ZERO_AUDIT_ACCEPTANCE_OK")
"""


@pytest.mark.slow
def test_zero_audit_catches_missing_boundary_gather(capfd):
    """PR-9 acceptance (satellite 1): with ``--shard-state`` the expected
    schedule's ``boundary_gather.count`` is the per-shardable-family count
    (no longer 0), and a step whose refresh lost the sharded path fails the
    audit with RA606."""
    r = subprocess.run(
        [sys.executable, "-c", ZERO_SCRIPT], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO, timeout=600,
    )
    assert "ZERO_AUDIT_ACCEPTANCE_OK" in r.stdout, (
        r.stdout[-3000:] + r.stderr[-3000:])
    assert "RA606" in r.stdout


@pytest.mark.slow
def test_train_audit_gate_runs_before_step_zero():
    """``train.py --audit --mesh data=2`` runs the sharded audit and then
    actually trains (exit 0 on the clean path)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "llama-60m",
         "--smoke", "--opt", "adamw", "--steps", "2", "--batch", "8",
         "--seq", "64", "--audit", "--mesh", "data=2", "--no-resume",
         "--ckpt-dir", "/tmp/repro_ckpt_audit_test"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO, timeout=600,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert "sharded:" in r.stdout      # the sharded audit report printed
    assert "done: step=2" in r.stdout  # ...and training still ran after it
