"""End-to-end behaviour: synthetic counterexample (paper Fig. 1), trainer
fault tolerance (resume-exactness, NaN guard, straggler monitor), and the
sharded train step (subprocess with 8 fake devices)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------- paper Fig. 1 counterexample


def test_synthetic_counterexample_fig1():
    sys.path.insert(0, REPO)
    from benchmarks.synthetic_counterexample import make_problem, run

    prob = make_problem()
    steps = 800
    l_muon = run(prob, "muon", steps=steps)[-1]
    l_galore = run(prob, "galore_muon", steps=steps, rank=12)[-1]
    l_gum = run(prob, "gum", steps=steps, rank=2, q=0.5)[-1]
    # GaLore-Muon stalls far from the optimum; GUM converges near Muon.
    assert l_galore > 5.0, l_galore
    assert abs(l_gum) < 0.5, l_gum
    assert abs(l_muon) < 0.5, l_muon
    assert l_galore > 10 * max(abs(l_gum), 1e-3)


# ------------------------------------------------- trainer fault tolerance


def _train(tmpdir, steps, resume=True, seed=0):
    from repro.configs import RunConfig, get_smoke
    from repro.core import OptimizerConfig
    from repro.data import DataConfig
    from repro.models import build_model
    from repro.train import Trainer

    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    trainer = Trainer(
        model,
        OptimizerConfig(name="gum", lr=1e-3, rank=4, gamma=1, period=3),
        RunConfig(steps=steps, ckpt_dir=tmpdir, ckpt_every=4, log_every=0,
                  resume=resume, seed=seed),
        DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=2, seed=seed),
    )
    return trainer


@pytest.mark.slow
def test_trainer_resume_exact(tmp_path):
    """train(12) straight == train(8) + crash + resume to 12 — exact same
    final params (counter-based data + deterministic optimizer)."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    t1 = _train(d1, 12)
    r1 = t1.train()
    t2 = _train(d2, 8)
    t2.train()
    t3 = _train(d2, 12)  # resumes from step 8 checkpoint
    r3 = t3.train()
    assert r3.resumed_from == 8

    from repro.checkpoint import CheckpointManager

    like = t1.init_state()
    a, _ = CheckpointManager(d1).restore(12, like)
    b, _ = CheckpointManager(d2).restore(12, like)
    fa, fb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    for x, y in zip(fa, fb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_nan_guard_skips_update():
    from repro.configs import get_smoke
    from repro.core import OptimizerConfig, build_optimizer
    from repro.launch.steps import make_train_step
    from repro.models import build_model

    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = build_optimizer(OptimizerConfig(name="adamw", lr=1e-3))
    st = opt.init(params)
    step = jax.jit(make_train_step(model, opt, grad_clip=1.0))

    bad = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    # poison the embedding -> NaN loss
    poisoned = jax.tree_util.tree_map(lambda x: x, params)
    poisoned["embed"]["embed"] = poisoned["embed"]["embed"].at[0, 0].set(jnp.nan)
    new_params, _, metrics = step(poisoned, st, bad)
    assert not bool(metrics["update_applied"])
    # params unchanged (still poisoned but not *further* changed)
    np.testing.assert_array_equal(
        np.asarray(new_params["final_norm"]["norm_scale"]),
        np.asarray(params["final_norm"]["norm_scale"]),
    )


def test_straggler_monitor():
    from repro.train import StepTimeMonitor

    mon = StepTimeMonitor(window=50, z=3.0, min_samples=5)
    for i in range(20):
        assert not mon.record(i, 0.1 + 0.001 * (i % 3))
    assert mon.record(20, 1.5)  # 15x the mean -> flagged
    assert mon.flagged and mon.flagged[0][0] == 20


# ------------------------------------------------- sharded step (8 devices)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device(tmp_path):
    """pjit on a (2,4) debug mesh must produce the same loss/params as the
    unsharded step (same inputs, same seed)."""
    script = """
from repro.launch.devices import force_host_device_count
force_host_device_count(8)  # shared helper: preserves other XLA_FLAGS
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.core import OptimizerConfig, build_optimizer
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import batch_shardings, batch_struct, make_train_step
from repro.models import build_model
from repro.sharding import named_sharding_tree, opt_state_sharding, use_mesh

cfg = get_smoke("qwen1.5-4b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = build_optimizer(OptimizerConfig(name="gum", lr=1e-2, rank=4, gamma=1, period=2, projector="svd"))
st = opt.init(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
step = make_train_step(model, opt, grad_clip=1.0)

p1, s1, m1 = jax.jit(step)(params, st, {"tokens": tokens})

mesh = make_debug_mesh((2, 4), ("data", "model"))
psh = named_sharding_tree(params, mesh)
osh = opt_state_sharding(st, mesh)
shape = ShapeConfig("t", 64, 8, "train")
bsh = batch_shardings(cfg, shape, mesh)
with use_mesh(mesh):
    p2, s2, m2 = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None))(params, st, {"tokens": tokens})

np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4, rtol=3e-3)
print("SHARDED_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=REPO, timeout=600)
    assert "SHARDED_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]


def test_dryrun_cell_smoke():
    """One real dry-run cell end-to-end in a subprocess (512 fake devices,
    16x16 mesh): lower + compile must succeed and report roofline terms."""
    script = """
import json, tempfile, os
from repro.launch.devices import force_host_device_count
force_host_device_count(512, verify=False)  # shared helper
from repro.launch.dryrun import run_cell
res = run_cell("mamba2-370m", "decode_32k", multi_pod=False)
assert res["status"] == "ok", res
assert res["roofline"]["flops"] > 0
assert res["roofline"]["collective_bytes"] >= 0
print("DRYRUN_OK", res["roofline"]["bottleneck"])
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=REPO, timeout=600)
    assert "DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]
