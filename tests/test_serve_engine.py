"""Continuous-batching serving engine: mixed-length requests, slot reuse,
and consistency with direct single-request decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.serve import ServeEngine

KEY = jax.random.PRNGKey(0)


def greedy_reference(model, params, prompt, n_new, max_seq):
    """Direct single-request greedy decode (the oracle)."""
    cache = model.init_cache(batch=1, max_seq=max_seq, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, cache=c, tokens=t, pos=pos))
    logits = None
    for i, tok in enumerate(prompt):
        logits, cache = step(params, cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(i))
    out = []
    tok = int(jnp.argmax(logits[0, -1]))
    for i in range(len(prompt), len(prompt) + n_new):
        out.append(tok)
        logits, cache = step(params, cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(i))
        tok = int(jnp.argmax(logits[0, -1]))
    return out[:n_new]


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "mamba2-370m"])
def test_engine_matches_direct_decode(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    eng = ServeEngine(model, params, slots=2, max_seq=48)

    prompts = [[5, 9, 3], [7, 1, 2, 8, 4]]
    reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    finished = eng.run()
    assert len(finished) == 2
    for req, prompt in zip(reqs, prompts):
        want = greedy_reference(model, params, prompt, 6, 48)
        assert req.output == want, (arch, req.output, want)


def test_engine_continuous_batching_slot_reuse():
    cfg = get_smoke("qwen1.5-4b")
    model = build_model(cfg)
    params = model.init(KEY)
    # 5 requests, 2 slots: slots must be reused as requests finish
    eng = ServeEngine(model, params, slots=2, max_seq=32)
    reqs = [eng.submit([i + 1, i + 2], max_new_tokens=3) for i in range(5)]
    finished = eng.run()
    assert len(finished) == 5
    assert all(len(r.output) == 3 for r in reqs)
    # identical prompts -> identical outputs regardless of scheduling slot
    e2 = ServeEngine(model, params, slots=2, max_seq=32)
    r_again = e2.submit([1, 2], max_new_tokens=3)
    e2.run()
    assert r_again.output == reqs[0].output
