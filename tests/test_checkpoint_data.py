"""Checkpoint manager (atomic commit, keep-N, elastic reshard) and the data
pipeline (determinism, resume, host sharding, packing)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, build_stream


def tree_eq(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


# ----------------------------------------------------------- checkpointing


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,), jnp.int32)}}
    mgr.save(7, tree, extra={"note": "x"})
    restored, extra = mgr.restore(7, tree)
    assert tree_eq(tree, restored)
    assert extra == {"note": "x"}


def test_keep_n_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"a": jnp.full((2,), float(s))})
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4
    step, restored, _ = mgr.restore_latest(tree)
    assert step == 4 and float(restored["a"][0]) == 4.0


def test_partial_write_is_invisible(tmp_path):
    """A crashed writer leaves only a .tmp dir — restore must ignore it."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"a": jnp.ones((2,))})
    os.makedirs(str(tmp_path / "step_000000002.tmp"))
    assert mgr.latest_step() == 1


def test_elastic_reshard_on_load(tmp_path):
    """Save on one mesh shape, restore onto a different one (in a subprocess
    with 8 fake devices so meshes exist)."""
    script = f"""
from repro.launch.devices import force_host_device_count
force_host_device_count(8)  # shared helper: preserves other XLA_FLAGS
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

mgr = CheckpointManager({str(tmp_path)!r}, keep=2)
mesh1 = jax.make_mesh((8,), ("data",))
x = jax.device_put(jnp.arange(64.0).reshape(8, 8), NamedSharding(mesh1, P("data", None)))
mgr.save(3, {{"w": x}})

# restore onto a DIFFERENT mesh (2x4) with model-axis sharding
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
sh = {{"w": NamedSharding(mesh2, P(None, "model"))}}
restored, _ = mgr.restore(3, {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}, shardings=sh)
assert restored["w"].sharding.spec == P(None, "model"), restored["w"].sharding
np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(64.0).reshape(8, 8))
print("ELASTIC_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


# ----------------------------------------------------------- data pipeline


def test_stream_deterministic():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=4, seed=42)
    a = build_stream(cfg).batch_at(17)
    b = build_stream(cfg).batch_at(17)
    np.testing.assert_array_equal(a, b)
    c = build_stream(cfg).batch_at(18)
    assert not np.array_equal(a, c)


def test_stream_resume_exact():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=2, seed=1)
    s1 = build_stream(cfg)
    first = [next(s1) for _ in range(6)]
    s2 = build_stream(cfg).resume(3)
    again = [next(s2) for _ in range(3)]
    for x, y in zip(first[3:], again):
        np.testing.assert_array_equal(x, y)


def test_stream_host_sharding_partitions_global_batch():
    base = DataConfig(vocab=500, seq_len=32, global_batch=4, seed=9)
    full = build_stream(base).batch_at(5)
    h0 = build_stream(DataConfig(**{**base.__dict__, "num_hosts": 2, "host_id": 0})).batch_at(5)
    h1 = build_stream(DataConfig(**{**base.__dict__, "num_hosts": 2, "host_id": 1})).batch_at(5)
    np.testing.assert_array_equal(np.concatenate([h0, h1]), full)


def test_stream_tokens_valid_and_packed():
    cfg = DataConfig(vocab=300, seq_len=512, global_batch=2, seed=3,
                     mean_doc_len=64)
    b = build_stream(cfg).batch_at(0)
    assert b.shape == (2, 512)
    assert b.min() >= 0 and b.max() < 300
    # packing: EOS separators present (docs shorter than seq_len)
    assert (b == cfg.eos_id).any()
