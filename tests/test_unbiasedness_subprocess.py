"""Runs tests/test_unbiasedness.py in a fresh interpreter.

The theory tests themselves are healthy, but executing them after the rest
of the suite in one process crashes XLA's CPU ``backend_compile`` (SIGSEGV,
rc 139).  ``tests/conftest.py`` therefore excludes the file from in-process
collection, and this wrapper keeps full-suite coverage by running it behind
a process boundary — ``pytest tests/test_unbiasedness.py`` names the file
explicitly, which bypasses the conftest isolation inside the subprocess.
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_unbiasedness_file_passes_in_clean_interpreter():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.join(REPO, "tests", "test_unbiasedness.py")],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert " passed" in r.stdout, r.stdout[-2000:]
