"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracle,
swept over deterministic shape/dtype grids (stdlib + pytest only — the seed
used hypothesis, which the CI image does not ship)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lowrank_update import lowrank_update
from repro.kernels.newton_schulz import gram, newton_schulz_pallas, poly_matmul_axpy
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- flash attn


@pytest.mark.parametrize("b,s_blocks,h,group,d,causal,dtype", [
    (1, 2, 2, 1, 8, True, jnp.float32),
    (1, 4, 4, 2, 16, False, jnp.float32),
    (2, 2, 4, 4, 32, True, jnp.float32),
    (2, 4, 2, 2, 16, True, jnp.bfloat16),
    (1, 2, 4, 1, 32, False, jnp.bfloat16),
    (2, 2, 2, 2, 8, False, jnp.float32),
])
def test_flash_attention_matches_oracle(b, s_blocks, h, group, d, causal, dtype):
    bq = 16
    s = s_blocks * bq
    kv = max(h // group, 1)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_kv=bq,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_flash_attention_short_query_offset():
    """Chunked-prefill shape: q covers only the last rows of kv (causal)."""
    q = jax.random.normal(KEY, (1, 32, 2, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 64, 2, 16))
    out = flash_attention(q, k, v, causal=True, block_q=16, block_kv=16,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------- newton-schulz


@pytest.mark.parametrize("m,n_mult,dtype", [
    (4, 1, jnp.float32),
    (8, 2, jnp.float32),
    (16, 4, jnp.float32),
    (8, 1, jnp.bfloat16),
    (16, 2, jnp.bfloat16),
])
def test_ns_kernels_match_oracle(m, n_mult, dtype):
    n = m * n_mult * 2
    x = jax.random.normal(KEY, (m, n), jnp.float32).astype(dtype)
    g_pal = gram(x, block_n=n // 2, interpret=True)
    np.testing.assert_allclose(g_pal, ref.gram_ref(x), atol=1e-2, rtol=1e-2)
    a2 = 0.5 * g_pal + 0.25 * (g_pal @ g_pal)
    y_pal = poly_matmul_axpy(a2, x.astype(jnp.float32), 3.0, block_n=n // 2,
                             interpret=True)
    np.testing.assert_allclose(
        y_pal, ref.poly_matmul_axpy_ref(a2, x, 3.0), atol=1e-2, rtol=1e-2
    )


def test_ns_kernels_batched_family():
    """The (L, nblocks) batch grid: a stacked family in one pallas_call."""
    x = jax.random.normal(KEY, (3, 8, 32))
    g_pal = gram(x, block_n=16, interpret=True)
    want = jnp.einsum("lmn,lkn->lmk", x, x)
    np.testing.assert_allclose(g_pal, want, atol=1e-4, rtol=1e-4)
    a2 = 0.5 * g_pal + 0.25 * (g_pal @ g_pal)
    y_pal = poly_matmul_axpy(a2, x, 3.0, block_n=16, interpret=True)
    np.testing.assert_allclose(
        y_pal, 3.0 * x + a2 @ x, atol=1e-4, rtol=1e-4
    )


def test_ns_full_iteration_matches_xla():
    x = jax.random.normal(KEY, (8, 24))
    out_pal = newton_schulz_pallas(x, interpret=True)
    out_xla = ops.newton_schulz(x, impl="xla")
    np.testing.assert_allclose(out_pal, out_xla, atol=1e-4, rtol=1e-4)


def test_ns_ops_batched_and_transposed():
    xb = jax.random.normal(KEY, (3, 24, 8))  # m > n: transposed path
    np.testing.assert_allclose(
        ops.newton_schulz(xb, impl="interpret"),
        ops.newton_schulz(xb, impl="xla"),
        atol=1e-4, rtol=1e-4,
    )


# ------------------------------------------------------------- lowrank update


@pytest.mark.parametrize("m,n,r,beta,coeff", [
    (16, 32, 2, 0.0, 1.0),
    (16, 64, 4, 0.9, 2.0),
    (32, 32, 8, 0.95, 4.0 / 3),
    (32, 64, 4, 0.9, 1.0),
    (64, 32, 8, 0.95, 2.0),
    (64, 64, 2, 0.0, 4.0 / 3),
])
def test_lowrank_update_matches_oracle(m, n, r, beta, coeff):
    ks = jax.random.split(KEY, 3)
    p = jax.random.normal(ks[0], (m, r))
    g = jax.random.normal(ks[1], (m, n))
    rst = jax.random.normal(ks[2], (r, n))
    out = lowrank_update(p, g, rst, beta, coeff, block_m=m // 2, block_n=n // 2,
                         interpret=True)
    want = ref.lowrank_update_ref(p, g, rst, beta, coeff)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


def test_lowrank_update_batched_family():
    from repro.kernels.lowrank_update import lowrank_update_batched

    L, m, n, r = 4, 16, 32, 4
    ks = jax.random.split(KEY, 3)
    p = jax.random.normal(ks[0], (L, m, r))
    g = jax.random.normal(ks[1], (L, m, n))
    rst = jax.random.normal(ks[2], (L, r, n))
    out = lowrank_update_batched(p, g, rst, 0.9, 1.5, block_m=8, block_n=16,
                                 interpret=True)
    want = 0.9 * rst + 1.5 * jnp.einsum("lmr,lmn->lrn", p, g)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------- ssd scan


@pytest.mark.parametrize("b,nch,h,p_dim,n_state", [
    (1, 2, 1, 4, 8),
    (1, 4, 3, 8, 16),
    (2, 2, 3, 4, 16),
    (2, 4, 1, 8, 8),
])
def test_ssd_kernel_matches_sequential_oracle(b, nch, h, p_dim, n_state):
    chunk = 16
    s = nch * chunk
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p_dim)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, s, n_state)) * 0.3
    cmat = jax.random.normal(ks[4], (b, s, n_state)) * 0.3
    d = jnp.full((h,), 0.1)

    y_seq, s_seq = ref.ssd_ref(x, dt, a, bmat, cmat, d)
    y_pal, s_pal = ssd_scan(x, dt, a, bmat, cmat, chunk=chunk, interpret=True)
    y_pal = y_pal + d[None, None, :, None] * x
    np.testing.assert_allclose(y_pal, y_seq, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(s_pal, s_seq, atol=2e-3, rtol=2e-3)


def test_ssd_decode_consistent_with_scan():
    """Running the scan then one decode step == scanning s+1 steps."""
    b, s, h, p_dim, n_state = 1, 32, 2, 4, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s + 1, h, p_dim)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s + 1, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, s + 1, n_state)) * 0.3
    cmat = jax.random.normal(ks[4], (b, s + 1, n_state)) * 0.3
    d = jnp.full((h,), 0.1)

    y_all, s_all = ref.ssd_ref(x, dt, a, bmat, cmat, d)
    _, s_prefix = ref.ssd_ref(
        x[:, :s], dt[:, :s], a, bmat[:, :s], cmat[:, :s], d
    )
    y_step, s_step = ops.ssd_decode_step(
        s_prefix, x[:, s], dt[:, s], a, bmat[:, s], cmat[:, s], d
    )
    np.testing.assert_allclose(y_step, y_all[:, s], atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(s_step, s_all, atol=1e-3, rtol=1e-3)


def test_ssd_chunked_ref_equals_sequential():
    b, s, h, p_dim, n_state = 2, 64, 2, 8, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p_dim)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, s, n_state)) * 0.3
    cmat = jax.random.normal(ks[4], (b, s, n_state)) * 0.3
    d = jnp.full((h,), 0.1)
    y1, s1 = ref.ssd_ref(x, dt, a, bmat, cmat, d)
    y2, s2 = ref.ssd_chunked_ref(x, dt, a, bmat, cmat, d, chunk=16)
    np.testing.assert_allclose(y1, y2, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(s1, s2, atol=1e-3, rtol=1e-3)
