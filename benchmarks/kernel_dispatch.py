"""Fused Pallas kernels vs jnp reference: per-step time for the two low-rank
optimizer hot loops at GaLore/GUM's production operating point (rank <= 512
against (m, n) hidden matrices, stacked (L, m, n) families).

Emits a step-time table comparing the dispatch paths:

  jnp       — the pure-jnp reference (what "auto" runs off-TPU)
  fused     — the Pallas kernels via repro.kernels.dispatch ("auto" on TPU;
              off-TPU this script falls back to the interpreter and the
              numbers measure correctness plumbing, not kernel speed — the
              table says which path actually ran)

Usage: PYTHONPATH=src python benchmarks/kernel_dispatch.py [--steps N]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.kernels import dispatch

# (L, m, n, r): stacked-family shapes at the paper's operating points.
SHAPES = [
    (1, 1024, 1024, 128),
    (4, 1024, 4096, 128),
    (4, 4096, 1024, 256),   # right-side projection (m > n)
    (8, 2048, 2048, 512),
    (1, 1000, 768, 96),     # ragged: exercises the padding wrappers
]

# Off-TPU the "fused" path is the Pallas *interpreter* — orders of magnitude
# slower than compiled code and only meaningful as a plumbing check, so the
# sweep drops to toy shapes that finish in seconds.
SHAPES_INTERPRET = [
    (1, 128, 128, 16),
    (2, 128, 256, 32),
    (2, 256, 128, 32),      # right-side projection
    (1, 100, 76, 12),       # ragged: exercises the padding wrappers
]


def _time_fn(fn, *args, steps: int, warmup: int = 2) -> float:
    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(steps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / steps


def bench_lowrank(L, m, n, r, *, steps: int, pallas_impl: str):
    side = "left" if m <= n else "right"
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    s = min(m, n)
    p = jax.random.normal(ks[0], (L, s, r))
    g = jax.random.normal(ks[1], (L, m, n))
    rst = jax.random.normal(
        ks[2], (L, r, n) if side == "left" else (L, m, r)
    )

    def run(impl):
        f = jax.jit(
            lambda p, g, rs: dispatch.lowrank_update(
                p, g, rs, 0.95, 4.0 / 3, side=side, impl=impl
            )
        )
        return _time_fn(f, p, g, rst, steps=steps)

    return run("jnp"), run(pallas_impl)


def bench_ns(L, m, n, r, *, steps: int, pallas_impl: str):
    # NS runs on the projected momentum (r, n) per block — the GUM hot loop.
    x = jax.random.normal(jax.random.PRNGKey(1), (L, r, max(m, n)))

    def run(impl):
        f = jax.jit(lambda x: dispatch.newton_schulz(x, impl=impl))
        return _time_fn(f, x, steps=steps)

    return run("jnp"), run(pallas_impl)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()
    if args.steps < 1:
        ap.error("--steps must be >= 1")

    pallas_impl = dispatch.resolve_impl("pallas")  # "pallas" on TPU else interpreter
    shapes = SHAPES if pallas_impl == "pallas" else SHAPES_INTERPRET
    print(f"# backend={dispatch.backend()} fused_path={pallas_impl} "
          f"steps={args.steps}")
    print("op,L,m,n,r,jnp_ms,fused_ms,speedup")
    for L, m, n, r in shapes:
        t_ref, t_fused = bench_lowrank(L, m, n, r, steps=args.steps,
                                       pallas_impl=pallas_impl)
        print(f"lowrank_update,{L},{m},{n},{r},{t_ref*1e3:.3f},"
              f"{t_fused*1e3:.3f},{t_ref/max(t_fused,1e-12):.2f}x")
        t_ref, t_fused = bench_ns(L, m, n, r, steps=args.steps,
                                  pallas_impl=pallas_impl)
        print(f"newton_schulz,{L},{m},{n},{r},{t_ref*1e3:.3f},"
              f"{t_fused*1e3:.3f},{t_ref/max(t_fused,1e-12):.2f}x")


if __name__ == "__main__":
    main()
