"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Heavy suites can be selected
with BENCH_ONLY=<name>; default runs everything.  ``--smoke`` runs every
suite at 1–2 steps with result-JSON writes disabled — no timing claims, just
an end-to-end execution check (a tier-1 test invokes it, so suites cannot
silently bit-rot; this harness itself had un-importable suites before that
test existed).

  synthetic_counterexample  — Fig. 1 (GaLore fails, GUM converges)
  memory_table              — Tables 1 & 3 (optimizer-state memory)
  pretrain_proxy            — Table 4 (optimizer comparison on LLaMA-60M)
  bias_residual             — Fig. 4 (GaLore's chi_t bias curve)
  stable_rank               — Figs. 2/3/5 (stable rank & spectra)
  roofline_report           — §Roofline aggregation from the dry-run JSONs
  optimizer_api             — per-leaf chained vs family-stacked per-step
                              overhead (PR 2/3; writes BENCH_optimizer_api.json)
  fused_step                — family-stacked fused engine vs per-leaf
                              chained: step time + kernel-launch counts
                              (PR 3; writes BENCH_fused_step.json)
  rank_policy               — rank-policy engine: projected-state bytes +
                              step time, fixed vs stepwise vs spectral
                              (writes BENCH_rank_policy.json)
  audit_matrix              — static-audit pass matrix: every factory
                              optimizer x fuse_families x fused_epilogue,
                              abstract tracing only (PR 6; writes
                              BENCH_audit_matrix.json)
  resilience                — health-monitor overhead, snapshot/rollback
                              latency, per-save checksum cost (PR 8;
                              writes BENCH_resilience.json)
  sharded_step              — ZeRO-sharded fused step: per-device state
                              bytes vs mesh size, boundary-gather wire
                              bytes, steady-step time sharded vs
                              replicated (PR 9; writes
                              BENCH_sharded_step.json)
  telemetry                 — telemetry bus + in-jit instrumentation
                              overhead vs the <=2% step-time budget, and
                              bus write throughput (PR 10; writes
                              BENCH_telemetry.json)
  kernel_micro              — per-kernel wall-time microbenchmarks (CPU
                              interpret/xla; indicative only, not TPU)
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# Make `benchmarks.<suite>` (and the suites' `_smoke` import) resolvable no
# matter where the harness is launched from: repo root for the package form,
# this directory for the script form.
_HERE = os.path.dirname(os.path.abspath(__file__))
for _p in (os.path.dirname(_HERE), _HERE):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def kernel_micro() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    key = jax.random.PRNGKey(0)

    def bench(fn, *args, n=5):
        fn(*args)  # compile
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.time() - t0) / n * 1e6

    q = jax.random.normal(key, (2, 256, 8, 64))
    k = jax.random.normal(key, (2, 256, 2, 64))
    us = bench(lambda q, k: ops.attention(q, k, k, causal=True, impl="xla"), q, k)
    print(f"kernel_attention_xla_b2s256,{us:.0f},oracle_path")

    x = jax.random.normal(key, (256, 1024))
    us = bench(lambda x: ops.newton_schulz(x, impl="xla"), x)
    print(f"kernel_newton_schulz_256x1024,{us:.0f},xla_path")

    p = jax.random.normal(key, (1024, 128))
    g = jax.random.normal(key, (1024, 2048))
    r = jax.random.normal(key, (128, 2048))
    us = bench(lambda p, g, r: ops.lowrank_update(p, g, r, 0.95, 1.0, impl="xla"), p, g, r)
    print(f"kernel_lowrank_update_1024x2048_r128,{us:.0f},xla_path")

    xs = jax.random.normal(key, (1, 512, 4, 32)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(key, (1, 512, 4)))
    a = -jnp.exp(jax.random.normal(key, (4,)) * 0.3)
    b = jax.random.normal(key, (1, 512, 16)) * 0.3
    d = jnp.ones((4,)) * 0.1
    us = bench(lambda: ops.ssd(xs, dt, a, b, b, d, chunk=64, impl="xla"))
    print(f"kernel_ssd_s512,{us:.0f},chunked_xla_path")


SUITES = [
    "synthetic_counterexample",
    "memory_table",
    "pretrain_proxy",
    "bias_residual",
    "stable_rank",
    "roofline_report",
    "optimizer_api",
    "fused_step",
    "rank_policy",
    "audit_matrix",
    "resilience",
    "sharded_step",
    "telemetry",
]

# Suites that commit a results/BENCH_*.json trajectory.  A registered suite
# whose JSON is missing means someone added (or regenerated) the suite and
# forgot to commit the numbers — warn loudly so it can't slip through CI.
RESULT_JSON = {
    "optimizer_api": "BENCH_optimizer_api.json",
    "fused_step": "BENCH_fused_step.json",
    "rank_policy": "BENCH_rank_policy.json",
    "audit_matrix": "BENCH_audit_matrix.json",
    "resilience": "BENCH_resilience.json",
    "sharded_step": "BENCH_sharded_step.json",
    "telemetry": "BENCH_telemetry.json",
}

# Suites that deliberately do NOT commit a result JSON — paper-figure
# reproductions whose output is the figure/table itself (stdout CSV or a
# plot), not a machine-checked trajectory.  Every SUITES entry must appear
# in exactly one of RESULT_JSON / NO_RESULT_JSON; anything in neither is
# registry drift and warns below.
NO_RESULT_JSON = {
    "synthetic_counterexample": "Fig. 1 reproduction; CSV trajectory only",
    "memory_table": "Tables 1 & 3; formula-derived rows, nothing to time",
    "pretrain_proxy": "Table 4; hours-long at paper scale, CSV rows only",
    "bias_residual": "Fig. 4; closed-form bias curve, CSV rows only",
    "stable_rank": "Figs. 2/3/5; spectra depend on the sampled checkpoint",
    "roofline_report": "aggregates results/dryrun/*.json, writes nothing new",
}


def warn_missing_results() -> None:
    results_dir = os.path.join(os.path.dirname(_HERE), "results")
    for suite, fname in RESULT_JSON.items():
        if not os.path.exists(os.path.join(results_dir, fname)):
            print(f"WARNING: suite '{suite}' is registered but "
                  f"results/{fname} is not committed — run "
                  f"PYTHONPATH=src python benchmarks/{suite}.py to record it",
                  file=sys.stderr, flush=True)
    for suite in SUITES:
        if suite not in RESULT_JSON and suite not in NO_RESULT_JSON:
            print(f"WARNING: suite '{suite}' is in neither RESULT_JSON nor "
                  f"NO_RESULT_JSON — declare whether it commits a results "
                  f"JSON (benchmarks/run.py registry drift)",
                  file=sys.stderr, flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1-2 steps per suite, no timing claims, no "
                         "result-JSON writes (CI execution check)")
    args, _ = ap.parse_known_args()
    if args.smoke:
        os.environ["BENCH_SMOKE"] = "1"
    only = os.environ.get("BENCH_ONLY")
    warn_missing_results()
    ran_header = False
    for name in SUITES:
        if only and only != name:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        # each suite prints its own CSV header; dedupe by capturing
        print(f"# --- {name} ---", file=sys.stderr)
        mod.main()
        ran_header = True
    if not only or only == "kernel_micro":
        if not ran_header:
            print("name,us_per_call,derived")
        kernel_micro()
    if args.smoke:
        print("# smoke run complete", flush=True)


if __name__ == "__main__":
    main()
