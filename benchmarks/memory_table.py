"""Paper Tables 1 & 3: optimizer-state memory accounting.

Table 1 (space complexity) is checked symbolically in tests; here we produce
the Table-3-style comparison — per-model peak *optimizer state* bytes for
GaLore rank 512 vs GUM gamma+128 — using the real optimizer states
instantiated against the real model parameter trees (the paper's LLaMA-3-8B
etc. are approximated by the assigned archs closest in size plus the paper's
own LLaMA sizes; the accounting is exact for whatever tree it is given).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.core import OptimizerConfig, build_optimizer, state_bytes
from repro.models import build_model


def optimizer_state_bytes(arch: str, opt_cfg: OptimizerConfig, smoke: bool) -> int:
    cfg = get_smoke(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt = build_optimizer(opt_cfg)
    st = jax.eval_shape(opt.init, params_struct)
    return sum(
        x.size * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(st)
        if hasattr(x, "dtype")
    )


ARCHS_FOR_TABLE = ["llama-130m", "llama-350m", "qwen1.5-4b", "starcoder2-7b",
                   "chatglm3-6b"]


def main() -> None:
    print("name,us_per_call,derived")
    for arch in ARCHS_FOR_TABLE:
        rows = {
            "adamw": OptimizerConfig(name="adamw"),
            "galore512": OptimizerConfig(name="galore", rank=512),
            "gum_2p128": OptimizerConfig(name="gum", rank=128, gamma=2),
        }
        vals = {}
        for name, oc in rows.items():
            vals[name] = optimizer_state_bytes(arch, oc, smoke=False)
        gb = {k: v / 1e9 for k, v in vals.items()}
        print(
            f"memory_table_{arch},0,"
            f"adamw_GB={gb['adamw']:.3f};galore512_GB={gb['galore512']:.3f};"
            f"gum_2p128_GB={gb['gum_2p128']:.3f};"
            f"gum_vs_galore={gb['gum_2p128']/max(gb['galore512'],1e-9):.3f}"
        )


if __name__ == "__main__":
    main()
