"""Telemetry subsystem cost model: step-time overhead + bus throughput.

Two questions with acceptance budgets (ISSUE 10):

  overhead    — step-time cost of the full telemetry path (in-jit subspace
                instrumentation riding the probe slots + host-side bus with
                a JSONL sink, metrics every step) vs a bare run of the same
                trainer, budget <= 2% of step time
  throughput  — raw bus write rate (records/s) through the JsonlSink, and
                the per-record emit cost with no sinks attached (the price
                every call site pays when telemetry is off at the bus level)

Runs the pretrain-proxy setup (LLaMA-60M smoke, GUM) through the real
Trainer so the measured loop is the shipping loop.  Writes
BENCH_telemetry.json unless BENCH_SMOKE=1.
"""
from __future__ import annotations

import json
import os
import shutil
import statistics
import tempfile
import time

from _smoke import smoke, steps as smoke_steps

STEPS = 30
BUDGET_PCT = 2.0


def _trainer(tmp, telemetry, steps, batch=8, seq=128):
    from repro.configs import RunConfig, get_smoke
    from repro.core import OptimizerConfig
    from repro.data import DataConfig
    from repro.models import build_model
    from repro.train import Trainer

    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    return Trainer(
        model,
        OptimizerConfig(name="gum", lr=1e-3, rank=8, gamma=1, period=10,
                        telemetry=telemetry is not None),
        RunConfig(steps=steps, ckpt_dir=tmp, ckpt_every=0, log_every=0,
                  resume=False),
        DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch),
        telemetry=telemetry,
    )


def _median_step_us(trainer, steps) -> float:
    trainer.monitor.times.clear()
    trainer.train(steps)
    times = list(trainer.monitor.times)
    # drop the compile step(s): the monitor window already caps history,
    # but the first recorded samples still straddle warmup
    times = times[2:] or times
    return statistics.median(times) * 1e6


def _bus_throughput(root):
    from repro.telemetry import JsonlSink, Telemetry

    n = 200 if smoke() else 20_000
    path = os.path.join(root, "throughput.jsonl")
    tele = Telemetry([JsonlSink(path)], run={"bench": "throughput"})
    t0 = time.time()
    for i in range(n):
        tele.metric(i, "loss", 1.0)
    dt = time.time() - t0
    tele.close()
    jsonl_rps = n / dt

    # emit cost with zero sinks: what every migrated print() pays when the
    # bus exists but nothing is attached
    tele = Telemetry([], run={})
    t0 = time.time()
    for i in range(n):
        tele.metric(i, "loss", 1.0)
    nosink_us = (time.time() - t0) / n * 1e6
    return jsonl_rps, nosink_us, n


def main() -> None:
    import jax

    n = smoke_steps(STEPS, 2)
    print("name,us_per_call,derived")
    root = tempfile.mkdtemp(prefix="bench_telemetry_")
    try:
        # --- full-path overhead.  Step-time noise on a shared CPU box is
        # larger than the effect and drifts on a seconds timescale, so the
        # two trainers run many short segments tightly interleaved (order
        # alternating each rep) and the overhead is computed between the
        # per-side medians — slow phases land on both sides equally
        # instead of being attributed to whichever side a min-vs-min
        # comparison happened to favor.  The on-side is the maximal
        # configuration: in-jit instrumentation (telemetry=True probe
        # slots), metrics every step, JSONL sink. ---------------------------
        t_off = _trainer(os.path.join(root, "off"), None, n)
        t_on = _trainer(os.path.join(root, "on"), "every=1,stdout=0", n)
        reps = 1 if smoke() else 12
        offs, ons = [], []
        for rep in range(reps):
            pair = [(t_off, offs), (t_on, ons)]
            if rep % 2:
                pair.reverse()
            for t, acc in pair:
                acc.append(_median_step_us(t, n))
        us_off = statistics.median(offs)
        us_on = statistics.median(ons)
        overhead = (us_on - us_off) / us_off * 100.0
        print(f"telemetry_step_off,{us_off:.0f},median")
        print(f"telemetry_step_on,{us_on:.0f},overhead={overhead:+.2f}%")

        # --- bus throughput ----------------------------------------------
        jsonl_rps, nosink_us, n_rec = _bus_throughput(root)
        print(f"telemetry_bus_jsonl,{1e6 / jsonl_rps:.1f},"
              f"{jsonl_rps:.0f}_records_per_s")
        print(f"telemetry_bus_nosink,{nosink_us:.2f},per_record")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    if smoke():
        return
    out = {
        "setup": {"arch": "llama-60m-smoke", "opt": "gum", "rank": 8,
                  "period": 10, "steps": n, "device": jax.devices()[0]
                  .platform},
        "overhead": {"step_us_off": us_off, "step_us_on": us_on,
                     "overhead_pct": overhead, "budget_pct": BUDGET_PCT,
                     "rep_medians_us": {"off": offs, "on": ons}},
        "throughput": {"jsonl_records_per_s": jsonl_rps,
                       "nosink_us_per_record": nosink_us,
                       "records": n_rec},
    }
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        os.pardir, "results", "BENCH_telemetry.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.normpath(path)}")


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    main()
