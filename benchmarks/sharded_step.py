"""ZeRO sharded-step benchmark (PR 9): what sharding the family-stacked
projected state actually buys.

Three measurements on the fused gum step over the llama-60m smoke model:

  * per-device optimizer-state bytes vs mesh size (1/2/4/8) — the static
    accountant (:func:`repro.analysis.buffers.per_shard_memory` with
    ``shard_state=True``), AbstractMesh only, no devices.  The shardable
    family leaves must scale ~1/N; the replicated remainder (non-divisible
    families, scalars) is reported so the gap is visible.
  * refresh-boundary gather cost vs mesh size — count, per-shard payload
    and ring wire bytes of the cond-gated all_gathers, from the traced
    schedule (paid once per refresh period, zero in steady state).
  * steady-step wall time, sharded vs replicated state, on a REAL host-CPU
    mesh (subprocess per mesh so device forcing precedes jax init) — the
    check that ZeRO sharding does not tax the steady path.

Emits ``name,us_per_call,derived`` CSV rows and writes
``BENCH_sharded_step.json`` under --out (default results/).  ``--smoke``
keeps one abstract mesh-8 row and skips the timed subprocesses + JSON.

Usage: PYTHONPATH=src python benchmarks/sharded_step.py [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.analysis.audit import audit_sharded
from repro.core import OptimizerConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MESHES = (1, 2, 4, 8)
TIMED_MESHES = (1, 2)

_TIMED_SCRIPT = """
import json, sys, time
from repro.launch.devices import force_host_device_count
N = int(sys.argv[1])
force_host_device_count(N)
import jax, jax.numpy as jnp
from repro.configs import get_smoke
from repro.core import OptimizerConfig, build_optimizer
from repro.launch.shardmap_fsdp import make_shardmap_train_step
from repro.models import build_model

cfg = get_smoke("llama-60m")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
batch = {"tokens": tokens}
mesh = jax.make_mesh((N,), ("data",), devices=jax.devices()[:N])
copy = lambda t: jax.tree_util.tree_map(jnp.copy, t)

out = {}
for shard_state in (True, False):
    opt = build_optimizer(OptimizerConfig(
        name="gum", lr=1e-2, rank=16, gamma=1, period=100, projector="svd",
        fuse_families=True))
    _, jit_builder = make_shardmap_train_step(
        model, opt, mesh, grad_clip=1.0, shard_state=shard_state)
    p, s = copy(params), opt.init(copy(params))
    jitted = jit_builder(p, s)
    p, s, m = jitted(p, s, batch)   # compile + the step-0 refresh
    jax.block_until_ready(p)
    t0 = time.time()
    steps = 5
    for _ in range(steps):          # period=100 -> pure steady state
        p, s, m = jitted(p, s, batch)
    jax.block_until_ready(p)
    out["sharded" if shard_state else "replicated"] = (
        (time.time() - t0) / steps * 1e6)
print("TIMED_JSON " + json.dumps(out))
"""


def abstract_rows(smoke_mode: bool):
    """Per-mesh static rows: per-device state bytes + boundary schedule,
    from the AbstractMesh audit (identical under run.py and standalone)."""
    cfg = OptimizerConfig(name="gum", rank=16, period=10, gamma=1,
                          kernel_impl="jnp", fuse_families=True,
                          shard_state=True)
    rows = {}
    for n in ((8,) if smoke_mode else MESHES):
        t0 = time.time()
        rep = audit_sharded(cfg, mesh_axes=(("data", n),), lower=False)
        us = (time.time() - t0) * 1e6
        mem = rep.summary["per_shard_memory"]
        exp = rep.summary["expected_schedule"]
        wire = rep.summary["wire"]
        gather = exp["boundary_gather"]
        boundary_wire = wire["boundary_bytes"]
        rows[f"mesh{n}"] = {
            "n_shards": n,
            "clean": rep.ok,
            "opt_state_bytes": mem["opt_state_bytes"],
            "opt_state_bytes_per_shard": mem["opt_state_bytes_per_shard"],
            "proj_state_bytes": mem["proj_state_bytes"],
            "proj_state_bytes_per_shard": mem["proj_state_bytes_per_shard"],
            "peak_bytes_per_shard": mem["peak_bytes_per_shard"],
            "boundary_gather_count": gather["count"],
            "boundary_gather_payload_bytes": gather["payload_bytes"],
            "boundary_gather_wire_bytes": boundary_wire,
        }
        derived = ("clean" if rep.ok else "+".join(sorted(rep.codes())))
        derived += (f",opt_bytes_per_shard={mem['opt_state_bytes_per_shard']}"
                    f",boundary_gathers={gather['count']}"
                    f",boundary_wire_bytes={boundary_wire}")
        print(f"sharded_step_state_mesh{n},{us:.0f},{derived}", flush=True)
    return rows


def timed_rows():
    """Steady-step wall time on real host-CPU meshes — one subprocess per
    mesh so ``force_host_device_count`` precedes jax initialisation."""
    rows = {}
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    for n in TIMED_MESHES:
        t0 = time.time()
        r = subprocess.run(
            [sys.executable, "-c", _TIMED_SCRIPT, str(n)],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
        line = next((ln for ln in r.stdout.splitlines()
                     if ln.startswith("TIMED_JSON ")), None)
        if line is None:
            print(f"sharded_step_time_mesh{n},0,"
                  f"failed:{r.stderr.strip()[-200:]}", flush=True)
            continue
        row = json.loads(line[len("TIMED_JSON "):])
        rows[f"mesh{n}"] = row
        ratio = row["sharded"] / row["replicated"]
        print(f"sharded_step_time_mesh{n},{row['sharded']:.0f},"
              f"replicated_us={row['replicated']:.0f}"
              f",sharded_over_replicated={ratio:.2f}"
              f",subprocess_s={time.time() - t0:.0f}", flush=True)
    return rows


def main() -> None:
    from _smoke import smoke

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results")
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")
    state = abstract_rows(smoke())
    if smoke():
        print("# smoke mode: skipping timed meshes and "
              "BENCH_sharded_step.json write", flush=True)
        return
    times = timed_rows()

    # the claim the JSON records: shardable projected state scales ~1/N
    b1 = state["mesh1"]["opt_state_bytes_per_shard"]
    b8 = state["mesh8"]["opt_state_bytes_per_shard"]
    assert b8 < b1, (b1, b8)

    entry = {
        "model": "llama-60m (smoke)",
        "optimizer": "gum fused (rank=16, gamma=1)",
        "per_device_state": state,
        "steady_step_us": times,
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "BENCH_sharded_step.json")
    with open(path, "w") as f:
        json.dump(entry, f, indent=2, default=str)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
