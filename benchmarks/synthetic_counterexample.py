"""Paper Figure 1: the noisy linear-regression counterexample where
GaLore-Muon fails to converge and GUM converges.

    min_X f(X) = 0.5 ||A X||_F^2 + <B, X>,
    grad f(X; xi) = grad f(X) + xi * sigma * C

with A = [I_{n-r} 0], B = [[D, 0], [0, 0]], C = [[0,0],[0,I_r]],
xi ~ Bernoulli(0.5), n=20, r=12, sigma=100.  The noise lives in a rank-r
subspace; whenever the projector is refreshed from a noisy gradient, GaLore's
top-r SVD projector locks onto pure noise and the low-rank update makes no
progress.  GUM's compensated full-rank branch keeps the true descent
direction in expectation.

Analytic optimum: X*_topleft = -D (rest free/zero), f* = -0.5 ||D||_F^2.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import apply_updates, galore_matrices, muon_matrices, unbiased_lowrank


@dataclasses.dataclass
class Problem:
    n: int
    r: int
    sigma: float
    d: jax.Array       # (n-r, n-r)
    f_star: float

    def loss(self, x: jax.Array) -> jax.Array:
        top = x[: self.n - self.r]
        return 0.5 * jnp.sum(top**2) + jnp.sum(
            self.d * x[: self.n - self.r, : self.n - self.r]
        )

    def grad(self, x: jax.Array, key: jax.Array) -> jax.Array:
        g = jnp.zeros_like(x)
        g = g.at[: self.n - self.r].set(x[: self.n - self.r])
        g = g.at[: self.n - self.r, : self.n - self.r].add(self.d)
        xi = jax.random.bernoulli(key, 0.5)
        noise = jnp.zeros_like(x).at[self.n - self.r :, self.n - self.r :].set(
            self.sigma * jnp.eye(self.r)
        )
        return g + xi * noise


def make_problem(n: int = 20, r: int = 12, sigma: float = 100.0, seed: int = 0) -> Problem:
    d = jax.random.normal(jax.random.PRNGKey(seed), (n - r, n - r))
    return Problem(n=n, r=r, sigma=sigma, d=d, f_star=float(-0.5 * jnp.sum(d**2)))


def run(
    prob: Problem,
    method: str,
    steps: int = 2000,
    lr: float = 2e-2,
    rank: int = 12,
    q: float = 0.5,
    period: int = 20,
    seed: int = 1,
    beta: float = 0.9,
) -> list[float]:
    """method in {muon, galore_muon, gum}; returns adjusted losses f - f*."""
    if method == "muon":
        opt = muon_matrices(lr, beta=beta)
    elif method == "galore_muon":
        # Algorithm-1 semantics (faithful GaLore): projector from the CURRENT
        # stochastic gradient every step, momentum persists across refreshes.
        # The sigma=100 noise flips the projector onto the noise subspace on
        # ~half the steps; the momentum mixes coordinates across unrelated
        # subspaces and the signal rows get noise-directed updates -> stall.
        opt = galore_matrices(
            lr, rank=rank, period=1, projector="svd", base="muon",
            beta=beta, reset_on_update=False,
        )
    elif method == "gum":
        opt = unbiased_lowrank(
            lr, rank=rank, q=q, period=period, projector="svd", base="muon",
            beta=beta, seed=seed + 1,
        )
    else:
        raise ValueError(method)

    params = {"w": jnp.zeros((prob.n, prob.n))}
    state = opt.init(params)

    @jax.jit
    def step(params, state, key):
        g = {"w": prob.grad(params["w"], key)}
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state

    losses = []
    key = jax.random.PRNGKey(seed)
    for t in range(steps):
        key, sub = jax.random.split(key)
        params, state = step(params, state, sub)
        if t % 10 == 0 or t == steps - 1:
            losses.append(float(prob.loss(params["w"]) - prob.f_star))
    return losses


def main() -> None:
    """CSV: method, final adjusted loss (paper Fig. 1)."""
    from _smoke import steps as smoke_steps

    prob = make_problem()
    print("name,us_per_call,derived")
    for method in ("muon", "galore_muon", "gum"):
        rank = 12 if method == "galore_muon" else 2
        losses = run(prob, method, steps=smoke_steps(2000), rank=rank)
        print(f"synthetic_fig1_{method},0,final_adjusted_loss={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
