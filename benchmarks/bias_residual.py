"""Paper Figure 4 / Appendix D.1: GaLore's bias residual along a real
training trajectory.

chi_t = ||G_t^u - G_t^p||_F / ||G_t^u||_F per block, where G^p = P Pᵀ G is
the low-rank projected gradient.  The paper shows chi_t is small right after
a projector refresh and rapidly climbs to 60-80%+ between refreshes —
the systematic bias GUM removes.  We reproduce the shape of that curve on
LLaMA-60M (smoke) with GaLore-Muon, measuring chi_t for attention and MLP
blocks every iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import apply_updates, find_lowrank_states, galore_matrices
from repro.core.lowrank_common import family_shape, reconstruct
from repro.data import DataConfig, build_stream
from repro.models import build_model


def main() -> None:
    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    period = 10
    rank = 8
    opt = galore_matrices(5e-3, rank=rank, period=period, projector="svd",
                          base="muon")
    # restrict to the stacked block leaves (like the optimizer itself)
    blocks = {"blocks": params["blocks"]}
    st = opt.init(blocks)
    stream = build_stream(DataConfig(vocab=cfg.vocab, seq_len=128,
                                     global_batch=8, seed=0))

    @jax.jit
    def grad_fn(p, tokens):
        def loss_fn(p):
            lg, aux, _ = model.forward(p, tokens)
            return model.loss(lg, tokens, aux)
        return jax.grad(loss_fn)(p)

    @jax.jit
    def chi(g_leaf, p_proj):
        fs = family_shape(g_leaf, rank)
        g = g_leaf.astype(jnp.float32)
        proj = reconstruct(p_proj, g, fs.side)
        num = jnp.linalg.norm(g - proj, axis=(-2, -1))
        den = jnp.linalg.norm(g, axis=(-2, -1)) + 1e-12
        return jnp.mean(num / den)

    from _smoke import steps as smoke_steps

    print("name,us_per_call,derived")
    at_refresh, mid_period = [], []
    for t in range(smoke_steps(3 * period)):
        tokens = jnp.asarray(stream.batch_at(t))
        g = grad_fn(params, tokens)
        gb = {"blocks": g["blocks"]}
        upd, st = opt.update(gb, st, blocks)
        # chi for the attention wq family using the CURRENT projector
        proj = find_lowrank_states(st)[0].projs["blocks"]["attn"]["wq"]
        x = float(chi(gb["blocks"]["attn"]["wq"], proj))
        (at_refresh if t % period == 0 else mid_period).append(x)
        params = dict(params)
        params["blocks"] = apply_updates(blocks, upd)["blocks"]
        blocks = {"blocks": params["blocks"]}

    avg_refresh = sum(at_refresh) / len(at_refresh)
    avg_mid = sum(mid_period) / len(mid_period)
    print(f"bias_residual_fig4,0,chi_at_refresh={avg_refresh:.3f};"
          f"chi_mid_period={avg_mid:.3f};ratio={avg_mid/max(avg_refresh,1e-9):.2f}")


if __name__ == "__main__":
    main()
