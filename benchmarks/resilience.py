"""Resilience subsystem cost model: monitor overhead, rollback latency,
checksum cost.

Three questions with acceptance budgets (ISSUE 8):

  monitor   — steady-state health-monitor overhead vs a bare run of the
              same trainer (extra in-jit reductions + host detectors),
              budget <= 2% of step time
  rollback  — snapshot-ring capture and restore latency for the real
              (params, opt_state) tree (host copy + re-upload), plus the
              forced off-cycle refresh (rung 1) cost
  checksum  — per-save cost of the manifest CRC32s
              (CheckpointManager(checksums=True) vs False)

Runs the pretrain-proxy setup (LLaMA-60M smoke, GUM) through the real
Trainer so the measured loop is the shipping loop.  Writes
BENCH_resilience.json unless BENCH_SMOKE=1.
"""
from __future__ import annotations

import json
import os
import shutil
import statistics
import tempfile
import time

from _smoke import smoke, steps as smoke_steps

STEPS = 60


def _trainer(tmp, resilience, steps, batch=8, seq=128):
    from repro.configs import RunConfig, get_smoke
    from repro.core import OptimizerConfig
    from repro.data import DataConfig
    from repro.models import build_model
    from repro.train import Trainer

    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    return Trainer(
        model,
        OptimizerConfig(name="gum", lr=1e-3, rank=8, gamma=1, period=10),
        RunConfig(steps=steps, ckpt_dir=tmp, ckpt_every=0, log_every=0,
                  resume=False),
        DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch),
        resilience=resilience,
    )


def _median_step_us(trainer, steps) -> float:
    trainer.monitor.times.clear()
    trainer.train(steps)
    times = list(trainer.monitor.times)
    # drop the compile step(s): the monitor window already caps history,
    # but the first recorded samples still straddle warmup
    times = times[2:] or times
    return statistics.median(times) * 1e6


def main() -> None:
    import jax

    from repro.checkpoint import CheckpointManager
    from repro.resilience.recovery import SnapshotRing, force_refresh

    n = smoke_steps(STEPS, 2)
    print("name,us_per_call,derived")
    root = tempfile.mkdtemp(prefix="bench_resilience_")
    try:
        # --- monitor overhead (interleaved min-of-medians: the off/on
        # trainers alternate inside each rep so load drift on a shared box
        # hits both sides; min across reps rejects one-sided noise) -------
        t_off = _trainer(os.path.join(root, "off"), None, n)
        t_on = _trainer(os.path.join(root, "on"), "", n)
        reps = 1 if smoke() else 3
        offs, ons = [], []
        for _ in range(reps):
            offs.append(_median_step_us(t_off, n))
            ons.append(_median_step_us(t_on, n))
        us_off, us_on = min(offs), min(ons)
        overhead = (us_on - us_off) / us_off * 100.0
        print(f"resilience_step_monitor_off,{us_off:.0f},median")
        print(f"resilience_step_monitor_on,{us_on:.0f},"
              f"overhead={overhead:+.2f}%")

        # --- rollback latency -------------------------------------------
        params, opt_state = t_on.init_state()
        ring = SnapshotRing(k=2)
        t0 = time.time()
        ring.add(0, params, opt_state)
        snap_ms = (time.time() - t0) * 1e3
        snap = ring.pop_latest()
        t0 = time.time()
        p2, s2 = ring.restore(snap)
        jax.block_until_ready((p2, s2))
        restore_ms = (time.time() - t0) * 1e3
        t0 = time.time()
        jax.block_until_ready(
            jax.tree_util.tree_leaves(force_refresh(s2, 10))[0])
        refresh_ms = (time.time() - t0) * 1e3
        print(f"resilience_snapshot_capture,{snap_ms * 1e3:.0f},host_copy")
        print(f"resilience_rollback_restore,{restore_ms * 1e3:.0f},reupload")
        print(f"resilience_force_refresh,{refresh_ms * 1e3:.0f},rung1")

        # --- checksum cost per save -------------------------------------
        tree = (params, opt_state)
        reps = 1 if smoke() else 5
        save_ms = {}
        for checks in (True, False):
            d = os.path.join(root, f"ck_{checks}")
            mgr = CheckpointManager(d, keep=2, checksums=checks)
            ts = []
            for i in range(reps):
                t0 = time.time()
                mgr.save(i, tree)
                ts.append(time.time() - t0)
            save_ms[checks] = statistics.median(ts) * 1e3
        crc_ms = save_ms[True] - save_ms[False]
        print(f"resilience_save_crc,{save_ms[True] * 1e3:.0f},per_save")
        print(f"resilience_save_nocrc,{save_ms[False] * 1e3:.0f},per_save")
        print(f"resilience_crc_cost,{crc_ms * 1e3:.0f},delta")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    if smoke():
        return
    out = {
        "setup": {"arch": "llama-60m-smoke", "opt": "gum", "rank": 8,
                  "period": 10, "steps": n, "device": jax.devices()[0]
                  .platform},
        "monitor": {"step_us_off": us_off, "step_us_on": us_on,
                    "overhead_pct": overhead, "budget_pct": 2.0},
        "rollback": {"snapshot_capture_ms": snap_ms,
                     "restore_ms": restore_ms,
                     "force_refresh_ms": refresh_ms},
        "checksum": {"save_ms_crc": save_ms[True],
                     "save_ms_nocrc": save_ms[False],
                     "crc_cost_ms": crc_ms},
    }
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        os.pardir, "results", "BENCH_resilience.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.normpath(path)}")


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    main()
