"""Aggregate the dry-run JSONs into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load(mesh: str, opt: str = "gum"):
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}__{opt}.json"))):
        rows.append(json.load(open(f)))
    return rows


def fmt_row(r) -> str:
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['status']} | "
                f"{r.get('reason', r.get('error', ''))[:60]} |  |  |  |  |  |")
    rf = r["roofline"]
    mem = r["memory"]
    dev_gb = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)) / 1e9
    return (
        f"| {r['arch']} | {r['shape']} | ok "
        f"| {rf['compute_s']*1e3:.1f} | {rf['memory_s']*1e3:.1f} "
        f"| {rf['collective_s']*1e3:.1f} | {rf['bottleneck']} "
        f"| {rf['useful_flops_frac']:.2f} | {dev_gb:.1f} |"
    )


HEADER = ("| arch | shape | status | compute (ms) | memory (ms) | "
          "collective (ms) | bottleneck | MF/HLO | dev mem (GB) |\n"
          "|---|---|---|---|---|---|---|---|---|")


def main() -> None:
    print("name,us_per_call,derived")
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = load(mesh)
        ok = [r for r in rows if r["status"] == "ok"]
        skipped = [r for r in rows if r["status"] == "skipped"]
        err = [r for r in rows if r["status"] == "error"]
        print(f"roofline_{mesh},0,ok={len(ok)};skipped={len(skipped)};errors={len(err)}")

    # markdown tables to stdout for EXPERIMENTS.md
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = load(mesh)
        if not rows:
            continue
        print(f"\n### Roofline — {mesh}\n")
        print(HEADER)
        for r in rows:
            print(fmt_row(r))


if __name__ == "__main__":
    main()
