"""Static-audit pass matrix (PR 6): every factory optimizer, all fuse modes.

Runs :func:`repro.analysis.audit.run_matrix` — chain lint, closed-form
launch model vs trace-time dispatch counts, dtype-flow and
recompilation-hazard passes — over the reference 3-family tree.  Everything
is abstract (eval_shape / make_jaxpr), so the whole matrix costs seconds and
zero accelerator time; the committed JSON records per-cell launch counts,
projected-state bytes and signature hashes so audit regressions are visible
across PRs.

Emits ``name,us_per_call,derived`` CSV rows (us = wall time to audit the
cell, derived = ``clean`` / the finding codes) and writes
``BENCH_audit_matrix.json`` under --out (default results/).

Usage: PYTHONPATH=src python benchmarks/audit_matrix.py [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.analysis.audit import audit_optimizer, default_params, matrix_configs


def main() -> None:
    from _smoke import smoke

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results")
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")
    params = default_params()
    cells = matrix_configs()
    if smoke():
        cells = cells[:2]  # execution check only — full matrix is tier-1
    reports = {}
    for cfg in cells:
        t0 = time.time()
        rep = audit_optimizer(cfg, params, ladder=cfg.rank_ladder)
        us = (time.time() - t0) * 1e6
        reports[rep.name] = rep
        derived = "clean" if rep.ok else "+".join(sorted(rep.codes()))
        print(f"audit_{rep.name},{us:.0f},{derived}", flush=True)

    if smoke():
        print("# smoke mode: skipping BENCH_audit_matrix.json write",
              flush=True)
        return
    entry = {
        "cells": {name: rep.to_json() for name, rep in reports.items()},
        "clean": sum(r.ok for r in reports.values()),
        "total": len(reports),
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "BENCH_audit_matrix.json")
    with open(path, "w") as f:
        json.dump(entry, f, indent=2, default=str)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
