"""Static-audit pass matrix (PR 6/PR 7): every factory optimizer, all fuse
modes, plus the sharded collective-schedule cells.

Runs :func:`repro.analysis.audit.run_matrix` — chain lint, closed-form
launch model vs trace-time dispatch counts, dtype-flow and
recompilation-hazard passes — over the reference 3-family tree, then the
PR-7 sharded pass (:func:`repro.analysis.audit.audit_sharded`,
trace-only ``AbstractMesh`` mode) for mesh 1/2/8 x {gum, galore_muon,
adamw} on the llama-60m smoke model.  Everything is abstract (eval_shape /
make_jaxpr / AbstractMesh), so the whole matrix costs seconds and zero
accelerator time — the sharded cells need no real devices at all; the
committed JSON records per-cell launch counts, collective counts, wire
bytes, projected-state bytes and signature hashes so audit regressions are
visible across PRs.

Emits ``name,us_per_call,derived`` CSV rows (us = wall time to audit the
cell, derived = ``clean`` / the finding codes; sharded rows append
collective counts + steady-state wire bytes) and writes
``BENCH_audit_matrix.json`` under --out (default results/).

Usage: PYTHONPATH=src python benchmarks/audit_matrix.py [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.analysis.audit import (
    audit_optimizer,
    audit_sharded,
    default_params,
    matrix_configs,
)
from repro.core import OptimizerConfig

SHARDED_OPTS = ("gum", "galore_muon", "adamw")
SHARDED_MESHES = (1, 2, 8)


def sharded_cells(smoke_mode: bool):
    """(row_name, cfg, n_shards) for the sharded pass.  Smoke keeps one
    mesh-8 cell — enough to prove the AbstractMesh trace path executes."""
    cells = []
    for opt in SHARDED_OPTS:
        cfg = OptimizerConfig(name=opt, rank=16, period=10, gamma=1,
                              kernel_impl="jnp")
        for n in SHARDED_MESHES:
            cells.append((f"audit_sharded_{opt}_mesh{n}", cfg, n))
    if smoke_mode:
        cells = [c for c in cells if c[0] == "audit_sharded_gum_mesh8"]
    return cells


def main() -> None:
    from _smoke import smoke

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results")
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")
    params = default_params()
    cells = matrix_configs()
    if smoke():
        cells = cells[:2]  # execution check only — full matrix is tier-1
    reports = {}
    for cfg in cells:
        t0 = time.time()
        rep = audit_optimizer(cfg, params, ladder=cfg.rank_ladder)
        us = (time.time() - t0) * 1e6
        reports[rep.name] = rep
        derived = "clean" if rep.ok else "+".join(sorted(rep.codes()))
        print(f"audit_{rep.name},{us:.0f},{derived}", flush=True)

    # Sharded collective-schedule cells (trace-only: AbstractMesh needs no
    # devices, so the rows are identical under run.py and standalone).
    for row, cfg, n in sharded_cells(smoke()):
        t0 = time.time()
        rep = audit_sharded(cfg, mesh_axes=(("data", n),), lower=False)
        us = (time.time() - t0) * 1e6
        reports[rep.name] = rep
        derived = "clean" if rep.ok else "+".join(sorted(rep.codes()))
        wire = rep.summary.get("wire", {})
        derived += (f",collectives={rep.summary.get('collectives') or 'none'}"
                    f",steady_wire_bytes={wire.get('steady_bytes_per_step')}")
        print(f"{row},{us:.0f},{derived}", flush=True)

    if smoke():
        print("# smoke mode: skipping BENCH_audit_matrix.json write",
              flush=True)
        return
    entry = {
        "cells": {name: rep.to_json() for name, rep in reports.items()},
        "clean": sum(r.ok for r in reports.values()),
        "total": len(reports),
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "BENCH_audit_matrix.json")
    with open(path, "w") as f:
        json.dump(entry, f, indent=2, default=str)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
