"""Rank-policy engine: memory footprint + step time across the ladder.

Runs the pretrain-proxy setup (the paper's LLaMA-60M over the synthetic C4
stream, GUM optimizer) under three rank regimes:

  fixed      — the legacy static rank (the ladder top)
  stepwise   — a declarative halving schedule
  spectral   — the adaptive policy: captured-energy probes shrink/grow rank
               along the ladder at refresh boundaries

and reports final-loss proxy, projected-state bytes (the LowRankState:
projectors + projected momenta + gamma slots — the Table-1 quantity the
policies are shaping) and median step time.  Writes BENCH_rank_policy.json
unless BENCH_SMOKE=1.
"""
from __future__ import annotations

import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import (
    OptimizerConfig,
    apply_updates,
    build_optimizer,
    clip_by_global_norm,
    find_lowrank_states,
    resolve_rank_policy,
    state_bytes,
)
from repro.core.rank_policy import RankPolicyController
from repro.data import DataConfig, build_stream
from repro.models import build_model

RANK, PERIOD, LADDER = 16, 10, (4, 8, 16)


def proj_bytes(st) -> int:
    return sum(state_bytes(lr) for lr in find_lowrank_states(st))


def run_policy(policy_spec, steps: int, batch: int = 8, seq: int = 128):
    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(
        name="gum", lr=1e-2, rank=RANK, gamma=1, period=PERIOD, base="muon",
        rank_policy=policy_spec, rank_ladder=LADDER,
    )
    ctrl = None
    policy = resolve_rank_policy(opt_cfg)
    if policy is not None:
        ctrl = RankPolicyController(
            policy, lambda m: build_optimizer(opt_cfg, rank_map=m),
            period=PERIOD, default_rank=RANK,
        )
        opt = ctrl.transform()
    else:
        opt = build_optimizer(opt_cfg)
    st = opt.init(params)
    stream = build_stream(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                     global_batch=batch, seed=0))

    def make_step(opt):
        @jax.jit
        def step(p, s, tokens):
            def loss_fn(p):
                lg, aux, _ = model.forward(p, tokens)
                return model.loss(lg, tokens, aux)

            loss, g = jax.value_and_grad(loss_fn)(p)
            g = clip_by_global_norm(g, 1.0)
            u, s = opt.update(g, s, p)
            return apply_updates(p, u), s, loss

        return step

    step_fns = {}
    losses, times, bytes_hist = [], [], []
    for i in range(steps):
        migrated = False
        if ctrl is not None:
            st, migrated = ctrl.maybe_update(st, params)
            if migrated:
                opt = ctrl.transform()
        key = ctrl.current_map if ctrl is not None else None
        if key not in step_fns:
            step_fns[key] = make_step(opt)
        tokens = jnp.asarray(stream.batch_at(i))
        t0 = time.time()
        params, st, loss = jax.block_until_ready(
            step_fns[key](params, st, tokens))
        if i > 0 and not migrated:  # skip compile steps in the timing
            times.append(time.time() - t0)
        losses.append(float(loss))
        bytes_hist.append(proj_bytes(st))
    tail = losses[-10:]
    return {
        "first": losses[0],
        "final10": sum(tail) / len(tail),
        "proj_bytes_final": bytes_hist[-1],
        "proj_bytes_mean": int(sum(bytes_hist) / len(bytes_hist)),
        "us_per_step_median": (statistics.median(times) * 1e6
                               if times else 0.0),
        "rank_history": ([[s, repr(m)] for s, m in ctrl.history]
                         if ctrl is not None else []),
    }


# 200 steps: long enough for the proxy loss to plateau — at that horizon the
# spectral policy's shrink to the energy-supported rank costs nothing (the
# 60-step mid-descent prefix still shows a ~0.05% gap, which is exactly the
# "fixed r wastes memory early or starves the subspace late" trade the
# policy navigates).
STEPS = 200

POLICIES = {
    "fixed16": None,                       # static cfg.rank (the ladder top)
    "stepwise_halving": f"stepwise:0={RANK},{6 * PERIOD}=8,{10 * PERIOD}=4",
    "spectral": "spectral:0.95",
}


def main() -> None:
    from _smoke import smoke, steps as smoke_steps

    steps = smoke_steps(STEPS)
    print("name,us_per_call,derived")
    results = {}
    for name, spec in POLICIES.items():
        r = run_policy(spec, steps)
        results[name] = r
        print(
            f"rank_policy_{name},{r['us_per_step_median']:.0f},"
            f"final10={r['final10']:.4f};proj_bytes={r['proj_bytes_final']};"
            f"proj_bytes_mean={r['proj_bytes_mean']}"
        )
    base = results["fixed16"]
    for name in ("stepwise_halving", "spectral"):
        r = results[name]
        print(
            f"rank_policy_{name}_vs_fixed,0,"
            f"bytes_ratio={r['proj_bytes_final'] / base['proj_bytes_final']:.3f};"
            f"loss_delta={r['final10'] - base['final10']:+.4f}"
        )
    if not smoke():
        payload = {
            "config": {"arch": "llama-60m-smoke", "opt": "gum", "rank": RANK,
                       "period": PERIOD, "ladder": list(LADDER),
                       "steps": steps, "policies": POLICIES},
            "results": results,
        }
        with open("results/BENCH_rank_policy.json", "w") as f:
            json.dump(payload, f, indent=2)
        print("# wrote results/BENCH_rank_policy.json")


if __name__ == "__main__":
    main()
