"""Family-stacked fused step engine vs the per-leaf chained path (PR 3).

The per-leaf chained path pays for a Python loop over parameter leaves
issuing three-plus dispatch launches per leaf.  This benchmark times the
three execution modes on a per-layer (unstacked-leaf) tree, where the
stacking engine has real work to do:

  chained        — per-leaf combinator path (the reference semantics and
                   baseline; the frozen monoliths it was measured against
                   were deleted in PR 7)
  stacked        — fuse_families=True: one batched launch per shape family
  stacked_fused  — + fused_epilogue=True: chain tails fold into the GEMM

and counts kernel launches per step via the dispatch layer's trace-time
counter — proving launches scale with the number of shape FAMILIES, not the
number of leaves.

Emits ``name,us_per_call,derived`` CSV rows and ``BENCH_fused_step.json``
under --out (default results/).  Acceptance (ISSUE 3): stacked/fused
per-step time at parity or better vs chained for gum, galore_muon and fira.

Usage: PYTHONPATH=src python benchmarks/fused_step.py [--steps N] [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

import repro.core as core
from repro.core import apply_updates
from repro.kernels import launch_count

from _smoke import smoke, steps as smoke_steps

KEY = jax.random.PRNGKey(0)


def _params():
    """A per-layer LLaMA-ish tree: 4 layers of separate (unstacked) leaves —
    3 shape families spread over 24 matrix leaves, plus fallback leaves."""
    tree, k = {}, KEY
    for i in range(4):
        k = jax.random.fold_in(KEY, i)
        tree[f"layer_{i}"] = {
            "wq": jax.random.normal(jax.random.fold_in(k, 0), (256, 256)) * 0.02,
            "wk": jax.random.normal(jax.random.fold_in(k, 1), (256, 256)) * 0.02,
            "wv": jax.random.normal(jax.random.fold_in(k, 2), (256, 256)) * 0.02,
            "wo": jax.random.normal(jax.random.fold_in(k, 3), (256, 256)) * 0.02,
            "w_in": jax.random.normal(jax.random.fold_in(k, 4), (256, 1024)) * 0.02,
            "w_out": jax.random.normal(jax.random.fold_in(k, 5), (1024, 256)) * 0.02,
        }
    tree["embed"] = jax.random.normal(jax.random.fold_in(KEY, 99), (4096, 256)) * 0.02
    tree["norm_scale"] = jnp.ones((256,))
    return tree


# rank = short_dim / 4 (GaLore's standard rank ratio on this tree's 256-wide
# matrices) — the operating point the launch-count and parity claims refer to.
OPT_KW = dict(rank=64, period=50, seed=0, kernel_impl="jnp")


def _builders():
    def modes(mk_new):
        return {
            "chained": mk_new(),
            "stacked": mk_new(fuse_families=True),
            "stacked_fused": mk_new(fuse_families=True, fused_epilogue=True),
        }

    return [
        ("gum", modes(
            lambda **kw: core.gum(1e-3, gamma=2, **OPT_KW, **kw))),
        ("galore_muon", modes(
            lambda **kw: core.galore(1e-3, base="muon", **OPT_KW, **kw))),
        ("fira", modes(
            lambda **kw: core.fira(1e-3, **OPT_KW, **kw))),
    ]


def _time_modes(opts: dict, params, steps: int, reps: int = 3) -> dict:
    """us/step per mode: ``reps`` timed blocks of ``steps`` steps per mode,
    REPS INTERLEAVED ACROSS MODES, best-of-reps per mode.  Interleaving makes
    the mode comparison robust to background-load drift on shared CPU
    runners (sequential per-mode timing attributes whatever the machine was
    doing during that mode's slot to the mode itself); min-of-reps then
    drops the load spikes."""
    g = jax.tree_util.tree_map(lambda p: 0.01 * jnp.ones_like(p), params)
    runners = {}
    for mode, opt in opts.items():
        @jax.jit
        def step(p, s, opt=opt):
            u, s = opt.update(g, s, p)
            return apply_updates(p, u), s

        p, st = step(params, opt.init(params))  # compile + warm
        jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])
        runners[mode] = (step, p, st)
    best = {mode: float("inf") for mode in opts}
    for _ in range(reps):
        for mode, (step, p, st) in runners.items():
            t0 = time.perf_counter()
            for _ in range(steps):
                p, st = step(p, st)
            jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])
            best[mode] = min(best[mode],
                             (time.perf_counter() - t0) / steps * 1e6)
            runners[mode] = (step, p, st)
    return best


def _launches(opt, params) -> dict:
    """Dispatch-level kernel launches in one traced step, per op —
    abstract tracing only (eval_shape), no math executes."""
    st = opt.init(params)
    g = jax.tree_util.tree_map(lambda p: 0.01 * jnp.ones_like(p), params)
    with launch_count.count_launches() as counts:
        jax.eval_shape(lambda g, s, p: opt.update(g, s, p), g, st, params)
    return counts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--out", default="results")
    args, _ = ap.parse_known_args()
    n_steps = smoke_steps(args.steps, 1)

    params = _params()
    print("name,us_per_call,derived")
    rows = []
    for name, opts in _builders():
        us = _time_modes(opts, params, n_steps, reps=1 if smoke() else 5)
        per_op = {mode: _launches(opt, params)
                  for mode, opt in opts.items()}
        launches = {mode: sum(c.values()) for mode, c in per_op.items()}
        # gum and fira's inner transforms emit full-shape (FullUpdate)
        # leaves, so the deferred-epilogue path never engages for them —
        # stacked_fused is computationally identical to stacked there, and
        # the row says so instead of presenting noise as a delta.
        epi_active = per_op["stacked_fused"].get("back_project_epilogue", 0) > 0
        for mode in ("chained", "stacked", "stacked_fused"):
            ovh = (us[mode] - us["chained"]) / us["chained"] * 100.0
            tag = ("baseline" if mode == "chained"
                   else f"vs_chained_pct={ovh:+.1f}")
            tag += f",launches={launches[mode]}"
            if mode == "stacked_fused" and not epi_active:
                tag += ",epilogue=inert(FullUpdate_path)"
            print(f"fusedstep_{name}_{mode},{us[mode]:.0f},{tag}")
        rows.append({
            "optimizer": name,
            **{f"us_{m}": round(v, 1) for m, v in us.items()},
            **{f"launches_{m}": v for m, v in launches.items()},
            "epilogue_active": epi_active,
            "stacked_vs_chained_pct":
                round((us["stacked"] - us["chained"]) / us["chained"] * 100.0, 2),
            "stacked_fused_vs_chained_pct":
                round((us["stacked_fused"] - us["chained"]) / us["chained"] * 100.0, 2),
        })

    if smoke():
        print("# smoke mode: skipping BENCH_fused_step.json write", flush=True)
        return
    os.makedirs(args.out, exist_ok=True)
    entry = {
        "suite": "fused_step",
        "backend": jax.default_backend(),
        "steps": n_steps,
        "kernel_impl": OPT_KW["kernel_impl"],
        "rank": OPT_KW["rank"],
        "tree": "4 per-layer blocks (24 matrix leaves, 3 shape families)",
        "rows": rows,
    }
    path = os.path.join(args.out, "BENCH_fused_step.json")
    with open(path, "w") as f:
        json.dump(entry, f, indent=2)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
